"""Dynamic expert load balancing (paper §4.5).

EAAS widens the load-balancing action space beyond EPLB's reorder+replicate:
(1) non-uniform expert counts per server, (2) scaling service instances of
hot experts up/down, (3) heterogeneous server capacity.  This module
implements the statistics pipeline and an EPLB-style greedy replication
planner producing the (mapping, redundant_table) pair consumed by
core.mapping / core.expert_server.
"""

from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np


@dataclass
class ExpertStats:
    """EMA of per-expert token traffic (fed from MoEStats.expert_load)."""

    num_experts: int
    decay: float = 0.9
    ema: Optional[np.ndarray] = None
    updates: int = 0           # observations folded in (rebalance warm-up)

    def update(self, load: np.ndarray) -> None:
        load = np.asarray(load, np.float64)
        if self.ema is None:
            self.ema = load.copy()
        else:
            self.ema = self.decay * self.ema + (1 - self.decay) * load
        self.updates += 1

    def hot_experts(self, top: int) -> np.ndarray:
        assert self.ema is not None
        return np.argsort(-self.ema)[:top]


def primary_owner(num_experts: int, num_servers: int) -> np.ndarray:
    """Block-ish primary placement.  Uniform when S | E; otherwise servers
    host ⌈E/S⌉ or ⌊E/S⌋ experts — EAAS does NOT require equal counts
    (paper §4.5: non-uniform experts per server is a balancing degree of
    freedom monolithic EP lacks)."""
    return (np.arange(num_experts) * num_servers // num_experts).astype(
        np.int32)


def eplb_plan(load: np.ndarray, num_servers: int, n_redundant: int,
              max_replicas: int = 4,
              capacities: Optional[np.ndarray] = None
              ) -> Tuple[np.ndarray, np.ndarray]:
    """Greedy EPLB-style replication plan.

    load: (E,) expected tokens per expert.  Returns
      mapping (E, max_replicas) int32 — candidate servers per expert,
      redundant_table (S, n_redundant) int32 — extra experts per server.

    Primary placement stays block-contiguous (primary_owner) so the weight
    shards never move; hot experts gain replicas on the least-loaded
    servers.  Expected per-server load is balanced under the EAAS client
    policy of spreading tokens uniformly over alive replicas.

    ``capacities`` (S,) models heterogeneous servers (paper §4.5 degree of
    freedom 3): loads are normalized by relative capacity when picking the
    least-loaded replica target, so a 2x server absorbs 2x the traffic
    before it looks "full".  (Clients additionally *spread* tokens over a
    replica set proportionally to capacity — see :func:`server_loads` /
    ``mapping.lookup`` — the planner's internal accounting keeps the
    uniform-share approximation, which is conservative: it under-credits
    big servers, never overloads them.)  All sort orders are stable, so the
    plan is a
    deterministic function of (load, S, n_redundant, max_replicas,
    capacities) — identical EMAs always produce the identical plan.
    """
    load = np.asarray(load, np.float64)
    E = load.shape[0]
    S = num_servers
    cap = (np.ones(S, np.float64) if capacities is None
           else np.asarray(capacities, np.float64))
    assert cap.shape == (S,) and (cap > 0).all(), cap

    mapping = np.full((E, max_replicas), -1, np.int32)
    mapping[:, 0] = primary_owner(E, S)

    red_table = np.full((S, n_redundant), -1, np.int32)
    red_used = np.zeros(S, np.int32)

    # effective load per server given current replica sets
    replicas = {e: [int(mapping[e, 0])] for e in range(E)}
    server_load = np.zeros(S, np.float64)
    for e in range(E):
        server_load[mapping[e, 0]] += load[e]

    total_slots = S * n_redundant
    order = np.argsort(-load, kind="stable")       # hottest first
    for _ in range(total_slots):
        # pick the expert whose replication most reduces the max load
        best_e, best_gain, best_s = -1, 0.0, -1
        for e in order[:max(32, 4 * S)]:
            reps = replicas[int(e)]
            if len(reps) >= max_replicas:
                continue
            share = load[e] / len(reps)
            new_share = load[e] / (len(reps) + 1)
            # candidate server: least capacity-normalized load with a free
            # redundant slot
            cand = -1
            for s in np.argsort(server_load / cap, kind="stable"):
                if red_used[s] < n_redundant and s not in reps:
                    cand = int(s)
                    break
            if cand < 0:
                continue
            gain = share - new_share - 1e-12
            # prioritize by current load pressure of the expert's servers
            pressure = max(server_load[s] / cap[s] for s in reps)
            score = gain * (1 + pressure)
            if score > best_gain:
                best_e, best_gain, best_s = int(e), score, cand
        if best_e < 0:
            break
        reps = replicas[best_e]
        old_share = load[best_e] / len(reps)
        new_share = load[best_e] / (len(reps) + 1)
        for s in reps:
            server_load[s] -= old_share - new_share
        server_load[best_s] += new_share
        red_table[best_s, red_used[best_s]] = best_e
        red_used[best_s] += 1
        mapping[best_e, len(reps)] = best_s
        reps.append(best_s)

    return mapping, red_table


def server_loads(load: np.ndarray, mapping: np.ndarray, num_servers: int,
                 alive: Optional[np.ndarray] = None,
                 capacities: Optional[np.ndarray] = None) -> np.ndarray:
    """(S,) expected per-server load under the client spreading policy
    :func:`repro.core.mapping.lookup` implements with its salt: uniform
    over the alive replicas when ``capacities`` is None, proportional to
    relative capacity otherwise (a 2x server absorbs 2x the replica
    traffic)."""
    load = np.asarray(load, np.float64)
    ok = (np.ones(num_servers, bool) if alive is None
          else np.asarray(alive, bool))
    cap = (None if capacities is None
           else np.asarray(capacities, np.float64))
    out = np.zeros(num_servers, np.float64)
    for e in range(load.shape[0]):
        reps = [int(s) for s in mapping[e] if s >= 0 and ok[s]]
        if not reps:
            continue
        if cap is None:
            for s in reps:
                out[s] += load[e] / len(reps)
        else:
            total = sum(cap[s] for s in reps)
            for s in reps:
                out[s] += load[e] * cap[s] / max(total, 1e-12)
    return out


def lane_loads(load: np.ndarray, mapping: np.ndarray, num_servers: int,
               alive: Optional[np.ndarray] = None,
               capacities: Optional[np.ndarray] = None) -> np.ndarray:
    """(S, E) per-(server, expert) load decomposition under the same client
    spreading policy as :func:`server_loads`: column ``e`` spreads
    ``load[e]`` uniformly over its alive replicas (capacity-proportionally
    when ``capacities`` is given), so each row sums to that server's
    :func:`server_loads` entry.  This is the async tier's per-expert queue
    *lane* decomposition — which expert's lane each server-second of a
    dispatched wave belongs to."""
    load = np.asarray(load, np.float64)
    ok = (np.ones(num_servers, bool) if alive is None
          else np.asarray(alive, bool))
    cap = (None if capacities is None
           else np.asarray(capacities, np.float64))
    out = np.zeros((num_servers, load.shape[0]), np.float64)
    for e in range(load.shape[0]):
        reps = [int(s) for s in mapping[e] if s >= 0 and ok[s]]
        if not reps:
            continue
        if cap is None:
            for s in reps:
                out[s, e] += load[e] / len(reps)
        else:
            total = sum(cap[s] for s in reps)
            for s in reps:
                out[s, e] += load[e] * cap[s] / max(total, 1e-12)
    return out


def imbalance(load: np.ndarray, mapping: np.ndarray, num_servers: int,
              alive: Optional[np.ndarray] = None,
              capacities: Optional[np.ndarray] = None) -> float:
    """max/mean capacity-normalized per-server load over the alive servers
    under the client spreading policy (uniform, or capacity-proportional
    when ``capacities`` is given).  1.0 = perfectly balanced; this is the
    factor by which the slowest server stretches a lockstep expert phase."""
    ok = (np.ones(num_servers, bool) if alive is None
          else np.asarray(alive, bool))
    if not ok.any():
        return 1.0
    eff = server_loads(load, mapping, num_servers, alive,
                       capacities=capacities)
    if capacities is not None:
        eff = eff / np.asarray(capacities, np.float64)
    eff = eff[ok]
    mean = eff.mean()
    return float(eff.max() / max(mean, 1e-12))


def plan_digest(mapping: np.ndarray, num_servers: int) -> str:
    """Short content hash of a placement's *routing-visible* shape: the
    per-expert replica sets (order-free — replica column order only shifts
    the salt spreading, never which servers can serve an expert) plus the
    pool size.  A live :class:`~repro.core.mapping.ExpertServerMap` that
    converged to a plan by incremental drop/register steps digests equal to
    the plan built in one shot — the cheap convergence assertion the
    rebalance controller and its tests use."""
    rows = [sorted(int(s) for s in row if s >= 0)
            for row in np.asarray(mapping)]
    blob = json.dumps([int(num_servers), rows]).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def migration_updates(old_red: np.ndarray, new_red: np.ndarray
                      ) -> Tuple[np.ndarray, List[Tuple[int, int, int, int]]]:
    """Diff two redundant tables into minimal per-slot migrations.

    Returns ``(aligned, updates)`` where ``aligned`` is ``new_red`` with
    each server's row reordered so experts already hosted keep their slot
    (slot order inside a server is routing-invisible — the local table is
    derived), and ``updates`` is ``[(server, red_slot, old_eid, new_eid)]``
    for exactly the slots whose occupant changes.  ``new_eid == -1`` means
    the slot empties (replica dropped without replacement).  Deterministic:
    plain in-order scans, no hashing."""
    old_red = np.asarray(old_red, np.int32)
    new_red = np.asarray(new_red, np.int32)
    assert old_red.shape == new_red.shape, (old_red.shape, new_red.shape)
    S, n = old_red.shape
    aligned = np.full_like(old_red, -1)
    updates: List[Tuple[int, int, int, int]] = []
    for s in range(S):
        remaining = [int(e) for e in new_red[s] if e >= 0]
        row = np.full(n, -1, np.int32)
        for j in range(n):                 # keep experts already in place
            e = int(old_red[s, j])
            if e >= 0 and e in remaining:
                row[j] = e
                remaining.remove(e)
        free = [j for j in range(n) if row[j] < 0]
        for j, e in zip(free, remaining):  # repurpose the rest
            row[j] = e
        for j in range(n):
            if row[j] != old_red[s, j]:
                updates.append((s, j, int(old_red[s, j]), int(row[j])))
        aligned[s] = row
    return aligned, updates
