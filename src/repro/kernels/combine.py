"""Pallas TPU fused top-k combine: the client-side epilogue of the buffer
protocol — weighted sum of the k returned expert partials per token.

out[t] = sum_k w[t, k] * x[t, k, :].  Grid tiles (tokens, d_model); the tiny
k dimension is kept whole per block.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import compiler_params


def _kernel(x_ref, w_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)          # (TT, k, TD)
    w = w_ref[...].astype(jnp.float32)          # (TT, k)
    o_ref[...] = jnp.einsum("tkd,tk->td", x, w).astype(o_ref.dtype)


def combine_weighted_pallas(x: jax.Array, w: jax.Array, *, tt: int = 128,
                            td: int = 512, interpret: bool = False
                            ) -> jax.Array:
    """x: (T, k, d), w: (T, k) -> (T, d).  T % tt == 0, d % td == 0."""
    T, k, d = x.shape
    assert T % tt == 0 and d % td == 0, (T, d, tt, td)
    return pl.pallas_call(
        _kernel,
        grid=(T // tt, d // td),
        in_specs=[
            pl.BlockSpec((tt, k, td), lambda i, j: (i, 0, j)),
            pl.BlockSpec((tt, k), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tt, td), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((T, d), x.dtype),
        compiler_params=compiler_params(("parallel", "parallel")),
        interpret=interpret,
    )(x, w)
