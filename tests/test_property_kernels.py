"""Hypothesis property tests on the kernel-layer invariants.

* grouped GEMM (pallas interpret + xla impls) == oracle for arbitrary group
  size vectors, including empty groups and padding rows;
* group-shrink tile tables: live tiles exactly cover the active groups in
  order, inactive groups contribute zero tiles;
* pad/unpad round-trips rows exactly.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install "
    "hypothesis); kernel oracles are also covered in test_kernels.py")
from hypothesis import given, settings, strategies as st

from repro.kernels import group_shrink as gs
from repro.kernels import ops, ref


@settings(max_examples=20, deadline=None)
@given(g=st.integers(1, 8), seed=st.integers(0, 10_000),
       impl=st.sampled_from(["pallas_interpret", "xla_ragged", "xla_dense"]))
def test_grouped_gemm_random_groups(g, seed, impl):
    rng = np.random.default_rng(seed)
    m, k, n, tm = 64, 16, 16, 8
    # random sizes, possibly summing under m (padding rows at the tail)
    sizes = rng.multinomial(rng.integers(0, m + 1), np.ones(g) / g)
    x = rng.normal(size=(m, k)).astype(np.float32)
    w = (rng.normal(size=(g, k, n)) * 0.1).astype(np.float32)
    kw = dict(tm=tm, tn=8, tk=8) if impl == "pallas_interpret" else {}
    out = ops.grouped_gemm(jnp.asarray(x), jnp.asarray(w),
                           jnp.asarray(sizes.astype(np.int32)), impl=impl,
                           expert_capacity=m, **kw)
    exp = ref.grouped_gemm_ref(jnp.asarray(x), jnp.asarray(w),
                               jnp.asarray(sizes.astype(np.int32)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=50, deadline=None)
@given(g=st.integers(1, 16), tm=st.sampled_from([8, 16, 32]),
       seed=st.integers(0, 10_000))
def test_tile_table_invariants(g, tm, seed):
    rng = np.random.default_rng(seed)
    m = 128
    sizes = rng.multinomial(rng.integers(0, m + 1), np.ones(g) / g).astype(
        np.int32)
    table = gs.build_tile_table(jnp.asarray(sizes), m, tm)
    tiles_per = -(-sizes // tm)                    # ceil
    total = int(tiles_per.sum())
    # live count matches the prefix-scan compaction
    assert int(table.num_tiles) == total
    valid = np.asarray(table.tile_valid).astype(bool)
    assert valid.sum() == total
    assert not valid[total:].any()                 # dead tail only
    # live tiles cover active groups, contiguously and in order
    gids = np.asarray(table.tile_gid)[:total]
    expect = np.repeat(np.arange(g), tiles_per)
    np.testing.assert_array_equal(gids, expect)
    # padded offsets are tile-aligned and monotone
    off = np.asarray(table.padded_offset)
    assert (off % tm == 0).all()
    assert (np.diff(off) >= 0).all()


@settings(max_examples=25, deadline=None)
@given(g=st.integers(1, 8), seed=st.integers(0, 10_000))
def test_pad_unpad_roundtrip(g, seed):
    rng = np.random.default_rng(seed)
    m, k, tm = 64, 4, 8
    sizes = rng.multinomial(rng.integers(0, m + 1), np.ones(g) / g).astype(
        np.int32)
    x = rng.normal(size=(m, k)).astype(np.float32)
    table = gs.build_tile_table(jnp.asarray(sizes), m, tm)
    xp, idx, live = gs.pad_rows_to_tiles(jnp.asarray(x), jnp.asarray(sizes),
                                         table, tm)
    back = gs.unpad_rows(xp, idx, live)
    n_live = int(sizes.sum())
    np.testing.assert_allclose(np.asarray(back)[:n_live], x[:n_live],
                               rtol=0, atol=0)
    assert np.allclose(np.asarray(back)[n_live:], 0)   # padding rows zeroed
    # padded positions are unique among live rows
    idx_np = np.asarray(idx)[:n_live]
    assert len(np.unique(idx_np)) == n_live


@settings(max_examples=15, deadline=None)
@given(b=st.integers(1, 3), kv=st.sampled_from([1, 2, 4]),
       s=st.sampled_from([16, 32]), seed=st.integers(0, 1000))
def test_flash_decode_property(b, kv, s, seed):
    rng = np.random.default_rng(seed)
    h, hd, ts = kv * 2, 16, 8
    q = rng.normal(size=(b, h, hd)).astype(np.float32)
    kc = rng.normal(size=(b, s, kv, hd)).astype(np.float32)
    vc = rng.normal(size=(b, s, kv, hd)).astype(np.float32)
    lengths = rng.integers(1, s + 1, size=b).astype(np.int32)
    out = ops.flash_decode(jnp.asarray(q), jnp.asarray(kc), jnp.asarray(vc),
                           jnp.asarray(lengths), impl="pallas_interpret",
                           ts=ts)
    exp = ref.flash_decode_ref(jnp.asarray(q), jnp.asarray(kc),
                               jnp.asarray(vc), jnp.asarray(lengths))
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)
    # outputs are convex combinations of V rows => bounded by V's range
    for i in range(b):
        lo = vc[i, :lengths[i]].min() - 1e-4
        hi = vc[i, :lengths[i]].max() + 1e-4
        assert np.asarray(out)[i].min() >= lo and np.asarray(out)[i].max() <= hi
