"""Version shims for the Pallas TPU surface.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` across
jax releases; the kernels target the new name and fall back to the old one
so interpret-mode tests run on whichever jax the environment ships.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def compiler_params(dimension_semantics) -> object:
    """Build compiler params with the given dimension semantics, on either
    side of the ``TPUCompilerParams`` -> ``CompilerParams`` rename."""
    return _CompilerParams(dimension_semantics=tuple(dimension_semantics))
