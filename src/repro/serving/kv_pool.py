"""Host-side KV block-pool manager (the paged-KV subsystem's control plane).

EaaS makes the expert tier stateless, so *attention-client memory* — the KV
cache — is what caps admitted traffic.  The dense per-slot cache strands
``max_seq - len`` slots per short request; the :class:`BlockPool` instead
carves client memory into fixed-size blocks and hands them out on demand:

* **refcounted blocks** — a block is ``free``, ``live`` (refcount > 0) or
  ``cached`` (refcount 0 but still holding a hashed prompt block: evictable
  LRU, resurrectable on a prefix hit);
* **hash-based prefix caching** — full prompt blocks are registered under a
  running (chained) hash of the token prefix, so a later request with the
  same system prompt adopts the cached blocks and prefills only its
  uncached suffix;
* **copy-on-write** — when a request must *write* a position inside a
  shared block (the fully-cached-prompt case: the last prompt token is
  always recomputed to produce first-token logits), the pool forks the
  block — bookkeeping here, the data copy in the executor;
* **eviction** — allocation falls back to reclaiming cached blocks oldest
  first; live blocks are never reclaimed (that is *preemption*, the
  scheduler's move).

Block 0 is reserved as the scratch sink: unset table entries point at it so
batched writes from inactive rows land somewhere harmless and never
alias a live block.

All of it is pure host bookkeeping over deterministic containers (deque +
insertion-ordered dicts) — replays are bit-identical under the virtual
clock, which the scenario fingerprint tests rely on.
"""

from __future__ import annotations

import hashlib
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

SCRATCH_BLOCK = 0


def block_hashes(tokens: np.ndarray, block_size: int) -> List[bytes]:
    """Chained content hashes of the *full* blocks of a token sequence.

    ``out[j]`` digests tokens ``[0, (j+1)*block_size)`` — each hash commits
    to the whole prefix, so equal hashes mean equal prefixes and matching
    can stop at the first miss.  Partial tail blocks are never hashed (they
    are private to their request).
    """
    h = hashlib.sha256()
    out: List[bytes] = []
    arr = np.asarray(tokens, np.int64)
    for j in range(len(arr) // block_size):
        h.update(arr[j * block_size:(j + 1) * block_size].tobytes())
        out.append(h.digest())
    return out


class BlockPool:
    """Refcounted fixed-size KV blocks with prefix caching and LRU eviction.

    Purely host-side: the pool never touches jax arrays.  It decides *which*
    pool slots hold *whose* tokens; the executor moves the actual K/V bytes.
    """

    def __init__(self, num_blocks: int, block_size: int,
                 enable_prefix_cache: bool = True):
        if num_blocks < 2:
            raise ValueError(f"need >= 2 blocks (1 is reserved scratch), "
                             f"got {num_blocks}")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.enable_prefix_cache = enable_prefix_cache
        self._free: Deque[int] = deque(range(1, num_blocks))
        self._ref = np.zeros(num_blocks, np.int64)
        self._hash_of: Dict[int, bytes] = {}     # live/cached block -> hash
        self._block_of: Dict[bytes, int] = {}    # hash -> block
        self._evictable: Dict[int, None] = {}    # refcount-0 cached (LRU)
        # counters (read by ServingMetrics)
        self.matched_blocks = 0
        self.queried_blocks = 0
        self.evictions = 0
        self.cow_forks = 0

    # ------------------------------------------------------------ capacity
    @property
    def usable_blocks(self) -> int:
        """Allocatable blocks (scratch excluded)."""
        return self.num_blocks - 1

    def available(self) -> int:
        """Blocks an allocation could obtain: free + evictable-cached."""
        return len(self._free) + len(self._evictable)

    def free_fraction(self) -> float:
        """The autoscaler's kv-pressure signal: available / usable."""
        return self.available() / max(self.usable_blocks, 1)

    def utilization(self) -> float:
        """Share of usable blocks currently live (referenced)."""
        return 1.0 - self.free_fraction()

    # ---------------------------------------------------------- allocation
    def allocate(self, n: int) -> Optional[List[int]]:
        """Take ``n`` fresh private blocks (refcount 1 each), evicting
        cached blocks oldest-first if the free list runs dry.  Returns None
        (allocating nothing) when fewer than ``n`` are obtainable."""
        if self.available() < n:
            return None
        out = []
        for _ in range(n):
            if self._free:
                bid = self._free.popleft()
            else:
                bid = next(iter(self._evictable))     # LRU: oldest first
                self._evict(bid)
            self._ref[bid] = 1
            out.append(bid)
        return out

    def _evict(self, bid: int) -> None:
        del self._evictable[bid]
        h = self._hash_of.pop(bid)
        del self._block_of[h]
        self.evictions += 1

    def incref(self, bid: int) -> None:
        if self._ref[bid] == 0 and bid in self._evictable:
            del self._evictable[bid]                  # resurrect
        self._ref[bid] += 1

    def decref(self, bid: int) -> None:
        assert self._ref[bid] > 0, bid
        self._ref[bid] -= 1
        if self._ref[bid] == 0:
            if bid in self._hash_of:
                self._evictable[bid] = None           # cached, LRU tail
            else:
                self._free.append(bid)

    # -------------------------------------------------------- prefix cache
    def match_prefix(self, hashes: List[bytes]) -> List[int]:
        """Adopt the longest cached prefix: returns the matched block ids
        (each increfed) — stops at the first miss."""
        out: List[int] = []
        if self.enable_prefix_cache:
            for h in hashes:
                self.queried_blocks += 1
                bid = self._block_of.get(h)
                if bid is None:
                    break
                self.incref(bid)
                self.matched_blocks += 1
                out.append(bid)
        return out

    def register(self, bid: int, h: bytes) -> None:
        """Publish a live block's content hash so later prompts can share
        it.  First writer wins — a concurrent duplicate keeps its private
        copy unregistered."""
        if not self.enable_prefix_cache:
            return
        if h in self._block_of or bid in self._hash_of:
            return
        self._block_of[h] = bid
        self._hash_of[bid] = h

    def fork(self, bid: int) -> Optional[int]:
        """Copy-on-write: allocate a fresh private block to replace shared
        ``bid``.  Returns the new block id, or None when the pool cannot
        supply one.

        The caller KEEPS its reference on ``bid`` until the executor has
        applied the data copy ``bid -> new`` (then ``decref(bid)``):
        releasing the source first would let allocation evict and reuse it
        while the copy is still pending, silently corrupting the adopted
        prefix."""
        fresh = self.allocate(1)
        if fresh is None:
            return None
        self.cow_forks += 1
        return fresh[0]
