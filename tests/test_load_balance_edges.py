"""EPLB planner edge cases (hypothesis-free): replica demand exceeding the
pool, heterogeneous server capacities (planner steering AND client-side
capacity-weighted replica spreading), and plan determinism."""

import jax.numpy as jnp
import numpy as np

from repro.core import load_balance
from repro.core import mapping as emap
from repro.core.expert_server import make_local_table


def test_more_replica_slots_than_servers():
    """Redundant capacity beyond one replica per other server: an expert
    can hold at most one replica per *distinct* server, so excess slots
    spill to other experts (or stay empty) instead of duplicating."""
    E, S, n_red, max_r = 8, 2, 4, 4
    load = np.ones(E)
    load[0] = 100.0                         # one extremely hot expert
    mapping, red = load_balance.eplb_plan(load, S, n_red, max_r)
    local = make_local_table(E, S, red)
    for e in range(E):
        reps = mapping[e][mapping[e] >= 0]
        assert len(set(reps.tolist())) == len(reps)   # distinct servers
        assert len(reps) <= S                         # bounded by the pool
        for s in reps:
            assert local[s, e] >= 0                   # actually hosted
    # the hot expert is on every server it can reach
    assert len(mapping[0][mapping[0] >= 0]) == S


def test_replicas_never_land_on_primary_server():
    """The make-before-break migration protocol relies on this: dropping a
    replica from (expert, server) can never touch the primary entry."""
    rng = np.random.default_rng(0)
    for _ in range(5):
        load = rng.random(16) * 10
        mapping, red = load_balance.eplb_plan(load, 4, 2)
        primary = load_balance.primary_owner(16, 4)
        for s in range(4):
            for e in red[s]:
                if e >= 0:
                    assert primary[e] != s, (e, s)


def test_heterogeneous_capacities_steer_replicas():
    """A high-capacity server absorbs replicas even when its raw load is
    already above its peers' (capacity-normalized least-loaded choice)."""
    E, S = 8, 4
    load = np.ones(E)
    load[0] = load[1] = 10.0      # server 0's primaries are busy
    load[6] = 50.0                # the hot expert (primary on server 3)
    flat_map, _ = load_balance.eplb_plan(load, S, n_redundant=1,
                                         max_replicas=2)
    caps = np.array([16.0, 1.0, 1.0, 1.0])
    cap_map, _ = load_balance.eplb_plan(load, S, n_redundant=1,
                                        max_replicas=2, capacities=caps)
    # homogeneous: raw-least-loaded server 1 takes the hot replica;
    # heterogeneous: the big server 0 looks emptiest after normalization
    # even though its *raw* load (its two busy primaries) is the highest
    assert flat_map[6, 1] == 1
    assert cap_map[6, 1] == 0


def test_capacity_weighted_lookup_spreads_proportionally():
    """ROADMAP item: on a heterogeneous pool, ``mapping.lookup`` spreads an
    expert's tokens over its alive replicas proportionally to the planner
    ``capacities``, not uniformly."""
    table = np.full((1, 4), -1, np.int32)
    table[0, :3] = [0, 1, 2]                  # replicas on servers 0,1,2
    alive = jnp.ones(4, bool)
    T = 4096                                   # one full salt lattice
    eids = jnp.zeros((T, 1), jnp.int32)
    salt = jnp.arange(T, dtype=jnp.int32)[:, None]
    caps = jnp.asarray([4.0, 2.0, 1.0, 1.0])
    sv = np.asarray(emap.lookup(jnp.asarray(table), alive, eids, salt,
                                weights=caps)).ravel()
    counts = np.bincount(sv, minlength=4).astype(float)
    assert counts[3] == 0                      # not a replica
    np.testing.assert_allclose(counts[:3] / counts[2], [4.0, 2.0, 1.0],
                               rtol=0.02)
    # uniform weights ≈ uniform spread (the homogeneous sanity check)
    svu = np.asarray(emap.lookup(jnp.asarray(table), alive, eids, salt,
                                 weights=jnp.ones(4))).ravel()
    cu = np.bincount(svu, minlength=4).astype(float)
    np.testing.assert_allclose(cu[:3], T / 3, rtol=0.05)
    # weights=None stays bitwise the pre-capacity salt % count policy
    sv_none = np.asarray(emap.lookup(jnp.asarray(table), alive, eids, salt))
    expect = np.asarray(table[0, :3])[np.arange(T) % 3]
    np.testing.assert_array_equal(sv_none.ravel(), expect)


def test_capacity_weighted_lookup_renormalizes_over_dead():
    """A dead replica's capacity share flows to the survivors pro rata."""
    table = np.full((1, 4), -1, np.int32)
    table[0, :3] = [0, 1, 2]
    alive = jnp.asarray([True, False, True, True])
    T = 4096
    eids = jnp.zeros((T, 1), jnp.int32)
    salt = jnp.arange(T, dtype=jnp.int32)[:, None]
    caps = jnp.asarray([4.0, 2.0, 1.0, 1.0])
    sv = np.asarray(emap.lookup(jnp.asarray(table), alive, eids, salt,
                                weights=caps)).ravel()
    counts = np.bincount(sv, minlength=4).astype(float)
    assert counts[1] == 0                      # dead
    np.testing.assert_allclose(counts[0] / counts[2], 4.0, rtol=0.02)


def test_server_loads_capacity_proportional_spread():
    """The expected-load model matches the weighted client policy: with
    capacities, a replica set's load splits pro rata, and the normalized
    imbalance of a proportional split is exactly 1."""
    E, S = 4, 2
    mapping = np.full((E, 2), -1, np.int32)
    mapping[:, 0] = [0, 0, 1, 1]
    mapping[0, 1] = 1                          # expert 0 replicated on both
    load = np.array([6.0, 1.0, 1.0, 1.0])
    caps = np.array([2.0, 1.0])
    uniform = load_balance.server_loads(load, mapping, S)
    weighted = load_balance.server_loads(load, mapping, S, capacities=caps)
    np.testing.assert_allclose(uniform, [3.0 + 1.0, 3.0 + 2.0])
    np.testing.assert_allclose(weighted, [4.0 + 1.0, 2.0 + 2.0])
    # perfectly proportional placement -> capacity-normalized imbalance 1
    flat = np.full((2, 1), -1, np.int32)
    flat[:, 0] = [0, 1]
    assert load_balance.imbalance(np.array([2.0, 1.0]), flat, 2,
                                  capacities=caps) == 1.0


def test_imbalance_respects_liveness():
    """Dead servers neither receive load nor count toward the mean."""
    E, S = 8, 4
    load = np.ones(E)
    mapping, _ = load_balance.eplb_plan(load, S, n_redundant=0)
    alive = np.array([True, True, True, False])
    # with server 3 dead its primaries have no alive replica: their load
    # vanishes and the remaining servers stay perfectly balanced
    assert load_balance.imbalance(load, mapping, S,
                                  alive=alive) == 1.0
    assert load_balance.imbalance(
        load, mapping, S, alive=np.zeros(S, bool)) == 1.0


def test_plan_deterministic_under_identical_emas():
    """Two ExpertStats fed the same observation stream produce identical
    EMAs, and identical EMAs produce the identical plan (stable sorts) —
    the property that makes rebalance ablations reproducible."""
    rng = np.random.default_rng(42)
    obs = [rng.integers(0, 50, size=16).astype(np.float64)
           for _ in range(12)]
    a = load_balance.ExpertStats(16)
    b = load_balance.ExpertStats(16)
    for o in obs:
        a.update(o)
        b.update(o)
    assert a.updates == b.updates == len(obs)
    np.testing.assert_array_equal(a.ema, b.ema)
    m1, r1 = load_balance.eplb_plan(a.ema, 4, 2, capacities=None)
    m2, r2 = load_balance.eplb_plan(b.ema, 4, 2, capacities=None)
    np.testing.assert_array_equal(m1, m2)
    np.testing.assert_array_equal(r1, r2)
    assert (load_balance.plan_digest(m1, 4)
            == load_balance.plan_digest(m2, 4))
    # ties in the load vector resolve identically run-to-run (stable sort)
    tie = np.ones(16)
    mt1, rt1 = load_balance.eplb_plan(tie, 4, 2)
    mt2, rt2 = load_balance.eplb_plan(tie, 4, 2)
    np.testing.assert_array_equal(mt1, mt2)
    np.testing.assert_array_equal(rt1, rt2)
