"""Live traffic-adaptive expert rebalancing (paper §4.5, Fig. 10).

The paper's "dynamic fine-grained adaptation to serving traffic" claim
rests on *live* expert replication: the serving loop observes per-step
router traffic and migrates expert replicas while decoding continues.
This module closes that loop:

* every decode step feeds ``MoEStats.expert_load`` into the pool's
  :class:`~repro.core.load_balance.ExpertStats` EMA (the engine's side);
* the :class:`RebalanceController` periodically re-runs the EPLB greedy
  planner on the EMA and diffs the plan against the live
  :class:`~repro.core.mapping.ExpertServerMap` via
  :func:`~repro.core.load_balance.plan_digest` — placement-identical plans
  are recorded as no-ops and nothing is rebuilt;
* a changed plan becomes a queue of per-slot migrations
  (:func:`~repro.core.load_balance.migration_updates`), applied a few
  expert-weight copies per engine step (``chunk``), interleaved with
  decode steps so serving never pauses.  Each chunk is break-before-make:
  the old replica is dropped from the mapping (its traffic falls back to
  the primaries + surviving replicas — always safe), the new expert's
  weights are copied into the slot (charged as a ``migrate`` step on the
  engine clock — the :class:`~repro.serving.clock.VirtualClock` cost
  model keeps ablations deterministic), and only then is the new replica
  registered.  Traffic thus never routes to a slot whose weights don't
  match.

Coordination with the :class:`~repro.serving.autoscale.Autoscaler`
(expert-level replication first, server-count scaling second): both share
the engine's ``last_placement_change`` cooldown, the autoscaler holds off
while a migration is in flight, and ``engine.scale_to`` aborts any pending
migration (a resize re-plans placement wholesale anyway).

The controller drives a narrow *host* interface — ``pool``, ``clk``,
``clock``, ``metrics``, ``apply_migration(copies)``,
``charge_migration(dt)``, ``last_placement_change`` — implemented by both
:class:`~repro.serving.engine.ServingEngine` (one executor) and
:class:`~repro.serving.cluster.Cluster` (the same weight copies fanned out
to every client's executor, so replicas never diverge across the
front-end).  ``charge_migration`` is where the execution modes diverge:
lockstep hosts advance their clock (the copy stalls the next step), async
hosts occupy the expert tier's micro-batch queues instead
(:meth:`~repro.serving.event_loop.AsyncExpertTier.occupy_all`) — chunks
become events that interleave with in-flight micro-batches while the
attention clients keep running, and the values migrated are identical
either way (the ``migrate_slots == rebuild`` equivalence holds per chunk).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from repro.core import load_balance
from repro.core.expert_server import redundant_slot


def oneshot_rebalance(host) -> None:
    """Re-plan from the traffic EMA and migrate in ONE step (the scripted
    ``rebalance`` scenario event / manual path).  ``host`` is an engine or
    a cluster — see the module docstring for the interface."""
    pool = host.pool
    mapping, red = pool.plan()
    changed = (load_balance.plan_digest(mapping, pool.num_servers)
               != pool.plan_digest)
    if changed:
        aligned, updates = load_balance.migration_updates(
            pool.redundant_table, red)
        E = pool.cfg.moe.num_experts
        copies = [(s, redundant_slot(E, pool.num_servers, j), new_e)
                  for s, j, _, new_e in updates if new_e >= 0]
        host.clk.start()
        if copies:
            host.apply_migration(copies)
        dt = host.clk.stop("migrate", tokens=len(copies),
                           servers=pool.num_servers)
        host.charge_migration(dt)
        pool.apply_plan(mapping, aligned)
        host.metrics.rebalances += 1
        host.metrics.migrated_experts += len(copies)
        host.metrics.migration_time += dt
        host.last_placement_change = host.clock
    else:
        host.metrics.rebalance_noops += 1
    host.metrics.events.append(
        {"t": host.clock, "event": "rebalance", "changed": changed})


@dataclass
class RebalanceConfig:
    # engine-clock seconds between plan evaluations
    interval: float = 0.02
    # expert-weight copies applied per engine step (migration granularity)
    chunk: int = 2
    # required relative imbalance improvement before migrating (hysteresis:
    # don't chase noise in the EMA)
    min_gain: float = 0.05
    # seconds after any placement change (commit or scale) before the next
    # evaluation — shared with the autoscaler
    cooldown: float = 0.05
    # decode steps observed before the first evaluation (EMA warm-up)
    min_observations: int = 4
    # read the async tier's live queue signals (host.queue_signals()):
    # when the tier is measurably backlogged, ``min_gain`` is evaluated
    # against a modeled queue-delay reduction — the max server backlog
    # now vs the balanced backlog the planned placement would leave —
    # instead of the routed-count imbalance alone.  Falls back to the
    # count-only gate whenever there is no tier or no backlog (lockstep
    # hosts behave exactly as before)
    queue_aware: bool = True


@dataclass
class RebalanceController:
    """Periodic replan + incremental migration driver for one engine."""

    cfg: RebalanceConfig = field(default_factory=RebalanceConfig)
    # (server, red_slot, old_eid, new_eid) still to apply
    _pending: List[Tuple[int, int, int, int]] = field(default_factory=list)
    _target_digest: Optional[str] = None
    _last_eval: float = float("-inf")

    @property
    def migrating(self) -> bool:
        """A staged migration has chunks left to apply."""
        return bool(self._pending)

    def abort(self) -> None:
        """Drop the rest of a staged migration (pool resize replans
        wholesale; chunks already applied are consistent and stay)."""
        self._pending = []
        self._target_digest = None

    # ---------------------------------------------------------------- loop
    def step(self, engine) -> None:
        """One control iteration, called once per engine step.  Either
        applies the next migration chunk or (at most every ``interval``
        seconds) re-evaluates the plan."""
        pool = engine.pool
        if pool is None:
            return
        if self._pending:
            self._apply_chunk(engine)
            return
        t = engine.clock
        if t - self._last_eval < self.cfg.interval:
            return
        self._last_eval = t
        if pool.stats.updates < self.cfg.min_observations:
            return
        if t - engine.last_placement_change < self.cfg.cooldown:
            return
        self._evaluate(engine)

    def _queue_signals(self, engine):
        """The host's live async-tier queue signals, or None (lockstep
        hosts / queue-awareness off / no measurable backlog)."""
        if not self.cfg.queue_aware:
            return None
        probe = getattr(engine, "queue_signals", None)
        if probe is None:
            return None
        sig = probe()
        if not sig or sig["alive"] <= 0 or sig["max_backlog"] <= 1e-12:
            return None
        return sig

    def _evaluate(self, engine) -> None:
        pool = engine.pool
        mapping, red = pool.plan()
        digest = load_balance.plan_digest(mapping, pool.num_servers)
        if digest == pool.plan_digest:
            engine.metrics.rebalance_noops += 1
            return
        current = pool.current_imbalance()
        planned = load_balance.imbalance(
            pool.stats.ema, mapping, pool.num_servers,
            alive=pool.smap.alive, capacities=pool.capacities)
        sig = self._queue_signals(engine)
        if sig is not None:
            # queue-aware gate: migrate when the modeled queue-delay
            # reduction clears min_gain.  The measured delay is the max
            # server backlog now; the planned placement redistributes the
            # queued seconds with its residual imbalance, leaving
            # ``planned_imbalance × mean backlog`` on its hottest server.
            # Routed EMA still decides WHERE replicas go — the live
            # backlog decides WHETHER moving them is worth the copies.
            cur_delay = sig["max_backlog"]
            planned_delay = planned * (sig["total_backlog"] / sig["alive"])
            if cur_delay - planned_delay < self.cfg.min_gain * cur_delay:
                engine.metrics.rebalance_noops += 1
                return
        elif current - planned < self.cfg.min_gain * current:
            engine.metrics.rebalance_noops += 1
            return
        aligned, updates = load_balance.migration_updates(
            pool.redundant_table, red)
        if not updates:
            engine.metrics.rebalance_noops += 1
            return
        self._pending = updates
        self._target_digest = digest
        event = {"t": engine.clock, "event": "rebalance_plan",
                 "updates": len(updates),
                 "imbalance": round(current, 6),
                 "planned_imbalance": round(planned, 6)}
        if sig is not None:
            event["queue_delay"] = round(sig["max_backlog"], 6)
            event["planned_queue_delay"] = round(
                planned * (sig["total_backlog"] / sig["alive"]), 6)
        engine.metrics.events.append(event)

    # ----------------------------------------------------------- migration
    def _apply_chunk(self, engine) -> None:
        pool = engine.pool
        updates = self._pending[:self.cfg.chunk]
        self._pending = self._pending[self.cfg.chunk:]

        # break: stop routing to the slots being repurposed (their traffic
        # falls back to the primaries + remaining replicas within the step)
        for s, _, old_e, _ in updates:
            if old_e >= 0:
                pool.smap.drop_replica(old_e, s)

        # move: copy the incoming experts' weights into the freed slots
        # (a cluster host fans the copies out to every client's executor)
        E = pool.cfg.moe.num_experts
        copies = [(s, redundant_slot(E, pool.num_servers, j), new_e)
                  for s, j, _, new_e in updates if new_e >= 0]
        engine.clk.start()
        if copies:
            engine.apply_migration(copies)
        dt = engine.clk.stop("migrate", tokens=len(copies),
                             servers=pool.num_servers)
        engine.charge_migration(dt)
        engine.metrics.migration_time += dt
        engine.metrics.migrated_experts += len(copies)

        # make: commit the placement now that the weights landed — the
        # local table is derived from the redundant table at the next
        # runtime() and the mapping registers the fresh replicas, so the
        # very next step routes to them
        for s, j, _, new_e in updates:
            pool.redundant_table[s, j] = new_e
            if new_e >= 0:
                pool.smap.register_replica(new_e, s)
        engine.metrics.events.append(
            {"t": engine.clock, "event": "migrate", "chunk": len(updates)})

        if not self._pending:
            engine.metrics.rebalances += 1
            engine.last_placement_change = engine.clock
            engine.metrics.events.append(
                {"t": engine.clock, "event": "rebalance_commit",
                 "digest": pool.plan_digest,
                 "converged": pool.plan_digest == self._target_digest})
            self._target_digest = None
