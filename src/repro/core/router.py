"""MoE gating: softmax / sigmoid scoring, top-k selection, aux losses.

The router runs on the attention client (paper Fig. 4): it is part of the
dense tier, so its weights are replicated over clients and it is computed in
fp32 (routing decisions must agree bit-exactly across replicas).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig


def init_router(key, d_model: int, num_experts: int) -> Dict:
    # fp32: router logits are tiny but numerically sensitive
    w = jax.random.normal(key, (d_model, num_experts), jnp.float32) * 0.02
    return {"w_router": w}


def route(params: Dict, x: jax.Array, cfg: MoEConfig,
          bias: jax.Array = None):
    """x: (T, d) -> RouterOutput over cfg.num_experts with cfg.top_k.

    ``bias``: optional (E,) fp32 logit offset added before scoring —
    runtime *data*, not params.  The serving tier uses it to shape expert
    traffic (scenario ``set_skew``: Zipf-skewed / shifting-hot-set traces);
    zeros reproduce the unbiased router bit-exactly.
    """
    from repro.core.types import RouterOutput

    logits = x.astype(jnp.float32) @ params["w_router"]     # (T, E)
    if bias is not None:
        logits = logits + bias.astype(jnp.float32)
    if cfg.router_score_fn == "softmax":
        probs = jax.nn.softmax(logits, axis=-1)
    elif cfg.router_score_fn == "sigmoid":
        probs = jax.nn.sigmoid(logits)
    else:
        raise ValueError(cfg.router_score_fn)

    scores, expert_ids = jax.lax.top_k(probs, cfg.top_k)     # (T, k)
    if cfg.normalize_topk:
        scores = scores / jnp.maximum(
            jnp.sum(scores, axis=-1, keepdims=True), 1e-9)

    # Switch-style load-balance loss: E * sum_e f_e * p_e
    T, E = probs.shape
    assign = jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32)
    f = jnp.mean(assign, axis=0)                  # fraction routed (top-1)
    p = jnp.mean(probs, axis=0)                   # mean router prob
    aux = E * jnp.sum(f * p) * cfg.router_aux_loss_coef

    lse = jax.nn.logsumexp(logits, axis=-1)
    z = jnp.mean(jnp.square(lse)) * cfg.router_z_loss_coef

    return RouterOutput(
        expert_ids=expert_ids.astype(jnp.int32),
        scores=scores,
        full_probs=probs,
        aux_loss=aux,
        z_loss=z,
    )


def expert_load(expert_ids: jax.Array, num_experts: int) -> jax.Array:
    """Token count per expert (the statistic fed to the load balancer)."""
    return jnp.bincount(expert_ids.reshape(-1), length=num_experts)
