"""Control plane: heartbeat monitor, buffer state flags, failure handling
(paper §3.4 + Fig. 6).

Pure host-side logic with injected time (deterministic — no wall clock), so
the fault-tolerance benchmarks and property tests replay exact schedules.

Protocol reproduced from the paper:

* every worker (client or server) heartbeats the monitor;
* on a missed heartbeat the monitor broadcasts: servers **release the dead
  client's buffer** (state flag → 3 OFFLINE); clients **mask the dead server
  out of their expert→server mapping** and re-send outstanding requests to a
  replica;
* clients may *independently* detect a dead server through a request
  timeout (paper Fig. 6 ②(b)) — the monitor is an optimization, not a
  correctness dependency;
* recovery: a new server simply registers (its experts are added back to
  the mapping) — no group rebuild.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Set, Tuple

import numpy as np

from repro.core.types import (STATE_CLIENT_WRITE_DONE, STATE_EMPTY,
                              STATE_OFFLINE, STATE_SERVER_DONE)


@dataclass
class WorkerInfo:
    worker_id: str
    kind: str                      # "client" | "server"
    last_heartbeat: float = 0.0
    alive: bool = True
    # servers: which experts this worker hosts (global ids)
    experts: Tuple[int, ...] = ()
    server_rank: int = -1


@dataclass
class Event:
    t: float
    kind: str
    detail: str


class Monitor:
    """Central health tracker (ZooKeeper-style, paper §4.4)."""

    def __init__(self, heartbeat_timeout: float = 3.0):
        self.timeout = heartbeat_timeout
        self.workers: Dict[str, WorkerInfo] = {}
        self.events: List[Event] = []
        self._on_server_down: List[Callable[[int], None]] = []
        self._on_client_down: List[Callable[[str], None]] = []
        self._on_server_up: List[Callable[[WorkerInfo], None]] = []

    # ------------------------------------------------------------ wiring
    def subscribe_server_down(self, fn: Callable[[int], None]) -> None:
        self._on_server_down.append(fn)

    def subscribe_client_down(self, fn: Callable[[str], None]) -> None:
        self._on_client_down.append(fn)

    def subscribe_server_up(self, fn: Callable[[WorkerInfo], None]) -> None:
        self._on_server_up.append(fn)

    # ---------------------------------------------------------- protocol
    def register(self, worker_id: str, kind: str, t: float,
                 experts: Tuple[int, ...] = (), server_rank: int = -1) -> None:
        info = WorkerInfo(worker_id, kind, t, True, tuple(experts),
                          server_rank)
        is_new = worker_id not in self.workers or not self.workers[worker_id].alive
        self.workers[worker_id] = info
        self.events.append(Event(t, "register", worker_id))
        if kind == "server" and is_new:
            for fn in self._on_server_up:
                fn(info)

    def heartbeat(self, worker_id: str, t: float) -> None:
        w = self.workers.get(worker_id)
        if w is not None and w.alive:
            w.last_heartbeat = t

    def tick(self, t: float) -> List[str]:
        """Detect timeouts; notify subscribers.  Returns newly-dead ids."""
        dead = []
        for w in self.workers.values():
            if w.alive and t - w.last_heartbeat > self.timeout:
                w.alive = False
                dead.append(w.worker_id)
                self.events.append(Event(t, "dead", w.worker_id))
                if w.kind == "server":
                    for fn in self._on_server_down:
                        fn(w.server_rank)
                else:
                    for fn in self._on_client_down:
                        fn(w.worker_id)
        return dead

    def alive_servers(self) -> Set[int]:
        return {w.server_rank for w in self.workers.values()
                if w.kind == "server" and w.alive}


class SharedBuffer:
    """The literal paper §3.2 buffer for one (client, server) pair.

    numpy-backed; used by the host-level disaggregated engine and the comm
    benchmark.  One-sided semantics: only the client calls write_request /
    read_result; only the server calls poll / write_result.
    """

    def __init__(self, capacity: int, d_model: int, dtype=np.float32):
        self.state = STATE_EMPTY
        self.layer_id = -1
        self.count = 0
        self.hidden = np.zeros((capacity, d_model), dtype)
        self.expert_id = np.full((capacity,), -1, np.int32)
        self.score = np.zeros((capacity,), np.float32)
        self.result = np.zeros((capacity, d_model), dtype)

    # client side (one-sided writes/reads)
    def write_request(self, layer_id: int, hidden, expert_id, score) -> None:
        assert self.state == STATE_EMPTY, f"slot busy (state={self.state})"
        n = len(hidden)
        self.layer_id = layer_id
        self.count = n
        self.hidden[:n] = hidden
        self.expert_id[:n] = expert_id
        self.score[:n] = score
        self.state = STATE_CLIENT_WRITE_DONE        # flag write is the fence

    def try_read_result(self):
        if self.state != STATE_SERVER_DONE:
            return None
        out = self.result[:self.count].copy()
        self.state = STATE_EMPTY
        return out

    # server side (never initiates communication — just polls its memory)
    def poll(self) -> bool:
        return self.state == STATE_CLIENT_WRITE_DONE

    def take_request(self):
        assert self.poll()
        return (self.layer_id, self.hidden[:self.count],
                self.expert_id[:self.count], self.score[:self.count])

    def write_result(self, result) -> None:
        self.result[:self.count] = result
        self.state = STATE_SERVER_DONE

    def release(self) -> None:
        """Monitor told the server this client is gone (paper Fig. 6 ①)."""
        self.state = STATE_OFFLINE
