"""Mamba2 block (zamba2 backbone) — chunked SSD, exact.

State-space recurrence per head (scalar decay, Mamba2 restriction):

    S_t = a_t · S_{t-1} + u_t ⊗ B_t          S: (P, N)
    y_t = S_t · C_t                           y: (P,)

with a_t = exp(dt_t · A), u_t = dt_t · x_t.  Training/prefill uses the
chunked form (intra-chunk quadratic + inter-chunk scan) so the sequential
dimension is seq/Q, not seq; decode is the one-step recurrence.  The
chunked path is property-tested against the naive per-step scan.

Simplifications vs. the reference CUDA implementation (noted per DESIGN.md):
ngroups=1 (B/C shared across heads) and the short conv applies to x only.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, rms_norm


class MambaState(NamedTuple):
    ssm: jax.Array      # (B, H, P, N) fp32
    conv: jax.Array     # (B, K-1, d_inner) — trailing conv inputs


def dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    heads = d_inner // s.head_dim        # derived: heads × head_dim = d_inner
    return d_inner, heads, s.head_dim, s.d_state


def init_mamba(key, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    d_inner, H, P, N = dims(cfg)
    s = cfg.ssm
    dt_proj = 2 * d_inner + 2 * N + H          # z, x, B, C, dt
    ks = jax.random.split(key, 4)
    dt_wide = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {
        "in_proj": dense_init(ks[0], d, dt_proj, dt_wide),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, d_inner), jnp.float32)
                   * 0.1).astype(dt_wide),
        "A_log": jnp.zeros((H,), jnp.float32),            # A = -exp(A_log)
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), jnp.float32),  # gated RMSNorm
        "out_proj": dense_init(ks[2], d_inner, d, dt_wide),
    }


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    d_inner, H, P, N = dims(cfg)
    z, x, Bm, Cm, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N],
        axis=-1)
    return z, x, Bm, Cm, dt


def _conv(x: jax.Array, w: jax.Array, state: jax.Array = None):
    """Causal depthwise conv over time.  x: (B, L, D); w: (K, D)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else pad
    return jax.nn.silu(out.astype(jnp.float32)).astype(x.dtype), new_state


def _ssd_chunked(u, logA, Bm, Cm, S0, chunk: int):
    """Exact chunked SSD scan.

    u: (B, L, H, P) dt-scaled inputs; logA: (B, L, H) per-step log decay;
    Bm/Cm: (B, L, N); S0: (B, H, P, N).
    Returns y (B, L, H, P), final state.
    """
    Bsz, L, H, P = u.shape
    N = Bm.shape[-1]
    Q = min(chunk, L)
    while L % Q:
        Q -= 1
    assert L % Q == 0, (L, Q)
    nc = L // Q

    u = u.reshape(Bsz, nc, Q, H, P)
    la = logA.reshape(Bsz, nc, Q, H)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)

    def per_chunk(S, inp):
        uq, laq, bq, cq = inp                     # (B,Q,H,P),(B,Q,H),(B,Q,N)
        cum = jnp.cumsum(laq, axis=1)             # inclusive (B,Q,H)
        # intra-chunk: y_t += sum_{j<=t} exp(cum_t - cum_j) (C_t·B_j) u_j
        G = jnp.einsum("bqn,bjn->bqj", cq, bq)    # (B,Q,Q)
        Mlog = cum[:, :, None, :] - cum[:, None, :, :]   # (B,Q,Q,H)
        tri = (jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :])
        M = jnp.where(tri[None, :, :, None], jnp.exp(Mlog), 0.0)
        y_intra = jnp.einsum("bqj,bqjh,bjhp->bqhp", G, M, uq)
        # inter-chunk: y_t += exp(cum_t) C_t · S0
        y_inter = jnp.einsum("bqh,bqn,bhpn->bqhp", jnp.exp(cum), cq, S)
        # next state: S' = exp(cum_Q) S + sum_j exp(cum_Q - cum_j) u_j ⊗ B_j
        wj = jnp.exp(cum[:, -1:, :] - cum)        # (B,Q,H)
        S_new = (jnp.exp(cum[:, -1, :])[:, :, None, None] * S +
                 jnp.einsum("bqh,bqhp,bqn->bhpn", wj, uq, bq))
        return S_new, y_intra + y_inter

    inputs = (u.swapaxes(0, 1), la.swapaxes(0, 1),
              Bc.swapaxes(0, 1), Cc.swapaxes(0, 1))
    S_final, ys = jax.lax.scan(
        jax.checkpoint(per_chunk), S0.astype(jnp.float32), inputs)
    y = ys.swapaxes(0, 1).reshape(Bsz, L, H, P)
    return y, S_final


def _ssd_scan_ref(u, logA, Bm, Cm, S0):
    """Naive per-step scan (the oracle for the chunked path)."""
    def step(S, inp):
        ut, lat, bt, ct = inp
        S = jnp.exp(lat)[:, :, None, None] * S + jnp.einsum(
            "bhp,bn->bhpn", ut, bt)
        y = jnp.einsum("bhpn,bn->bhp", S, ct)
        return S, y
    inputs = (u.swapaxes(0, 1), logA.swapaxes(0, 1),
              Bm.swapaxes(0, 1), Cm.swapaxes(0, 1))
    S, ys = jax.lax.scan(step, S0.astype(jnp.float32), inputs)
    return ys.swapaxes(0, 1), S


def mamba_forward(params: Dict, cfg: ModelConfig, x: jax.Array,
                  state: MambaState = None, *, chunk: int = 64,
                  use_ref_scan: bool = False
                  ) -> Tuple[jax.Array, MambaState]:
    """Full-sequence forward (train / prefill).  x: (B, L, d_model)."""
    Bsz, L, d = x.shape
    d_inner, H, P, N = dims(cfg)
    proj = x @ params["in_proj"]
    z, xs, Bm, Cm, dt = _split_proj(cfg, proj)

    conv_state = None if state is None else state.conv
    xs, conv_state = _conv(xs, params["conv_w"], conv_state)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,L,H)
    A = -jnp.exp(params["A_log"])                                     # (H,)
    logA = dt * A
    xh = xs.reshape(Bsz, L, H, P).astype(jnp.float32)
    u = xh * dt[..., None]

    S0 = (jnp.zeros((Bsz, H, P, N), jnp.float32)
          if state is None else state.ssm)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)
    if use_ref_scan:
        y, S = _ssd_scan_ref(u, logA, Bf, Cf, S0)
    else:
        y, S = _ssd_chunked(u, logA, Bf, Cf, S0, chunk)
    y = y + params["D"][None, None, :, None] * xh
    y = y.reshape(Bsz, L, d_inner)

    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(x.dtype), params["norm_scale"], cfg.rms_norm_eps)
    out = y @ params["out_proj"]
    return out, MambaState(ssm=S, conv=conv_state)


def mamba_decode(params: Dict, cfg: ModelConfig, x: jax.Array,
                 state: MambaState) -> Tuple[jax.Array, MambaState]:
    """One-token decode.  x: (B, 1, d_model)."""
    Bsz, _, d = x.shape
    d_inner, H, P, N = dims(cfg)
    proj = x @ params["in_proj"]
    z, xs, Bm, Cm, dt = _split_proj(cfg, proj)

    xs, conv_state = _conv(xs, params["conv_w"], state.conv)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])[:, 0]
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt * A)                                    # (B,H)
    xh = xs.reshape(Bsz, H, P).astype(jnp.float32)
    u = xh * dt[..., None]

    S = (a[:, :, None, None] * state.ssm +
         jnp.einsum("bhp,bn->bhpn", u, Bm[:, 0].astype(jnp.float32)))
    y = jnp.einsum("bhpn,bn->bhp", S, Cm[:, 0].astype(jnp.float32))
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(Bsz, 1, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(x.dtype), params["norm_scale"], cfg.rms_norm_eps)
    return y @ params["out_proj"], MambaState(ssm=S, conv=conv_state)


def init_mamba_state(cfg: ModelConfig, batch: int) -> MambaState:
    d_inner, H, P, N = dims(cfg)
    K = cfg.ssm.d_conv
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return MambaState(
        ssm=jnp.zeros((batch, H, P, N), jnp.float32),
        conv=jnp.zeros((batch, K - 1, d_inner), dt),
    )
