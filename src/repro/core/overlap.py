"""Double-batch-overlap (paper §4.2).

Client pipelining: while microbatch A's expert round-trip is in flight, the
client computes microbatch B's attention.  On TPU the overlap is realized by
XLA's latency-hiding scheduler: we split the batch and express the two
microbatches' dense compute and dispatch collectives as *independent*
subgraphs, so the a2a of A can be hoisted behind the attention FLOPs of B.
The host-level engine gets the same effect by keeping two batches in flight
(serving/engine.py).
"""

from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp


def double_batch_overlap(dense_fn: Callable, moe_fn: Callable,
                         x: jax.Array, *, enabled: bool = True):
    """y = moe_fn(dense_fn(x)) computed as two interleaved microbatches.

    dense_fn/moe_fn must be batch-elementwise (true for transformer blocks).
    With ``enabled=False`` the same split runs sequentially chained, which
    pins the collectives on the critical path (the ablation baseline).
    """
    B = x.shape[0]
    assert B % 2 == 0, "double-batch overlap needs an even batch"
    x0, x1 = jnp.split(x, 2, axis=0)

    if enabled:
        # independent subgraphs: scheduler may overlap a2a(0) with dense(1)
        a0 = dense_fn(x0)
        a1 = dense_fn(x1)
        y0 = moe_fn(a0)
        y1 = moe_fn(a1)
    else:
        # serialized: artificial dependency chains mb1 behind mb0's combine
        a0 = dense_fn(x0)
        y0 = moe_fn(a0)
        # the zero-valued coupling forces a data dependency without changing
        # the math (ablation: communication is exposed)
        a1 = dense_fn(x1 + 0 * jnp.sum(y0).astype(x1.dtype))
        y1 = moe_fn(a1)
    return jnp.concatenate([y0, y1], axis=0)


def split_batch_decode(step_fn: Callable, tokens: jax.Array, cache, *,
                       axis: int, enabled: bool = True):
    """One decode step as two half-batch microbatches (engine-level DBO).

    ``step_fn(tokens_half, cache_half) -> (logits, cache, stats)`` is the
    whole-model decode step; ``axis`` is the batch axis shared by every
    cache leaf.  With ``enabled=True`` the two halves are independent
    subgraphs, so XLA's latency-hiding scheduler may overlap microbatch A's
    expert a2a with microbatch B's attention — the serving executor's
    pipelined decode.  With ``enabled=False`` a zero-valued coupling chains
    B behind A's logits without changing the math: the serialized ablation,
    bit-identical outputs, collectives exposed on the critical path.
    """
    B = tokens.shape[0]
    assert B % 2 == 0, "two-microbatch decode needs an even batch"
    half = B // 2
    t0, t1 = jnp.split(tokens, 2, axis=0)

    def cache_half(i: int):
        return jax.tree.map(
            lambda a: jax.lax.slice_in_dim(a, i * half, (i + 1) * half,
                                           axis=axis), cache)

    l0, c0, s0 = step_fn(t0, cache_half(0))
    if not enabled:
        # artificial data dependency: mb1's tokens wait on mb0's logits
        t1 = t1 + (0 * jnp.sum(l0)).astype(t1.dtype)
    l1, c1, s1 = step_fn(t1, cache_half(1))

    logits = jnp.concatenate([l0, l1], axis=0)
    new_cache = jax.tree.map(
        lambda a, b: jnp.concatenate([a, b], axis=axis), c0, c1)
    stats = jax.tree.map(lambda a, b: a + b, s0, s1)
    return logits, new_cache, stats


def microbatch_schedule(n: int) -> Tuple[Tuple[int, str], ...]:
    """The steady-state two-batch schedule (for the engine + docs):
    (mb, phase) pairs — attention(i+1) overlaps expert(i)."""
    steps = []
    for i in range(n):
        steps.append((i, "attention"))
        if i > 0:
            steps.append((i - 1, "combine"))
        steps.append((i, "dispatch"))
    steps.append((n - 1, "combine"))
    return tuple(steps)
