#!/usr/bin/env python
"""Benchmark trajectory: append dated per-benchmark summary records to the
committed ``BENCH_trajectory.json``.

The nightly workflow runs the gated smokes, then::

    python tools/bench_history.py --append --date $(date -u +%F)

which appends one record per benchmark JSON under ``experiments/bench/``
(headline numbers only — throughput, p99 ITL, resource saving — pulled
from the ``gate.tolerance`` section so the schema tracks whatever each
benchmark already pins) and commits the file back.  Re-appending the same
(date, benchmark) pair replaces the old record, so a rerun nightly never
duplicates.

``--show`` prints the trajectory one line per record (date benchmark
k=v ...) for eyeballing trends without JSON spelunking.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List

TRAJECTORY = "BENCH_trajectory.json"
BENCH_DIR = os.path.join("experiments", "bench")
# headline gate.tolerance keys worth tracking over time; everything else
# (ratios, raw resource-seconds) stays in the per-run JSON
HEADLINE_TAGS = ("tok_per_s", "p99", "saving")


def load_trajectory(path: str) -> List[Dict]:
    if not os.path.exists(path):
        return []
    with open(path) as f:
        doc = json.load(f)
    return doc.get("records", [])


def summarize(doc: Dict) -> Dict[str, float]:
    tol = (doc.get("gate") or {}).get("tolerance", {})
    return {k: round(float(v), 6) for k, v in sorted(tol.items())
            if any(tag in k for tag in HEADLINE_TAGS)}


def append_records(traj_path: str, bench_dir: str, date: str) -> int:
    records = load_trajectory(traj_path)
    added = 0
    for path in sorted(glob.glob(os.path.join(bench_dir, "*.json"))):
        name = os.path.splitext(os.path.basename(path))[0]
        with open(path) as f:
            doc = json.load(f)
        metrics = summarize(doc)
        if not metrics:        # no gate → not a tracked benchmark
            continue
        rec = {"date": date, "benchmark": name, "metrics": metrics}
        env = doc.get("env")
        if env:
            rec["jax"] = env.get("jax")
        records = [r for r in records
                   if not (r["date"] == date and r["benchmark"] == name)]
        records.append(rec)
        added += 1
    records.sort(key=lambda r: (r["date"], r["benchmark"]))
    with open(traj_path, "w") as f:
        json.dump({"records": records}, f, indent=1)
        f.write("\n")
    print(f"bench_history: {added} record(s) for {date} -> {traj_path} "
          f"({len(records)} total)")
    return 0 if added else 1


def show(traj_path: str) -> int:
    for r in load_trajectory(traj_path):
        kv = " ".join(f"{k}={v:g}" for k, v in r["metrics"].items())
        print(f"{r['date']} {r['benchmark']}: {kv}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="benchmark trajectory log")
    ap.add_argument("--append", action="store_true",
                    help="append one dated record per benchmark JSON")
    ap.add_argument("--show", action="store_true",
                    help="print the trajectory, one line per record")
    ap.add_argument("--date", default=None,
                    help="record date (YYYY-MM-DD; required with "
                         "--append so reruns are reproducible)")
    ap.add_argument("--dir", default=BENCH_DIR,
                    help="directory of benchmark JSONs to summarize "
                         f"(default {BENCH_DIR})")
    ap.add_argument("--trajectory", default=TRAJECTORY,
                    help=f"trajectory file (default {TRAJECTORY})")
    args = ap.parse_args(argv)
    if args.show:
        return show(args.trajectory)
    if args.append:
        if not args.date:
            ap.error("--append requires --date YYYY-MM-DD")
        return append_records(args.trajectory, args.dir, args.date)
    ap.error("one of --append / --show required")


if __name__ == "__main__":
    sys.exit(main())
