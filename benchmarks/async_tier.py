"""Async expert tier benchmark: event-driven vs lockstep execution.

One seeded request trace replayed under ``EngineConfig.exec_mode``
``lockstep`` and ``async`` on an expert-dominated
:class:`~repro.serving.clock.VirtualClock` cost model:

* ``lockstep`` / ``async``          — the plain trace: the bitwise
  token-identity contract (values never depend on execution mode) and the
  ping-pong pipelining throughput edge (wave k+1's attention overlaps
  wave k's expert phase instead of summing with it);
* ``lockstep_straggler`` / ``async_straggler`` — the same trace with one
  expert server running 6x slow: lockstep stretches EVERY decode step by
  the slowest alive server, async queues only that server's micro-batches
  — the p99 ITL gap is the paper's tail-latency claim, and the headline
  gate (``async_p99_beats_lockstep_straggler``).

The full (non-smoke) run adds a saturated bursty-trace pair and the
``async_depth=1`` ablation (strict wave-at-a-time: identity holds and the
cadence collapses back to lockstep — the pipelining win is depth >= 2).

Deterministic under the virtual clock: every number in the JSON is exactly
reproducible, so the ``gate`` section (consumed by ``tools/check_bench.py``
against ``experiments/baselines/async_tier.json``) pins identity and the
p99 win exactly and throughputs within tolerance.
"""

from __future__ import annotations

import argparse
import hashlib
from typing import Dict, List

from benchmarks.common import bench_model_cfg, csv_row, save_result
from repro.serving import (EngineConfig, Scenario, ServingEngine,
                           VirtualClock)

NUM_SERVERS = 4
MAX_BATCH = 4
STRAGGLER_RANK = 1
STRAGGLER_FACTOR = 6.0


def _clock() -> VirtualClock:
    # expert-dominated decode: the regime where the tier's queues (and a
    # straggler server) actually gate the step
    return VirtualClock(decode_base=2e-4, decode_per_token=2e-3,
                        expert_share=0.8)


def _engine(cfg, exec_mode: str, **kw) -> ServingEngine:
    ecfg = EngineConfig(
        mode="eaas", num_servers=NUM_SERVERS, max_batch=MAX_BATCH,
        max_seq=64, n_redundant=2,
        # drop-free dispatch capacity (the bitwise-identity contract)
        pool_tokens_per_client=MAX_BATCH * NUM_SERVERS,
        exec_mode=exec_mode, **kw)
    return ServingEngine(cfg, ecfg, seed=0, clock=_clock())


def _token_fingerprint(tokens: Dict[int, tuple]) -> str:
    blob = repr(sorted(tokens.items())).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def _measure(eng: ServingEngine, sc: Scenario) -> Dict:
    res = sc.run(eng)
    m = res.metrics
    tokens = {r.request_id: tuple(r.output_tokens) for r in res.requests}
    out = {
        "requests": m.total_requests,
        "completed": m.completed,
        "decode_tok_per_s": m.decode_throughput,
        "p99_itl_s": m.p99_itl,
        "wall_s": eng.clock,
        "token_fingerprint": _token_fingerprint(tokens),
        "_tokens": tokens,
    }
    if eng.tier is not None:
        out["micro_batches"] = eng.tier.completed
        out["queue_delay"] = m.queue_delay_stats()
        out["fired_events"] = len(eng.timeline.log)
    return out


def run(horizon: float = 0.5, rate: float = 100.0, max_new: int = 12,
        smoke: bool = False) -> Dict:
    if smoke:
        horizon, rate, max_new = 0.25, 100.0, 8
    cfg = bench_model_cfg()
    V = cfg.vocab_size

    def plain():
        return Scenario(horizon=horizon, seed=7, prompt_len=8,
                        max_new=max_new, vocab=V).poisson(rate=rate)

    def straggled():
        return plain().slow_server(STRAGGLER_RANK, t=horizon / 20,
                                   factor=STRAGGLER_FACTOR)

    variants: Dict[str, Dict] = {}
    variants["lockstep"] = _measure(_engine(cfg, "lockstep"), plain())
    variants["async"] = _measure(_engine(cfg, "async"), plain())
    variants["lockstep_straggler"] = _measure(_engine(cfg, "lockstep"),
                                              straggled())
    variants["async_straggler"] = _measure(_engine(cfg, "async"),
                                           straggled())

    if not smoke:
        def bursty():
            return (Scenario(horizon=horizon / 4, seed=11, prompt_len=8,
                             max_new=max_new, vocab=V)
                    .bursty(base=rate / 2, peak=6 * rate,
                            period=horizon / 8, duty=0.3))
        variants["lockstep_bursty"] = _measure(_engine(cfg, "lockstep"),
                                               bursty())
        variants["async_bursty"] = _measure(_engine(cfg, "async"),
                                            bursty())
        variants["async_depth1"] = _measure(
            _engine(cfg, "async", async_depth=1), plain())

    lk, an = variants["lockstep"], variants["async"]
    lks, ans = variants["lockstep_straggler"], variants["async_straggler"]
    out: Dict = {"figure": "async_tier", "smoke": smoke,
                 "num_servers": NUM_SERVERS,
                 "straggler": {"rank": STRAGGLER_RANK,
                               "factor": STRAGGLER_FACTOR},
                 "variants": {}}
    out["tokens_identical_plain"] = lk["_tokens"] == an["_tokens"]
    out["tokens_identical_straggler"] = lks["_tokens"] == ans["_tokens"]
    out["async_speedup_plain"] = (an["decode_tok_per_s"]
                                  / max(lk["decode_tok_per_s"], 1e-9))
    out["straggler_p99_ratio"] = (ans["p99_itl_s"]
                                  / max(lks["p99_itl_s"], 1e-12))
    for name, v in variants.items():
        out["variants"][name] = {k: val for k, val in v.items()
                                 if k != "_tokens"}

    out["gate"] = {
        "exact": {
            "smoke": smoke,
            "tokens_identical_plain": out["tokens_identical_plain"],
            "tokens_identical_straggler":
                out["tokens_identical_straggler"],
            "token_fingerprint_async": an["token_fingerprint"],
            # the headline claims, pinned as booleans (the ratios below
            # track the margins within tolerance)
            "async_p99_beats_lockstep_straggler":
                ans["p99_itl_s"] < lks["p99_itl_s"],
            "async_throughput_not_worse":
                an["decode_tok_per_s"] >= lk["decode_tok_per_s"],
        },
        "tolerance": {
            "tok_per_s_lockstep": lk["decode_tok_per_s"],
            "tok_per_s_async": an["decode_tok_per_s"],
            "p99_itl_lockstep_straggler": lks["p99_itl_s"],
            "p99_itl_async_straggler": ans["p99_itl_s"],
            "straggler_p99_ratio": out["straggler_p99_ratio"],
        },
    }
    save_result("async_tier", out)
    return out


def main() -> List[str]:
    res = run()
    rows = []
    for name, v in res["variants"].items():
        rows.append(csv_row(
            f"async_tier_{name}", 0.0,
            f"tok_per_s={v['decode_tok_per_s']:.1f}"
            f";p99_itl={v['p99_itl_s']:.5f}"
            f";completed={v['completed']}"))
    rows.append(csv_row(
        "async_tier_summary", 0.0,
        f"speedup=x{res['async_speedup_plain']:.3f}"
        f";straggler_p99_ratio={res['straggler_p99_ratio']:.3f}"
        f";identical={int(res['tokens_identical_plain'])}"))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single short configuration (CI regression gate)")
    args = ap.parse_args()
    res = run(smoke=args.smoke)
    for name, v in res["variants"].items():
        print(f"{name}: tok_per_s={v['decode_tok_per_s']:.1f} "
              f"p99_itl={v['p99_itl_s']:.5f} completed={v['completed']}")
    print(f"async speedup x{res['async_speedup_plain']:.3f}, straggler "
          f"p99 ratio {res['straggler_p99_ratio']:.3f} (identical="
          f"{res['tokens_identical_plain']}/"
          f"{res['tokens_identical_straggler']})")
