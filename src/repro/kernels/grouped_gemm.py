"""Pallas TPU grouped GEMM with group-shrink (the paper's expert-server
kernel, §4.1, adapted per DESIGN.md §6).

Computes ``out[i] = x[i] @ w[g(i)]`` for rows sorted by group, where the
tile→group mapping comes from :mod:`repro.kernels.group_shrink` through
scalar prefetch (SMEM).  Grid = (row_tiles, N tiles, K tiles); inactive
groups occupy zero row tiles, dead tail tiles skip the MXU via ``pl.when``.

VMEM working set per grid step: TM·TK (x) + TK·TN (w) + TM·TN·4 (fp32 acc)
— defaults (128, 128, 128) use 96 KiB, far below the ~16 MiB VMEM budget;
larger TN/TK amortize the HBM weight stream better and are swept in
EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import group_shrink as gs
from repro.kernels.compat import compiler_params


def _kernel(tile_gid, tile_valid, x_ref, w_ref, o_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    i = pl.program_id(0)

    @pl.when(tile_valid[i] > 0)
    def _compute():
        o_ref[...] += jnp.dot(
            x_ref[...], w_ref[...], preferred_element_type=jnp.float32)


def grouped_gemm_pallas(x_sorted: jax.Array, w: jax.Array,
                        group_sizes: jax.Array, *,
                        tm: int = 128, tn: int = 128, tk: int = 128,
                        interpret: bool = False) -> jax.Array:
    """x_sorted: (M, K) rows sorted by group; w: (G, K, N); -> (M, N).

    Rows beyond ``sum(group_sizes)`` yield zeros.  K and N must be multiples
    of tk/tn (the launch layer pads model dims to 128 already; tests sweep
    unaligned tile choices explicitly).
    """
    M, K = x_sorted.shape
    G, K2, N = w.shape
    assert K == K2, (K, K2)
    assert K % tk == 0 and N % tn == 0, (K, N, tk, tn)

    table = gs.build_tile_table(group_sizes, M, tm)
    x_pad, padded_idx, row_live = gs.pad_rows_to_tiles(
        x_sorted, group_sizes, table, tm)
    T = table.tile_gid.shape[0]

    grid = (T, N // tn, K // tk)
    kernel = functools.partial(_kernel, n_k=K // tk)
    out_pad = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tm, tk), lambda i, j, k, gid, vld: (i, k)),
                pl.BlockSpec((None, tk, tn),
                             lambda i, j, k, gid, vld: (gid[i], k, j)),
            ],
            out_specs=pl.BlockSpec((tm, tn), lambda i, j, k, gid, vld: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((T * tm, N), jnp.float32),
        compiler_params=compiler_params(
            ("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(table.tile_gid, table.tile_valid, x_pad, w)

    out = gs.unpad_rows(out_pad, padded_idx, row_live)
    return out.astype(x_sorted.dtype)
