"""Monolithic baselines the paper compares against (§2.2, §5).

* ``monolithic_ep`` — DeepEP-style expert parallelism: static expert→rank
  placement inside one collective group, no service indirection, no replicas.
  Structurally this is EAAS with a primary-only mapping — which is the point:
  the paper's architecture strictly generalizes monolithic EP, so the
  overhead of the indirection is measurable (EXPERIMENTS.md §Ablation), and
  the baseline halts if any rank dies (`alive` is not consulted).
* ``tp_moe`` — tensor-parallel MoE: every rank holds a 1/P slice of every
  expert; no token exchange, but the model is replicated per 16-GPU unit,
  which caps batch size (the paper's SGL-TP line).  In the CPU simulation
  this is the S=1 local layer; the memory/batch consequences are modeled in
  the serving engine.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import mapping as emap
from repro.core.moe_layer import (MoERuntime, MoEStats, default_capacity,
                                  eaas_moe_apply, init_eaas_moe)


def monolithic_runtime(cfg: ModelConfig, num_servers: int,
                       tokens_per_client: int,
                       gemm_impl: str = "auto") -> MoERuntime:
    """Primary-only mapping, liveness pinned alive (a dead rank = a hang)."""
    from repro.core import expert_server
    m = cfg.moe
    table = emap.default_mapping(m.num_experts, num_servers, max_replicas=1)
    local = expert_server.make_local_table(
        m.num_experts, num_servers, np.zeros((num_servers, 0), np.int32))
    return MoERuntime(
        mapping=jnp.asarray(table),
        alive=jnp.ones((num_servers,), bool),
        local_table=jnp.asarray(local),
        num_servers=num_servers,
        capacity=default_capacity(tokens_per_client, m.top_k, num_servers,
                                  m.capacity_factor),
        gemm_impl=gemm_impl,
    )


def init_monolithic_ep(key, cfg: ModelConfig, num_servers: int) -> Dict:
    return init_eaas_moe(key, cfg, num_servers, n_redundant=0)


def monolithic_ep_apply(params: Dict, x: jax.Array, cfg: ModelConfig,
                        runtime: MoERuntime, **kw
                        ) -> Tuple[jax.Array, MoEStats]:
    """Identical dataflow to EAAS minus indirection (R=1, no failover)."""
    return eaas_moe_apply(params, x, cfg.moe, runtime,
                          activation=cfg.activation, **kw)


def init_tp_moe(key, cfg: ModelConfig) -> Dict:
    # one logical server holding every expert (weights TP-sharded at launch)
    return init_eaas_moe(key, cfg, num_servers=1, n_redundant=0)


def tp_moe_apply(params: Dict, x: jax.Array, cfg: ModelConfig,
                 gemm_impl: str = "auto") -> Tuple[jax.Array, MoEStats]:
    rt = monolithic_runtime(cfg, 1, x.shape[0], gemm_impl)
    return eaas_moe_apply(params, x, cfg.moe, rt, activation=cfg.activation)
