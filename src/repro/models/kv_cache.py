"""KV caches and recurrent states for serving.

:class:`KVCache` — per-layer (batch, slots, kv_heads, head_dim) buffers with
a per-sequence length counter.  Sliding-window layers allocate only
``window`` slots and write round-robin.  ``window`` is a *static* pytree
field so stacked caches can ride ``lax.scan`` over layers.

:class:`PagedKVCache` — the block-pool alternative: one shared
``(num_blocks, block_size, kv_heads, head_dim)`` pool per layer, with each
sequence naming its blocks through a ``(batch, max_blocks)`` block table
(position ``p`` of sequence ``b`` lives in pool block
``block_tables[b, p // block_size]`` at offset ``p % block_size``).  Block
tables and lengths are *data* — the host-side
:class:`~repro.serving.kv_pool.BlockPool` rewrites them between steps
(admission, prefix-cache sharing, preemption) without recompiling.  Block 0
is reserved as a scratch sink: unset table entries point at it, so writes
from inactive batch rows land somewhere harmless and masked reads of it
contribute exact zeros.

All update ops are functional (return a new cache) so they can live inside
jitted ``serve_step``s and be donated.  The paged view gathered by
:func:`gather_blocks` has width ``max_blocks * block_size``; sized equal to
the dense cache's ``slots``, the paged attention math is lane-for-lane the
dense math, which is what makes dense/paged greedy decode token-identical.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclass
class KVCache:
    """One layer's cache.  k/v: (batch, slots, kv_heads, head_dim)."""

    k: jax.Array
    v: jax.Array
    # number of tokens already written per sequence: (batch,) int32
    length: jax.Array
    # ring buffer (sliding window) if window > 0, else linear — STATIC
    window: int = field(default=0, metadata=dict(static=True))


def init_kv_cache(batch: int, max_seq: int, kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16, window: int = 0) -> KVCache:
    slots = min(window, max_seq) if window else max_seq
    return KVCache(
        k=jnp.zeros((batch, slots, kv_heads, head_dim), dtype),
        v=jnp.zeros((batch, slots, kv_heads, head_dim), dtype),
        length=jnp.zeros((batch,), jnp.int32),
        window=window,
    )


def kv_cache_spec(batch: int, max_seq: int, kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16, window: int = 0) -> KVCache:
    """ShapeDtypeStruct twin of :func:`init_kv_cache` (for the dry-run)."""
    slots = min(window, max_seq) if window else max_seq
    sds = jax.ShapeDtypeStruct
    return KVCache(
        k=sds((batch, slots, kv_heads, head_dim), dtype),
        v=sds((batch, slots, kv_heads, head_dim), dtype),
        length=sds((batch,), jnp.int32),
        window=window,
    )


def append_decode(cache: KVCache, k_new: jax.Array, v_new: jax.Array) -> KVCache:
    """Append ONE token per sequence.  k_new/v_new: (batch, 1, kv_heads, hd).

    Implemented as a vmapped dynamic-update-slice (not a gather-scatter):
    GSPMD keeps the batch dim partitioned through DUS, whereas the explicit-
    index scatter forced an all-gather of the cache every layer.
    """
    slots = cache.k.shape[1]
    idx = cache.length % slots if cache.window else cache.length

    def upd(c, new, i):                  # (slots, KV, hd), (KV, hd), scalar
        return jax.lax.dynamic_update_slice_in_dim(c, new[None], i, axis=0)

    k = jax.vmap(upd)(cache.k, k_new[:, 0], idx)
    v = jax.vmap(upd)(cache.v, v_new[:, 0], idx)
    return KVCache(k=k, v=v, length=cache.length + 1, window=cache.window)


def write_prefill(cache: KVCache, k: jax.Array, v: jax.Array) -> KVCache:
    """Write a full prompt (batch, seq, kv_heads, hd) starting at position 0."""
    seq = k.shape[1]
    slots = cache.k.shape[1]
    if cache.window and seq > slots:
        # only the trailing `window` tokens are retained; keep ring phase
        k_tail, v_tail = k[:, -slots:], v[:, -slots:]
        pos = (jnp.arange(seq - slots, seq) % slots)
        ck = cache.k.at[:, pos].set(k_tail)
        cv = cache.v.at[:, pos].set(v_tail)
    else:
        ck = jax.lax.dynamic_update_slice_in_dim(cache.k, k, 0, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache.v, v, 0, axis=1)
    length = jnp.full_like(cache.length, seq)
    return KVCache(k=ck, v=cv, length=length, window=cache.window)


def write_chunk(cache: KVCache, k: jax.Array, v: jax.Array,
                start) -> KVCache:
    """Write a prompt *chunk* (batch, chunk, kv_heads, hd) at position
    ``start`` (scalar int32, may be traced).  Linear caches only — chunked
    prefill is gated off for sliding-window layers by the caller."""
    assert cache.window == 0, "write_chunk needs a linear cache"
    seq = k.shape[1]
    ck = jax.lax.dynamic_update_slice_in_dim(cache.k, k, start, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache.v, v, start, axis=1)
    length = jnp.full_like(cache.length, start + seq)
    return KVCache(k=ck, v=cv, length=length, window=cache.window)


def valid_mask(cache: KVCache) -> jax.Array:
    """(batch, slots) bool — which cache slots hold valid tokens."""
    slots = cache.k.shape[1]
    pos = jnp.arange(slots)[None, :]
    if cache.window:
        n_valid = jnp.minimum(cache.length, slots)[:, None]
        return pos < jnp.broadcast_to(n_valid, (cache.k.shape[0], slots))
    return pos < cache.length[:, None]


# --------------------------------------------------------------- paged cache

@jax.tree_util.register_dataclass
@dataclass
class PagedKVCache:
    """One layer's block-pool cache.

    k/v: (num_blocks, block_size, kv_heads, head_dim) — shared pool;
    block_tables: (batch, max_blocks) int32 — per-sequence block names
    (0 = unset, the reserved scratch block);
    length: (batch,) int32 — tokens written per sequence.
    """

    k: jax.Array
    v: jax.Array
    block_tables: jax.Array
    length: jax.Array
    block_size: int = field(default=0, metadata=dict(static=True))


def init_paged_kv_cache(num_blocks: int, block_size: int, batch: int,
                        max_blocks: int, kv_heads: int, head_dim: int,
                        dtype=jnp.bfloat16) -> PagedKVCache:
    return PagedKVCache(
        k=jnp.zeros((num_blocks, block_size, kv_heads, head_dim), dtype),
        v=jnp.zeros((num_blocks, block_size, kv_heads, head_dim), dtype),
        block_tables=jnp.zeros((batch, max_blocks), jnp.int32),
        length=jnp.zeros((batch,), jnp.int32),
        block_size=block_size,
    )


def paged_kv_cache_spec(num_blocks: int, block_size: int, batch: int,
                        max_blocks: int, kv_heads: int, head_dim: int,
                        dtype=jnp.bfloat16) -> PagedKVCache:
    """ShapeDtypeStruct twin of :func:`init_paged_kv_cache`."""
    sds = jax.ShapeDtypeStruct
    return PagedKVCache(
        k=sds((num_blocks, block_size, kv_heads, head_dim), dtype),
        v=sds((num_blocks, block_size, kv_heads, head_dim), dtype),
        block_tables=sds((batch, max_blocks), jnp.int32),
        length=sds((batch,), jnp.int32),
        block_size=block_size,
    )


def _lookup_blocks(cache: PagedKVCache, positions: jax.Array) -> jax.Array:
    """Map per-row positions (batch, n) to pool block ids via the table.

    Positions at or past capacity clamp to the last table entry — the same
    "write the final slot" behaviour the dense cache's dynamic-update-slice
    shows at capacity (the engine retires such requests right after)."""
    bi = jnp.clip(positions // cache.block_size, 0,
                  cache.block_tables.shape[1] - 1)
    return jnp.take_along_axis(cache.block_tables, bi, axis=1)


def paged_append_decode(cache: PagedKVCache, k_new: jax.Array,
                        v_new: jax.Array) -> PagedKVCache:
    """Append ONE token per sequence.  k_new/v_new: (batch, 1, kv_heads, hd).

    The tail block of every *live* sequence is private (the block-pool
    invariant), so the batched scatter has no cross-row aliasing; inactive
    rows (length 0, table all-unset) write the scratch block, which is never
    read.
    """
    blocks = _lookup_blocks(cache, cache.length[:, None])[:, 0]   # (batch,)
    off = cache.length % cache.block_size
    k = cache.k.at[blocks, off].set(k_new[:, 0])
    v = cache.v.at[blocks, off].set(v_new[:, 0])
    return PagedKVCache(k=k, v=v, block_tables=cache.block_tables,
                        length=cache.length + 1,
                        block_size=cache.block_size)


def paged_write_chunk(cache: PagedKVCache, k: jax.Array, v: jax.Array,
                      start) -> PagedKVCache:
    """Write a prompt chunk (batch, chunk, kv_heads, hd) at position
    ``start`` (scalar int32, may be traced) through the block table."""
    B, C = k.shape[0], k.shape[1]
    pos = start + jnp.arange(C, dtype=jnp.int32)
    blocks = _lookup_blocks(cache, jnp.broadcast_to(pos[None], (B, C)))
    off = jnp.broadcast_to((pos % cache.block_size)[None], (B, C))
    ck = cache.k.at[blocks, off].set(k)
    cv = cache.v.at[blocks, off].set(v)
    length = jnp.full_like(cache.length, start + C)
    return PagedKVCache(k=ck, v=cv, block_tables=cache.block_tables,
                        length=length, block_size=cache.block_size)


def gather_blocks(cache: PagedKVCache):
    """Materialize the per-sequence view: 2× (batch, max_blocks·bs, KV, hd).

    View lane ``j`` of row ``b`` holds position ``j`` — identical layout to
    a dense :class:`KVCache` of ``max_blocks * block_size`` slots, so the
    downstream attention math is shared verbatim."""
    B, mb = cache.block_tables.shape
    bs, kvh, hd = cache.k.shape[1], cache.k.shape[2], cache.k.shape[3]
    kv = cache.k[cache.block_tables].reshape(B, mb * bs, kvh, hd)
    vv = cache.v[cache.block_tables].reshape(B, mb * bs, kvh, hd)
    return kv, vv


def paged_valid_mask(cache: PagedKVCache) -> jax.Array:
    """(batch, max_blocks·bs) bool over the gathered view."""
    slots = cache.block_tables.shape[1] * cache.block_size
    pos = jnp.arange(slots)[None, :]
    return pos < cache.length[:, None]


def copy_blocks(cache: PagedKVCache, src: jax.Array, dst: jax.Array, *,
                stacked: bool = False) -> PagedKVCache:
    """Copy pool blocks ``src[i] -> dst[i]`` (copy-on-write forks).

    ``stacked`` handles the scan-over-layers layout where every leaf
    carries a leading layer dim (blocks at axis 1 instead of 0)."""
    if stacked:
        k = cache.k.at[:, dst].set(cache.k[:, src])
        v = cache.v.at[:, dst].set(cache.v[:, src])
    else:
        k = cache.k.at[dst].set(cache.k[src])
        v = cache.v.at[dst].set(cache.v[src])
    return PagedKVCache(k=k, v=v, block_tables=cache.block_tables,
                        length=cache.length, block_size=cache.block_size)
