"""Production mesh definitions.

A FUNCTION, not a module constant — importing this module never touches JAX
device state (the dry-run pins the fake device count before any jax import).
"""

from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips for the multi-pod run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def data_axes(mesh) -> Tuple[str, ...]:
    """Batch axes of a production mesh (everything but 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def make_test_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for SPMD tests (requires forced host device count)."""
    return jax.make_mesh(
        (n_data, n_model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
