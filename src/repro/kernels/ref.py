"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` of DESIGN.md §6).

These are written for *obvious correctness*, not speed; the test suite sweeps
shapes/dtypes and asserts the kernels (interpret mode) match these exactly
(up to accumulation-order tolerance).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def grouped_gemm_ref(x: jax.Array, w: jax.Array,
                     group_sizes: jax.Array) -> jax.Array:
    """Row-grouped matmul oracle.

    x: (M, K) tokens sorted by group; w: (G, K, N); group_sizes: (G,) with
    sum(group_sizes) <= M.  Row i belongs to group g iff
    offsets[g] <= i < offsets[g+1].  Rows beyond sum(group_sizes) (padding)
    produce zeros.
    Implementation: G masked dense matmuls — exact and trivially correct.
    """
    M = x.shape[0]
    G = w.shape[0]
    offsets = jnp.concatenate(
        [jnp.zeros((1,), group_sizes.dtype), jnp.cumsum(group_sizes)])
    rows = jnp.arange(M)
    out = jnp.zeros((M, w.shape[2]), jnp.float32)
    for g in range(G):
        mask = (rows >= offsets[g]) & (rows < offsets[g + 1])
        xg = jnp.where(mask[:, None], x, 0)
        out = out + (xg.astype(jnp.float32) @ w[g].astype(jnp.float32))
    return out.astype(x.dtype)


def flash_decode_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     lengths: jax.Array) -> jax.Array:
    """Single-token GQA attention oracle.

    q: (B, H, hd); k_cache/v_cache: (B, S, KV, hd); lengths: (B,) >= 1.
    Returns (B, H, hd).
    """
    B, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    qf = q.reshape(B, KV, G, hd).astype(jnp.float32)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    scores = jnp.einsum("bkgh,bskh->bkgs", qf, kf) / np.sqrt(hd)
    mask = (jnp.arange(k_cache.shape[1])[None, :] < lengths[:, None])
    scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p, vf)
    return out.reshape(B, H, hd).astype(q.dtype)


def paged_flash_decode_ref(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, block_tables: jax.Array,
                           lengths: jax.Array) -> jax.Array:
    """Paged flash-decode oracle: gather the block-table view, then run the
    dense oracle.

    q: (B, H, hd); k/v_pool: (num_blocks, bs, KV, hd);
    block_tables: (B, max_blocks) int32; lengths: (B,) >= 1.
    Sequence ``b``'s view lane ``p`` is pool block ``block_tables[b, p//bs]``
    offset ``p % bs``; lanes at or past ``lengths[b]`` are masked.
    """
    B = q.shape[0]
    nb, bs, KV, hd = k_pool.shape
    mb = block_tables.shape[1]
    kv = k_pool[block_tables].reshape(B, mb * bs, KV, hd)
    vv = v_pool[block_tables].reshape(B, mb * bs, KV, hd)
    return flash_decode_ref(q, kv, vv, lengths)


def combine_weighted_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Fused top-k combine oracle: x (T, k, d), w (T, k) -> (T, d)."""
    return jnp.einsum("tkd,tk->td", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)
