"""Per-expert micro-batch queue lanes — the async expert tier's data plane.

The paper's disaggregation claim is that expert servers are *independent
services*: attention clients enqueue micro-batches and servers drain them
continuously, so one slow or busy server delays only the work routed to it
instead of barriering the whole step.  This module is the host-side model
of that tier:

* :class:`MicroBatch` — one client wave's routed share on one server (one
  expert lane of it under ``queue_mode="expert"``): ``tokens`` of routed
  load, ``work`` seconds of compute at speed 1, enqueue/start/finish times
  filled in by the queue simulation;
* :class:`ExpertLane` — one expert's FIFO on one server: its own
  ``busy_until`` frontier plus per-lane conservation counters.  A
  Zipf-hot expert queues only in its own lane; cold co-located experts
  keep flowing through theirs;
* :class:`ServerQueue` — one expert server: ``budget`` work-conserving
  service streams (the per-server service-rate budget) draining the
  expert lanes, a per-server ``slowdown`` factor (scenario
  ``slow_server``) and a liveness flag.  A micro-batch starts at
  ``max(now, its lane's frontier, the earliest service stream)`` — FIFO
  within a lane, work-conserving across lanes, deterministic tie-break by
  stream index;
* :class:`AsyncExpertTier` — the shared tier: lane dispatch, lane-aware
  failure re-dispatch (queued micro-batches of a dead server move into
  the same expert's lane on the survivor with the earliest start — no
  token is lost, the paper's replica failover), recovery and elastic
  resize that *reconcile* live lane state, migration occupancy
  (rebalance weight-copy chunks busy the servers, not the clients),
  live queue signals for the rebalancer, and conservation counters
  (``enqueued == completed + cancelled + in_flight()`` at the tier AND
  per lane — the invariants the property tests pin).

The tier computes *when* modeled work finishes; it never touches arrays —
the engine computes values eagerly at dispatch (decode outputs are bitwise
independent of batch composition and of placement, so timing and values
decouple) and posts the finish times onto its
:class:`~repro.serving.clock.EventTimeline`.  Under a cluster the tier is
shared: every client's micro-batches queue on the same lane frontiers, so
cross-client contention emerges from queueing instead of an analytic
stretch factor.

Back-compat: ``queue_mode="server"`` (or any dispatch through the legacy
per-server :meth:`AsyncExpertTier.dispatch` vector API) funnels a server's
whole share through the single aggregate lane ``expert=-1``; with
``lane_budget=1`` that reduces bit-exactly to the original per-server
FIFO, so existing timings and fingerprints are reproducible on demand.

Re-dispatch bookkeeping: each micro-batch carries a ``generation`` bumped
when it moves servers.  Completion events posted for the old placement
carry the stale generation and are ignored (:meth:`AsyncExpertTier.
is_current`) — the standard DES trick for revising an eagerly scheduled
future (the engine additionally cancels the superseded events outright).
A server's ``slowdown`` applies to micro-batches dispatched from then on;
already-queued work keeps its committed finish time (the model's service
commitment, kept for determinism).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, Iterator, List, Optional, Tuple

import numpy as np

#: lane key for a server's aggregate (non-expert-split) share — the legacy
#: per-server FIFO funnels through this lane
AGGREGATE_LANE = -1


@dataclass
class MicroBatch:
    """One wave's routed share on one expert server (modeled timing).

    ``expert`` keys the queue lane the share drains through:
    a real expert id under ``queue_mode="expert"``, or
    :data:`AGGREGATE_LANE` for a whole-server aggregate share."""

    mb_id: int
    client_id: int
    wave_id: int
    server: int
    tokens: float              # routed load share (diagnostic)
    work: float                # seconds of compute at slowdown 1.0
    enqueue_t: float
    expert: int = AGGREGATE_LANE
    start_t: float = 0.0
    finish_t: float = 0.0
    generation: int = 0        # bumped on failure re-dispatch
    done: bool = False
    cancelled: bool = False


@dataclass
class ExpertLane:
    """One expert's FIFO on one server: frontier + conservation counters.

    Per-lane conservation: ``enqueued == drained + cancelled + moved +
    in_flight()`` — ``moved`` counts departures to another server's lane
    on failure re-dispatch (the arrival increments the target lane's
    ``enqueued``), so summing ``in_flight()`` over every lane equals the
    tier's in-flight count."""

    server: int
    expert: int
    busy_until: float = 0.0
    enqueued: int = 0
    drained: int = 0
    cancelled: int = 0
    moved: int = 0             # re-dispatched away (failure/resize)

    def in_flight(self) -> int:
        return self.enqueued - self.drained - self.cancelled - self.moved


class ServerQueue:
    """One expert server: ``budget`` service streams draining expert lanes.

    ``budget=1`` is the classic single work-conserving FIFO (every lane
    shares one service stream, so service order equals dispatch order and
    timing is bit-identical to the pre-lane tier).  ``budget=B`` models B
    concurrent service streams (the per-server service-rate budget):
    micro-batches of *different* lanes overlap up to B-wide while each
    lane stays FIFO — a hot expert saturates one stream and its cold
    co-located neighbours keep flowing through the others."""

    def __init__(self, rank: int, budget: int = 1, slowdown: float = 1.0,
                 alive: bool = True, free_at: float = 0.0):
        if budget < 1:
            raise ValueError(f"service budget must be >= 1, got {budget}")
        self.rank = rank
        self.budget = budget
        self.slowdown = slowdown   # >1 = straggler (scenario slow_server)
        self.alive = alive
        # per-stream service frontiers (work-conserving: a micro-batch
        # takes the earliest-free stream, ties to the lowest index)
        self.streams: List[float] = [float(free_at)] * budget
        self.lanes: Dict[int, ExpertLane] = {}
        # server-level conservation mirror of the lane counters
        self.enqueued = 0
        self.drained = 0
        self.cancelled = 0
        self.moved = 0

    # ------------------------------------------------------------ frontier
    @property
    def busy_until(self) -> float:
        """Committed-work frontier: when the last service stream frees."""
        return max(self.streams)

    def free_at(self) -> float:
        """When the next service stream frees (earliest start for a
        lane with no backlog)."""
        return min(self.streams)

    def lane(self, expert: int) -> ExpertLane:
        ln = self.lanes.get(expert)
        if ln is None:
            ln = self.lanes[expert] = ExpertLane(self.rank, expert)
        return ln

    def eta(self, expert: int, now: float) -> float:
        """Earliest start a new micro-batch on ``expert``'s lane would
        get — the lane-aware re-dispatch target metric."""
        ln = self.lanes.get(expert)
        lane_t = ln.busy_until if ln is not None else 0.0
        return max(float(now), lane_t, self.free_at())

    def in_flight(self) -> int:
        return self.enqueued - self.drained - self.cancelled - self.moved

    # ------------------------------------------------------------- service
    def schedule(self, mb: MicroBatch, now: float) -> None:
        """Append ``mb`` to its expert's lane: it starts when both the
        lane's previous micro-batch and a service stream free up, and runs
        for ``work * slowdown`` seconds on that stream.

        Stream choice is best-fit: among the streams giving the earliest
        start, take the one freeing *latest* (least idle waste — a
        lane-FIFO-constrained micro-batch must not park the earliest
        stream, which stays open for other lanes), ties to the lowest
        index.  Deterministic, and identical to the single FIFO at
        budget=1."""
        ln = self.lane(mb.expert)
        now = float(now)
        best = 0
        best_start = max(now, ln.busy_until, self.streams[0])
        for j in range(1, self.budget):
            st = max(now, ln.busy_until, self.streams[j])
            if st < best_start or (st == best_start
                                   and self.streams[j] > self.streams[best]):
                best, best_start = j, st
        mb.server = self.rank
        mb.start_t = best_start
        mb.finish_t = mb.start_t + mb.work * self.slowdown
        ln.busy_until = mb.finish_t
        self.streams[best] = mb.finish_t
        ln.enqueued += 1
        self.enqueued += 1

    # ------------------------------------------------------------- control
    def clamp_down(self, now: float) -> None:
        """Pull every frontier back to ``now`` (server death: committed
        future work is void, the queues re-dispatch)."""
        now = float(now)
        self.streams = [min(s, now) for s in self.streams]
        for ln in self.lanes.values():
            ln.busy_until = min(ln.busy_until, now)

    def clamp_up(self, now: float) -> None:
        """Raise every frontier to at least ``now`` (recovery: a rejoined
        server serves from now, never from its stale past)."""
        now = float(now)
        self.streams = [max(s, now) for s in self.streams]
        for ln in self.lanes.values():
            ln.busy_until = max(ln.busy_until, now)

    def occupy(self, now: float, dt: float) -> None:
        """A migration weight-copy busies the whole server (every service
        stream) for ``dt``; in-flight lanes keep their committed times and
        the *next* dispatches queue behind the copy."""
        now, dt = float(now), float(dt)
        self.streams = [max(s, now) + dt for s in self.streams]


class AsyncExpertTier:
    """The shared micro-batch queue tier over ``num_servers`` servers.

    ``queue_mode="expert"`` (default) drains per-expert lanes;
    ``queue_mode="server"`` funnels everything through each server's
    aggregate lane (the pre-lane FIFO).  ``lane_budget`` is each server's
    service-stream count (see :class:`ServerQueue`)."""

    def __init__(self, num_servers: int, queue_mode: str = "expert",
                 lane_budget: int = 1):
        if queue_mode not in ("expert", "server"):
            raise ValueError(f"unknown queue_mode {queue_mode!r}; expected "
                             "'expert' or 'server'")
        if lane_budget < 1:
            raise ValueError(
                f"lane_budget must be >= 1, got {lane_budget}")
        self.queue_mode = queue_mode
        self.lane_budget = int(lane_budget)
        self.queues: List[ServerQueue] = [
            ServerQueue(s, budget=self.lane_budget)
            for s in range(num_servers)]
        # in-flight micro-batches only: retired (done/cancelled) entries
        # are pruned at retirement, so memory stays bounded by in-flight
        # work and the failure/cancel scans are O(in-flight), not
        # O(all-time micro-batches)
        self.mbs: Dict[int, MicroBatch] = {}
        self._next_id = 0
        self.enqueued = 0
        self.completed = 0
        self.cancelled = 0
        self.redispatched = 0
        self.migration_busy = 0.0          # seconds of migrate occupancy

    @property
    def num_servers(self) -> int:
        return len(self.queues)

    def in_flight(self) -> int:
        """Micro-batches dispatched but neither completed nor cancelled —
        the conservation counter (enqueued == completed + cancelled +
        in_flight)."""
        return self.enqueued - self.completed - self.cancelled

    def lanes(self) -> Iterator[ExpertLane]:
        """Every materialized lane on every server (conservation sweeps)."""
        for q in self.queues:
            for e in sorted(q.lanes):
                yield q.lanes[e]

    # ----------------------------------------------------------- dispatch
    def dispatch(self, client_id: int, wave_id: int, work: np.ndarray,
                 now: float, tokens: Optional[np.ndarray] = None
                 ) -> List[MicroBatch]:
        """Enqueue one wave through the legacy per-server vector API:
        ``work[s]`` seconds of expert compute on server ``s`` (zero
        entries skipped), each server's share funneled through its
        aggregate lane.  Returns the micro-batches with committed
        start/finish times."""
        work = np.asarray(work, np.float64)
        entries = []
        for s in range(min(len(work), self.num_servers)):
            w = float(work[s])
            if w <= 0.0:
                continue
            entries.append((s, AGGREGATE_LANE, w,
                            float(tokens[s]) if tokens is not None else w))
        return self.dispatch_lanes(client_id, wave_id, entries, now)

    def dispatch_lanes(self, client_id: int, wave_id: int,
                       entries: Iterable[Tuple], now: float
                       ) -> List[MicroBatch]:
        """Enqueue one wave as explicit ``(server, expert, work[, tokens])``
        lane shares, scheduled in iteration order (the engine emits them
        server-major, expert-ascending — deterministic).  Zero/negative
        work entries are skipped."""
        out: List[MicroBatch] = []
        for entry in entries:
            s, e, w = int(entry[0]), int(entry[1]), float(entry[2])
            tok = float(entry[3]) if len(entry) > 3 else w
            if w <= 0.0 or not 0 <= s < self.num_servers:
                continue
            mb = MicroBatch(
                mb_id=self._next_id, client_id=client_id, wave_id=wave_id,
                server=s, tokens=tok, work=w, enqueue_t=float(now),
                expert=e)
            self._next_id += 1
            self.queues[s].schedule(mb, now)
            self.mbs[mb.mb_id] = mb
            self.enqueued += 1
            out.append(mb)
        return out

    def is_current(self, mb_id: int, generation: int) -> bool:
        """True when a completion event for (mb_id, generation) is still
        valid — not re-dispatched since, not cancelled, not already done
        (retired entries are pruned, so a missing id is simply stale)."""
        mb = self.mbs.get(mb_id)
        return (mb is not None and not mb.cancelled and not mb.done
                and mb.generation == generation)

    def mark_done(self, mb: MicroBatch) -> None:
        mb.done = True
        q = self.queues[mb.server]
        q.drained += 1
        q.lane(mb.expert).drained += 1
        self.completed += 1
        # retire: any duplicate/stale-generation event still in a timeline
        # resolves to "not current" via the missing id
        self.mbs.pop(mb.mb_id, None)

    def _cancel_mb(self, mb: MicroBatch) -> None:
        mb.cancelled = True
        q = self.queues[mb.server]
        q.cancelled += 1
        q.lane(mb.expert).cancelled += 1
        self.cancelled += 1
        self.mbs.pop(mb.mb_id, None)

    # ------------------------------------------------------------- faults
    def _redispatch_from(self, rank: int, now: float,
                         pool: Optional[List[ServerQueue]] = None
                         ) -> List[MicroBatch]:
        """Move every unfinished micro-batch off ``rank`` (already marked
        dead/dropped) onto the alive queues in ``pool`` — lane-aware: each
        victim re-queues in the *same expert's* lane on the server giving
        it the earliest start (ties to the lowest rank).  FIFO order per
        source is preserved by the deterministic ``(start_t, mb_id)``
        victim sort.  With no survivors the work cancels explicitly."""
        pool = self.queues if pool is None else pool
        src = self.queues[rank]
        victims = sorted(
            (mb for mb in self.mbs.values()
             if mb.server == rank and not mb.done and not mb.cancelled),
            key=lambda m: (m.start_t, m.mb_id))
        moved: List[MicroBatch] = []
        for mb in victims:
            survivors = [t for t in pool if t.alive]
            if not survivors:
                # nobody can serve it: the wave will be completed by the
                # engine's degenerate path; count the loss explicitly and
                # retire the entry (engines see the missing id as
                # cancelled when reconciling their waves)
                self._cancel_mb(mb)
                continue
            target = min(survivors,
                         key=lambda t: (t.eta(mb.expert, now), t.rank))
            src.lane(mb.expert).moved += 1
            src.moved += 1
            mb.generation += 1
            target.schedule(mb, now)
            self.redispatched += 1
            moved.append(mb)
        return moved

    def fail_server(self, rank: int, now: float) -> List[MicroBatch]:
        """A server dies mid-drain: every unfinished micro-batch queued on
        it is re-dispatched into the same expert's lane on the surviving
        server with the earliest start (FIFO order preserved; no token
        loss).  Returns the moved micro-batches — the owning engines post
        fresh completion events from the new finish times (old events are
        stale by generation, and the engine cancels them outright)."""
        if rank >= self.num_servers:
            return []
        q = self.queues[rank]
        q.alive = False
        q.clamp_down(now)
        return self._redispatch_from(rank, now)

    def recover_server(self, rank: int, now: float) -> None:
        """A dead server rejoins: it serves from ``now`` — every stale
        stream/lane frontier left from before the failure is raised to
        ``now`` so no new micro-batch is scheduled into the server's dead
        past (the lane-aware reconcile the recovery tests pin)."""
        if rank >= self.num_servers:
            return
        q = self.queues[rank]
        q.alive = True
        q.clamp_up(now)

    def set_slowdown(self, rank: int, factor: float) -> None:
        """Scenario ``slow_server``: future micro-batches on ``rank`` run
        ``factor``× slower in every lane (already-queued work keeps its
        committed finish time).  ``factor=1.0`` restores full speed."""
        if rank >= self.num_servers:
            return
        if factor <= 0:
            raise ValueError(f"slowdown factor must be > 0, got {factor}")
        self.queues[rank].slowdown = float(factor)

    def reset_speeds(self) -> None:
        """Restore every server to full speed (elastic resize replans the
        pool wholesale — fresh pool, fresh speeds)."""
        for q in self.queues:
            q.slowdown = 1.0

    def cancel_client(self, client_id: int) -> int:
        """A client died: its in-flight micro-batches are abandoned (the
        servers finish the dispatched compute and discard the results —
        dispatched work cannot be clawed back, so the occupancy stays)."""
        n = 0
        for mb in list(self.mbs.values()):
            if mb.client_id == client_id and not mb.done \
                    and not mb.cancelled:
                self._cancel_mb(mb)
                n += 1
        return n

    # ----------------------------------------------------------- control
    def occupy_all(self, now: float, dt: float) -> None:
        """A migration chunk busies every alive server for ``dt`` (the
        weight copy lands on the servers, not the clients): in-flight
        micro-batches keep their committed times, the *next* dispatches
        queue behind the copy — migration interleaves with decoding
        instead of stalling the clients."""
        for q in self.queues:
            if q.alive:
                q.occupy(now, dt)
        self.migration_busy += float(dt)

    def resize(self, num_servers: int, now: float) -> List[MicroBatch]:
        """Elastic pool resize, *reconciling* live lane state instead of
        resetting it: surviving servers keep their committed stream/lane
        frontiers (and in-flight micro-batches); dropped ranks re-dispatch
        their unfinished work to the survivors exactly like a failure
        (cancelled outright when nothing survives); new ranks join free
        from ``now``.  Returns the moved micro-batches — the owning
        engines re-post their completion events.  Speed factors are NOT
        reset here; callers replanning the pool wholesale follow up with
        :meth:`reset_speeds` (engines normally quiesce via
        ``_drain_async`` first, so a mid-flight resize only matters under
        direct tier use — which this reconcile keeps conservation-safe)."""
        old_n = self.num_servers
        if num_servers == old_n:
            return []
        moved: List[MicroBatch] = []
        if num_servers < old_n:
            survivors = self.queues[:num_servers]
            dropped = self.queues[num_servers:]
            for q in dropped:
                q.alive = False
                q.clamp_down(now)
            for q in dropped:
                moved.extend(
                    self._redispatch_from(q.rank, now, pool=survivors))
            self.queues = survivors
        else:
            for r in range(old_n, num_servers):
                self.queues.append(ServerQueue(
                    r, budget=self.lane_budget, free_at=float(now)))
        return moved

    def expert_in_flight(self, expert: int) -> int:
        """In-flight micro-batches across every server's lane for
        ``expert`` — the scale-to-zero page-out gate: an expert only pages
        out of the tier once its lanes have fully drained (nonzero means
        the reconcile paths still owe it completions, so eviction waits a
        round)."""
        expert = int(expert)
        n = 0
        for q in self.queues:
            ln = q.lanes.get(expert)
            if ln is not None:
                n += ln.in_flight()
        return n

    # ----------------------------------------------------------- signals
    def queue_signals(self, now: float) -> Dict:
        """Live queueing-delay signals for the queue-aware rebalancer.

        Per alive server, the backlog is how far its committed-work
        frontier runs past ``now`` (seconds until fully idle — the delay a
        new aggregate dispatch would see at worst); per lane, the same for
        the lane frontier.  Dead servers report zero (nothing queues on
        them).  ``max_backlog`` is the measured worst-case queueing delay
        the rebalancer targets; ``total_backlog / alive`` is the balanced
        ideal it models migration against."""
        now = float(now)
        server_backlog: List[float] = []
        lane_backlog: Dict[Tuple[int, int], float] = {}
        lane_depth: Dict[Tuple[int, int], int] = {}
        for q in self.queues:
            if not q.alive:
                server_backlog.append(0.0)
                continue
            server_backlog.append(max(q.busy_until - now, 0.0))
            for e in sorted(q.lanes):
                ln = q.lanes[e]
                b = ln.busy_until - now
                if b > 0.0:
                    lane_backlog[(q.rank, e)] = b
                d = ln.in_flight()
                if d > 0:
                    lane_depth[(q.rank, e)] = d
        alive = sum(1 for q in self.queues if q.alive)
        return {
            "server_backlog": server_backlog,
            "max_backlog": max(server_backlog, default=0.0),
            "total_backlog": float(sum(server_backlog)),
            "alive": alive,
            "lane_backlog": lane_backlog,
            "lane_depth": lane_depth,
        }
