"""Training launcher.

CPU-scale runs execute directly (reduced configs, real training with
checkpointing).  For pod-scale runs this assembles the same jitted
``train_step`` the dry-run compiles (mesh, shardings, Adafactor, remat,
ZeRO-3) — on TPU hosts it executes; in this container use
``repro.launch.dryrun`` to verify the compiled artifact.

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --reduced --steps 50
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.core.moe_layer import default_runtime
from repro.models.transformer import ParallelCtx, build_model
from repro.training.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.training.data import synthetic_lm_batches
from repro.training.optimizer import adamw, cosine_schedule
from repro.training.train_loop import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale config (required in this container)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    elif jax.default_backend() == "cpu":
        raise SystemExit(
            "full configs need a TPU pod; use --reduced on CPU, or "
            "python -m repro.launch.dryrun to verify the pod compilation")

    S = 2 if cfg.moe else 1
    model = build_model(cfg, num_servers=S)
    rt = (default_runtime(cfg, S, args.batch * args.seq,
                          gemm_impl="xla_ragged") if cfg.moe else None)
    ctx = ParallelCtx(remat=False, moe_runtime=rt,
                      ce_chunk=min(64, args.seq))
    opt = adamw(lr=cosine_schedule(args.lr, warmup=10, total=args.steps))
    data = synthetic_lm_batches(cfg, args.batch, args.seq, seed=0)

    state = init_train_state(model, opt, jax.random.PRNGKey(0),
                             compression=args.compress_grads)
    start = 0
    ck = None
    if args.ckpt_dir:
        ck = AsyncCheckpointer(args.ckpt_dir)
        if args.resume and latest_step(args.ckpt_dir) is not None:
            state, start = restore_checkpoint(args.ckpt_dir, state)
            print(f"resumed at step {start}")

    step = jax.jit(make_train_step(model, opt, ctx,
                                   compression=args.compress_grads))
    for i in range(start, args.steps):
        state, m = step(state, next(data))
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:5d}  loss {float(m['loss']):.4f}  "
                  f"gnorm {float(m['grad_norm']):.3f}")
        if ck and (i + 1) % 20 == 0:
            ck.save(i + 1, state)
    if ck:
        ck.wait()


if __name__ == "__main__":
    main()
