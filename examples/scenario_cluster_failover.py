"""Cluster front-end tour: scale-out identity + client-failure containment.

One seeded Poisson trace replayed three ways under the virtual clock:

* **N=1** — a single attention client over the 4-server expert tier;
* **N=4** — the same trace through the cluster front-end (round_robin):
  requests run on different clients, yet every per-request greedy token
  stream is BITWISE identical to the N=1 run — the front-end changes
  *where* a request runs, never *what* it computes;
* **N=4 + client failure** — client 0 dies mid-trace: its in-flight
  requests strand (counted as failed, never silently retried) while the
  expert tier keeps serving the other three clients.  The cluster
  throughput dip is the dead client's capacity share — compare the
  monolithic single-engine stall on the same trace, which drops to zero.

Run:  PYTHONPATH=src python examples/scenario_cluster_failover.py
Same seed ⇒ identical output, every run, on any machine.
"""

import numpy as np

from repro.configs import get_config
from repro.serving import (Cluster, ClusterConfig, EngineConfig, Scenario,
                           ServingEngine, VirtualClock)

NUM_SERVERS, MAX_BATCH = 4, 4
HORIZON, RATE, MAX_NEW = 0.4, 250.0, 16
T_FAIL, T_RECOVER = 0.2, 0.35


def build_cluster(cfg, n: int) -> Cluster:
    ecfg = EngineConfig(
        mode="eaas", num_servers=NUM_SERVERS, max_batch=MAX_BATCH,
        max_seq=64, n_redundant=2,
        pool_tokens_per_client=MAX_BATCH * NUM_SERVERS)  # drop-free
    return Cluster(cfg, ClusterConfig(clients=n, engine=ecfg), seed=0,
                   clock_factory=VirtualClock)


def trace(cfg, clients: int = 1) -> Scenario:
    return Scenario(horizon=HORIZON, seed=7, prompt_len=8, max_new=MAX_NEW,
                    vocab=cfg.vocab_size, clients=clients).poisson(RATE)


def dip(metrics) -> float:
    curve = metrics.throughput_curve(HORIZON / 10)
    pre = [v for t, v in curve if 0.1 * HORIZON <= t < T_FAIL]
    post = [v for t, v in curve if T_FAIL <= t < HORIZON]
    return 1.0 - min(post) / max(np.mean(pre), 1e-9)


def main() -> None:
    cfg = get_config("deepseek-r1").reduced()

    res1 = trace(cfg).run(build_cluster(cfg, 1))
    res4 = trace(cfg, clients=4).run(build_cluster(cfg, 4))
    t1 = {r.request_id: tuple(r.output_tokens) for r in res1.requests}
    t4 = {r.request_id: tuple(r.output_tokens) for r in res4.requests}
    print(f"N=1: {res1.metrics.completed} requests, "
          f"{res1.metrics.decode_throughput:.0f} tok/s")
    print(f"N=4: {res4.metrics.completed} requests, "
          f"{res4.metrics.decode_throughput:.0f} tok/s")
    print(f"per-request token streams bitwise identical: {t1 == t4}")

    cl = build_cluster(cfg, 4)
    res_f = (trace(cfg, clients=4)
             .fail_client(i=0, t=T_FAIL)
             .recover_client(i=0, t=T_RECOVER)).run(cl)
    mono = ServingEngine(
        cfg, EngineConfig(mode="monolithic_ep", num_servers=NUM_SERVERS,
                          max_batch=MAX_BATCH, max_seq=64, restart_steps=50,
                          pool_tokens_per_client=MAX_BATCH * NUM_SERVERS),
        seed=0, clock=VirtualClock())
    res_m = trace(cfg).fail(rank=1, t=T_FAIL).run(mono)

    print(f"\nclient 0 dies at t={T_FAIL}: "
          f"{cl.metrics.failed_requests} in-flight requests strand, "
          f"{cl.metrics.completed} complete")
    print(f"cluster throughput dip:    {dip(res_f.metrics):.1%} "
          f"(one of 4 clients lost)")
    print(f"monolithic restart stall:  {dip(res_m.metrics):.1%} "
          f"(the whole engine halts)")
    assert dip(res_f.metrics) < dip(res_m.metrics)
    assert t1 == t4


if __name__ == "__main__":
    main()
