"""granite-3-2b — IBM Granite 3.0 2B base.

[hf:ibm-granite/granite-3.0-2b-base; hf]  dense, GQA.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-3-2b",
    family="dense",
    num_layers=40,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=49155,
    d_head=64,
    rope_theta=10000.0,
    activation="swiglu",
    tie_embeddings=True,
    subquadratic=False,
    source="hf:ibm-granite/granite-3.0-2b-base",
)
