"""Shared building blocks: norms, embeddings, initializers, dtype policy."""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


def resolve_dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# --------------------------------------------------------------------------
# Initializers.  All weights are created in the config dtype (bf16 for every
# production config); norm scales in fp32.
# --------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype) -> jax.Array:
    """Truncated-normal fan-in init (matches common LLM pretraining inits)."""
    std = 1.0 / np.sqrt(in_dim)
    return (jax.random.truncated_normal(key, -3, 3, (in_dim, out_dim), jnp.float32)
            * std).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


def rms_norm_init(dim: int) -> jax.Array:
    return jnp.ones((dim,), jnp.float32)


# --------------------------------------------------------------------------
# Norms — computed in fp32, cast back to input dtype.
# --------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * scale
    return y.astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias
    return y.astype(dtype)


# --------------------------------------------------------------------------
# Activations
# --------------------------------------------------------------------------

def activation_fn(name: str):
    if name == "swiglu":  # handled structurally in mlp.py; gate act is silu
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu_sq":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name!r}")


def softcap(logits: jax.Array, cap: float) -> jax.Array:
    """Gemma-style logit soft-capping."""
    return cap * jnp.tanh(logits / cap)


# --------------------------------------------------------------------------
# Small helpers
# --------------------------------------------------------------------------

def count_params(params) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))


def param_bytes(params) -> int:
    return sum(int(np.prod(p.shape)) * p.dtype.itemsize
               for p in jax.tree.leaves(params))


def assert_finite(tree, name: str = "tree"):
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        if not bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))):
            raise FloatingPointError(f"non-finite values in {name}{path}")
