"""Admission and step planning — the scheduler half of the engine split.

The :class:`Scheduler` owns the request queue and the slot table and decides
what the next engine step *is*: a prefill chunk, a decode step over the
decode-ready slots, or idle.  It never touches params, caches or jitted
functions — that is the :class:`~repro.serving.executor.Executor`'s side of
the line — so policies stay pure host logic, trivially swappable and
deterministic under a virtual clock.

Chunked prefill (bounded TTFT *and* bounded ITL): a prompt is split into
chunks of at most ``prefill_chunk`` tokens and each chunk is one engine
step, so decode steps can interleave with a long prompt's admission instead
of stalling behind it.  ``prefill_chunk=0`` reproduces the pre-split
engine: whole prompts in one step.

Policies (what runs when both prefill work and decode-ready slots exist):

* ``prefill-priority`` (default, the pre-split behaviour): drain every
  pending prefill chunk before decoding.  Best TTFT; under bursty arrivals
  decode gaps grow with the whole prefill backlog.
* ``fair``: strictly alternate — at most one prefill chunk between
  consecutive decode steps, so the worst-case decode gap is one chunk, not
  one backlog.  This is what makes chunked prefill's ITL bound real.
* ``fcfs``: run-to-completion in arrival order — in-flight requests decode
  to completion before any queued prompt is prefilled (the static-batching
  baseline: best ITL, worst TTFT).

Memory-aware mode (a :class:`~repro.serving.kv_pool.BlockPool` attached):

* **admission** gates on free blocks — a request enters a slot only when
  the pool can cover its first prefill chunk (plus any copy-on-write
  fork), after adopting whatever cached prefix blocks match its prompt;
* **chunked-prefill planning** allocates each chunk's blocks at plan time
  and shrinks the chunk to what the pool can hold right now;
* **prefix-cache hits** set the slot's initial progress *past* the cached
  prefix, so only the uncached suffix is ever planned (and charged by the
  virtual clock — the deterministic TTFT win);
* a full pool **preempts** the lowest-priority victim (latest arrival,
  ties to the larger request id): its blocks are released, the request is
  re-queued at the *front* with its generated tokens intact, and on
  re-admission it is re-planned as a prompt *extension*
  (``prompt + outputs[:-1]``) — recompute, not migration, so token streams
  are unchanged.  The engine stays live as long as the pool can hold one
  maximal request (validated at engine construction).
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field as dc_field
from typing import Deque, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.serving.kv_pool import SCRATCH_BLOCK, BlockPool, block_hashes
from repro.serving.request import Request

POLICIES = ("prefill-priority", "fair", "fcfs")


@dataclass
class SchedulerConfig:
    max_batch: int
    prefill_chunk: int = 0             # 0 = whole prompt in one step
    policy: str = "prefill-priority"   # prefill-priority | fair | fcfs
    batch_cap: Optional[int] = None    # TP weight-replication slot cap
    max_seq: int = 0                   # cache capacity (paged mode only)


def _check_policy(policy: str) -> None:
    if policy not in POLICIES:
        raise ValueError(
            f"unknown scheduling policy {policy!r}; expected one of "
            f"{POLICIES}")


# ------------------------------------------------------------------- plans

@dataclass(frozen=True)
class PrefillChunk:
    """Run sequence positions [start, start+length) of ``request`` (slot b).

    ``tokens`` carries the chunk's token ids (the *effective* sequence —
    after a preemption this is the prompt extended with the regenerated
    tokens, which ``request.prompt`` alone no longer covers).  ``copies``
    lists pending copy-on-write block forks ``(src, dst)`` the executor
    must apply before this chunk runs.
    """
    slot: int
    request: Request
    start: int
    length: int
    is_first: bool
    is_last: bool
    tokens: Optional[np.ndarray] = None
    copies: Tuple[Tuple[int, int], ...] = ()


@dataclass(frozen=True)
class DecodeBatch:
    """One decode step over the decode-ready slots."""
    slots: Tuple[int, ...]


@dataclass(frozen=True)
class Idle:
    """Nothing to do — sweep the clock forward."""


@dataclass
class _SlotKV:
    """Paged-mode per-slot state."""
    hashes: List[bytes] = dc_field(default_factory=list)
    n_prompt_blocks: int = 0           # full prompt blocks (hashable)
    registered: int = 0                # prompt blocks already published
    cached_len: int = 0                # prefix tokens adopted from the cache
    copies: List[Tuple[int, int]] = dc_field(default_factory=list)


# --------------------------------------------------------------- scheduler

class Scheduler:
    """Slot admission + step planning over a fixed slot pool, optionally
    memory-aware over a KV :class:`~repro.serving.kv_pool.BlockPool`."""

    def __init__(self, cfg: SchedulerConfig,
                 kv_pool: Optional[BlockPool] = None):
        _check_policy(cfg.policy)
        self.cfg = cfg
        self.kv = kv_pool
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * cfg.max_batch
        # per-slot sampling keys: fold_in(PRNGKey(sampling.seed), request_id)
        self.slot_keys = np.zeros((cfg.max_batch, 2), np.uint32)
        # slot -> sequence tokens already prefilled (present = mid-prefill,
        # i.e. NOT decode-ready); insertion order = admission order
        self._progress: Dict[int, int] = {}
        # slots held out of decode planning while their wave is in flight
        # (async exec mode: a dispatched slot must not be re-planned until
        # its completion event lands)
        self._held: set = set()
        self._last_was_prefill = False
        self.preemptions = 0
        if kv_pool is not None:
            if cfg.max_seq % kv_pool.block_size:
                raise ValueError(
                    f"max_seq={cfg.max_seq} must be a multiple of the KV "
                    f"block size {kv_pool.block_size}")
            self.max_blocks = cfg.max_seq // kv_pool.block_size
            self.block_tables = np.zeros((cfg.max_batch, self.max_blocks),
                                         np.int32)
            self._kvmeta: Dict[int, _SlotKV] = {}

    # ------------------------------------------------------------ control
    def set_policy(self, policy: str) -> None:
        _check_policy(policy)
        self.cfg.policy = policy

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def release(self, slot: int) -> None:
        """Free a slot whose request completed."""
        self.slots[slot] = None
        self._progress.pop(slot, None)
        self._held.discard(slot)
        if self.kv is not None:
            self._release_slot_kv(slot)

    def hold(self, slot: int) -> None:
        """Exclude a slot from decode planning (its decode wave is in
        flight on the async expert tier; the completion event unholds)."""
        self._held.add(slot)

    def unhold(self, slot: int) -> None:
        self._held.discard(slot)

    # ------------------------------------------------------------ signals
    def decode_ready(self) -> List[int]:
        return [b for b, r in enumerate(self.slots)
                if r is not None and b not in self._progress
                and b not in self._held]

    @staticmethod
    def _eff_len(req: Request) -> int:
        """Length of the sequence a slot must hold *before* decoding: the
        prompt plus any already-generated tokens except the last (which is
        the next decode step's input) — a plain prompt for fresh requests,
        the recompute target for preempted ones."""
        return len(req.prompt) + max(len(req.output_tokens) - 1, 0)

    @staticmethod
    def _eff_tokens(req: Request) -> np.ndarray:
        if not req.output_tokens:
            return np.asarray(req.prompt, np.int32)
        return np.concatenate([
            np.asarray(req.prompt, np.int32),
            np.asarray(req.output_tokens[:-1], np.int32)])

    def pending_prefill_tokens(self) -> int:
        """Sequence tokens not yet prefilled (queued + mid-chunk backlog) —
        the autoscaler's prefill-pressure signal."""
        queued = sum(self._eff_len(r) for r in self.queue)
        inflight = sum(self._eff_len(self.slots[b]) - done
                       for b, done in self._progress.items())
        return queued + inflight

    def kv_free_fraction(self) -> float:
        """Free-block fraction of the KV pool (1.0 when not paged) — the
        autoscaler's kv-pressure signal."""
        return self.kv.free_fraction() if self.kv is not None else 1.0

    def cache_length(self, slot: int) -> int:
        """Tokens a live slot's cache holds right now (paged lengths are
        host-authoritative; the engine passes them into each jitted step)."""
        r = self.slots[slot]
        if r is None:
            return 0
        if slot in self._progress:
            return self._progress[slot]
        return self._eff_len(r)

    def cache_lengths(self) -> np.ndarray:
        return np.asarray([self.cache_length(b)
                           for b in range(len(self.slots))], np.int32)

    # ----------------------------------------------------------- planning
    def _admit(self) -> None:
        cap = self.cfg.batch_cap
        for b in range(len(self.slots)):
            if cap is not None and b >= cap:
                break
            if self.slots[b] is None and self.queue:
                req = self.queue[0]
                if self.kv is not None and not self._admit_blocks(b, req):
                    break                  # head-of-line waits for memory
                self.queue.popleft()
                self.slots[b] = req
                # a prefix-cache hit starts progress past the cached prefix
                # (always < eff_len: a whole-sequence hit is capped one
                # token short by the copy-on-write fork, so prefill always
                # has logits to produce)
                self._progress[b] = (self._kvmeta[b].cached_len
                                     if self.kv is not None else 0)
                self.slot_keys[b] = np.asarray(jax.random.fold_in(
                    jax.random.PRNGKey(req.sampling.seed), req.request_id))

    def _chunk_plan(self) -> Optional[PrefillChunk]:
        b, done = next(iter(self._progress.items()))
        req = self.slots[b]
        total = self._eff_len(req)
        chunk = self.cfg.prefill_chunk or total
        length = min(chunk, total - done)
        copies: Tuple[Tuple[int, int], ...] = ()
        if self.kv is not None:
            length = self._ensure_prefill_blocks(b, done, length)
            if length == 0:
                return None
            meta = self._kvmeta[b]
            copies = tuple(meta.copies)
            meta.copies = []
            # the engine applies the COW data copies before this chunk runs,
            # with no allocation in between — safe to release the sources
            for src, _ in copies:
                self.kv.decref(src)
        tokens = self._eff_tokens(req)[done:done + length]
        return PrefillChunk(slot=b, request=req, start=done, length=length,
                            is_first=(done == (self._kvmeta[b].cached_len
                                               if self.kv is not None
                                               else 0)),
                            is_last=(done + length >= total),
                            tokens=tokens, copies=copies)

    def next_plan(self):
        """Admit what fits, then pick the next step per the active policy."""
        self._admit()
        pending = bool(self._progress)
        ready = self.decode_ready()
        policy = self.cfg.policy
        if pending and ready:
            if policy == "prefill-priority":
                do_prefill = True
            elif policy == "fcfs":
                do_prefill = False
            else:                        # fair: strict alternation
                do_prefill = not self._last_was_prefill
        else:
            do_prefill = pending
        if do_prefill:
            plan = self._chunk_plan()
            if plan is not None:
                self._last_was_prefill = True
                return plan
            ready = self.decode_ready()  # planning may have preempted
        self._last_was_prefill = False
        if ready:
            if self.kv is not None:
                ready = self._ensure_decode_blocks(ready)
            if ready:
                return DecodeBatch(slots=tuple(ready))
        return Idle()

    def prefill_advanced(self, slot: int, length: int) -> bool:
        """Record chunk completion; True when the slot became decode-ready."""
        self._progress[slot] += length
        done = self._progress[slot]
        if self.kv is not None:
            self._register_full_blocks(slot, done)
        if done >= self._eff_len(self.slots[slot]):
            del self._progress[slot]
            return True
        return False

    # ----------------------------------------------------- paged admission
    def _admit_blocks(self, slot: int, req: Request) -> bool:
        """Adopt cached prefix blocks and allocate the first chunk's fresh
        blocks for ``req``; False (nothing held) when the pool can't cover
        it yet."""
        kv, bs = self.kv, self.kv.block_size
        eff = self._eff_tokens(req)
        eff_len = len(eff)
        if eff_len > self.cfg.max_seq:
            raise ValueError(f"request {req.request_id} needs {eff_len} "
                             f"cache slots > max_seq={self.cfg.max_seq}")
        n_prompt_blocks = len(req.prompt) // bs
        hashes = block_hashes(req.prompt, bs)
        matched = kv.match_prefix(hashes)
        copies: List[Tuple[int, int]] = []
        if len(matched) * bs >= eff_len:
            # whole sequence cached: recompute at least the last position so
            # prefill produces logits — which *writes* into the final shared
            # block, so fork it (copy-on-write).  The match's reference on
            # the source block is kept until the executor applies the data
            # copy (released at plan handoff / slot release).
            dst = kv.fork(matched[-1])
            if dst is None:
                for bid in matched:
                    kv.decref(bid)
                return False
            copies.append((matched[-1], dst))
            matched[-1] = dst
            cached_len = eff_len - 1
        else:
            cached_len = len(matched) * bs
        # fresh blocks for the first prefill chunk past the cached prefix
        chunk = self.cfg.prefill_chunk or (eff_len - cached_len)
        first_end = min(cached_len + chunk, eff_len)
        n_have = len(matched)
        n_need = _ceil_div(first_end, bs) - n_have
        fresh = kv.allocate(n_need) if n_need > 0 else []
        if fresh is None:
            for bid in matched:
                kv.decref(bid)
            for src, _ in copies:
                kv.decref(src)
            return False
        row = self.block_tables[slot]
        row[:] = SCRATCH_BLOCK
        ids = matched + fresh
        row[:len(ids)] = ids
        self._kvmeta[slot] = _SlotKV(
            hashes=hashes, n_prompt_blocks=n_prompt_blocks,
            registered=min(len(matched), n_prompt_blocks),
            cached_len=cached_len, copies=copies)
        return True

    def _ensure_prefill_blocks(self, slot: int, done: int,
                               length: int) -> int:
        """Allocate blocks covering [done, done+length); shrink the chunk
        to what the pool can hold, preempting lower-priority slots when
        even one new token cannot be covered.  (The engine validates at
        construction that one maximal request fits the pool, so with every
        other slot preempted the allocation always succeeds.)

        A shrunk chunk length is a new jit shape for the executor — the
        same one-compile-per-distinct-chunk-length property the dense
        chunked-prefill path already has; the set stays small because
        shrink points are block-aligned coverage edges."""
        bs = self.kv.block_size
        row = self.block_tables[slot]
        while True:
            for idx in range(self._covered_until(slot) // bs,
                             _ceil_div(done + length, bs)):
                one = self.kv.allocate(1)
                if one is None:
                    break
                row[idx] = one[0]
            have = self._covered_until(slot)
            if have > done:
                return min(length, have - done)
            if self._preempt_lowest(exclude=slot) is None:
                return 0

    def _covered_until(self, slot: int) -> int:
        """First sequence position NOT covered by the slot's block table."""
        row = self.block_tables[slot]
        n = 0
        while n < self.max_blocks and row[n] != SCRATCH_BLOCK:
            n += 1
        return n * self.kv.block_size

    def _ensure_decode_blocks(self, ready: List[int]) -> List[int]:
        """Guarantee each decode-ready slot a block for the position it is
        about to write; preempt victims (dropping them from ``ready``)
        until the survivors fit."""
        bs = self.kv.block_size
        survivors = list(ready)
        for b in list(survivors):
            if b not in survivors:       # preempted as a victim meanwhile
                continue
            if self.slots[b] is None:
                survivors.remove(b)
                continue
            pos = self.cache_length(b)
            idx = pos // bs
            if idx >= self.max_blocks:
                # at cache capacity: the write clamps into the last block
                # (dense-cache behaviour) and the engine retires the
                # request right after this step
                continue
            row = self.block_tables[b]
            while row[idx] == SCRATCH_BLOCK:
                got = self.kv.allocate(1)
                if got is not None:
                    row[idx] = got[0]
                    break
                victim = self._preempt_lowest(exclude=b)
                if victim is None:
                    raise RuntimeError(
                        "KV pool cannot hold a single request — "
                        "num_blocks is below the per-request maximum")
                if victim in survivors:
                    survivors.remove(victim)
        return survivors

    # ---------------------------------------------------------- preemption
    def _preempt_lowest(self, exclude: int) -> Optional[int]:
        """Preempt the lowest-priority live slot (latest arrival, ties to
        the larger request id), excluding ``exclude``.  Returns the slot
        preempted, or None when no victim exists."""
        victims = [(r.arrival_time, r.request_id, b)
                   for b, r in enumerate(self.slots)
                   if r is not None and b != exclude]
        if not victims:
            return None
        _, _, b = max(victims)
        self.preempt(b)
        return b

    def preempt(self, slot: int) -> Request:
        """Release a slot's blocks and re-queue its request at the front
        (it keeps arrival priority); generated tokens ride along so the
        re-admitted request is re-planned as a prompt extension."""
        req = self.slots[slot]
        assert req is not None and self.kv is not None
        self._release_slot_kv(slot)
        self.slots[slot] = None
        self._progress.pop(slot, None)
        self.queue.appendleft(req)
        self.preemptions += 1
        return req

    def _release_slot_kv(self, slot: int) -> None:
        """Release a slot's table blocks plus any still-held COW sources
        (pending copies whose data never got applied)."""
        row = self.block_tables[slot]
        for bid in row:
            if bid != SCRATCH_BLOCK:
                self.kv.decref(int(bid))
        row[:] = SCRATCH_BLOCK
        meta = self._kvmeta.pop(slot, None)
        if meta is not None:
            for src, _ in meta.copies:
                self.kv.decref(src)

    def _register_full_blocks(self, slot: int, done: int) -> None:
        """Publish freshly completed full *prompt* blocks to the prefix
        cache (blocks holding generated tokens stay private)."""
        meta = self._kvmeta[slot]
        bs = self.kv.block_size
        upto = min(meta.n_prompt_blocks, done // bs)
        row = self.block_tables[slot]
        for j in range(meta.registered, upto):
            self.kv.register(int(row[j]), meta.hashes[j])
        meta.registered = max(meta.registered, upto)


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)
