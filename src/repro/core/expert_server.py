"""The stateless expert server (paper §3.3, Fig. 5).

A server aggregates every ready client slot into one dynamic batch,
reorganizes tokens by (local) expert, runs grouped GEMM over the active
groups only (group-shrink), weights by the router scores carried in the
payload, and writes the results back into the same slot layout.

The server is a *pure function* — it holds no sequence state and initiates
no communication (comm.py is invoked by the client side only).  That purity
is the paper's statelessness argument, and it is what makes replication,
failover and elastic scaling trivial.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import ops as kops
from repro.models.common import dense_init


class ServerWeights(NamedTuple):
    """One server's expert weights: primaries + redundant (replica) slots.

    Shapes (single server view):
      w_gate/w_up: (L, d, f)   w_down: (L, f, d)
    where L = E/S primaries + n_red redundant slots.
    ``local_table``: (E,) int32 — global expert id -> local slot (or -1).
    """

    w_gate: jax.Array
    w_up: jax.Array
    w_down: jax.Array
    local_table: jax.Array


def init_expert_weights(key, cfg: ModelConfig) -> Dict:
    """Global expert bank: (E, d, f) — sharded over the server axis at launch."""
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_expert, m.num_experts
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    ks = jax.random.split(key, 3)
    return {
        "w_gate": jax.vmap(lambda k: dense_init(k, d, f, dt))(
            jax.random.split(ks[0], E)),
        "w_up": jax.vmap(lambda k: dense_init(k, d, f, dt))(
            jax.random.split(ks[1], E)),
        "w_down": jax.vmap(lambda k: dense_init(k, f, d, dt))(
            jax.random.split(ks[2], E)),
    }


def _layout_ids(num_experts: int, num_servers: int,
                redundant_table: np.ndarray) -> np.ndarray:
    """(S, L) local-slot → global-expert-id layout (-1 = empty slot).

    Slots 0..E/S-1 are the block-contiguous primaries; the rest mirror
    ``redundant_table``.
    """
    E, S = num_experts, num_servers
    per = E // S
    assert per * S == E, (E, S)
    primary_ids = np.arange(E, dtype=np.int32).reshape(S, per)
    red = np.asarray(redundant_table, np.int32)              # (S, n_red)
    return np.concatenate([primary_ids, red], axis=1)        # (S, L)


def make_local_table(num_experts: int, num_servers: int,
                     redundant_table: np.ndarray) -> np.ndarray:
    """(S, E) global-expert-id → local-slot lookup (-1 = not hosted).

    Slots 0..E/S-1 are the block-contiguous primaries; the rest mirror
    ``redundant_table``.  This is *placement data* (runtime, not params):
    rebalancing rewrites it without touching the compiled program.
    """
    E, S = num_experts, num_servers
    local_ids = _layout_ids(E, S, redundant_table)
    local_table = np.full((S, E), -1, np.int32)
    for s in range(S):
        for slot, e in enumerate(local_ids[s]):
            if e >= 0 and local_table[s, e] < 0:
                local_table[s, e] = slot
    return local_table


def build_server_weights(bank: Dict, num_servers: int,
                         redundant_table: np.ndarray) -> Dict:
    """Materialize per-server weight arrays from the global bank.

    Returns stacked per-server arrays (S, L, ...) (shard dim0 over the server
    axis at launch).  Redundant slots are *copies* — replication costs
    memory, exactly as in the paper.
    """
    E = bank["w_gate"].shape[0]
    S = num_servers
    per = E // S
    assert per * S == E

    primary_ids = np.arange(E, dtype=np.int32).reshape(S, per)
    red = np.asarray(redundant_table, np.int32)              # (S, n_red)
    local_ids = np.concatenate([primary_ids, red], axis=1)   # (S, L)

    gather_ids = jnp.asarray(np.maximum(local_ids, 0))       # (S, L)
    mask = jnp.asarray(local_ids >= 0)[..., None, None]

    def per_server(w):
        return jnp.where(mask, w[gather_ids], 0)

    return {
        "w_gate": per_server(bank["w_gate"]),                # (S, L, d, f)
        "w_up": per_server(bank["w_up"]),
        "w_down": per_server(bank["w_down"]),
    }


def extract_bank(server_w: Dict, num_experts: int) -> Dict:
    """Recover the global (…, E, d, f) expert bank from per-server arrays.

    Inverse of :func:`build_server_weights` restricted to the primary slots
    (which are block-contiguous and never move — redundant slots are mere
    copies).  Accepts arbitrary leading dims (e.g. a scan-stacked layer
    axis): (…, S, L, d, f) → (…, E, d, f).
    """
    def un_shard(w):
        *lead, S, L, a, b = w.shape
        per = num_experts // S
        assert per * S == num_experts, (num_experts, S)
        return w[..., :per, :, :].reshape(*lead, num_experts, a, b)

    return {k: un_shard(v) for k, v in server_w.items()}


def redundant_slot(num_experts: int, num_servers: int, j: int) -> int:
    """Local slot index of redundant column ``j`` — slots 0..E/S-1 are the
    block-contiguous primaries (single owner of the layout knowledge in
    :func:`_layout_ids`; the rebalance paths build their weight-copy
    targets through this)."""
    return num_experts // num_servers + j


def replica_columns(redundant_table: np.ndarray,
                    expert: int) -> Tuple[Tuple[int, int], ...]:
    """``(server, column)`` positions of every replica slot holding
    ``expert`` in the redundant table, in deterministic row-major order —
    the scale-to-zero page-out scan (each hit becomes a
    ``(server, redundant_slot(...), -1)`` eviction update for
    :func:`migrate_slots`)."""
    red = np.asarray(redundant_table)
    return tuple((int(s), int(j)) for s, j in np.argwhere(red == expert))


def migrate_slots(server_w: Dict, num_experts: int,
                  updates) -> Dict:
    """Copy expert weights into specific server slots in place — the weight
    half of one incremental rebalance chunk (paper §4.5 live migration).

    updates: ``[(server, local_slot, expert_id)]``; ``expert_id == -1``
    zeroes the slot (replica dropped).  Sources are read straight from the
    block-contiguous primary slots (expert ``e`` lives at server ``e//per``
    slot ``e%per``), which never move and are disjoint from the redundant
    targets — so a chunk is O(chunk) data movement, not a bank rebuild,
    and chunks compose in any order.  Accepts arbitrary leading dims
    (scan-stacked layer axis), like the other weight-path helpers.
    """
    def apply(w):
        S = w.shape[-4]
        per = num_experts // S
        assert per * S == num_experts, (num_experts, S)
        for s, slot, e in updates:
            src = w[..., e // per, e % per, :, :] if e >= 0 else 0
            w = w.at[..., s, slot, :, :].set(src)
        return w

    return {k: apply(v) for k, v in server_w.items()}


def reshard_server_weights(server_w: Dict, num_experts: int,
                           new_servers: int,
                           redundant_table: np.ndarray) -> Dict:
    """Re-materialize per-server weights for a different pool size.

    This is elastic scaling's weight path (paper §5.3): the global bank is
    recovered from the primary slots and re-laid-out for ``new_servers``
    with the new replication plan.  Pure data movement — router / client
    params are untouched, expert math is bit-identical.
    """
    bank = extract_bank(server_w, num_experts)
    local_ids = _layout_ids(num_experts, new_servers, redundant_table)
    gather = jnp.asarray(np.maximum(local_ids, 0).reshape(-1))   # (S'*L',)
    mask = jnp.asarray(local_ids >= 0)[..., None, None]          # (S', L',1,1)

    def re_shard(w):
        *lead, E, a, b = w.shape
        g = jnp.take(w, gather, axis=-3)
        g = g.reshape(*lead, *local_ids.shape, a, b)
        return jnp.where(mask, g, 0)

    return {k: re_shard(v) for k, v in bank.items()}


class ServeStats(NamedTuple):
    miss: jax.Array           # tokens whose expert this server doesn't host
    served: jax.Array         # valid tokens processed


def serve(tokens: jax.Array, expert_ids: jax.Array, scores: jax.Array,
          counts: jax.Array, weights: ServerWeights, *,
          impl: str = "auto") -> Tuple[jax.Array, ServeStats]:
    """Process one aggregated dynamic batch on one server.

    tokens: (Clients, C, d) — the server's view of every client slot;
    expert_ids/scores: (Clients, C); counts: (Clients,) header.
    Returns (Clients, C, d) score-weighted outputs (zeros on invalid slots)
    and ServeStats.
    """
    Sc, C, d = tokens.shape
    M = Sc * C
    x = tokens.reshape(M, d)
    eid = expert_ids.reshape(M)
    sc = scores.reshape(M)
    valid = (jnp.arange(C)[None, :] < counts[:, None]).reshape(M)
    valid &= eid >= 0

    L = weights.w_gate.shape[0]
    slot = jnp.where(valid, weights.local_table[jnp.clip(eid, 0)], L)
    hosted = slot >= 0
    miss = jnp.sum(valid & ~hosted)
    slot = jnp.where(hosted, slot, L)                         # L = padding grp

    # ---- reorganize tokens by local expert (paper Fig. 5) --------------
    order = jnp.argsort(slot)                                 # stable
    xs = x[order]
    group_sizes = jnp.bincount(slot, length=L + 1)[:L]        # drop pad group

    # ---- grouped GEMM over active groups only (group-shrink) -----------
    # per-expert capacity for the dense lowering: ideal share × the buffer
    # capacity factor (under-provisioned experts drop, exactly like slots)
    ecap = max(8, ((-(-(M * 5) // (4 * L))) + 7) // 8 * 8)
    gg = lambda a, w: kops.grouped_gemm(a, w, group_sizes, impl=impl,
                                        expert_capacity=ecap)
    h_gate = gg(xs, weights.w_gate)
    h_up = gg(xs, weights.w_up)
    h = jax.nn.silu(h_gate.astype(jnp.float32)).astype(h_up.dtype) * h_up
    y = gg(h, weights.w_down)

    # ---- score weighting + masking, back to slot order ------------------
    y = y.astype(jnp.float32) * sc[order][:, None]
    in_group = jnp.arange(M) < jnp.sum(group_sizes)           # pad rows off
    y = jnp.where((valid[order] & hosted[order] & in_group)[:, None], y, 0)
    out = jnp.zeros((M, d), jnp.float32).at[order].set(y)
    out = out.reshape(Sc, C, d).astype(tokens.dtype)
    return out, ServeStats(miss=miss, served=jnp.sum(valid & hosted))
