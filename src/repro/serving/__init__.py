"""Serving runtime: continuous batching engine (SPMD, jitted), the
host-level physically-disaggregated engine (paper-literal buffer protocol),
and the deterministic scenario/autoscaling harness the paper's timeline
claims are tested with."""

from repro.serving.engine import ServingEngine, EngineConfig  # noqa: F401
from repro.serving.request import Request, SamplingParams  # noqa: F401
from repro.serving.clock import Clock, VirtualClock, WallClock  # noqa: F401
from repro.serving.scenario import Scenario, ScenarioResult  # noqa: F401
from repro.serving.autoscale import Autoscaler, AutoscalerConfig  # noqa: F401
