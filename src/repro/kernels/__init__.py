"""Pallas TPU kernels for the EAAS hot spots (DESIGN.md §6).

* :mod:`repro.kernels.grouped_gemm` — expert-server grouped GEMM with
  group-shrink (the paper's §4.1 kernel).
* :mod:`repro.kernels.decode_attention` — flash-decode GQA attention,
  dense and paged (K/V gathered through a block table via scalar-prefetch
  index maps).
* :mod:`repro.kernels.combine` — fused top-k combine epilogue.
* :mod:`repro.kernels.ops` — jit wrappers + CPU lowerings.
* :mod:`repro.kernels.ref` — pure-jnp oracles.
* :mod:`repro.kernels.compat` — Pallas API shims across jax versions.
"""

from repro.kernels import ops, ref  # noqa: F401
