"""Benchmark harness entry point — a registry, one entry per paper
table/figure.

Default mode prints ``name,us_per_call,derived`` CSV for every registered
suite (heavy figures skipped with REPRO_BENCH_FAST=1 — CI smoke).

``--smoke`` runs each registered *smoke* configuration instead (the short
deterministic run that writes ``experiments/bench/<name>.json`` with a
``gate`` object); with ``--gated`` it is restricted to benchmarks that
have a committed baseline under ``experiments/baselines/``.  This is the
CI regression lane: a new benchmark enrolls by (a) registering here with
a ``smoke`` runner and (b) committing a baseline — no workflow edit.

    python benchmarks/run.py --smoke --gated     # run every gated smoke
    python tools/check_bench.py --all            # then gate them all
"""

import argparse
import os
import sys
import traceback
from dataclasses import dataclass
from typing import Callable, List, Optional

BASELINES_DIR = os.path.join(os.path.dirname(__file__), "..",
                             "experiments", "baselines")


@dataclass(frozen=True)
class Bench:
    """One registered benchmark suite.

    ``main`` is the full CSV run; ``smoke`` (optional) is the short
    deterministic run that writes ``experiments/bench/<name>.json`` with
    a ``gate`` object.  ``heavy`` suites are skipped under
    REPRO_BENCH_FAST=1.
    """
    name: str
    main: Callable[[], List[str]]
    smoke: Optional[Callable[[], dict]] = None
    heavy: bool = False

    @property
    def gated(self) -> bool:
        """Enrolled in the CI regression lane: has a smoke runner AND a
        committed baseline (registration alone keeps it smoke-only)."""
        return self.smoke is not None and os.path.exists(
            os.path.join(BASELINES_DIR, f"{self.name}.json"))


def registry() -> List[Bench]:
    from benchmarks import (ablation, async_tier, comm, elasticity,
                            expert_balance, fault_tolerance,
                            frontend_routing, latency, overlap_ablation,
                            paged_kv, roofline, scaling, throughput)
    return [
        Bench("fig8_throughput", throughput.main, heavy=True),
        Bench("fig8_overlap_ablation", overlap_ablation.main, heavy=True),
        Bench("fig9_latency", latency.main, heavy=True),
        Bench("fig10_fault_tolerance", fault_tolerance.main, heavy=True),
        Bench("fig11_scaling", scaling.main, heavy=True),
        Bench("paged_kv", paged_kv.main,
              smoke=lambda: paged_kv.run(smoke=True), heavy=True),
        Bench("expert_balance", expert_balance.main,
              smoke=lambda: expert_balance.run(smoke=True), heavy=True),
        Bench("frontend_routing", frontend_routing.main,
              smoke=lambda: frontend_routing.run(smoke=True), heavy=True),
        Bench("async_tier", async_tier.main,
              smoke=lambda: async_tier.run(smoke=True), heavy=True),
        Bench("elasticity", elasticity.main,
              smoke=lambda: elasticity.run(smoke=True), heavy=True),
        Bench("fig12_comm", comm.main),
        Bench("fig13_ablation", ablation.main),
        Bench("roofline", roofline.main),
    ]


def run_smokes(benches: List[Bench]) -> int:
    failures = 0
    for b in benches:
        print(f"== {b.name} (smoke) ==", flush=True)
        try:
            b.smoke()
        except Exception as e:
            failures += 1
            print(f"{b.name}: ERROR:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    return failures


def run_csv(benches: List[Bench]) -> int:
    print("name,us_per_call,derived")
    failures = 0
    for b in benches:
        try:
            for row in b.main():
                print(row)
        except Exception as e:
            failures += 1
            print(f"{b.name},nan,ERROR:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    return failures


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="run registered smoke configurations (writes "
                         "experiments/bench/<name>.json) instead of the "
                         "full CSV suites")
    ap.add_argument("--gated", action="store_true",
                    help="restrict to benchmarks with a committed "
                         "baseline under experiments/baselines/")
    ap.add_argument("--only", default="",
                    help="comma-separated benchmark names to run")
    ap.add_argument("--list", action="store_true",
                    help="print the registry (name, smoke?, gated?) and "
                         "exit")
    args = ap.parse_args(argv)

    benches = registry()
    if args.list:
        for b in benches:
            print(f"{b.name},smoke={int(b.smoke is not None)},"
                  f"gated={int(b.gated)}")
        return
    if args.only:
        names = {n.strip() for n in args.only.split(",") if n.strip()}
        unknown = names - {b.name for b in benches}
        if unknown:
            raise SystemExit(f"unknown benchmark(s): {sorted(unknown)}")
        benches = [b for b in benches if b.name in names]
    if args.gated:
        benches = [b for b in benches if b.gated]
    if args.smoke:
        benches = [b for b in benches if b.smoke is not None]
        failures = run_smokes(benches)
    else:
        fast = os.environ.get("REPRO_BENCH_FAST", "0") == "1"
        if fast:
            benches = [b for b in benches if not b.heavy]
        failures = run_csv(benches)
    if failures:
        raise SystemExit(f"{failures} benchmark suites failed")


if __name__ == "__main__":
    main()
