"""Throughput / latency meters for the serving benchmarks."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List

import numpy as np


@dataclass
class ServingMetrics:
    total_requests: int = 0
    completed: int = 0
    total_output_tokens: int = 0
    wall_time: float = 0.0
    itls: List[float] = field(default_factory=list)
    events: List[Dict] = field(default_factory=list)
    # per-interval decode throughput (for the fault-tolerance timeline)
    timeline: List[Dict] = field(default_factory=list)

    @property
    def decode_throughput(self) -> float:
        """Output tokens per second."""
        return self.total_output_tokens / max(self.wall_time, 1e-9)

    def itl_stats(self) -> Dict[str, float]:
        if not self.itls:
            return {"mean": 0.0, "p50": 0.0, "p99": 0.0}
        a = np.asarray(self.itls)
        return {"mean": float(a.mean()),
                "p50": float(np.percentile(a, 50)),
                "p99": float(np.percentile(a, 99))}

    def summary(self) -> Dict:
        return {
            "requests": self.total_requests,
            "completed": self.completed,
            "output_tokens": self.total_output_tokens,
            "wall_time_s": round(self.wall_time, 3),
            "decode_tok_per_s": round(self.decode_throughput, 2),
            "itl": {k: round(v * 1e3, 3) for k, v in self.itl_stats().items()},
        }
