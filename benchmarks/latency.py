"""Paper Fig. 9 — decoding throughput vs inter-token latency."""

from __future__ import annotations

from typing import Dict, List

from benchmarks.common import (bench_model_cfg, csv_row, make_requests,
                               run_engine, save_result)
from repro.serving import EngineConfig


def run(loads: List[int] = (8, 16, 32), max_new: int = 12) -> Dict:
    cfg = bench_model_cfg()
    out = {"figure": "fig9_latency", "modes": {}}
    for mode in ("eaas", "monolithic_ep", "tp"):
        pts = []
        for load in loads:
            ecfg = EngineConfig(mode=mode, num_servers=4, max_batch=4,
                                max_seq=64, tp_batch_cap=2, n_redundant=2)
            reqs = make_requests(load, max_new=max_new, vocab=cfg.vocab_size)
            _, m = run_engine(cfg, ecfg, reqs)
            pts.append({"load": load, "tok_per_s": m.decode_throughput,
                        **{f"itl_{k}": v for k, v in m.itl_stats().items()}})
        out["modes"][mode] = pts
    save_result("fig9_latency", out)
    return out


def main() -> List[str]:
    res = run()
    rows = []
    for mode, pts in res["modes"].items():
        best = max(pts, key=lambda p: p["tok_per_s"])
        rows.append(csv_row(
            f"fig9_{mode}", best["itl_mean"] * 1e6,
            f"tok_per_s={best['tok_per_s']:.2f};itl_p99_ms="
            f"{best['itl_p99']*1e3:.2f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
