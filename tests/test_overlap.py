"""Double-batch overlap (paper §4.2): schedule invariants and numerical
equivalence of the overlapped vs. serialized program structures."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.overlap import (double_batch_overlap, microbatch_schedule,
                                split_batch_decode)


# ----------------------------------------------------- microbatch_schedule

@pytest.mark.parametrize("n", [1, 2, 3, 5, 8])
def test_schedule_phase_order_per_microbatch(n):
    """Every microbatch runs attention -> dispatch -> combine, exactly once
    each."""
    steps = microbatch_schedule(n)
    for mb in range(n):
        phases = [ph for (i, ph) in steps if i == mb]
        assert phases == ["attention", "dispatch", "combine"], (mb, phases)


@pytest.mark.parametrize("n", [2, 3, 5, 8])
def test_schedule_overlaps_attention_with_expert_round_trip(n):
    """The pipelining property: attention(i+1) is issued after dispatch(i)
    but before combine(i) — the expert round-trip of microbatch i is hidden
    behind the next microbatch's attention."""
    steps = microbatch_schedule(n)
    pos = {(mb, ph): t for t, (mb, ph) in enumerate(steps)}
    for i in range(n - 1):
        assert pos[(i, "dispatch")] < pos[(i + 1, "attention")] \
            < pos[(i, "combine")]


def test_schedule_starts_and_ends_clean():
    steps = microbatch_schedule(3)
    assert steps[0] == (0, "attention")
    assert steps[-1] == (2, "combine")
    assert len(steps) == 3 * 3


# --------------------------------------------------- double_batch_overlap

def _toy_fns(key, d=16):
    k1, k2 = jax.random.split(key)
    wd = jax.random.normal(k1, (d, d), jnp.float32) * 0.1
    wm = jax.random.normal(k2, (d, d), jnp.float32) * 0.1
    dense = lambda a: jnp.tanh(a @ wd)
    moe = lambda a: a + jax.nn.gelu(a @ wm)
    return dense, moe


def test_double_batch_overlap_matches_serialized():
    """enabled=True and enabled=False are the same math — the zero-valued
    coupling must not perturb a single bit."""
    dense, moe = _toy_fns(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16), jnp.float32)
    y_overlap = jax.jit(
        lambda a: double_batch_overlap(dense, moe, a, enabled=True))(x)
    y_serial = jax.jit(
        lambda a: double_batch_overlap(dense, moe, a, enabled=False))(x)
    np.testing.assert_array_equal(np.asarray(y_overlap),
                                  np.asarray(y_serial))


def test_double_batch_overlap_matches_unsplit():
    dense, moe = _toy_fns(jax.random.PRNGKey(2))
    x = jax.random.normal(jax.random.PRNGKey(3), (6, 16), jnp.float32)
    y = double_batch_overlap(dense, moe, x, enabled=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(moe(dense(x))),
                               rtol=1e-6)


def test_double_batch_overlap_rejects_odd_batch():
    dense, moe = _toy_fns(jax.random.PRNGKey(4))
    x = jnp.zeros((5, 16), jnp.float32)
    with pytest.raises(AssertionError):
        double_batch_overlap(dense, moe, x)


# ----------------------------------------------------- split_batch_decode

def test_split_batch_decode_matches_full_step():
    """The engine-level two-microbatch decode: same logits, same updated
    state, summed stats — with the state batch axis not at position 0."""
    w = jax.random.normal(jax.random.PRNGKey(5), (16, 16), jnp.float32) * 0.1

    def step(tokens, state):
        # toy "decode": state is {"cache": (layers, B, d)} with batch axis 1
        x = jax.nn.one_hot(tokens[:, 0], 16) @ w
        new_cache = state["cache"] + x[None]
        logits = new_cache.sum(0)
        stats = {"load": jnp.sum(tokens, dtype=jnp.int32)}
        return logits, {"cache": new_cache}, stats

    tokens = jnp.arange(8, dtype=jnp.int32)[:, None] % 16
    state = {"cache": jax.random.normal(jax.random.PRNGKey(6), (3, 8, 16))}
    l_full, s_full, st_full = step(tokens, state)
    for enabled in (True, False):
        l_sp, s_sp, st_sp = split_batch_decode(step, tokens, state,
                                               axis=1, enabled=enabled)
        np.testing.assert_array_equal(np.asarray(l_full), np.asarray(l_sp))
        np.testing.assert_array_equal(np.asarray(s_full["cache"]),
                                      np.asarray(s_sp["cache"]))
        assert int(st_sp["load"]) == int(st_full["load"])
