"""Paper Fig. 12 — communication-library round-trip latency.

Client sends a (batch, 1, d_model) tensor to servers; servers echo it back.
Two implementations:

* ``eaas``      — the buffer-protocol exchange compiled into ONE jitted
  program (GPU-initiated, CPU-free: the IBGDA analogue — zero host
  involvement per round trip).
* ``cpu_staged`` — StepMesh/GDRCopy analogue: the host mediates every hop
  (device→host→device per direction), modeling CPU-controlled comm.

Symmetric (2 clients / 2 servers) and asymmetric (1 client / 3 servers)
settings, matching the paper's §5.5 experiment.
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, save_result

D_MODEL = 7168          # the paper uses DeepSeek-R1 decode shape (b, 1, 7168)


def _round_trip_jit(n_clients: int, n_servers: int):
    """One-program round trip: slot-pack → serve(echo) → return → combine."""

    @jax.jit
    def rt(x):                        # x: (n_clients, B, d)
        # client write: each client splits its batch across server slots
        B = x.shape[1]
        per = max(B // n_servers, 1)
        slots = x[:, :n_servers * per].reshape(
            x.shape[0], n_servers, per, x.shape[2])
        # server processes (echo) — transpose = the a2a transfer
        recv = jnp.swapaxes(slots, 0, 1)          # (S, C_clients, per, d)
        served = recv * 1.0                       # stateless echo
        back = jnp.swapaxes(served, 0, 1)
        return back.reshape(x.shape[0], n_servers * per, x.shape[2])

    return rt


def _round_trip_cpu_staged(n_clients: int, n_servers: int):
    """Host-mediated: device→host→device on each hop (CPU-controlled)."""
    dev = jax.devices()[0]

    def rt(x):
        host = np.asarray(x)                       # D2H (client write)
        per = max(host.shape[1] // n_servers, 1)
        slots = host[:, :n_servers * per].reshape(
            host.shape[0], n_servers, per, host.shape[2])
        recv = np.swapaxes(slots, 0, 1).copy()
        served_dev = jax.device_put(recv, dev)     # H2D (server read)
        served = np.asarray(served_dev * 1.0)      # compute + D2H
        back = np.swapaxes(served, 0, 1).copy()
        out = jax.device_put(back.reshape(host.shape[0], n_servers * per,
                                          host.shape[2]), dev)
        return out

    return rt


def _time(fn, x, iters: int = 20) -> float:
    y = fn(x)
    if hasattr(y, "block_until_ready"):
        y.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        y = fn(x)
    if hasattr(y, "block_until_ready"):
        y.block_until_ready()
    return (time.perf_counter() - t0) / iters


def run(batch_sizes: List[int] = (16, 64, 128, 256, 512)) -> Dict:
    out = {"figure": "fig12_comm", "scenarios": {}}
    for name, (nc, ns) in {"symmetric": (2, 2),
                           "asymmetric": (1, 3)}.items():
        pts = []
        jit_rt = _round_trip_jit(nc, ns)
        cpu_rt = _round_trip_cpu_staged(nc, ns)
        for b in batch_sizes:
            x = jnp.ones((nc, b, D_MODEL), jnp.bfloat16)
            t_eaas = _time(jit_rt, x)
            t_cpu = _time(cpu_rt, x)
            pts.append({"batch": b, "eaas_us": t_eaas * 1e6,
                        "cpu_staged_us": t_cpu * 1e6,
                        "reduction_pct": 100 * (1 - t_eaas / t_cpu)})
        out["scenarios"][name] = pts
    save_result("fig12_comm", out)
    return out


def main() -> List[str]:
    res = run()
    rows = []
    for name, pts in res["scenarios"].items():
        p = pts[-1]          # batch 512, the paper's headline point
        rows.append(csv_row(f"fig12_{name}", p["eaas_us"],
                            f"reduction_vs_cpu={p['reduction_pct']:.1f}pct"))
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
