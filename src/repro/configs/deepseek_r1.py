"""deepseek_r1 — DeepSeek-R1 671B (the EAAS paper's evaluation model).

[arXiv:2412.19437 / 2501.12948]  61L, 256 routed experts top-8 + 1 shared,
first 3 layers dense, sigmoid gating.  NOTE: DeepSeek uses MLA attention; this
substrate models attention as GQA (kv=8) of matched KV-cache footprint — the
EAAS technique concerns the MoE/FFN tier, which is reproduced exactly.
This config is *additional* to the 10 assigned archs (used by the paper-figure
benchmarks); it is not one of the 40 graded dry-run cells.
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="deepseek-r1",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=129280,
    d_head=64,
    rope_theta=10000.0,
    activation="swiglu",
    moe=MoEConfig(
        num_experts=256,
        top_k=8,
        d_expert=2048,
        num_shared_experts=1,
        first_k_dense=3,
        router_score_fn="sigmoid",
        normalize_topk=True,
    ),
    subquadratic=False,
    source="arXiv:2412.19437",
)
