"""Serving runtime: continuous batching engine (SPMD, jitted) and the
host-level physically-disaggregated engine (paper-literal buffer protocol)."""

from repro.serving.engine import ServingEngine, EngineConfig  # noqa: F401
from repro.serving.request import Request, SamplingParams  # noqa: F401
