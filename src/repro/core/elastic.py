"""Elastic scaling of the expert-service tier (paper §5.3).

Monolithic EP scales in units of whole communication groups; EAAS scales one
server at a time.  On TPU the *logical* server pool (mapping table) changes
freely at runtime; the *physical* mesh changes through AOT-compiled variants
(jit caches one executable per server-count).  This module provides:

* :class:`ServerPool` — host-side pool with add/remove/rebalance, emitting
  fresh MoERuntime arrays each change (no recompile for liveness/mapping
  changes; recompile only when the physical mesh itself grows).
* :func:`provision` — the traffic→server-count policy used by the weak-
  scaling benchmark (the paper's 37.5% saving comes from this curve).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import load_balance
from repro.core.mapping import ExpertServerMap
from repro.core.moe_layer import MoERuntime, default_capacity


@dataclass
class ServerPool:
    """Logical expert-server pool with liveness + replication state."""

    cfg: ModelConfig
    num_servers: int
    tokens_per_client: int
    n_redundant: int = 2
    max_replicas: int = 4
    stats: load_balance.ExpertStats = None
    smap: ExpertServerMap = None
    redundant_table: np.ndarray = None

    def __post_init__(self):
        E = self.cfg.moe.num_experts
        self.stats = load_balance.ExpertStats(E)
        mapping, red = load_balance.eplb_plan(
            np.ones(E), self.num_servers, self.n_redundant,
            self.max_replicas)
        self.smap = ExpertServerMap(mapping, self.num_servers)
        self.redundant_table = red

    # ------------------------------------------------------------- events
    def server_failed(self, rank: int) -> None:
        self.smap.mark_dead(rank)

    def server_recovered(self, rank: int) -> None:
        self.smap.mark_alive(rank)

    def observe_load(self, expert_load: np.ndarray) -> None:
        self.stats.update(expert_load)

    def rebalance(self) -> None:
        """Re-plan replication from traffic EMA (paper §4.5 / EPLB)."""
        load = self.stats.ema if self.stats.ema is not None else None
        if load is None:
            return
        mapping, red = load_balance.eplb_plan(
            load, self.num_servers, self.n_redundant, self.max_replicas)
        alive = self.smap.alive.copy()
        self.smap = ExpertServerMap(mapping, self.num_servers)
        self.smap.alive = alive
        self.redundant_table = red

    # ------------------------------------------------------------- elastic
    def feasible_counts(self) -> List[int]:
        """Pool sizes the block-contiguous primary layout supports (E % n == 0)."""
        E = self.cfg.moe.num_experts
        return [n for n in range(1, E + 1) if E % n == 0]

    def scale_to(self, n: int) -> None:
        """Grow/shrink the logical pool to ``n`` servers (paper §5.3).

        Re-plans the EPLB mapping for the new size from the traffic EMA
        (uniform load when no traffic has been observed yet) and preserves
        the liveness mask of surviving ranks; newly added ranks start
        alive.  The caller owns the weight path — see
        :func:`repro.core.expert_server.reshard_server_weights`.
        """
        E = self.cfg.moe.num_experts
        if E % n:
            raise ValueError(
                f"cannot scale to {n} servers: {E} experts need E % n == 0 "
                f"(feasible: {self.feasible_counts()})")
        if n == self.num_servers:
            return
        load = self.stats.ema if self.stats.ema is not None else np.ones(E)
        mapping, red = load_balance.eplb_plan(
            load, n, self.n_redundant, self.max_replicas)
        old_alive = self.smap.alive
        self.num_servers = n
        self.smap = ExpertServerMap(mapping, n)
        k = min(len(old_alive), n)
        self.smap.alive[:k] = old_alive[:k]
        self.redundant_table = red

    # ------------------------------------------------------------ runtime
    def runtime(self, gemm_impl: str = "auto") -> MoERuntime:
        from repro.core import expert_server
        table, alive = self.smap.device_arrays()
        m = self.cfg.moe
        local = expert_server.make_local_table(
            m.num_experts, self.num_servers, self.redundant_table)
        return MoERuntime(
            mapping=table,
            alive=alive,
            local_table=jnp.asarray(local),
            num_servers=self.num_servers,
            capacity=default_capacity(self.tokens_per_client, m.top_k,
                                      self.num_servers, m.capacity_factor),
            gemm_impl=gemm_impl,
        )


def provision(request_rate: float, rate_per_server: float,
              granularity: int = 1) -> int:
    """Servers needed for a traffic level, at EAAS (1) vs monolithic (group)
    granularity.  The scaling benchmark sweeps this for both."""
    need = max(1, math.ceil(request_rate / max(rate_per_server, 1e-9)))
    return int(math.ceil(need / granularity) * granularity)


def resource_saving(request_rate: float, rate_per_server: float,
                    monolithic_group: int) -> float:
    """Fraction of chips EAAS saves vs group-granular scaling (paper: 37.5%)."""
    fine = provision(request_rate, rate_per_server, 1)
    coarse = provision(request_rate, rate_per_server, monolithic_group)
    return 1.0 - fine / coarse
