"""whisper-base — OpenAI Whisper base (encoder-decoder, conv frontend stubbed).

[arXiv:2212.04356; unverified]  The transformer backbone only; the mel/conv
frontend is a stub — ``input_specs()`` supplies precomputed frame embeddings
of shape (batch, 1500, d_model).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-base",
    family="audio",
    num_layers=6,                  # decoder layers
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    d_head=64,
    rope_theta=10000.0,            # (whisper uses learned/sinusoidal; backbone sub)
    activation="gelu",
    is_encoder_decoder=True,
    num_encoder_layers=6,
    encoder_seq_len=1500,
    frontend="audio_frames",
    tie_embeddings=True,
    subquadratic=False,
    source="arXiv:2212.04356",
)
