"""Qwen2-VL frontend stub + M-RoPE position builders.

The ViT patch encoder is stubbed (DESIGN.md): the backbone consumes token
embeddings plus 3-stream M-RoPE position ids.  This module builds the
(t, h, w) position grids for image patches placed in a text sequence —
the piece of Qwen2-VL that actually interacts with the backbone.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def image_mrope_positions(text_len_before: int, grid_h: int, grid_w: int,
                          text_len_after: int) -> jax.Array:
    """(3, seq) position ids for [text, image(h×w patches), text].

    Text tokens advance all three streams together; image patches share one
    temporal position while h/w advance over the grid (Qwen2-VL §2.1).
    """
    t0 = text_len_before
    txt0 = jnp.arange(t0, dtype=jnp.int32)
    pre = jnp.stack([txt0, txt0, txt0])

    hh, ww = jnp.meshgrid(jnp.arange(grid_h, dtype=jnp.int32),
                          jnp.arange(grid_w, dtype=jnp.int32), indexing="ij")
    n_patch = grid_h * grid_w
    img = jnp.stack([jnp.full((n_patch,), t0, jnp.int32),
                     (t0 + hh.reshape(-1)).astype(jnp.int32),
                     (t0 + ww.reshape(-1)).astype(jnp.int32)])

    # text after the image resumes from max position + 1
    t1 = t0 + max(grid_h, grid_w)
    txt1 = jnp.arange(t1, t1 + text_len_after, dtype=jnp.int32)
    post = jnp.stack([txt1, txt1, txt1])
    return jnp.concatenate([pre, img, post], axis=1)


def patch_embeddings(cfg: ModelConfig, batch: int, n_patches: int,
                     seed: int = 0) -> jax.Array:
    """Precomputed ViT patch embedding stand-in: (B, n_patches, d_model)."""
    key = jax.random.PRNGKey(seed)
    return jax.random.normal(key, (batch, n_patches, cfg.d_model),
                             jnp.float32) * 0.1
