"""Fault-tolerance protocol (paper §3.4, Fig. 6): monitor heartbeats, buffer
release, registration; engine-level failover vs monolithic halt."""

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.monitor import (Monitor, SharedBuffer, STATE_CLIENT_WRITE_DONE,
                                STATE_EMPTY, STATE_OFFLINE, STATE_SERVER_DONE)
from repro.serving import EngineConfig, Request, SamplingParams, ServingEngine


# ----------------------------------------------------------------- monitor

def test_monitor_detects_timeout_and_notifies():
    mon = Monitor(heartbeat_timeout=2.0)
    downs = []
    mon.subscribe_server_down(downs.append)
    mon.register("srv0", "server", t=0.0, experts=(0, 1), server_rank=0)
    mon.register("srv1", "server", t=0.0, experts=(2, 3), server_rank=1)
    mon.heartbeat("srv0", 1.0)
    mon.heartbeat("srv1", 1.0)
    assert mon.tick(2.5) == []
    mon.heartbeat("srv0", 3.0)            # srv1 goes silent
    dead = mon.tick(3.5)
    assert dead == ["srv1"] and downs == [1]
    assert mon.alive_servers() == {0}


def test_monitor_reregistration_recovers():
    mon = Monitor(heartbeat_timeout=1.0)
    ups = []
    mon.subscribe_server_up(lambda w: ups.append(w.server_rank))
    mon.register("srv0", "server", t=0.0, server_rank=0)
    mon.tick(5.0)
    assert mon.alive_servers() == set()
    mon.register("srv0", "server", t=6.0, server_rank=0)   # simple re-register
    assert mon.alive_servers() == {0}
    assert ups == [0, 0]


def test_client_failure_releases_buffer():
    """Paper Fig. 6 ①: server releases a dead client's buffer slot."""
    mon = Monitor(heartbeat_timeout=1.0)
    buf = SharedBuffer(capacity=4, d_model=8)
    mon.subscribe_client_down(lambda cid: buf.release())
    mon.register("client0", "client", t=0.0)
    buf.write_request(0, np.ones((2, 8)), np.zeros(2, np.int32),
                      np.ones(2))
    assert buf.state == STATE_CLIENT_WRITE_DONE
    mon.tick(3.0)
    assert buf.state == STATE_OFFLINE


# ----------------------------------------------------- buffer state machine

def test_shared_buffer_protocol_roundtrip():
    buf = SharedBuffer(capacity=4, d_model=3)
    assert buf.state == STATE_EMPTY
    assert buf.try_read_result() is None
    h = np.arange(6, dtype=np.float32).reshape(2, 3)
    buf.write_request(layer_id=5, hidden=h,
                      expert_id=np.array([1, 2], np.int32),
                      score=np.array([0.5, 0.5], np.float32))
    assert buf.poll()
    layer_id, hid, eid, sc = buf.take_request()
    assert layer_id == 5
    np.testing.assert_array_equal(hid, h)
    buf.write_result(hid * 2)
    assert buf.state == STATE_SERVER_DONE
    out = buf.try_read_result()
    np.testing.assert_array_equal(out, h * 2)
    assert buf.state == STATE_EMPTY          # slot recycled


def test_shared_buffer_rejects_overwrite():
    buf = SharedBuffer(capacity=2, d_model=2)
    buf.write_request(0, np.zeros((1, 2)), np.zeros(1, np.int32),
                      np.zeros(1))
    with pytest.raises(AssertionError):
        buf.write_request(0, np.zeros((1, 2)), np.zeros(1, np.int32),
                          np.zeros(1))


# ------------------------------------------------------------ engine level

def _requests(n, cfg, max_new=8):
    rng = np.random.default_rng(0)
    return [Request(i, rng.integers(0, cfg.vocab_size, size=6).astype(
        np.int32), SamplingParams(max_new_tokens=max_new)) for i in range(n)]


def test_engine_eaas_survives_failure():
    cfg = get_config("deepseek-r1").reduced()
    ecfg = EngineConfig(mode="eaas", num_servers=4, max_batch=2, max_seq=48,
                        n_redundant=2)
    eng = ServingEngine(cfg, ecfg)
    for r in _requests(4, cfg):
        eng.submit(r)
    eng.run(max_steps=20)                      # mid-flight
    eng.inject_server_failure(1)
    m = eng.run(max_steps=500)
    assert m.completed == 4
    assert not any(t.get("halted") for t in m.timeline)


def test_engine_monolithic_halts_on_failure():
    cfg = get_config("deepseek-r1").reduced()
    ecfg = EngineConfig(mode="monolithic_ep", num_servers=4, max_batch=2,
                        max_seq=48, restart_steps=15)
    eng = ServingEngine(cfg, ecfg)
    for r in _requests(4, cfg):
        eng.submit(r)
    eng.run(max_steps=10)
    eng.inject_server_failure(0)
    m = eng.run(max_steps=800)
    halted = [t for t in m.timeline if t.get("halted")]
    assert len(halted) == 15                  # full group restart window
    assert m.completed == 4                   # …but it does recover after
