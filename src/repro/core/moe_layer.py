"""EaasMoELayer — the paper's contribution as a composable JAX module.

One function, three execution modes (DESIGN.md §2):

* ``axis_name=None``  — single-device simulation: the S logical servers are
  vmapped.  Used by CPU tests, the host-level serving engine and examples.
* ``mode="a2a"``      — SPMD inside shard_map: tokens sharded over the server
  axis; one all_to_all each way (train / prefill).
* ``mode="replicated"`` — SPMD decode: activations replicated over the server
  axis; zero request traffic, one psum to combine.

The full flow mirrors paper Fig. 4(b):

    router → mapping lookup (replica choice, liveness) → pack into
    per-server buffer slots → send → server: aggregate + grouped GEMM
    (group-shrink) + score-weight → return → combine (+ shared experts /
    dense residual on the client).
"""

from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, MoEConfig
from repro.core import comm, dispatch, expert_server, mapping as emap, router
from repro.core.expert_server import ServerWeights


class MoERuntime(NamedTuple):
    """Runtime (non-compiled) state of the expert-service tier.

    Everything here is *data*: replacing these arrays re-routes traffic
    without touching the compiled program (failover / rebalance / scale).
    """

    mapping: jax.Array         # (E, R) int32 candidate server per replica
    alive: jax.Array           # (S,) bool server liveness
    local_table: jax.Array     # (S, E) int32 global eid -> server-local slot
    num_servers: int           # static: logical server count
    capacity: int              # static: tokens per (client, server) slot
    dispatch_method: str = "onehot"   # "onehot" | "sort"
    gemm_impl: str = "auto"
    # (E,) fp32 router-logit offset (traffic shaping — scenario set_skew);
    # None = unbiased.  Data like the mapping: rewriting it never recompiles.
    route_bias: Optional[jax.Array] = None
    # (S,) fp32 relative server capacities — replica picks spread tokens
    # proportionally to these (heterogeneous pools, paper §4.5 degree of
    # freedom 3); None = homogeneous, uniform spreading (bit-identical to
    # the pre-capacity behaviour).
    replica_weights: Optional[jax.Array] = None


class MoEStats(NamedTuple):
    aux_loss: jax.Array
    z_loss: jax.Array
    dropped: jax.Array         # tokens over slot capacity
    miss: jax.Array            # tokens sent to a server not hosting them
    expert_load: jax.Array     # (E,) token counts (feeds the load balancer)


def default_capacity(tokens_per_client: int, top_k: int, num_servers: int,
                     capacity_factor: float) -> int:
    """Paper §3.2 buffer sizing: fixed slots with a capacity-factor headroom."""
    ideal = tokens_per_client * top_k / num_servers
    return max(8, int(math.ceil(ideal * capacity_factor / 8.0) * 8))


# ----------------------------------------------------------------------- init

def init_eaas_moe(key, cfg: ModelConfig, num_servers: int,
                  n_redundant: int = 0,
                  redundant_table: Optional[np.ndarray] = None) -> Dict:
    """Router + per-server expert weights (+ shared / residual client FFNs)."""
    from repro.models.mlp import init_mlp

    m = cfg.moe
    assert m is not None
    ks = jax.random.split(key, 4)
    bank = expert_server.init_expert_weights(ks[0], cfg)
    if redundant_table is None:
        redundant_table = np.full((num_servers, max(n_redundant, 0)), -1,
                                  np.int32)
    server_w = expert_server.build_server_weights(
        bank, num_servers, redundant_table)
    params = {
        "router": router.init_router(ks[1], cfg.d_model, m.num_experts),
        "servers": server_w,
    }
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if m.num_shared_experts:
        params["shared"] = init_mlp(
            ks[2], cfg.d_model, m.d_expert * m.num_shared_experts,
            cfg.activation, dt)
    if m.dense_residual:
        params["residual"] = init_mlp(ks[3], cfg.d_model, cfg.d_ff,
                                      cfg.activation, dt)
    return params


def default_runtime(cfg: ModelConfig, num_servers: int,
                    tokens_per_client: int, max_replicas: int = 4,
                    gemm_impl: str = "auto",
                    redundant_table: Optional[np.ndarray] = None
                    ) -> MoERuntime:
    m = cfg.moe
    table = emap.default_mapping(m.num_experts, num_servers, max_replicas)
    if redundant_table is None:
        redundant_table = np.zeros((num_servers, 0), np.int32)
    local = expert_server.make_local_table(m.num_experts, num_servers,
                                           redundant_table)
    return MoERuntime(
        mapping=jnp.asarray(table),
        alive=jnp.ones((num_servers,), bool),
        local_table=jnp.asarray(local),
        num_servers=num_servers,
        capacity=default_capacity(tokens_per_client, m.top_k, num_servers,
                                  m.capacity_factor),
        gemm_impl=gemm_impl,
    )


# ---------------------------------------------------------------------- apply

def _client_extras(params: Dict, x: jax.Array, cfg_moe: MoEConfig,
                   activation: str) -> jax.Array:
    """Shared experts + dense residual — the client-side dense FFN tier."""
    from repro.models.mlp import mlp

    extra = jnp.zeros_like(x)
    if "shared" in params:
        extra = extra + mlp(params["shared"], x, activation)
    if "residual" in params:
        extra = extra + mlp(params["residual"], x, activation)
    return extra


def eaas_moe_apply(params: Dict, x: jax.Array, cfg_moe: MoEConfig,
                   runtime: MoERuntime, *, activation: str = "swiglu",
                   axis_name: Optional[str] = None, mode: str = "local",
                   token_salt: Optional[jax.Array] = None,
                   ) -> Tuple[jax.Array, MoEStats]:
    """Apply the EAAS MoE layer to x: (T, d) -> (T, d).

    In SPMD modes this must be called inside shard_map with ``axis_name``
    bound to the server mesh axis; params["servers"] arrays then hold only
    the local shard (leading dim 1) and are squeezed here.
    """
    T, d = x.shape
    S, C = runtime.num_servers, runtime.capacity

    # ---- client: route + resolve service instances ----------------------
    r = router.route(params["router"], x, cfg_moe,
                     bias=runtime.route_bias)
    if token_salt is None:
        token_salt = jnp.arange(T, dtype=jnp.int32)[:, None] + jnp.arange(
            r.expert_ids.shape[1], dtype=jnp.int32)[None, :]
    server_ids = emap.lookup(runtime.mapping, runtime.alive,
                             r.expert_ids, token_salt,
                             weights=runtime.replica_weights)

    # ---- client: pack buffer slots (paper §3.2) --------------------------
    buffers = dispatch.pack(x, r.expert_ids, r.scores, server_ids, S, C,
                            method=runtime.dispatch_method)

    # ---- transfer + server compute ---------------------------------------
    if axis_name is None:
        sw = params["servers"]
        # vmap the stateless server over the S logical instances
        def one_server(wg, wu, wd, tbl, hid, eid, sc, cnt):
            w = ServerWeights(wg, wu, wd, tbl)
            out, st = expert_server.serve(hid[None], eid[None], sc[None],
                                          cnt[None], w,
                                          impl=runtime.gemm_impl)
            return out[0], st
        hid, eid, sc, cnt = comm.send_to_servers(buffers, None, "local")
        out_slots, st = jax.vmap(one_server)(
            sw["w_gate"], sw["w_up"], sw["w_down"], runtime.local_table,
            hid, eid, sc, cnt)
        result = comm.return_to_clients(out_slots, None, "local")
        miss = jnp.sum(st.miss)
    else:
        sw = params["servers"]
        w = ServerWeights(sw["w_gate"][0], sw["w_up"][0], sw["w_down"][0],
                          runtime.local_table[0])
        hid, eid, sc, cnt = comm.send_to_servers(buffers, axis_name, mode)
        out_slots, st = expert_server.serve(hid, eid, sc, cnt, w,
                                            impl=runtime.gemm_impl)
        result = comm.return_to_clients(out_slots, axis_name, mode)
        miss = st.miss

    # ---- client: combine (weighted sum arrives pre-weighted) -------------
    y = dispatch.combine(result, buffers.combine_slot, out_dtype=x.dtype)
    y = comm.finalize_combine(y, axis_name, mode)

    y = y + _client_extras(params, x, cfg_moe, activation)

    stats = MoEStats(
        aux_loss=r.aux_loss,
        z_loss=r.z_loss,
        dropped=buffers.dropped,
        miss=miss,
        expert_load=router.expert_load(r.expert_ids, cfg_moe.num_experts),
    )
    return y, stats
