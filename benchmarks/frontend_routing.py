"""Cluster front-end benchmark: policy × client-count sweep + fault story.

Three claims about the M:N attention:expert shape, measured on seeded
traces under the deterministic :class:`~repro.serving.clock.VirtualClock`:

* **Scale-out identity** — the SAME seeded trace replayed at N=1 and N=4
  clients (round_robin, drop-free dispatch) produces bitwise-identical
  per-request token streams: the front-end changes *where* a request runs,
  never *what* it computes.  The per-request fingerprint is the exact gate.
* **Client-failure containment** — killing one of 4 attention clients
  mid-run strands only its in-flight requests; the expert tier keeps
  serving everyone else, so the cluster throughput dip is strictly smaller
  than the monolithic single-engine stall under the same trace (the
  client-side half of paper Fig. 10 — with more clients the dip shrinks
  toward the paper's <2%).
* **Routing policy effects** — on a shared-prefix (multi-tenant system
  prompt) paged-KV workload, ``session_affinity`` routes same-prefix
  requests to the client whose BlockPool already caches the prefix: its
  prefix hit rate beats ``round_robin``'s, which spreads every prefix
  cold across all clients.  ``least_loaded`` is the backlog/memory-aware
  middle ground.

The JSON carries a ``gate`` section consumed by ``tools/check_bench.py``
(exact token fingerprints + equivalence/ordering booleans, toleranced
throughputs and hit rates) — the CI benchmark-regression lane.
"""

from __future__ import annotations

import argparse
import hashlib
from typing import Dict, List

import numpy as np

from benchmarks.common import (bench_model_cfg, csv_row,
                               run_cluster_scenario, save_result)
from repro.serving import (ClusterConfig, EngineConfig, Scenario,
                           ServingEngine, VirtualClock)

NUM_SERVERS = 4
MAX_BATCH = 4
MAX_SEQ = 64
POLICIES = ("round_robin", "least_loaded", "session_affinity")


def _clock():
    return VirtualClock()


def _ecfg(paged: bool = False) -> EngineConfig:
    return EngineConfig(
        mode="eaas", num_servers=NUM_SERVERS, max_batch=MAX_BATCH,
        max_seq=MAX_SEQ, n_redundant=2,
        # drop-free dispatch: routing a request to a different client must
        # never change which tokens reach their experts (the identity gate)
        pool_tokens_per_client=MAX_BATCH * NUM_SERVERS,
        kv_mode=("paged" if paged else "dense"), kv_block_size=8,
        prefill_chunk=(8 if paged else 0))


def _ccfg(n: int, policy: str, paged: bool = False) -> ClusterConfig:
    return ClusterConfig(clients=n, frontend_policy=policy,
                         engine=_ecfg(paged))


def _token_fingerprint(tokens: Dict[int, tuple]) -> str:
    blob = repr(sorted(tokens.items())).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def _collect(res) -> Dict:
    m = res.metrics
    tokens = {r.request_id: tuple(r.output_tokens) for r in res.requests}
    out = {
        "requests": m.total_requests,
        "completed": m.completed,
        "decode_tok_per_s": m.decode_throughput,
        "token_fingerprint": _token_fingerprint(tokens),
        "_tokens": tokens,
        "_metrics": m,
    }
    if hasattr(m, "failed_requests"):
        out["failed"] = m.failed_requests
        out["routed"] = list(m.routed)
    out["prefix_hit_rate"] = float(m.prefix_hit_rate)
    return out


def _measure_cluster(cfg, ccfg: ClusterConfig, sc: Scenario) -> Dict:
    _, res = run_cluster_scenario(cfg, ccfg, sc, seed=0, clock="virtual")
    return _collect(res)


def _dip(metrics, t_fail: float, horizon: float, bin_w: float) -> float:
    """1 - (worst post-failure bin / pre-failure steady mean), inside the
    scripted horizon (drain-tail bins would read as a false collapse)."""
    curve = metrics.throughput_curve(bin_w)
    pre = [v for t, v in curve if 0.2 * horizon <= t < t_fail]
    post = [v for t, v in curve if t_fail <= t < horizon]
    if not pre or not post:
        return 0.0
    steady = float(np.mean(pre))
    return 1.0 - min(post) / max(steady, 1e-9)


def run(horizon: float = 0.5, rate: float = 120.0, max_new: int = 8,
        smoke: bool = False) -> Dict:
    if smoke:
        horizon, rate, max_new = 0.4, 120.0, 8
    cfg = bench_model_cfg()
    V = cfg.vocab_size
    counts = (1, 4) if smoke else (1, 2, 4)

    def trace(n=1, r=rate, new=max_new) -> Scenario:
        return Scenario(horizon=horizon, seed=7, prompt_len=8,
                        max_new=new, vocab=V, clients=n).poisson(r)

    def prefix_trace(n=1) -> Scenario:
        # 3 prefixes over 4 clients: coprime, so round_robin smears every
        # prefix across every client (the cold-miss worst case) while
        # affinity pins each prefix to one home
        return trace(n).shared_prefix(n_prefixes=3, prefix_len=16,
                                      suffix_len=8)

    variants: Dict[str, Dict] = {}

    # ---- scale-out identity (dense, round_robin) ------------------------
    for n in counts:
        variants[f"n{n}_round_robin"] = _measure_cluster(
            cfg, _ccfg(n, "round_robin"), trace(n))
    n_hi = counts[-1]
    tokens_identical = (variants["n1_round_robin"]["_tokens"]
                        == variants[f"n{n_hi}_round_robin"]["_tokens"])

    # ---- client failure vs monolithic stall -----------------------------
    t_fail = 0.5 * horizon
    bin_w = horizon / 10.0
    # saturating trace (long generations, 2.5x arrivals): every client
    # holds in-flight work when the axe falls, so the failure demonstrably
    # strands requests (metrics.failed) and the dip is a capacity story
    sc_fail = trace(n_hi, r=2.5 * rate, new=3 * max_new) \
        .fail_client(i=0, t=t_fail).recover_client(i=0, t=0.8 * horizon)
    variants["fail_client"] = _measure_cluster(
        cfg, _ccfg(n_hi, "round_robin"), sc_fail)
    mono = ServingEngine(
        cfg, EngineConfig(mode="monolithic_ep", num_servers=NUM_SERVERS,
                          max_batch=MAX_BATCH, max_seq=MAX_SEQ,
                          restart_steps=50,
                          pool_tokens_per_client=MAX_BATCH * NUM_SERVERS),
        seed=0, clock=_clock())
    variants["monolithic_stall"] = _collect(
        trace(r=2.5 * rate, new=3 * max_new).fail(rank=1, t=t_fail)
        .run(mono))
    cluster_dip = _dip(variants["fail_client"]["_metrics"], t_fail,
                       horizon, bin_w)
    mono_dip = _dip(variants["monolithic_stall"]["_metrics"], t_fail,
                    horizon, bin_w)

    # ---- policy sweep on shared-prefix paged traffic --------------------
    for policy in POLICIES:
        variants[f"prefix_n{n_hi}_{policy}"] = _measure_cluster(
            cfg, _ccfg(n_hi, policy, paged=True), prefix_trace(n_hi))
    hit_rr = variants[f"prefix_n{n_hi}_round_robin"]["prefix_hit_rate"]
    hit_aff = variants[f"prefix_n{n_hi}_session_affinity"]["prefix_hit_rate"]

    out: Dict = {"figure": "frontend_routing", "smoke": smoke,
                 "num_servers": NUM_SERVERS, "clients": list(counts),
                 "variants": {}}
    out["tokens_identical_n1_vs_n4"] = tokens_identical
    out["cluster_dip"] = cluster_dip
    out["monolithic_dip"] = mono_dip
    out["cluster_dip_smaller"] = bool(cluster_dip < mono_dip)
    out["affinity_hit_rate"] = hit_aff
    out["round_robin_hit_rate"] = hit_rr
    out["affinity_beats_round_robin"] = bool(hit_aff > hit_rr)
    for name, v in variants.items():
        out["variants"][name] = {k: val for k, val in v.items()
                                 if not k.startswith("_")}

    out["gate"] = {
        "exact": {
            "smoke": smoke,
            "tokens_identical_n1_vs_n4": tokens_identical,
            "cluster_dip_smaller": out["cluster_dip_smaller"],
            "affinity_beats_round_robin": out["affinity_beats_round_robin"],
            "token_fingerprint_n1":
                variants["n1_round_robin"]["token_fingerprint"],
            "token_fingerprint_fail_client":
                variants["fail_client"]["token_fingerprint"],
        },
        "tolerance": {
            "tok_per_s_n1":
                variants["n1_round_robin"]["decode_tok_per_s"],
            f"tok_per_s_n{n_hi}":
                variants[f"n{n_hi}_round_robin"]["decode_tok_per_s"],
            "cluster_dip": cluster_dip,
            "monolithic_dip": mono_dip,
            "affinity_hit_rate": hit_aff,
            "round_robin_hit_rate": hit_rr,
        },
    }
    save_result("frontend_routing", out)
    return out


def main() -> List[str]:
    res = run()
    rows = []
    for name, v in res["variants"].items():
        rows.append(csv_row(
            f"frontend_routing_{name}", 0.0,
            f"tok_per_s={v['decode_tok_per_s']:.1f}"
            f";completed={v['completed']}"
            f";hit_rate={v['prefix_hit_rate']:.3f}"))
    rows.append(csv_row(
        "frontend_routing_summary", 0.0,
        f"identical={int(res['tokens_identical_n1_vs_n4'])}"
        f";cluster_dip={res['cluster_dip']:.3f}"
        f";mono_dip={res['monolithic_dip']:.3f}"
        f";affinity_hit={res['affinity_hit_rate']:.3f}"
        f";rr_hit={res['round_robin_hit_rate']:.3f}"))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short two-point configuration (CI gate)")
    args = ap.parse_args()
    res = run(smoke=args.smoke)
    for name, v in res["variants"].items():
        print(f"{name}: tok_per_s={v['decode_tok_per_s']:.1f} "
              f"completed={v['completed']} "
              f"hit_rate={v['prefix_hit_rate']:.3f}")
    print(f"n1 vs n4 identical tokens: {res['tokens_identical_n1_vs_n4']}; "
          f"client-failure dip {res['cluster_dip']:.3f} vs monolithic "
          f"{res['monolithic_dip']:.3f}; affinity hit rate "
          f"{res['affinity_hit_rate']:.3f} vs rr "
          f"{res['round_robin_hit_rate']:.3f}")
