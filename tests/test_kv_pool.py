"""Unit tests for the KV block pool, the memory-aware scheduler, and the
autoscaler's kv-pressure signal — pure host logic, no jax engine runs."""

import numpy as np
import pytest

from repro.serving import Autoscaler, AutoscalerConfig, BlockPool, \
    block_hashes
from repro.serving.kv_pool import SCRATCH_BLOCK
from repro.serving.request import Request, SamplingParams
from repro.serving.scheduler import (DecodeBatch, PrefillChunk, Scheduler,
                                     SchedulerConfig)


# ------------------------------------------------------------- block hashes

def test_block_hashes_chain_and_prefix_property():
    a = np.arange(24, dtype=np.int32)
    b = np.concatenate([np.arange(16, dtype=np.int32),
                        np.array([99, 98, 97, 96, 95, 94, 93, 92], np.int32)])
    ha, hb = block_hashes(a, 8), block_hashes(b, 8)
    assert len(ha) == len(hb) == 3
    assert ha[:2] == hb[:2]            # shared 16-token prefix
    assert ha[2] != hb[2]              # divergent third block
    # partial tail blocks are never hashed
    assert len(block_hashes(a[:23], 8)) == 2
    # each hash commits to the whole prefix, not just its own block
    c = np.concatenate([np.array([7] * 8, np.int32), a[8:16]])
    assert block_hashes(c, 8)[1] != ha[1]


# --------------------------------------------------------------- block pool

def test_pool_allocate_free_refcount():
    p = BlockPool(6, 8)                # 5 usable + scratch
    assert p.usable_blocks == 5 and p.available() == 5
    got = p.allocate(3)
    assert got is not None and len(got) == 3
    assert SCRATCH_BLOCK not in got
    assert p.available() == 2
    assert p.allocate(3) is None       # over-ask: nothing allocated
    assert p.available() == 2
    p.incref(got[0])
    p.decref(got[0])
    assert p.available() == 2          # still referenced once
    for bid in got:
        p.decref(bid)
    assert p.available() == 5
    assert p.free_fraction() == 1.0


def test_pool_prefix_cache_match_register_evict():
    p = BlockPool(4, 8)                # 3 usable
    hs = block_hashes(np.arange(24, dtype=np.int32), 8)
    got = p.allocate(3)
    for bid, h in zip(got, hs):
        p.register(bid, h)
    # release all -> cached-evictable, still matchable
    for bid in got:
        p.decref(bid)
    assert p.available() == 3
    m = p.match_prefix(hs)
    assert m == got                    # resurrection in order
    assert p.matched_blocks == 3 and p.queried_blocks == 3
    for bid in m:
        p.decref(bid)
    # allocation pressure evicts oldest-released first and unregisters it
    fresh = p.allocate(1)
    assert fresh == [got[0]]
    assert p.evictions == 1
    m2 = p.match_prefix(hs)
    assert m2 == []                    # chain broken at evicted block 0
    assert p.allocate(3) is None       # fresh[0] still live


def test_pool_match_stops_at_first_miss():
    p = BlockPool(8, 8)
    hs = block_hashes(np.arange(32, dtype=np.int32), 8)
    got = p.allocate(4)
    p.register(got[0], hs[0])
    p.register(got[2], hs[2])          # hole at hs[1]
    for bid in got:
        p.decref(bid)
    assert p.match_prefix(hs) == [got[0]]
    p.decref(got[0])


def test_pool_fork_cow():
    p = BlockPool(4, 8)
    h = block_hashes(np.arange(8, dtype=np.int32), 8)[0]
    (src,) = p.allocate(1)
    p.register(src, h)
    dst = p.fork(src)
    assert dst is not None and dst != src
    assert p.cow_forks == 1
    # the caller's reference on src is KEPT until the data copy lands;
    # src stays registered and matchable, dst is private
    assert p.match_prefix([h]) == [src]
    p.decref(src)                      # the match's ref
    p.decref(src)                      # copy applied: forker's ref
    p.decref(dst)
    assert p.available() == p.usable_blocks


def test_pool_fork_source_safe_from_eviction_until_copy():
    """The COW source must survive allocation pressure while the data copy
    is pending: releasing it at fork time would let a decode-step
    allocation evict and overwrite it, corrupting the adopted prefix."""
    p = BlockPool(3, 8)                # 2 usable
    h = block_hashes(np.arange(8, dtype=np.int32), 8)[0]
    (src,) = p.allocate(1)
    p.register(src, h)
    p.decref(src)                      # cached-evictable
    assert p.match_prefix([h]) == [src]
    dst = p.fork(src)                  # takes the last free block
    assert dst is not None
    assert p.allocate(1) is None       # src is pinned while copy pending
    p.decref(src)                      # copy applied -> evictable again
    assert p.allocate(1) == [src]
    assert p.evictions == 1


def test_pool_disabled_prefix_cache():
    p = BlockPool(4, 8, enable_prefix_cache=False)
    h = block_hashes(np.arange(8, dtype=np.int32), 8)[0]
    (bid,) = p.allocate(1)
    p.register(bid, h)
    p.decref(bid)
    assert p.match_prefix([h]) == []
    assert p.available() == 3          # went straight to the free list


# ------------------------------------------------- memory-aware scheduler

def _req(i, n=16, max_new=4, arrival=0.0):
    return Request(i, np.arange(i * 100, i * 100 + n, dtype=np.int32),
                   SamplingParams(max_new_tokens=max_new),
                   arrival_time=arrival)


def _sched(max_batch=2, prefill_chunk=0, num_blocks=9, block_size=8,
           max_seq=32, **pool_kw):
    pool = BlockPool(num_blocks, block_size, **pool_kw)
    s = Scheduler(SchedulerConfig(max_batch=max_batch,
                                  prefill_chunk=prefill_chunk,
                                  max_seq=max_seq), kv_pool=pool)
    return s, pool


def test_admission_gates_on_free_blocks():
    # 4 usable blocks, requests need 2 each (12 tokens, and the next decode
    # write at position 12 stays inside block 1) -> third admission waits
    s, pool = _sched(max_batch=3, num_blocks=5, block_size=8, max_seq=32)
    for i in range(3):
        s.submit(_req(i, n=12))
    p = s.next_plan()
    assert isinstance(p, PrefillChunk) and p.slot == 0
    s.prefill_advanced(0, p.length)
    p = s.next_plan()
    assert isinstance(p, PrefillChunk) and p.slot == 1
    s.prefill_advanced(1, p.length)
    # head-of-line request 2 cannot get blocks: decode runs instead
    plan = s.next_plan()
    assert isinstance(plan, DecodeBatch) and plan.slots == (0, 1)
    assert s.slots[2] is None and len(s.queue) == 1
    assert s.preemptions == 0
    assert s.kv_free_fraction() == 0.0
    # completion frees blocks; request 2 admits
    s.release(0)
    p = s.next_plan()
    assert isinstance(p, PrefillChunk) and p.request.request_id == 2


def test_prefix_hit_skips_cached_prefix_and_cow_on_full_hit():
    s, pool = _sched(max_batch=2, num_blocks=9, block_size=8, max_seq=32)
    a = Request(0, np.arange(16, dtype=np.int32), SamplingParams())
    s.submit(a)
    p = s.next_plan()
    assert p.start == 0 and p.length == 16 and not p.copies
    s.prefill_advanced(0, 16)          # registers both full blocks
    s.release(0)                       # blocks go cached-evictable
    # identical prompt: full hit -> COW fork of the last block, 1-token plan
    b = Request(1, np.arange(16, dtype=np.int32), SamplingParams())
    s.submit(b)
    p = s.next_plan()
    assert isinstance(p, PrefillChunk)
    assert p.start == 15 and p.length == 1
    assert len(p.copies) == 1 and pool.cow_forks == 1
    src, dst = p.copies[0]
    assert s.block_tables[p.slot][1] == dst != src
    assert list(p.tokens) == [15]      # only the recomputed last token
    # partial hit: shared first block only
    s.prefill_advanced(p.slot, 1)
    c = Request(2, np.concatenate([np.arange(8, dtype=np.int32),
                                   np.full(8, 7, np.int32)]),
                SamplingParams())
    s.submit(c)
    p = s.next_plan()
    assert p.start == 8 and p.length == 8 and not p.copies


def test_chunk_planning_shrinks_to_pool_then_preempts():
    """Chunked-prefill planning allocates per chunk: a chunk shrinks to
    the blocks the pool can supply, and when not even one new token can
    be covered the lowest-priority slot is preempted."""
    s, pool = _sched(max_batch=2, prefill_chunk=16, num_blocks=6,
                     block_size=8, max_seq=40)
    s.submit(_req(0, n=32, arrival=0.0))
    s.submit(_req(1, n=16, arrival=1.0))
    p = s.next_plan()                  # both admit: 2 + 2 blocks, 1 free
    assert (p.slot, p.start, p.length) == (0, 0, 16)
    s.prefill_advanced(0, 16)
    p = s.next_plan()                  # chunk [16, 32) wants 2 blocks,
    assert (p.slot, p.start, p.length) == (0, 16, 8)   # shrinks to 1
    s.prefill_advanced(0, 8)
    p = s.next_plan()                  # pool dry: preempt the younger slot
    assert (p.slot, p.start, p.length) == (0, 24, 8)
    assert s.preemptions == 1 and s.slots[1] is None
    assert s.queue[0].request_id == 1
    s.prefill_advanced(0, 8)
    assert 0 in s.decode_ready()


def test_preemption_keeps_oldest_and_requeues_victim():
    # two live requests, pool exhausted: the younger one is preempted when
    # the older needs a decode block
    s, pool = _sched(max_batch=2, num_blocks=5, block_size=8, max_seq=32)
    old = _req(0, n=16, arrival=0.0)
    young = _req(1, n=16, arrival=1.0)
    for r in (old, young):
        s.submit(r)
    for _ in range(2):
        p = s.next_plan()
        s.prefill_advanced(p.slot, p.length)
    # both decode-ready, 0 free blocks; old's next token needs block idx 2
    old.output_tokens.append(5)        # cache_length -> 16 (block boundary)
    young.output_tokens.append(6)
    plan = s.next_plan()
    assert isinstance(plan, DecodeBatch)
    assert plan.slots == (0,)          # young was evicted from the batch
    assert s.preemptions == 1
    assert s.queue[0] is young         # re-queued at the front, tokens kept
    assert young.output_tokens == [6]
    assert s.block_tables[1].max() == SCRATCH_BLOCK


def test_resumed_request_replans_as_prompt_extension():
    s, pool = _sched(max_batch=1, num_blocks=9, block_size=8, max_seq=32)
    r = _req(0, n=12)
    r.output_tokens = [3, 4, 5]        # preempted after generating 3 tokens
    s.submit(r)
    p = s.next_plan()
    assert isinstance(p, PrefillChunk)
    # effective sequence = prompt (12) + outputs[:-1] (2) = 14 tokens
    assert p.start == 0 and p.length == 14
    assert list(p.tokens[-2:]) == [3, 4]
    s.prefill_advanced(0, 14)
    assert s.cache_length(0) == 14
    assert isinstance(s.next_plan(), DecodeBatch)


# ------------------------------------------------------- autoscaler signal

def test_autoscaler_kv_pressure_signal():
    asc = Autoscaler(AutoscalerConfig(rate_per_server=100, min_servers=1,
                                      max_servers=8, window=0.1,
                                      kv_pressure_threshold=0.25))
    for t in (0.0, 0.01, 0.02):
        asc.observe_arrival(t)
    base = asc.desired_servers(0.05, queue_depth=0, kv_free_fraction=1.0)
    calm = asc.desired_servers(0.05, queue_depth=0, kv_free_fraction=0.3)
    tight = asc.desired_servers(0.05, queue_depth=0, kv_free_fraction=0.2)
    assert calm == base                # above threshold: no extra server
    assert tight == base + 1           # memory pressure scales up


def test_kv_pressure_fires_before_admission_stalls():
    """The pool signal leads the queue signal: free fraction drops below
    the threshold while admission still succeeds (queue empty), so the
    autoscaler reacts a step before requests start waiting."""
    asc_cfg = AutoscalerConfig(rate_per_server=1000, min_servers=1,
                               max_servers=8, window=0.1,
                               kv_pressure_threshold=0.5)
    asc = Autoscaler(asc_cfg)
    s, pool = _sched(max_batch=4, num_blocks=9, block_size=8, max_seq=32)
    for i in range(3):
        s.submit(_req(i, n=16))        # 2 blocks each
    for _ in range(3):
        p = s.next_plan()              # all three admit (6 of 8 blocks)
        s.prefill_advanced(p.slot, p.length)
    assert not s.queue                 # no admission stall yet
    assert s.kv_free_fraction() == pytest.approx(0.25)
    n = asc.desired_servers(0.05, queue_depth=len(s.queue),
                            kv_free_fraction=s.kv_free_fraction())
    assert n > asc.desired_servers(0.05, queue_depth=0,
                                   kv_free_fraction=1.0)
