"""Request / sampling types for the serving engine."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


@dataclass
class SamplingParams:
    temperature: float = 0.0          # 0 = greedy
    max_new_tokens: int = 64
    seed: int = 0


@dataclass
class Request:
    request_id: int
    prompt: np.ndarray                # (prompt_len,) int32
    sampling: SamplingParams = field(default_factory=SamplingParams)
    arrival_time: float = 0.0

    # --- engine-filled ---------------------------------------------------
    output_tokens: List[int] = field(default_factory=list)
    prefill_time: Optional[float] = None
    finish_time: Optional[float] = None
    token_times: List[float] = field(default_factory=list)

    @property
    def done(self) -> bool:
        return self.finish_time is not None

    @property
    def ttft(self) -> Optional[float]:
        """Time to first token (arrival -> first sampled token)."""
        if self.prefill_time is None:
            return None
        return self.prefill_time - self.arrival_time

    def itl(self) -> List[float]:
        """Inter-token latencies (seconds)."""
        ts = ([self.prefill_time] if self.prefill_time is not None else []) \
            + self.token_times
        return [b - a for a, b in zip(ts, ts[1:])]
