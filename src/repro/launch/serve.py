"""Serving launcher: the EAAS cluster front-end on a selectable architecture.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch kimi-k2-1t-a32b \
        --reduced --requests 12 [--mode eaas|monolithic_ep|tp] \
        [--clients 4 --frontend-policy least_loaded] \
        [--fail-at 12:1] [--servers 4]

``--clients N`` runs the paper's M:N attention:expert shape through
:class:`repro.serving.Cluster`; ``--mode tp`` has no disaggregated expert
tier and therefore only supports a single client.

``--exec-mode async`` serves through the event-driven expert tier
(per-expert queue lanes, ``--async-depth`` pipelined decode waves) under
the deterministic :class:`~repro.serving.clock.VirtualClock` — token
streams are bitwise identical to lockstep, only the timing model changes.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.serving import (Cluster, ClusterConfig, EngineConfig, Request,
                           SamplingParams, ServingEngine, VirtualClock)
from repro.serving.frontend import FRONTEND_POLICIES


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-r1")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mode", default="eaas",
                    choices=["eaas", "monolithic_ep", "tp"])
    ap.add_argument("--clients", type=int, default=1,
                    help="attention clients sharing the expert tier")
    ap.add_argument("--frontend-policy", default="round_robin",
                    choices=list(FRONTEND_POLICIES))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--servers", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--fail-at", default=None,
                    help="step:rank — inject an expert-server failure")
    ap.add_argument("--exec-mode", default="lockstep",
                    choices=["lockstep", "async"],
                    help="async = event-driven expert tier with per-expert "
                         "queue lanes (needs --mode eaas and an MoE arch; "
                         "runs under the deterministic VirtualClock)")
    ap.add_argument("--async-depth", type=int, default=2,
                    help="decode waves in flight under --exec-mode async "
                         "(1 = lockstep cadence, K = deeper speculative "
                         "wave pipelining)")
    ap.add_argument("--elastic", action="store_true",
                    help="attach the full-system autoscaler: expert-server "
                         "count (and, with --clients > 1, client count and "
                         "scale-to-zero expert paging) follows observed "
                         "traffic; token streams never change")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced or jax.default_backend() == "cpu":
        cfg = cfg.reduced()

    ecfg = EngineConfig(mode=args.mode, num_servers=args.servers,
                        max_batch=args.max_batch, max_seq=96,
                        n_redundant=2,
                        exec_mode=args.exec_mode,
                        async_depth=args.async_depth,
                        tp_batch_cap=max(args.max_batch // 2, 1))
    if args.exec_mode == "async" and (args.mode != "eaas" or not cfg.moe):
        # surface the engine's own validation as a CLI error
        raise SystemExit("--exec-mode async models the EAAS expert tier: "
                         "it needs --mode eaas and an MoE arch")
    # the async event timeline is defined against the deterministic
    # virtual cost model; lockstep keeps the wall clock (the seed default)
    clock_factory = VirtualClock if args.exec_mode == "async" else None
    if args.mode == "tp" or not cfg.moe:
        if args.clients != 1:
            raise SystemExit("--clients > 1 needs a shared expert tier: "
                             "an MoE arch in eaas/monolithic_ep mode")
        system = ServingEngine(cfg, ecfg, seed=0)
    else:
        system = Cluster(cfg, ClusterConfig(
            clients=args.clients, frontend_policy=args.frontend_policy,
            engine=ecfg, max_clients=args.clients), seed=0,
            clock_factory=clock_factory)
    scaler = None
    if args.elastic:
        from repro.serving.autoscale import Autoscaler, AutoscalerConfig
        scaler = Autoscaler(AutoscalerConfig(
            rate_per_server=12.0, min_servers=1, max_servers=args.servers,
            window=0.1, cooldown=0.1,
            rate_per_client=24.0, min_clients=1, max_clients=args.clients,
            expert_idle_fraction=0.5))
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        if scaler is not None:
            scaler.observe_arrival(system.clock)
        system.submit(Request(
            i, rng.integers(0, cfg.vocab_size, size=8).astype(np.int32),
            SamplingParams(max_new_tokens=args.max_new)))

    fail = None
    if args.fail_at:
        step_s, rank_s = args.fail_at.split(":")
        fail = (int(step_s), int(rank_s))

    def on_step(s):
        if scaler is not None:
            scaler.step(s, s.clock)
        if fail and s.step_idx == fail[0]:
            print(f"[t={s.clock:.2f}s] injecting failure of server {fail[1]}")
            s.inject_server_failure(fail[1])

    m = system.run(max_steps=5000, on_step=on_step)
    print("\n=== summary ===")
    for k, v in m.summary().items():
        print(f"  {k}: {v}")


if __name__ == "__main__":
    main()
