"""Training substrate: optimizers converge, compression preserves training,
checkpoint save/restore/resume, async + atomicity."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.transformer import ParallelCtx, build_model
from repro.training import checkpoint as ckpt
from repro.training.data import ShareGPTLike, synthetic_lm_batches
from repro.training.optimizer import (adafactor, adamw, clip_by_global_norm,
                                      cosine_schedule)
from repro.training.train_loop import (
    TrainState, init_train_state, make_train_step)


def _tiny_model():
    cfg = get_config("granite-3-2b").reduced().replace(
        num_layers=2, d_ff=128, vocab_size=64)
    return cfg, build_model(cfg)


@pytest.mark.parametrize("make_opt", [lambda: adamw(lr=3e-3),
                                      lambda: adafactor(lr=3e-2)])
def test_training_reduces_loss(make_opt):
    cfg, model = _tiny_model()
    ctx = ParallelCtx(remat=False, ce_chunk=16)
    data = synthetic_lm_batches(cfg, batch=8, seq=32, seed=0)
    opt = make_opt()
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, opt, ctx))
    losses = []
    for i in range(30):
        state, m = step(state, next(data))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses[::6]


def test_compressed_gradients_still_train():
    cfg, model = _tiny_model()
    ctx = ParallelCtx(remat=False, ce_chunk=16)
    data = synthetic_lm_batches(cfg, batch=8, seq=32, seed=0)
    opt = adamw(lr=3e-3)
    state = init_train_state(model, opt, jax.random.PRNGKey(0),
                             compression=True)
    step = jax.jit(make_train_step(model, opt, ctx, compression=True))
    losses = []
    for i in range(30):
        state, m = step(state, next(data))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses[::6]
    # error-feedback residuals are being carried
    assert any(float(jnp.max(jnp.abs(r))) > 0
               for r in jax.tree.leaves(state.ef_residual))


def test_clip_and_schedule():
    g = {"a": jnp.ones((4,)) * 100.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-4)
    lr = cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(5)) == pytest.approx(0.5)
    assert float(lr(10)) == pytest.approx(1.0, rel=1e-2)
    assert float(lr(100)) == pytest.approx(0.0, abs=1e-6)


def test_sharegpt_like_distribution():
    p, r = ShareGPTLike(seed=0).sample(2000)
    assert r.max() <= 768 and p.max() <= 4096       # the paper's caps
    assert 50 < np.median(p) < 1000


def test_checkpoint_roundtrip(tmp_path):
    cfg, model = _tiny_model()
    opt = adamw(lr=1e-3)
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    path = ckpt.save_checkpoint(str(tmp_path), 7, state.params)
    assert os.path.basename(path) == "step_00000007"
    restored, step = ckpt.restore_checkpoint(str(tmp_path), state.params)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_gc_and_latest(tmp_path):
    tree = {"w": np.arange(4.0)}
    for s in (1, 2, 3, 4):
        ckpt.save_checkpoint(str(tmp_path), s, tree, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 4
    kept = sorted(os.listdir(tmp_path))
    assert kept == ["step_00000003", "step_00000004"]


def test_async_checkpointer(tmp_path):
    tree = {"w": np.arange(8.0)}
    ac = ckpt.AsyncCheckpointer(str(tmp_path))
    ac.save(1, tree)
    ac.save(2, {"w": np.arange(8.0) * 2})     # waits for 1 internally
    ac.wait()
    restored, step = ckpt.restore_checkpoint(str(tmp_path), tree)
    assert step == 2
    np.testing.assert_array_equal(restored["w"], np.arange(8.0) * 2)


def test_restart_resumes_training(tmp_path):
    """Fault-tolerance e2e: kill-and-restore mid-run reproduces state."""
    cfg, model = _tiny_model()
    ctx = ParallelCtx(remat=False, ce_chunk=16)
    opt = adamw(lr=3e-3)
    data = synthetic_lm_batches(cfg, batch=4, seq=32, seed=1)
    batches = [next(data) for _ in range(8)]
    step = jax.jit(make_train_step(model, opt, ctx))

    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    for b in batches[:4]:
        state, _ = step(state, b)
    ckpt.save_checkpoint(str(tmp_path), 4, state)
    for b in batches[4:]:
        state, m_final = step(state, b)

    # "crash", restore, replay the remaining batches
    fresh = init_train_state(model, opt, jax.random.PRNGKey(0))
    restored, s = ckpt.restore_checkpoint(str(tmp_path), fresh)
    assert s == 4
    state2 = TrainState(*restored) if not isinstance(restored, TrainState) \
        else restored
    for b in batches[4:]:
        state2, m2_final = step(state2, b)
    assert float(m2_final["loss"]) == pytest.approx(float(m_final["loss"]),
                                                    rel=1e-5)
