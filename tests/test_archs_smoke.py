"""Per-architecture smoke tests (deliverable (f)): a REDUCED config of each
assigned arch runs one train forward/backward step and one prefill+decode
step on CPU, asserting output shapes, finiteness, and exact teacher-forcing
consistency between decode-after-prefill and full-prefill logits."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core.moe_layer import default_runtime
from repro.models.transformer import ParallelCtx, build_model
from repro.training.optimizer import adamw
from repro.training.train_loop import init_train_state, make_train_step


def _setup(arch):
    cfg = get_config(arch).reduced()
    S = 2 if cfg.moe else 1
    model = build_model(cfg, num_servers=S)
    B, L = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, L + 1), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.encoder_seq_len, cfg.d_model),
            jnp.float32)
    rt = None
    if cfg.moe:
        rt = default_runtime(cfg, S, B * L)
        rt = rt._replace(capacity=B * L * cfg.moe.top_k,
                         gemm_impl="xla_ragged")
    ctx = ParallelCtx(remat=False, moe_runtime=rt)
    return cfg, model, batch, ctx


# the heaviest reduced configs (>9s each on CPU) ride the `slow` marker so
# plain `pytest -m "not slow"` stays fast; the full sweep still runs by default
_SLOW_SMOKE = {"arctic-480b", "kimi-k2-1t-a32b", "zamba2-2.7b"}


def _marked(archs, slow):
    return [pytest.param(a, marks=pytest.mark.slow) if a in slow else a
            for a in archs]


@pytest.mark.parametrize("arch", _marked(ASSIGNED_ARCHS, _SLOW_SMOKE))
def test_smoke_forward_and_decode(arch):
    cfg, model, batch, ctx = _setup(arch)
    params = model.init_params(jax.random.PRNGKey(0))
    loss, metrics = model.loss_fn(params, batch, ctx)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0

    S = batch["tokens"].shape[1]
    logits_full, _ = model.prefill(params, batch["tokens"], ctx, batch=batch,
                                   max_slots=S + 4)
    assert logits_full.shape == (2, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits_full)).all()
    # padded vocab slots are masked out of sampling
    if cfg.padded_vocab != cfg.vocab_size:
        assert np.asarray(logits_full)[:, cfg.vocab_size:].max() < -1e29

    _, cache = model.prefill(params, batch["tokens"][:, :S - 1], ctx,
                             batch=batch, max_slots=S + 4)
    logits_dec, cache, _ = model.decode_step(
        params, batch["tokens"][:, S - 1:S], cache, ctx, batch=batch)
    np.testing.assert_allclose(np.asarray(logits_dec)[:, :cfg.vocab_size],
                               np.asarray(logits_full)[:, :cfg.vocab_size],
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", _marked(
    ["granite-3-2b", "kimi-k2-1t-a32b", "zamba2-2.7b", "rwkv6-7b"],
    {"zamba2-2.7b", "rwkv6-7b"}))
def test_smoke_train_step(arch):
    """One optimizer step runs and produces finite params (repr. families)."""
    cfg, model, batch, ctx = _setup(arch)
    opt = adamw(lr=1e-3)
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, opt, ctx))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    for leaf in jax.tree.leaves(state.params):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()
