"""Live traffic-adaptive expert rebalancing: controller invariants, the
skew-scenario throughput pin, token identity, and the check_bench gate."""

import dataclasses
import json
import pathlib
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import expert_server, load_balance
from repro.core.elastic import ServerPool
from repro.serving import (Autoscaler, AutoscalerConfig, EngineConfig,
                           Scenario, ServingEngine, VirtualClock, zipf_bias)

NUM_EXPERTS, NUM_SERVERS, MAX_BATCH = 16, 4, 8


def _cfg(num_experts=NUM_EXPERTS):
    cfg = get_config("deepseek-r1").reduced()
    return cfg.replace(moe=dataclasses.replace(cfg.moe,
                                               num_experts=num_experts))


def _engine(cfg, rebalance: bool) -> ServingEngine:
    ecfg = EngineConfig(
        mode="eaas", num_servers=NUM_SERVERS, max_batch=MAX_BATCH,
        max_seq=64, n_redundant=2,
        pool_tokens_per_client=MAX_BATCH * NUM_SERVERS,
        charge_imbalance=True,
        rebalance_interval=0.02 if rebalance else 0.0)
    clock = VirtualClock(decode_base=2e-4, decode_per_token=2e-3,
                         expert_share=0.8)
    return ServingEngine(cfg, ecfg, seed=0, clock=clock)


def _skew_scenario(vocab: int) -> Scenario:
    return (Scenario(horizon=0.5, seed=7, prompt_len=8, max_new=24,
                     vocab=vocab)
            .poisson(rate=60)
            .zipf_skew(alpha=1.2, scale=1.0))


@pytest.fixture(scope="module")
def skew_runs():
    """(frozen, rebalance, rebalance-rerun) results on one seeded
    Zipf(1.2) trace — shared across the scenario-level assertions."""
    cfg = _cfg()
    out = {}
    for name, reb in (("frozen", False), ("rebalance", True),
                      ("rerun", True)):
        eng = _engine(cfg, reb)
        res = _skew_scenario(cfg.vocab_size).run(eng)
        out[name] = (eng, res,
                     {r.request_id: tuple(r.output_tokens)
                      for r in res.requests})
    return out


# ------------------------------------------------------ prefill feedback

def test_prompt_heavy_trace_triggers_rebalance_via_prefill_feedback():
    """Chunked-prefill steps feed ``MoEStats.expert_load`` into the traffic
    EMA (the ROADMAP item): a prompt-heavy skewed trace rebalances from
    prompt traffic alone, and warms the EMA far faster than decode-only
    feedback does."""
    cfg = _cfg()

    def run(feedback: bool):
        ecfg = EngineConfig(
            mode="eaas", num_servers=NUM_SERVERS, max_batch=MAX_BATCH,
            max_seq=96, n_redundant=2, prefill_chunk=8,
            pool_tokens_per_client=MAX_BATCH * NUM_SERVERS,
            charge_imbalance=True, rebalance_interval=0.02,
            prefill_load_feedback=feedback)
        eng = ServingEngine(cfg, ecfg, seed=0, clock=VirtualClock(
            decode_base=2e-4, decode_per_token=2e-3, expert_share=0.8))
        # prompt-heavy: 48-token prompts, a single output token each --
        # nearly all router traffic happens during prefill
        sc = (Scenario(horizon=0.5, seed=7, prompt_len=48, max_new=1,
                       vocab=cfg.vocab_size)
              .poisson(rate=40).zipf_skew(alpha=1.2, scale=1.0))
        sc.run(eng)
        return eng

    fed = run(True)
    unfed = run(False)
    assert fed.metrics.rebalances >= 1
    assert fed.pool.stats.updates > 2 * unfed.pool.stats.updates
    # and the fed run actually migrated replicas toward the hot experts
    assert fed.metrics.migrated_experts > 0


# ------------------------------------------------------------ scenario pins

def test_rebalance_throughput_speedup(skew_runs):
    """The acceptance pin: under Zipf(1.2) expert traffic the live
    rebalancer sustains >= 1.3x the frozen-placement throughput."""
    _, res_f, _ = skew_runs["frozen"]
    _, res_r, _ = skew_runs["rebalance"]
    thr_f = res_f.metrics.decode_throughput
    thr_r = res_r.metrics.decode_throughput
    assert thr_r >= 1.3 * thr_f, (thr_r, thr_f)
    assert res_r.metrics.rebalances >= 1
    assert res_r.metrics.migrated_experts > 0
    assert res_r.metrics.migration_time > 0


def test_rebalance_token_streams_bitwise_identical(skew_runs):
    """Placement moves where experts run, never what they compute."""
    _, _, tok_f = skew_runs["frozen"]
    _, _, tok_r = skew_runs["rebalance"]
    assert tok_f == tok_r
    assert sum(len(t) for t in tok_f.values()) > 0


def test_rebalance_run_deterministic(skew_runs):
    """Same seed + virtual clock => identical metrics timeline, including
    migration chunks and imbalance gauges."""
    _, res_a, tok_a = skew_runs["rebalance"]
    _, res_b, tok_b = skew_runs["rerun"]
    assert tok_a == tok_b
    assert res_a.metrics.fingerprint() == res_b.metrics.fingerprint()


def test_rebalance_reduces_live_imbalance(skew_runs):
    eng_f, res_f, _ = skew_runs["frozen"]
    eng_r, res_r, _ = skew_runs["rebalance"]
    assert res_f.metrics.expert_imbalance > 1.5     # skew bites
    assert res_r.metrics.expert_imbalance < 1.3     # rebalance absorbs it
    # the hot traffic really is concentrated (the Zipf bias dominates)
    ema = eng_r.pool.stats.ema
    top2 = np.sort(ema)[-2:].sum() / ema.sum()
    assert top2 > 0.5, top2


def test_rebalance_converges_and_noops(skew_runs):
    """After the commit the live table digests equal to the planner's
    output, further evaluations are recorded as no-ops, and the controller
    is idle (nothing left to migrate)."""
    eng, res, _ = skew_runs["rebalance"]
    commits = [e for e in res.metrics.events
               if e["event"] == "rebalance_commit"]
    assert commits and all(e["converged"] for e in commits)
    assert not eng.rebalancer.migrating
    assert res.metrics.rebalance_noops > 0
    mapping, _ = eng.pool.plan()
    assert (load_balance.plan_digest(mapping, eng.pool.num_servers)
            == eng.pool.plan_digest)


def test_manual_rebalance_migrates_weights_token_identical(skew_runs):
    """The scripted one-shot ``rebalance(t)`` event moves replica weights
    together with the mapping — outputs stay bitwise identical to the
    frozen run (a stale-weight replica would corrupt expert math)."""
    _, _, tok_f = skew_runs["frozen"]
    cfg = _cfg()
    eng = _engine(cfg, rebalance=False)
    res = _skew_scenario(cfg.vocab_size).rebalance(t=0.15).run(eng)
    toks = {r.request_id: tuple(r.output_tokens) for r in res.requests}
    assert toks == tok_f
    assert res.metrics.rebalances == 1
    assert res.metrics.migrated_experts > 0
    # and the one-shot replan beats frozen placement too
    assert (res.metrics.decode_throughput
            > 1.2 * skew_runs["frozen"][1].metrics.decode_throughput)


def test_skew_events_recorded(skew_runs):
    _, res, _ = skew_runs["rebalance"]
    assert any(e["event"] == "set_skew" for e in res.metrics.events)
    assert res.applied[0]["kind"] == "set_skew"


# -------------------------------------------------------- controller units

def test_migrate_slots_matches_rebuilt_layout():
    """Incremental per-slot weight migration lands exactly the layout a
    from-scratch build of the target table would produce."""
    cfg = _cfg(num_experts=8)
    E, S = 8, 4
    bank = expert_server.init_expert_weights(jax.random.PRNGKey(0), cfg)
    red_old = np.array([[4, -1], [5, -1], [6, -1], [7, -1]], np.int32)
    red_new = np.array([[6, 5], [4, -1], [7, -1], [-1, -1]], np.int32)
    aligned, updates = load_balance.migration_updates(red_old, red_new)
    sw = expert_server.build_server_weights(bank, S, red_old)
    per = E // S
    sw = expert_server.migrate_slots(
        sw, E, [(s, per + j, new_e) for s, j, _, new_e in updates])
    want = expert_server.build_server_weights(bank, S, aligned)
    for k in sw:
        np.testing.assert_array_equal(np.asarray(sw[k]),
                                      np.asarray(want[k]))


def test_migration_updates_alignment():
    """Experts that stay on a server keep their slot (no pointless
    copies); only real occupant changes become updates."""
    old = np.array([[3, 7], [2, -1]], np.int32)
    new = np.array([[7, 3], [2, 5]], np.int32)     # same content, +5 on s1
    aligned, updates = load_balance.migration_updates(old, new)
    np.testing.assert_array_equal(aligned, [[3, 7], [2, 5]])
    assert updates == [(1, 1, -1, 5)]
    # no-change diff is empty
    _, none = load_balance.migration_updates(old, old)
    assert none == []


def test_autoscaler_defers_to_migration_in_flight():
    cfg = _cfg(num_experts=8)
    eng = _engine(cfg, rebalance=True)
    asc = Autoscaler(AutoscalerConfig(rate_per_server=1.0, min_servers=1,
                                      max_servers=8, window=0.1,
                                      cooldown=0.01))
    for t in np.linspace(0.9, 1.0, 20):
        asc.observe_arrival(float(t))       # high observed rate: wants 8
    eng.rebalancer._pending = [(0, 0, -1, 4)]
    assert asc.step(eng, t=1.0) is None     # replication first
    eng.rebalancer.abort()
    assert asc.step(eng, t=1.0) == 8        # then server-count scaling


def test_scale_to_aborts_staged_migration():
    cfg = _cfg(num_experts=8)
    eng = _engine(cfg, rebalance=True)
    eng.rebalancer._pending = [(0, 0, -1, 4)]
    eng.scale_to(2)
    assert not eng.rebalancer.migrating
    assert eng.pool.num_servers == 2
    assert eng.last_placement_change == eng.clock


# ------------------------------------------------------------- pool + plan

def test_server_pool_rebalance_skips_noop_replan():
    cfg = _cfg(num_experts=8)
    pool = ServerPool(cfg, num_servers=4, tokens_per_client=32,
                      n_redundant=2)
    load = np.ones(8)
    load[5] = 40.0
    pool.observe_load(load)
    assert pool.rebalance() is True
    smap, red = pool.smap, pool.redundant_table
    assert pool.rebalance() is False        # identical plan: no rebuild
    assert pool.smap is smap and pool.redundant_table is red


def test_plan_digest_ignores_replica_column_order():
    mapping = np.array([[0, 2, -1], [1, -1, 3]], np.int32)
    shuffled = np.array([[0, -1, 2], [1, 3, -1]], np.int32)
    other = np.array([[0, 2, -1], [1, -1, 2]], np.int32)
    d = load_balance.plan_digest(mapping, 4)
    assert d == load_balance.plan_digest(shuffled, 4)
    assert d != load_balance.plan_digest(other, 4)
    assert d != load_balance.plan_digest(mapping, 5)


def test_zipf_bias_shape_and_determinism():
    b1 = zipf_bias(16, 1.2, scale=2.0, seed=3)
    b2 = zipf_bias(16, 1.2, scale=2.0, seed=3)
    np.testing.assert_array_equal(b1, b2)
    assert b1.max() == 0.0 and b1.min() < 0.0
    np.testing.assert_array_equal(zipf_bias(16, 0.0), np.zeros(16))
    # rotation moves the hot expert
    r0 = int(np.argmax(zipf_bias(16, 1.2, seed=3)))
    r1 = int(np.argmax(zipf_bias(16, 1.2, seed=3, rotation=1)))
    assert r0 != r1


def test_shifting_hot_set_schedules_rotations():
    sc = Scenario(horizon=0.6, seed=0).shifting_hot_set(1.2, period=0.2)
    skews = [e for e in sc.events if e.kind == "set_skew"]
    assert [e.t for e in skews] == [0.0, 0.2, 0.4]
    assert [e.value[2] for e in skews] == [0, 1, 2]


def test_virtual_clock_migrate_and_imbalance_charging():
    clk = VirtualClock(decode_base=1e-3, decode_per_token=1e-3,
                       expert_share=0.5, migrate_base=1e-3,
                       migrate_per_expert=2e-3)
    assert clk.stop("migrate", tokens=3) == pytest.approx(7e-3)
    base = clk.stop("decode", tokens=8, servers=4)
    skewed = clk.stop("decode", tokens=8, servers=4, imbalance=2.0)
    assert base == pytest.approx(1e-3 + 2e-3)      # the pre-existing model
    assert skewed == pytest.approx(1e-3 + 2e-3 * 1.5)
    assert clk.stop("decode", tokens=8, servers=4, imbalance=1.0) == base


# ------------------------------------------------------------- gate (tool)

CHECK_BENCH = str(pathlib.Path(__file__).resolve().parent.parent
                  / "tools" / "check_bench.py")


def _run_gate(tmp_path, cur, base, extra=()):
    cur_p, base_p = tmp_path / "cur.json", tmp_path / "base.json"
    cur_p.write_text(json.dumps(cur))
    base_p.write_text(json.dumps(base))
    return subprocess.run(
        [sys.executable, CHECK_BENCH, "--current", str(cur_p),
         "--baseline", str(base_p), *extra],
        capture_output=True, text=True)


def _doc(fp="abc", thr=100.0):
    return {"gate": {"exact": {"token_fingerprint": fp},
                     "tolerance": {"tok_per_s": thr}}}


def test_check_bench_pass_and_tolerance(tmp_path):
    assert _run_gate(tmp_path, _doc(), _doc()).returncode == 0
    # 10% drift passes at the default 20% tolerance
    assert _run_gate(tmp_path, _doc(thr=110.0), _doc()).returncode == 0
    # 30% drift fails ...
    r = _run_gate(tmp_path, _doc(thr=130.0), _doc())
    assert r.returncode == 1 and "tok_per_s" in r.stdout
    # ... unless the tolerance is widened
    assert _run_gate(tmp_path, _doc(thr=130.0), _doc(),
                     ("--tolerance", "0.5")).returncode == 0


def test_check_bench_exact_and_missing_keys(tmp_path):
    r = _run_gate(tmp_path, _doc(fp="zzz"), _doc(fp="abc"))
    assert r.returncode == 1 and "token_fingerprint" in r.stdout
    # baseline keys missing from the current run fail; new keys pass
    cur = {"gate": {"exact": {}, "tolerance": {"tok_per_s": 100.0,
                                               "new_metric": 5.0}}}
    assert _run_gate(tmp_path, cur, _doc()).returncode == 1
    base = {"gate": {"exact": {}, "tolerance": {"tok_per_s": 100.0}}}
    cur_ok = {"gate": {"exact": {"extra": 1},
                       "tolerance": {"tok_per_s": 101.0, "more": 2.0}}}
    assert _run_gate(tmp_path, cur_ok, base).returncode == 0


def test_check_bench_gate_contract_errors(tmp_path):
    r = _run_gate(tmp_path, {"no_gate": 1}, _doc())
    assert r.returncode == 2
    missing = subprocess.run(
        [sys.executable, CHECK_BENCH, "--current",
         str(tmp_path / "nope.json"), "--baseline",
         str(tmp_path / "also_nope.json")],
        capture_output=True, text=True)
    assert missing.returncode == 2


def test_check_bench_write_baseline(tmp_path):
    cur_p = tmp_path / "cur.json"
    base_p = tmp_path / "sub" / "base.json"
    cur_p.write_text(json.dumps(_doc(thr=130.0)))
    r = subprocess.run(
        [sys.executable, CHECK_BENCH, "--current", str(cur_p),
         "--baseline", str(base_p), "--write-baseline"],
        capture_output=True, text=True)
    assert r.returncode == 0
    assert json.loads(base_p.read_text()) == _doc(thr=130.0)
