"""Scenario harness battery (paper §5 claims as deterministic tests).

Everything here runs under the virtual clock — no wall time, no hypothesis,
bit-identical across runs and machines:

* same seed ⇒ identical ServingMetrics timeline (fingerprint equality);
* the paper's fault-tolerance ordering: EAAS throughput dip strictly
  smaller than the monolithic restart stall (Fig. 10);
* the autoscaler converges to ``provision()``'s server count under a rate
  step (Fig. 11);
* ``pack(method="sort") == pack(method="onehot")`` buffer-for-buffer
  (the dispatch equivalence property, hypothesis-free form);
* arrival traces are seed-deterministic and rate-faithful.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import dispatch
from repro.core.elastic import provision
from repro.serving import (Autoscaler, AutoscalerConfig, EngineConfig,
                           Scenario, ServingEngine, VirtualClock)
from repro.serving.metrics import ServingMetrics
from repro.serving.scenario import (bursty_rate, diurnal_rate,
                                    sample_arrival_times)


@pytest.fixture(scope="module")
def cfg():
    return get_config("deepseek-r1").reduced()


def _engine(cfg, mode="eaas", num_servers=4, **kw):
    kw.setdefault("n_redundant", 2)
    ecfg = EngineConfig(mode=mode, num_servers=num_servers, max_batch=4,
                        max_seq=64, tp_batch_cap=2, restart_steps=40,
                        tp_restart_steps=10, **kw)
    return ServingEngine(cfg, ecfg, clock=VirtualClock())


# ------------------------------------------------------------- determinism

def test_virtual_clock_determinism(cfg):
    """Same seed ⇒ identical ServingMetrics timeline, bit for bit."""
    def one_run():
        sc = (Scenario(horizon=0.2, seed=7, max_new=6, vocab=cfg.vocab_size)
              .poisson(rate=100)
              .fail(rank=1, t=0.08).recover(rank=1, t=0.15))
        res = sc.run(_engine(cfg))
        return res.metrics

    m1, m2 = one_run(), one_run()
    assert m1.fingerprint() == m2.fingerprint()
    assert m1.timeline == m2.timeline
    assert m1.events == m2.events
    assert m1.itls == m2.itls
    # and it actually did something
    assert m1.completed == m1.total_requests > 0


def test_different_seed_changes_trace(cfg):
    traces = []
    for seed in (0, 1):
        sc = Scenario(horizon=0.2, seed=seed, max_new=4,
                      vocab=cfg.vocab_size).poisson(rate=100)
        traces.append([r.arrival_time for r in sc.build_arrivals()])
    assert traces[0] != traces[1]


# ----------------------------------------------------------- fault ordering

def test_fault_ordering_eaas_vs_monolithic(cfg):
    """Paper Fig. 10: under the same scripted failure, the EAAS throughput
    dip is strictly smaller than the monolithic group-restart stall."""
    def drop(mode):
        def run(with_fail):
            sc = Scenario(horizon=0.25, seed=3, max_new=8,
                          vocab=cfg.vocab_size).poisson(rate=300)
            if with_fail:
                sc.fail(rank=1, t=0.1).recover(rank=1, t=0.2)
            return sc.run(_engine(cfg, mode)).metrics

        m0, m1 = run(False), run(True)
        assert m1.completed == m1.total_requests      # nobody loses work
        return 1.0 - m1.decode_throughput / m0.decode_throughput

    d_eaas = drop("eaas")
    d_mono = drop("monolithic_ep")
    assert 0.0 < d_eaas < d_mono
    # the EAAS dip is the lost compute share, not a stall: well under half
    # the monolithic drop at these restart costs
    assert d_eaas < 0.5 * d_mono


def test_eaas_failure_no_halted_steps(cfg):
    sc = (Scenario(horizon=0.2, seed=0, max_new=6, vocab=cfg.vocab_size)
          .poisson(rate=200).fail(rank=2, t=0.05).recover(rank=2, t=0.15))
    res = sc.run(_engine(cfg, "eaas"))
    assert not any(t.get("halted") for t in res.metrics.timeline)
    fails = [e for e in res.metrics.events if e["event"] == "server_fail"]
    assert len(fails) == 1 and fails[0]["rank"] == 2


# -------------------------------------------------------------- autoscaler

def test_autoscaler_converges_to_provision(cfg):
    """Rate step down: the pool walks to provision(rate)'s server count."""
    asc = Autoscaler(AutoscalerConfig(rate_per_server=40, min_servers=1,
                                      max_servers=8, window=0.2,
                                      cooldown=0.1))
    eng = _engine(cfg, num_servers=8, n_redundant=1)
    sc = (Scenario(horizon=1.2, seed=1, max_new=4, vocab=cfg.vocab_size)
          .poisson(rate=300).set_rate(t=0.6, rate=80).autoscale(asc))
    res = sc.run(eng)
    target = provision(80, rate_per_server=40, granularity=1)
    assert eng.pool.num_servers == target
    # it scaled down from 8 through intermediate sizes, not in one jump
    sizes = {n for _, n in res.server_trace}
    assert 8 in sizes and target in sizes
    scale_events = [e for e in res.metrics.events if e["event"] == "scale"]
    assert scale_events and scale_events[-1]["to"] == target
    # all work still completes across the resizes
    assert res.metrics.completed == res.metrics.total_requests > 0


def test_autoscaler_granularity_matches_provision(cfg):
    """Monolithic group granularity provisions in whole groups (the gap
    behind the paper's 37.5% saving)."""
    asc = Autoscaler(AutoscalerConfig(rate_per_server=40, min_servers=1,
                                      max_servers=8, granularity=4,
                                      window=0.2, cooldown=0.1))
    eng = _engine(cfg, num_servers=8, n_redundant=1)
    sc = (Scenario(horizon=0.8, seed=1, max_new=4, vocab=cfg.vocab_size)
          .poisson(rate=80).autoscale(asc))
    sc.run(eng)
    # fine-grained target would be 2; group granularity keeps 4
    assert eng.pool.num_servers == provision(80, 40, granularity=4) == 4


def test_explicit_scale_event_resizes_pool(cfg):
    eng = _engine(cfg, num_servers=4, n_redundant=1)
    sc = (Scenario(horizon=0.3, seed=0, max_new=4, vocab=cfg.vocab_size)
          .poisson(rate=100).scale_to(n=2, t=0.1).scale_to(n=8, t=0.2))
    res = sc.run(eng)
    assert eng.pool.num_servers == 8
    tos = [e["to"] for e in res.metrics.events if e["event"] == "scale"]
    assert tos == [2, 8]
    assert res.metrics.completed == res.metrics.total_requests > 0


# ------------------------------------------------- dispatch method equality

def test_pack_sort_equals_onehot_without_hypothesis():
    """pack(method="sort") and pack(method="onehot") produce identical
    buffers — including under capacity overflow (drops)."""
    for seed, (T, k, S, C) in enumerate([(32, 4, 4, 64), (16, 2, 2, 8),
                                         (64, 4, 8, 16), (8, 1, 4, 2)]):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(T, 8)).astype(np.float32))
        eids = jnp.asarray(rng.integers(0, 100, size=(T, k)).astype(np.int32))
        scores = jnp.asarray(rng.random(size=(T, k)).astype(np.float32))
        servers = jnp.asarray(rng.integers(0, S, size=(T, k)).astype(np.int32))
        a = dispatch.pack(x, eids, scores, servers, S, C, method="sort")
        b = dispatch.pack(x, eids, scores, servers, S, C, method="onehot")
        for field in ("hidden", "expert_id", "score", "counts",
                      "combine_slot", "dropped"):
            np.testing.assert_array_equal(
                np.asarray(getattr(a, field)), np.asarray(getattr(b, field)),
                err_msg=f"{field} differs (seed={seed})")
        # combine round-trips identically through either buffer
        ya = dispatch.combine(a.hidden, a.combine_slot)
        yb = dispatch.combine(b.hidden, b.combine_slot)
        np.testing.assert_array_equal(np.asarray(ya), np.asarray(yb))


# --------------------------------------------------------- traces & metrics

def test_arrival_rate_follows_set_rate():
    sc = (Scenario(horizon=2.0, seed=0).poisson(rate=200)
          .set_rate(t=1.0, rate=20))
    times = np.asarray([r.arrival_time for r in sc.build_arrivals()])
    first, second = np.sum(times < 1.0), np.sum(times >= 1.0)
    assert first > 5 * second            # 10x rate drop, Poisson noise aside
    assert times.max() < 2.0 and np.all(np.diff(times) >= 0)


def test_bursty_and_diurnal_rate_shapes():
    b = bursty_rate(base=10, peak=100, period=1.0, duty=0.2)
    assert b(0.1) == 100 and b(0.5) == 10 and b(1.1) == 100
    d = diurnal_rate(mean=40, amplitude=0.5, period=1.0)
    assert d(0.25) == pytest.approx(60) and d(0.75) == pytest.approx(20)
    rng = np.random.default_rng(0)
    times = sample_arrival_times(d, 4.0, rng)
    assert len(times) == pytest.approx(160, rel=0.25)    # mean 40/s * 4s


def test_throughput_curve_bins_conserve_tokens():
    m = ServingMetrics()
    for i in range(10):
        m.timeline.append({"t": 0.01 * (i + 1), "tokens": 2, "halted": False})
    m.total_output_tokens = 20
    curve = m.throughput_curve(bin_width=0.05)
    assert sum(thr * 0.05 for _, thr in curve) == pytest.approx(20)
    assert m.fingerprint() != ServingMetrics().fingerprint()
