"""Paper Fig. 8 — overlap ablation: decoding with and without client
pipelining, plus the chunked-prefill latency trade.

Thin driver over the scenario harness: one seeded bursty trace (long
prompts — the regime where prefill stalls hurt) replayed across engine
variants under the overlap-aware virtual clock:

* ``pipelined``   — two-microbatch decode, expert round-trip of microbatch
  A overlapped with the attention of microbatch B (charged
  ``max(attn, expert) + ε`` per step);
* ``serialized``  — the same two-microbatch split with the collectives
  exposed on the critical path (charged the sum — the ablation baseline);
* ``lockstep``    — the pre-split single-batch step (cost == serialized;
  kept as the semantics reference);

crossed with unchunked vs chunked prefill (``policy="fair"``), which trades
a little prefill overhead (one ``prefill_base`` per chunk) for bounded
decode gaps — the max-ITL column.

Outputs decode throughput and ITL/TTFT summaries per variant.  Runs under
the virtual clock by default — deterministic and reproducible bit-for-bit
(pass ``clock="wall"`` for real step timing).
"""

from __future__ import annotations

from typing import Dict, List

from benchmarks.common import (bench_model_cfg, csv_row, run_scenario,
                               save_result)
from repro.serving import EngineConfig, Scenario, VirtualClock

VARIANTS = (
    ("pipelined", dict(decode_mode="pipelined")),
    ("serialized", dict(decode_mode="serialized")),
    ("lockstep", dict(decode_mode="lockstep")),
    ("pipelined_chunked", dict(decode_mode="pipelined", prefill_chunk=8,
                               policy="fair")),
    ("serialized_chunked", dict(decode_mode="serialized", prefill_chunk=8,
                                policy="fair")),
)


def _engine_cfg(**kw) -> EngineConfig:
    # dispatch buffers sized for the longest prefill step so no variant
    # drops tokens — outputs stay identical across the whole sweep
    return EngineConfig(mode="eaas", num_servers=4, max_batch=4, max_seq=128,
                        n_redundant=2, pool_tokens_per_client=128, **kw)


def _scenario(vocab: int, horizon: float, max_new: int) -> Scenario:
    # flash-crowd bursts of long prompts: prefill pressure + decode load
    return (Scenario(horizon=horizon, seed=0, prompt_len=32,
                     max_new=max_new, vocab=vocab)
            .bursty(base=20, peak=200, period=0.2, duty=0.3))


def run(horizon: float = 0.6, max_new: int = 16,
        clock=None) -> Dict:
    cfg = bench_model_cfg()
    if clock is None:
        # expert-heavy decode cost: the overlap term dominates the base,
        # as on a real mesh where the a2a round-trip is the long pole
        clock = VirtualClock(decode_per_token=4e-3)
    out = {"figure": "fig8_overlap_ablation",
           "clock": type(clock).__name__, "variants": {}}
    for name, kw in VARIANTS:
        _, res = run_scenario(cfg, _engine_cfg(**kw),
                              _scenario(cfg.vocab_size, horizon, max_new),
                              clock=clock)
        m = res.metrics
        out["variants"][name] = {
            "decode_tok_per_s": m.decode_throughput,
            "wall_time_s": m.wall_time,
            "itl": m.itl_stats(),
            "ttft": m.ttft_stats(),
            "completed": m.completed,
        }
    pipe = out["variants"]["pipelined"]["decode_tok_per_s"]
    ser = out["variants"]["serialized"]["decode_tok_per_s"]
    out["overlap_speedup"] = pipe / max(ser, 1e-9)
    save_result("fig8_overlap_ablation", out)
    return out


def main() -> List[str]:
    res = run()
    rows = []
    for name, r in res["variants"].items():
        rows.append(csv_row(
            f"fig8_{name}", 0.0,
            f"tok_per_s={r['decode_tok_per_s']:.1f}"
            f";itl_max_ms={r['itl']['max'] * 1e3:.2f}"
            f";ttft_p99_ms={r['ttft']['p99'] * 1e3:.2f}"))
    rows.append(csv_row("fig8_overlap_speedup", 0.0,
                        f"x{res['overlap_speedup']:.3f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
