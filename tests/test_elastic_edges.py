"""Edge cases of the elastic expert-service tier (hypothesis-free).

ServerPool rebalance/scale liveness invariants, expert_server.serve
miss/served accounting for unhosted experts, provision()/resource_saving()
at zero and fractional rates, and the weight-resharding path behind
engine.scale_to.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.elastic import ServerPool, provision, resource_saving
from repro.core.expert_server import (ServerWeights, build_server_weights,
                                      extract_bank, make_local_table,
                                      reshard_server_weights, serve)


@pytest.fixture()
def cfg():
    return get_config("deepseek-r1").reduced()     # 8 experts


# ----------------------------------------------------------------- rebalance

def test_rebalance_preserves_liveness_mask(cfg):
    pool = ServerPool(cfg, num_servers=4, tokens_per_client=8, n_redundant=2)
    pool.server_failed(2)
    load = np.ones(cfg.moe.num_experts)
    load[0] = 50.0                                  # hot expert skew
    pool.observe_load(load)
    dead_before = pool.smap.alive.copy()
    pool.rebalance()
    np.testing.assert_array_equal(pool.smap.alive, dead_before)
    assert not pool.smap.alive[2]
    # re-plan actually replicated the hot expert
    assert (pool.smap.table[0] >= 0).sum() >= 2


def test_rebalance_without_traffic_is_noop(cfg):
    pool = ServerPool(cfg, num_servers=4, tokens_per_client=8, n_redundant=2)
    table_before = pool.smap.table.copy()
    pool.rebalance()                                # no EMA yet
    np.testing.assert_array_equal(pool.smap.table, table_before)


# ------------------------------------------------------------------ scale_to

def test_scale_to_preserves_surviving_liveness(cfg):
    pool = ServerPool(cfg, num_servers=4, tokens_per_client=8, n_redundant=1)
    pool.server_failed(1)
    pool.scale_to(8)
    assert pool.num_servers == 8
    assert not pool.smap.alive[1]                   # survivor keeps its state
    assert pool.smap.alive[[0, 2, 3, 4, 5, 6, 7]].all()  # new ranks alive
    pool.scale_to(2)
    assert pool.num_servers == 2
    assert pool.smap.alive[0] and not pool.smap.alive[1]


def test_scale_to_rejects_non_divisor(cfg):
    pool = ServerPool(cfg, num_servers=4, tokens_per_client=8)
    with pytest.raises(ValueError, match="feasible"):
        pool.scale_to(3)                            # 8 experts % 3 != 0
    assert pool.feasible_counts() == [1, 2, 4, 8]


def test_scale_to_mapping_local_table_coherent(cfg):
    """After a resize every mapped replica actually hosts the expert
    (the miss == 0 property)."""
    pool = ServerPool(cfg, num_servers=4, tokens_per_client=8, n_redundant=2)
    pool.observe_load(np.arange(cfg.moe.num_experts, dtype=float) + 1)
    pool.scale_to(2)
    E = cfg.moe.num_experts
    local = make_local_table(E, pool.num_servers, pool.redundant_table)
    for e in range(E):
        for s in pool.smap.table[e][pool.smap.table[e] >= 0]:
            assert local[s, e] >= 0, (e, s)


def test_reshard_roundtrips_weight_bank():
    rng = np.random.default_rng(0)
    E, d, f = 8, 4, 6
    bank = {k: jnp.asarray(rng.normal(size=(E, d, f)).astype(np.float32))
            for k in ("w_gate", "w_up", "w_down")}
    red4 = np.array([[1], [3], [5], [7]], np.int32)
    sw4 = build_server_weights(bank, 4, red4)
    # bank recovery from the primary slots is exact
    bank_rt = extract_bank(sw4, E)
    for k in bank:
        np.testing.assert_array_equal(np.asarray(bank_rt[k]),
                                      np.asarray(bank[k]))
    # reshard 4 -> 2 matches building from the bank directly
    red2 = np.array([[6], [0]], np.int32)
    sw2 = reshard_server_weights(sw4, E, 2, red2)
    expect = build_server_weights(bank, 2, red2)
    for k in bank:
        np.testing.assert_array_equal(np.asarray(sw2[k]),
                                      np.asarray(expect[k]))
    # and a stacked leading (layer) dim passes through untouched
    sw4_l = {k: jnp.stack([v, v]) for k, v in sw4.items()}
    sw2_l = reshard_server_weights(sw4_l, E, 2, red2)
    for k in bank:
        assert sw2_l[k].shape == (2,) + expect[k].shape
        np.testing.assert_array_equal(np.asarray(sw2_l[k][1]),
                                      np.asarray(expect[k]))


# ------------------------------------------------------- serve miss accounting

def test_serve_counts_miss_for_unhosted_expert():
    rng = np.random.default_rng(0)
    E, L, d, f, C = 4, 2, 8, 16, 4
    # this server hosts experts {0, 3} in slots {0, 1}
    local = jnp.asarray(np.array([0, -1, -1, 1], np.int32))
    w = ServerWeights(
        w_gate=jnp.asarray(rng.normal(size=(L, d, f)).astype(np.float32)),
        w_up=jnp.asarray(rng.normal(size=(L, d, f)).astype(np.float32)),
        w_down=jnp.asarray(rng.normal(size=(L, f, d)).astype(np.float32)),
        local_table=local)
    tokens = jnp.asarray(rng.normal(size=(1, C, d)).astype(np.float32))
    eids = jnp.asarray(np.array([[0, 2, 3, 0]], np.int32))   # expert 2 unhosted
    scores = jnp.ones((1, C), jnp.float32)
    counts = jnp.asarray(np.array([3], np.int32))            # last slot invalid

    out, stats = serve(tokens, eids, scores, counts, w, impl="xla_ragged")
    assert int(stats.miss) == 1                     # the expert-2 token
    assert int(stats.served) == 2                   # experts 0 and 3
    out = np.asarray(out)
    assert np.any(out[0, 0] != 0) and np.any(out[0, 2] != 0)
    np.testing.assert_array_equal(out[0, 1], 0)     # miss row zeroed
    np.testing.assert_array_equal(out[0, 3], 0)     # invalid row zeroed


def test_serve_all_hosted_no_miss():
    rng = np.random.default_rng(1)
    E, S, d = 4, 2, 8
    bank = {k: jnp.asarray(rng.normal(size=(E, d, d)).astype(np.float32))
            for k in ("w_gate", "w_up", "w_down")}
    sw = build_server_weights(bank, S, np.zeros((S, 0), np.int32))
    local = make_local_table(E, S, np.zeros((S, 0), np.int32))
    w = ServerWeights(sw["w_gate"][0], sw["w_up"][0], sw["w_down"][0],
                      jnp.asarray(local[0]))
    tokens = jnp.asarray(rng.normal(size=(1, 2, d)).astype(np.float32))
    eids = jnp.asarray(np.array([[0, 1]], np.int32))
    _, stats = serve(tokens, eids, jnp.ones((1, 2)), jnp.asarray([2]), w,
                     impl="xla_ragged")
    assert int(stats.miss) == 0 and int(stats.served) == 2


# --------------------------------------------------- provision edge behaviour

def test_provision_zero_and_fractional_rates():
    assert provision(0.0, 10.0) == 1                # never provision zero
    assert provision(-5.0, 10.0) == 1
    assert provision(0.1, 10.0) == 1                # fractional need ceils
    assert provision(10.1, 10.0) == 2
    # degenerate per-server rate: the 1e-9 guard yields a finite (huge)
    # demand instead of a ZeroDivisionError
    assert provision(0.5, 0.0) >= 1
    assert provision(5.0, 1.0, granularity=4) == 8  # group rounding
    assert provision(8.0, 1.0, granularity=4) == 8


def test_resource_saving_zero_and_fractional():
    # zero traffic: EAAS keeps 1, monolithic keeps one whole group
    assert resource_saving(0.0, 10.0, monolithic_group=8) == pytest.approx(
        1 - 1 / 8)
    # the paper's 37.5%: 5120 req/s at 128 req/s/server vs a 64-group
    assert resource_saving(5120, 8192 / 64, 64) == pytest.approx(0.375)
    # fractional rate just under one server of traffic
    assert resource_saving(0.9 * 10, 10.0, 4) == pytest.approx(1 - 1 / 4)
    # when fine == coarse there is nothing to save
    assert resource_saving(32.0, 1.0, 4) == pytest.approx(0.0)
