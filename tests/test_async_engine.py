"""Differential battery: ``exec_mode='async'`` vs ``'lockstep'``.

Async execution is where silent nondeterminism breeds, so every claim the
event-driven expert tier makes is pinned against the lockstep engine on
seeded scenario traces:

* **bitwise token identity**: bursty / diurnal / straggler traces replayed
  under both modes produce identical per-request token streams (values are
  computed eagerly at dispatch and are independent of batch composition,
  placement, and timing — only the clock moves differently);
* **throughput**: on a saturated trace the async engine's ping-pong wave
  pipelining (attention share overlapping the expert share) finishes the
  same work no slower than lockstep;
* **tail latency**: under one injected straggler server, lockstep stretches
  every decode step by the slowest server while async queues only that
  server's micro-batches — async p99 ITL must beat lockstep's (the
  acceptance pin, also gated in ``experiments/baselines/async_tier.json``);
* **faults**: a server failure mid-drain re-dispatches its queued
  micro-batches to survivors with no token loss; a client failure under a
  shared tier strands only that client's work;
* **rebalancing**: migration chunks become tier-occupancy events that
  interleave with in-flight micro-batches, and the migrated weights still
  equal a from-scratch rebuild of the committed placement;
* **determinism**: same seed ⇒ identical metrics *and* event-log
  fingerprints; the lockstep path records no event-tier state at all (its
  fingerprint — and every committed baseline — is unchanged).
"""

import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import expert_server
from repro.serving import (Cluster, ClusterConfig, EngineConfig, Scenario,
                           ServingEngine, VirtualClock)

NUM_SERVERS, MAX_BATCH = 4, 4


@pytest.fixture(scope="module")
def cfg():
    return get_config("deepseek-r1").reduced()


def _ecfg(**kw):
    kw.setdefault("mode", "eaas")
    kw.setdefault("num_servers", NUM_SERVERS)
    kw.setdefault("max_batch", MAX_BATCH)
    kw.setdefault("max_seq", 64)
    kw.setdefault("n_redundant", 2)
    # drop-free dispatch: the identity pins require placement/routing to
    # never change which tokens reach their experts
    kw.setdefault("pool_tokens_per_client", 16)
    return EngineConfig(**kw)


def _engine(cfg, exec_mode, clock=None, **kw):
    return ServingEngine(cfg, _ecfg(exec_mode=exec_mode, **kw), seed=0,
                         clock=clock or VirtualClock())


def _expert_heavy_clock():
    """Cost model where the expert share dominates the step (share 0.8):
    under this regime a straggler server actually queues work — with the
    default attention-heavy constants a 6x straggler still finishes inside
    the client's attention time and nothing ever waits."""
    return VirtualClock(decode_base=2e-4, decode_per_token=2e-3,
                        expert_share=0.8)


def _tokens(res):
    return {r.request_id: tuple(r.output_tokens) for r in res.requests}


def _bursty(cfg):
    return (Scenario(horizon=0.06, seed=11, prompt_len=8, max_new=12,
                     vocab=cfg.vocab_size)
            .bursty(base=50, peak=600, period=0.03, duty=0.3))


def _diurnal(cfg):
    return (Scenario(horizon=0.15, seed=3, prompt_len=8, max_new=8,
                     vocab=cfg.vocab_size)
            .diurnal(mean=150, amplitude=0.8, period=0.1))


def _straggler(cfg):
    return (Scenario(horizon=0.2, seed=7, prompt_len=8, max_new=6,
                     vocab=cfg.vocab_size)
            .poisson(rate=100)
            .slow_server(1, t=0.01, factor=6.0))


TRACES = {"bursty": _bursty, "diurnal": _diurnal, "straggler": _straggler}


@pytest.fixture(scope="module")
def runs(cfg):
    """{trace: {mode: (engine, result, tokens)}} for the seeded traces,
    plus an async rerun of the straggler trace (determinism pin)."""
    out = {}
    for name, make in TRACES.items():
        out[name] = {}
        clk = _expert_heavy_clock if name == "straggler" else VirtualClock
        for mode in ("lockstep", "async"):
            eng = _engine(cfg, mode, clock=clk())
            res = make(cfg).run(eng)
            out[name][mode] = (eng, res, _tokens(res))
    eng = _engine(cfg, "async", clock=_expert_heavy_clock())
    res = _straggler(cfg).run(eng)
    out["straggler"]["async_rerun"] = (eng, res, _tokens(res))
    return out


# --------------------------------------------------------------- identity

@pytest.mark.parametrize("trace", sorted(TRACES))
def test_async_bitwise_token_identity(runs, trace):
    """The acceptance pin: each seeded trace replayed under async produces
    the same per-request token stream as lockstep, bit for bit, and both
    modes complete every request (drop-free capacity)."""
    _, res_l, tok_l = runs[trace]["lockstep"]
    _, res_a, tok_a = runs[trace]["async"]
    assert tok_l == tok_a
    assert res_l.metrics.completed == res_l.metrics.total_requests > 0
    assert res_a.metrics.completed == res_a.metrics.total_requests


def test_async_throughput_not_worse(runs):
    """On the saturated bursty trace the async engine's wave pipelining
    overlaps the client's attention share with the tier's expert share, so
    it drains the same token count no slower than lockstep."""
    eng_l, _, _ = runs["bursty"]["lockstep"]
    eng_a, _, _ = runs["bursty"]["async"]
    thr_l = eng_l.metrics.total_output_tokens / eng_l.clock
    thr_a = eng_a.metrics.total_output_tokens / eng_a.clock
    assert eng_a.metrics.total_output_tokens \
        == eng_l.metrics.total_output_tokens
    assert thr_a >= thr_l, (thr_a, thr_l)


def test_straggler_p99_itl_improves(runs):
    """The acceptance pin: with server 1 running 6x slow, lockstep waits
    for it every decode step while async only queues that server's
    micro-batches — async p99 ITL beats lockstep's."""
    eng_l, _, _ = runs["straggler"]["lockstep"]
    eng_a, _, _ = runs["straggler"]["async"]
    assert eng_a.metrics.p99_itl < eng_l.metrics.p99_itl, \
        (eng_a.metrics.p99_itl, eng_l.metrics.p99_itl)
    # the tier recorded real queueing (the straggler's micro-batches wait)
    assert eng_a.metrics.queue_delays
    assert max(eng_a.metrics.queue_delays) > 0.0


# ------------------------------------------------------------ determinism

def test_async_same_seed_same_fingerprints(runs):
    """Same seed ⇒ identical metrics fingerprint AND identical fired-event
    log fingerprint (the discrete-event determinism contract)."""
    eng_a, res_a, tok_a = runs["straggler"]["async"]
    eng_b, res_b, tok_b = runs["straggler"]["async_rerun"]
    assert tok_a == tok_b
    assert res_a.metrics.fingerprint() == res_b.metrics.fingerprint()
    assert eng_a.timeline.fingerprint() == eng_b.timeline.fingerprint()
    assert eng_a.timeline.log            # the log actually recorded events


def test_lockstep_records_no_event_state(runs):
    """The lockstep path never touches the event tier: no queue delays, no
    fired events — its metrics fingerprint (and every committed benchmark
    baseline) is exactly what it was before exec_mode existed."""
    eng_l, _, _ = runs["straggler"]["lockstep"]
    assert eng_l.metrics.queue_delays == []
    assert eng_l.timeline.log == []
    assert eng_l.tier is None


def test_async_depth1_matches_lockstep_cadence(cfg):
    """The ablation knob: async_depth=1 (strict wave-at-a-time) keeps
    token identity and lands within 1% of the lockstep wall clock — the
    pipelining win comes from depth >= 2, not from bookkeeping drift."""
    sc = (Scenario(horizon=0.1, seed=5, prompt_len=8, max_new=6,
                   vocab=cfg.vocab_size).poisson(rate=80))
    eng_l = _engine(cfg, "lockstep")
    res_l = sc.run(eng_l)
    sc = (Scenario(horizon=0.1, seed=5, prompt_len=8, max_new=6,
                   vocab=cfg.vocab_size).poisson(rate=80))
    eng_a = _engine(cfg, "async", async_depth=1)
    res_a = sc.run(eng_a)
    assert _tokens(res_l) == _tokens(res_a)
    assert abs(eng_a.clock - eng_l.clock) <= 0.01 * eng_l.clock


def test_shifting_hot_set_completes_deterministically(cfg):
    """Shifting-hot-set traces re-bias the router at *clock* times, which
    land between different token indices in each mode — cross-mode token
    identity is structurally unpinnable here.  What must hold: both modes
    complete every request, and the async replay is self-deterministic."""
    def make():
        return (Scenario(horizon=0.12, seed=13, prompt_len=8, max_new=6,
                         vocab=cfg.vocab_size)
                .poisson(rate=100)
                .shifting_hot_set(alpha=1.2, period=0.04))
    res_l = make().run(_engine(cfg, "lockstep"))
    res_a = make().run(_engine(cfg, "async"))
    res_b = make().run(_engine(cfg, "async"))
    assert res_l.metrics.completed == res_l.metrics.total_requests > 0
    assert res_a.metrics.completed == res_a.metrics.total_requests \
        == res_l.metrics.total_requests
    assert _tokens(res_a) == _tokens(res_b)
    assert res_a.metrics.fingerprint() == res_b.metrics.fingerprint()


def test_max_new_tokens_1_matches_lockstep_and_drains(cfg):
    """Regression: with ``max_new_tokens=1`` the prefill-sampled first
    token already satisfies the done condition.  Lockstep still decodes
    each ready slot exactly once and releases it at the post-append done
    check; async must dispatch those slots the same way rather than hold
    them with no wave in flight — held-forever slots were zombies that
    filled the batch and starved serving (0 completions), and the
    one-token streams diverged from lockstep's two-token streams."""
    def make():
        return (Scenario(horizon=0.06, seed=21, prompt_len=8, max_new=1,
                         vocab=cfg.vocab_size).poisson(rate=100))
    res_l = make().run(_engine(cfg, "lockstep"))
    res_a = make().run(_engine(cfg, "async"))
    assert res_l.metrics.completed == res_l.metrics.total_requests > 0
    assert res_a.metrics.completed == res_a.metrics.total_requests
    assert _tokens(res_l) == _tokens(res_a)


def test_prefill_sampled_eos_matches_lockstep(cfg):
    """Regression, EOS flavour: pick an ``eos_token`` a request provably
    samples at *prefill* time (probed from an eos-free lockstep run —
    first-token sampling keys depend only on the request id, so the probe
    transfers).  Lockstep's done check never inspects the prefill token,
    so such a request keeps decoding; async must not hold its pend-empty
    slot either — streams stay bitwise identical and everything drains."""
    def make():
        return (Scenario(horizon=0.05, seed=23, prompt_len=8, max_new=6,
                         vocab=cfg.vocab_size).poisson(rate=80))
    probe = make().run(_engine(cfg, "lockstep"))
    eos = int(min(probe.requests,
                  key=lambda r: r.request_id).output_tokens[0])
    res_l = make().run(_engine(cfg, "lockstep", eos_token=eos))
    res_a = make().run(_engine(cfg, "async", eos_token=eos))
    # the edge case actually triggered: some request's first token is EOS
    assert any(r.output_tokens and r.output_tokens[0] == eos
               for r in res_l.requests)
    assert res_l.metrics.completed == res_l.metrics.total_requests > 0
    assert res_a.metrics.completed == res_a.metrics.total_requests
    assert _tokens(res_l) == _tokens(res_a)


@pytest.mark.parametrize("depth", [1, 2, 4])
def test_depth_k_bitwise_token_identity(runs, cfg, depth):
    """Depth-K wave pipelining keeps the identity pin at every depth: the
    speculative waves change only when work is dispatched, never which
    tokens it computes (mispredicted waves are cancelled on the timeline
    and recomputed identically)."""
    _, _, tok_l = runs["bursty"]["lockstep"]
    eng = _engine(cfg, "async", async_depth=depth)
    res = _bursty(cfg).run(eng)
    assert _tokens(res) == tok_l
    assert res.metrics.completed == res.metrics.total_requests > 0


def test_async_depth_validation(cfg):
    with pytest.raises(ValueError):
        _engine(cfg, "async", async_depth=0)
    with pytest.raises(ValueError):
        _engine(cfg, "async", queue_mode="bogus")
    with pytest.raises(ValueError):
        _engine(cfg, "async", lane_budget=0)


def test_hot_expert_lanes_beat_server_queue(cfg):
    """The lane acceptance pin: Zipf-skewed traffic with a straggler on a
    hot expert's server.  Per-expert lanes with a service budget of 2 let
    cold co-located experts overlap the hot lane's backlog; the aggregate
    per-server FIFO serializes them behind it.  Lanes must win on
    throughput AND p99 ITL, with bitwise-identical token streams (the
    queue model changes timing only).  The moderate ``scale=0.5`` bias
    keeps several lanes live per server — at extreme skew every server
    degenerates to one lane and the models coincide."""
    wide = cfg.replace(moe=dataclasses.replace(cfg.moe, num_experts=16))

    def run(queue_mode):
        ecfg = _ecfg(exec_mode="async", max_batch=8,
                     pool_tokens_per_client=32, charge_imbalance=True,
                     queue_mode=queue_mode, lane_budget=2)
        eng = ServingEngine(wide, ecfg, seed=0,
                            clock=_expert_heavy_clock())
        sc = (Scenario(horizon=0.3, seed=19, prompt_len=8, max_new=16,
                       vocab=wide.vocab_size)
              .poisson(rate=80).zipf_skew(alpha=1.2, scale=0.5)
              .slow_server(3, t=0.015, factor=6.0))
        res = sc.run(eng)
        return eng, res

    eng_srv, res_srv = run("server")
    eng_lane, res_lane = run("expert")
    assert _tokens(res_srv) == _tokens(res_lane)
    assert res_lane.metrics.completed == res_lane.metrics.total_requests > 0
    # the regime check: several expert lanes actually materialized
    assert max(len(q.lanes) for q in eng_lane.tier.queues) >= 3
    thr_srv = eng_srv.metrics.total_output_tokens / eng_srv.clock
    thr_lane = eng_lane.metrics.total_output_tokens / eng_lane.clock
    assert thr_lane >= thr_srv, (thr_lane, thr_srv)
    assert eng_lane.metrics.p99_itl < eng_srv.metrics.p99_itl, \
        (eng_lane.metrics.p99_itl, eng_srv.metrics.p99_itl)
    # the lane engine actually recorded per-lane queueing breakdown, and
    # the per-server groups partition exactly the flat queue_delays list
    by_server = eng_lane.metrics.queue_delay_stats(by="server")
    groups = eng_lane.metrics._queue_groups("server")
    assert by_server and set(by_server) == set(groups)
    assert sum(len(v) for v in groups.values()) \
        == len(eng_lane.metrics.queue_delays)


def test_queue_aware_rebalance_token_identity(cfg):
    """The rebalance gate reads live tier backlog instead of routed counts
    — it may stage different migrations at different times, but tokens are
    placement-independent: streams stay bitwise identical between the
    queue-aware and count-only gates, and the queue-aware plan events
    record the modeled delay they acted on."""
    wide = cfg.replace(moe=dataclasses.replace(cfg.moe, num_experts=16))

    def run(queue_aware):
        ecfg = _ecfg(exec_mode="async", max_batch=8,
                     pool_tokens_per_client=32, charge_imbalance=True,
                     rebalance_interval=0.02,
                     rebalance_queue_aware=queue_aware)
        eng = ServingEngine(wide, ecfg, seed=0,
                            clock=_expert_heavy_clock())
        sc = (Scenario(horizon=0.5, seed=7, prompt_len=8, max_new=24,
                       vocab=wide.vocab_size)
              .poisson(rate=60).zipf_skew(alpha=1.2, scale=1.0))
        res = sc.run(eng)
        return eng, res

    eng_q, res_q = run(True)
    eng_c, res_c = run(False)
    assert _tokens(res_q) == _tokens(res_c)
    assert res_q.metrics.completed == res_q.metrics.total_requests > 0
    assert eng_q.metrics.rebalances >= 1
    plans = [e for e in eng_q.metrics.events
             if e["event"] == "rebalance_plan"]
    assert plans and all("queue_delay" in e for e in plans)
    # count-only plans carry no queue fields (the gate never read them)
    assert all("queue_delay" not in e for e in eng_c.metrics.events
               if e["event"] == "rebalance_plan")


# ----------------------------------------------------------------- faults

def test_fail_server_mid_drain_redispatches_without_token_loss(cfg):
    """A server dies while micro-batches sit in its queue: the tier moves
    them to surviving replicas (fresh completion events, stale ones
    ignored by generation), every request still completes, and the token
    streams still match lockstep bit for bit — replica failover changes
    *where* an expert runs, never *what* it computes."""
    def make():
        return (Scenario(horizon=0.15, seed=17, prompt_len=8, max_new=8,
                         vocab=cfg.vocab_size)
                .poisson(rate=120)
                .fail(0, t=0.04).recover(0, t=0.1))
    eng_l = _engine(cfg, "lockstep")
    res_l = make().run(eng_l)
    eng_a = _engine(cfg, "async")
    res_a = make().run(eng_a)
    assert _tokens(res_l) == _tokens(res_a)
    assert res_a.metrics.completed == res_a.metrics.total_requests > 0
    assert eng_a.tier.redispatched > 0       # queued work actually moved
    assert eng_a.tier.in_flight() == 0       # conservation at drain
    assert eng_a.tier.enqueued == (eng_a.tier.completed
                                   + eng_a.tier.cancelled)


def test_fail_client_async_strands_only_that_client(cfg):
    """Cluster half of the fault story: with one shared tier, killing one
    attention client cancels only its queued micro-batches; the sibling
    keeps serving and the cluster drains clean."""
    cl = Cluster(cfg, ClusterConfig(clients=2,
                                    engine=_ecfg(exec_mode="async")),
                 seed=0, clock_factory=VirtualClock)
    sc = (Scenario(horizon=0.15, seed=9, prompt_len=8, max_new=8,
                   vocab=cfg.vocab_size, clients=2)
          .poisson(rate=120)
          .fail_client(i=0, t=0.05))
    res = sc.run(cl)
    m = res.metrics
    assert m.failed_requests > 0
    assert m.completed > 0
    assert m.completed + m.failed_requests == m.total_requests
    # the shared tier accounted the stranded client's micro-batches as
    # cancelled, and nothing is left in flight after the drain
    tier = cl._tier
    assert tier.cancelled > 0
    assert tier.in_flight() == 0
    # the surviving client's engine kept its own timeline consistent
    assert cl.clients[1].metrics.completed > 0


# ------------------------------------------------------------- rebalancing

def test_rebalance_chunks_interleave_and_match_rebuild(cfg):
    """Live rebalancing under async: migration chunks occupy the tier's
    queues (they interleave with in-flight micro-batches — the clients'
    clocks never stall), token streams still match lockstep bit for bit,
    and the migrated weights equal a from-scratch reshard of the committed
    placement — the ``migrate_slots == rebuild`` equivalence of
    ``tests/test_rebalance.py``, now holding through interleaved events."""
    wide = cfg.replace(moe=dataclasses.replace(cfg.moe, num_experts=16))

    def run(exec_mode):
        ecfg = _ecfg(exec_mode=exec_mode, max_batch=8,
                     pool_tokens_per_client=32, charge_imbalance=True,
                     rebalance_interval=0.02)
        eng = ServingEngine(wide, ecfg, seed=0,
                            clock=_expert_heavy_clock())
        sc = (Scenario(horizon=0.5, seed=7, prompt_len=8, max_new=24,
                       vocab=wide.vocab_size)
              .poisson(rate=60).zipf_skew(alpha=1.2, scale=1.0))
        res = sc.run(eng)
        return eng, res
    eng_l, res_l = run("lockstep")
    eng_a, res_a = run("async")
    assert _tokens(res_l) == _tokens(res_a)
    assert eng_a.metrics.rebalances >= 1
    assert eng_a.metrics.migrated_experts > 0
    assert eng_a.tier.migration_busy > 0.0   # chunks occupied the tier
    # migrate_slots == rebuild: resharding the async engine's migrated
    # weights against its own committed table is an exact no-op
    E = wide.moe.num_experts
    red = eng_a.pool.redundant_table
    def collect(params, out):
        if isinstance(params, dict):
            for k, v in params.items():
                if k == "moe" and isinstance(v, dict) and "servers" in v:
                    out.append(v["servers"])
                else:
                    collect(v, out)
        return out
    layers = collect(eng_a.executor.params, [])
    assert layers
    for sw in layers:
        want = expert_server.reshard_server_weights(
            sw, E, eng_a.pool.num_servers, red)
        for k in sw:
            np.testing.assert_array_equal(np.asarray(sw[k]),
                                          np.asarray(want[k]))
