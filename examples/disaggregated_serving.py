"""Physically-disaggregated EAAS demo: the paper's protocol, literally.

Two attention clients and three expert servers interact only through
shared buffer slots (state flag / header / payload).  The servers batch
requests from BOTH clients dynamically (paper Fig. 5).  Mid-run we kill a
server WITHOUT telling the clients — the request timeout (paper Fig. 6
②(b)) masks it and re-sends to replicas; the answer is bit-identical.

Run:  PYTHONPATH=src python examples/disaggregated_serving.py
"""

import numpy as np

from repro.configs import get_config
from repro.serving.disaggregated import build_cluster


def main():
    cfg = get_config("deepseek-r1").reduced()
    clients, servers, smap, bank = build_cluster(
        cfg, n_clients=2, n_servers=3, n_redundant=3)
    # make every expert 2-homed so any single failure is survivable
    print(f"cluster: {len(clients)} clients / {len(servers)} servers, "
          f"experts per server: "
          f"{[len(s.expert_ids) for s in servers]}")

    def drive():
        for s in servers:
            s.tick()

    rng = np.random.default_rng(0)
    x0 = rng.normal(size=(16, cfg.d_model)).astype(np.float32) * 0.3
    x1 = rng.normal(size=(12, cfg.d_model)).astype(np.float32) * 0.3

    y0_healthy = clients[0].moe_layer(x0, drive)
    y1_healthy = clients[1].moe_layer(x1, drive)
    print(f"healthy pass: server batches = "
          f"{[s.batches for s in servers]}, "
          f"tokens served = {[s.served_tokens for s in servers]}")

    # --- kill server 1 silently: clients discover it via timeout -------
    servers[1].alive = False
    print("\n*** server 1 killed (no notification) ***")
    y0_failover = clients[0].moe_layer(x0, drive)
    print(f"client0 retries (timeout failovers): {clients[0].retries}")
    err = float(np.max(np.abs(y0_healthy - y0_failover)))
    print(f"output delta after failover: {err:.2e}")
    assert err < 1e-3, "failover must be transparent"
    assert not smap.alive[1]

    # --- a new server registers and takes traffic back ------------------
    servers[1].alive = True
    smap.mark_alive(1)
    y0_back = clients[0].moe_layer(x0, drive)
    assert float(np.max(np.abs(y0_healthy - y0_back))) < 1e-3
    print("server 1 re-registered; traffic restored")
    print("disaggregated_serving OK")


if __name__ == "__main__":
    main()
