"""Paper Fig. 10 — decoding throughput under repeated server failures.

Thin driver over the scenario harness (``repro.serving.scenario``): one
scripted fail/recover timeline replayed across all three engine modes
(EAAS / monolithic EP / TP) under saturating traffic.  Failures are
injected one at a time with recovery between them, as in the paper's
experiment (10 sequential GPU failures).  EAAS reroutes to replicas
(throughput dips only by the lost compute share); monolithic EP halts for
a full group restart; TP halts one unit but its weight replication caps
the batch.

Runs under the virtual clock by default — deterministic, CPU-fast, and
reproducible bit-for-bit (pass ``clock="wall"`` for real step timing).
"""

from __future__ import annotations

from typing import Dict, List

from benchmarks.common import (bench_model_cfg, csv_row, run_scenario,
                               save_result)
from repro.serving import EngineConfig, Scenario

MODES = ("eaas", "monolithic_ep", "tp")


def _engine_cfg(mode: str) -> EngineConfig:
    return EngineConfig(mode=mode, num_servers=4, max_batch=4, max_seq=64,
                        tp_batch_cap=2, n_redundant=2, restart_steps=40,
                        tp_restart_steps=10)


def _scenario(rate: float, horizon: float, max_new: int, vocab: int,
              n_failures: int = 0, period: float = 0.1,
              num_servers: int = 4) -> Scenario:
    """Saturating Poisson traffic; every ``period`` one server fails and
    recovers halfway through (ranks cycle over the whole pool)."""
    sc = Scenario(horizon=horizon, seed=0, max_new=max_new, vocab=vocab)
    sc.poisson(rate)
    for i in range(n_failures):
        t0 = 0.05 + period * i
        sc.fail(rank=i % num_servers, t=t0)
        sc.recover(rank=i % num_servers, t=t0 + period / 2)
    return sc


def run(n_failures: int = 4, rate: float = 300.0, max_new: int = 16,
        clock: str = "virtual") -> Dict:
    cfg = bench_model_cfg()
    horizon = 0.05 + 0.1 * n_failures + 0.05
    out = {"figure": "fig10_fault_tolerance", "clock": clock, "modes": {}}

    for mode in MODES:
        _, base = run_scenario(
            cfg, _engine_cfg(mode),
            _scenario(rate, horizon, max_new, cfg.vocab_size), clock=clock)
        _, fail = run_scenario(
            cfg, _engine_cfg(mode),
            _scenario(rate, horizon, max_new, cfg.vocab_size,
                      n_failures=n_failures), clock=clock)
        thr0 = base.metrics.decode_throughput
        thr1 = fail.metrics.decode_throughput
        out["modes"][mode] = {
            "baseline_tok_per_s": thr0,
            "under_failures_tok_per_s": thr1,
            "throughput_drop_pct": 100 * (1 - thr1 / max(thr0, 1e-9)),
            "curve": fail.metrics.throughput_curve(bin_width=0.02),
            "timeline": fail.metrics.timeline[:200],
        }
    save_result("fig10_fault_tolerance", out)
    return out


def main() -> List[str]:
    res = run()
    rows = []
    for mode, r in res["modes"].items():
        rows.append(csv_row(
            f"fig10_{mode}", 0.0,
            f"drop_pct={r['throughput_drop_pct']:.1f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
