"""EAAS core: experts disaggregated into independent, stateless services.

Modules (paper section in parens):
  router          gating + top-k (+ aux losses)              (§2.1)
  mapping         expert→server service discovery table      (Fig. 6)
  dispatch        buffer-slot packing / combine              (§3.2)
  comm            client-initiated transfers (a2a/psum)      (§4.4, adapted)
  expert_server   stateless dynamic-batch server             (§3.3, Fig. 5)
  moe_layer       the composable EaasMoELayer                (Fig. 4)
  monolithic      EP / TP baselines                          (§2.2)
  monitor         heartbeats, state flags, failover          (§3.4, Fig. 6)
  load_balance    EPLB-style replication planner             (§4.5)
  elastic         fine-grained server-pool scaling           (§5.3)
  overlap         double-batch-overlap                       (§4.2)
"""

from repro.core.moe_layer import (MoERuntime, MoEStats, default_runtime,
                                  eaas_moe_apply, init_eaas_moe)  # noqa: F401
