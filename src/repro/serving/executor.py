"""Jitted step execution — the executor half of the engine split.

The :class:`Executor` owns the model params, the batched KV cache, the
per-slot prefill staging caches, and the jitted step variants:

* ``prefill``       — whole-prompt prefill into a fresh batch-1 cache
  (the pre-split path; one compile per bucketed prompt length);
* ``prefill_chunk`` — chunked-prefill continuation against a staging cache
  (decoder family only; one compile per distinct chunk length);
* ``decode``        — one token for the whole slot batch, in one of three
  modes: ``lockstep`` (single full-batch step, the pre-split behaviour),
  ``pipelined`` (two half-batch microbatches as *independent* subgraphs —
  :func:`repro.core.overlap.split_batch_decode` — so the expert round-trip
  of microbatch A overlaps the attention of microbatch B, paper §4.2), or
  ``serialized`` (same split with an artificial dependency: the ablation
  baseline, bit-identical outputs, collectives exposed).

The expert→server mapping, liveness mask and local placement table remain
jit *arguments*: failover and rebalancing never recompile.  A pool resize
(:meth:`resize`) re-shards the expert weights and rebuilds the jits for the
new static server count — the AOT-per-server-count story.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import expert_server
from repro.core.overlap import split_batch_decode
from repro.models.transformer import Model, ParallelCtx


class Executor:
    """Owns params + caches + jitted step variants for one engine."""

    def __init__(self, model: Model, params, pool, *, max_batch: int,
                 max_seq: int, gemm_impl: str = "xla_ragged",
                 decode_mode: str = "lockstep"):
        assert decode_mode in ("lockstep", "pipelined", "serialized"), \
            decode_mode
        if decode_mode != "lockstep":
            if model.cache_batch_axis is None:
                raise ValueError(
                    f"decode_mode={decode_mode!r} needs a model family with "
                    "a uniform cache batch axis (decoder-family only)")
            if max_batch % 2:
                raise ValueError(
                    f"decode_mode={decode_mode!r} needs an even max_batch "
                    f"(got {max_batch}) to form two microbatches")
        self.model = model
        self.params = params
        self.pool = pool
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.gemm_impl = gemm_impl
        self.decode_mode = decode_mode
        self.cache = model.init_cache(max_batch, max_seq)
        self._staging: Dict[int, object] = {}     # slot -> batch-1 cache
        self._rt0 = pool.runtime(gemm_impl) if pool else None
        self._build_jits()

    @property
    def supports_chunked_prefill(self) -> bool:
        return self.model.prefill_chunk is not None

    # -------------------------------------------------------------- jits
    def _build_jits(self) -> None:
        """(Re)build the jitted step functions around the current ``_rt0``.

        Static runtime fields (num_servers, capacity) are baked into the
        closures, so a pool resize needs fresh variants; liveness/mapping
        stay jit arguments and never recompile.
        """
        model, rt0 = self.model, self._rt0
        gemm_impl, max_seq = self.gemm_impl, self.max_seq

        def ctx_of(rt_arrays):
            rt = None
            if rt0 is not None:
                mapping, alive, local = rt_arrays
                rt = rt0._replace(mapping=mapping, alive=alive,
                                  local_table=local)
            return ParallelCtx(moe_runtime=rt, gemm_impl=gemm_impl,
                               remat=False)

        def prefill_fn(params, tokens, rt_arrays):
            return model.prefill(params, tokens, ctx_of(rt_arrays),
                                 max_slots=max_seq)

        def decode_step(params, tokens, cache, rt_arrays):
            return model.decode_step(params, tokens, cache,
                                     ctx_of(rt_arrays))

        def decode_fn(params, tokens, cache, rt_arrays):
            if self.decode_mode == "lockstep":
                logits, cache, st = decode_step(params, tokens, cache,
                                                rt_arrays)
            else:
                logits, cache, st = split_batch_decode(
                    lambda t, c: decode_step(params, t, c, rt_arrays),
                    tokens, cache, axis=model.cache_batch_axis,
                    enabled=(self.decode_mode == "pipelined"))
            # per-expert token counts feed the pool's traffic EMA — this is
            # what rebalance() and traffic-aware scale_to re-plan from
            return logits, cache, st.expert_load

        self._jit_prefill = jax.jit(prefill_fn)
        self._jit_decode = jax.jit(decode_fn)
        self._jit_chunk = None
        if model.prefill_chunk is not None:
            def chunk_fn(params, tokens, cache, start, rt_arrays):
                return model.prefill_chunk(params, tokens, cache, start,
                                           ctx_of(rt_arrays))
            self._jit_chunk = jax.jit(chunk_fn)

    def _rt_arrays(self):
        if self.pool is None:
            return ()
        rt = self.pool.runtime(self.gemm_impl)
        return (rt.mapping, rt.alive, rt.local_table)

    # ------------------------------------------------------------ prefill
    def prefill(self, slot: int, prompt: np.ndarray) -> jax.Array:
        """Whole-prompt prefill straight into ``slot`` of the batch cache."""
        tokens = jnp.asarray(prompt, jnp.int32)[None]
        logits, cache_one = self._jit_prefill(self.params, tokens,
                                              self._rt_arrays())
        self.cache = jax.tree.map(
            lambda big, one: _slot_write(big, one, slot),
            self.cache, cache_one)
        return logits

    def prefill_chunk(self, slot: int, chunk: np.ndarray, start: int,
                      *, is_first: bool, is_last: bool) -> jax.Array:
        """One chunked-prefill continuation step for ``slot``.

        Chunks accumulate in a batch-1 staging cache; the final chunk
        commits the staging cache into the batch cache slot.
        """
        assert self._jit_chunk is not None, "model has no prefill_chunk"
        if is_first:
            self._staging[slot] = self.model.init_cache(1, self.max_seq)
        tokens = jnp.asarray(chunk, jnp.int32)[None]
        logits, staging = self._jit_chunk(
            self.params, tokens, self._staging[slot],
            jnp.asarray(start, jnp.int32), self._rt_arrays())
        self._staging[slot] = staging
        if is_last:
            self.cache = jax.tree.map(
                lambda big, one: _slot_write(big, one, slot),
                self.cache, self._staging.pop(slot))
        return logits

    # ------------------------------------------------------------- decode
    def decode(self, tokens: np.ndarray) -> Tuple[jax.Array, np.ndarray]:
        """One decode step over the whole slot batch -> (logits, load)."""
        logits, self.cache, expert_load = self._jit_decode(
            self.params, jnp.asarray(tokens), self.cache, self._rt_arrays())
        return logits, expert_load

    # ------------------------------------------------------------- elastic
    def resize(self, pool) -> None:
        """Adopt a resized expert-server pool: re-shard the expert weights
        from the recovered global bank and rebuild the jitted variants for
        the new static server count.  The batch KV cache and any staging
        caches are untouched — scaling never drops in-flight work."""
        self.pool = pool
        E = self.model.cfg.moe.num_experts
        n = pool.num_servers
        red = pool.redundant_table
        self.params = _map_server_weights(
            self.params,
            lambda sw: expert_server.reshard_server_weights(sw, E, n, red))
        self._rt0 = pool.runtime(self.gemm_impl)
        self._build_jits()


# ------------------------------------------------------------------ helpers

def _map_server_weights(params, fn):
    """Apply ``fn`` to every MoE layer's per-server weight dict in a params
    tree (the ``{"moe": {"servers": ...}}`` sub-dicts), leaving everything
    else untouched."""
    if isinstance(params, dict):
        out = {}
        for k, v in params.items():
            if k == "moe" and isinstance(v, dict) and "servers" in v:
                out[k] = dict(v, servers=fn(v["servers"]))
            else:
                out[k] = _map_server_weights(v, fn)
        return out
    return params


def _slot_write(big, one, b: int):
    """Write a batch-1 cache pytree leaf into slot b of the engine cache.

    The batch dim is the first one where `big` and `one` differ with
    ``one == 1``.
    """
    if not hasattr(big, "shape"):
        return big
    if big.shape == getattr(one, "shape", None):
        return one.astype(big.dtype)      # max_batch == 1: replace wholesale
    for axis, (db, do) in enumerate(zip(big.shape, one.shape)):
        if db != do and do == 1:
            idx = [slice(None)] * big.ndim
            idx[axis] = slice(b, b + 1)
            return big.at[tuple(idx)].set(one.astype(big.dtype))
    return big
