"""Edge cases for the model-layer KV caches (dense and paged): boundary
writes, capacity behaviour, and dense/paged view agreement."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import kv_cache as kvc

KV, HD = 2, 4


def _kv(rng, b, s):
    return (jnp.asarray(rng.normal(size=(b, s, KV, HD)), jnp.float32),
            jnp.asarray(rng.normal(size=(b, s, KV, HD)), jnp.float32))


# ------------------------------------------------------------- dense edges

def test_write_chunk_ending_exactly_at_max_seq(rng):
    max_seq = 16
    cache = kvc.init_kv_cache(2, max_seq, KV, HD, jnp.float32)
    k1, v1 = _kv(rng, 2, 12)
    cache = kvc.write_chunk(cache, k1, v1, jnp.asarray(0, jnp.int32))
    k2, v2 = _kv(rng, 2, 4)
    cache = kvc.write_chunk(cache, k2, v2, jnp.asarray(12, jnp.int32))
    assert int(cache.length[0]) == max_seq
    assert bool(kvc.valid_mask(cache).all())
    np.testing.assert_array_equal(np.asarray(cache.k[:, 12:]),
                                  np.asarray(k2))
    np.testing.assert_array_equal(np.asarray(cache.k[:, :12]),
                                  np.asarray(k1))


def test_append_decode_on_linear_slot_at_capacity(rng):
    """A linear cache at capacity: dynamic_update_slice clamps the write to
    the last slot (no error, no growth) and the mask stays all-valid —
    the engine's done-condition retires requests before this point, and
    this pins that an off-by-one cannot corrupt earlier slots."""
    slots = 8
    cache = kvc.init_kv_cache(1, slots, KV, HD, jnp.float32)
    k, v = _kv(rng, 1, slots)
    cache = kvc.write_prefill(cache, k, v)
    assert int(cache.length[0]) == slots
    extra_k, extra_v = _kv(rng, 1, 1)
    full = kvc.append_decode(cache, extra_k, extra_v)
    assert int(full.length[0]) == slots + 1
    assert full.k.shape == cache.k.shape
    assert bool(kvc.valid_mask(full).all())
    # the clamped write may only touch the final slot
    np.testing.assert_array_equal(np.asarray(full.k[:, :-1]),
                                  np.asarray(cache.k[:, :-1]))
    np.testing.assert_array_equal(np.asarray(full.k[:, -1]),
                                  np.asarray(extra_k[:, 0]))


def test_append_decode_on_ring_slot_at_capacity_wraps(rng):
    window = 4
    cache = kvc.init_kv_cache(1, 100, KV, HD, jnp.float32, window=window)
    ks, _ = _kv(rng, 1, 6)
    for i in range(6):
        cache = kvc.append_decode(cache, ks[:, i:i + 1], ks[:, i:i + 1])
    assert int(cache.length[0]) == 6
    assert bool(kvc.valid_mask(cache).all())          # ring full
    # slot layout wraps: positions 4,5 overwrote slots 0,1
    np.testing.assert_array_equal(np.asarray(cache.k[:, 0]),
                                  np.asarray(ks[:, 4]))
    np.testing.assert_array_equal(np.asarray(cache.k[:, 1]),
                                  np.asarray(ks[:, 5]))
    np.testing.assert_array_equal(np.asarray(cache.k[:, 2]),
                                  np.asarray(ks[:, 2]))


# ----------------------------------------------------- dense/paged agreement

@pytest.mark.parametrize("chunks,appends", [
    ((5, 6), 3),       # unaligned chunk boundary crossing a block edge
    ((4, 4, 4), 4),    # block-aligned chunks, appends into a fresh block
    ((15,), 1),        # chunk to one-below-capacity, append the last slot
])
def test_paged_view_and_valid_mask_agree_with_dense(rng, chunks, appends):
    """The same write sequence through the dense cache and through the
    block pool yields identical per-sequence views and identical masks —
    the invariant behind dense/paged token identity."""
    bs, max_seq, B = 4, 16, 3
    mb = max_seq // bs
    dense = kvc.init_kv_cache(B, max_seq, KV, HD, jnp.float32)
    paged = kvc.init_paged_kv_cache(1 + B * mb, bs, B, mb, KV, HD,
                                    jnp.float32)
    # each row gets its own private blocks, deliberately shuffled so the
    # block table (not pool layout) defines position order
    perm = np.random.default_rng(1).permutation(np.arange(1, 1 + B * mb))
    tables = jnp.asarray(perm.reshape(B, mb), jnp.int32)
    paged = kvc.PagedKVCache(k=paged.k, v=paged.v, block_tables=tables,
                             length=paged.length, block_size=bs)
    start = 0
    for c in chunks:
        k, v = _kv(rng, B, c)
        dense = kvc.write_chunk(dense, k, v, jnp.asarray(start, jnp.int32))
        paged = kvc.paged_write_chunk(paged, k, v,
                                      jnp.asarray(start, jnp.int32))
        start += c
    for _ in range(appends):
        k, v = _kv(rng, B, 1)
        dense = kvc.append_decode(dense, k, v)
        paged = kvc.paged_append_decode(paged, k, v)
    kview, vview = kvc.gather_blocks(paged)
    assert kview.shape == dense.k.shape
    np.testing.assert_array_equal(np.asarray(kvc.valid_mask(dense)),
                                  np.asarray(kvc.paged_valid_mask(paged)))
    np.testing.assert_array_equal(np.asarray(dense.length),
                                  np.asarray(paged.length))
    mask = np.asarray(kvc.valid_mask(dense))[..., None, None]
    np.testing.assert_array_equal(np.asarray(kview) * mask,
                                  np.asarray(dense.k) * mask)
    np.testing.assert_array_equal(np.asarray(vview) * mask,
                                  np.asarray(dense.v) * mask)


def test_paged_copy_blocks(rng):
    bs = 4
    cache = kvc.init_paged_kv_cache(6, bs, 1, 2, KV, HD, jnp.float32)
    k, v = _kv(rng, 1, bs)
    cache = kvc.PagedKVCache(
        k=cache.k, v=cache.v,
        block_tables=jnp.asarray([[2, 0]], jnp.int32),
        length=cache.length, block_size=bs)
    cache = kvc.paged_write_chunk(cache, k, v, jnp.asarray(0, jnp.int32))
    out = kvc.copy_blocks(cache, jnp.asarray([2], jnp.int32),
                          jnp.asarray([5], jnp.int32))
    np.testing.assert_array_equal(np.asarray(out.k[5]), np.asarray(out.k[2]))
    np.testing.assert_array_equal(np.asarray(out.v[5]), np.asarray(out.v[2]))
