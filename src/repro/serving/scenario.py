"""Deterministic scenario engine for serving experiments (paper §5).

The paper's headline numbers — <2% throughput loss under failures (Fig. 10)
and 37.5% resource saving from fine-grained scaling (Fig. 11) — are claims
about *timelines*: traffic arrives, servers die and recover, the pool
resizes.  A :class:`Scenario` scripts such a timeline once, deterministically,
and replays it against any :class:`~repro.serving.engine.ServingEngine`
(EAAS / monolithic EP / TP — the engine modes), usually under a
:class:`~repro.serving.clock.VirtualClock` so two runs with the same seed
produce bit-identical metrics.

DSL (builder style, times are engine-clock seconds)::

    sc = (Scenario(horizon=2.0, seed=0, max_new=16, clients=4)
          .poisson(rate=40)                 # or .bursty(...) / .diurnal(...)
          .set_rate(t=1.0, rate=10)         # piecewise-constant override
          .fail(rank=1, t=0.5)              # expert-server failure
          .recover(rank=1, t=0.9)
          .fail_client(i=0, t=0.6)          # attention-client failure
          .recover_client(i=0, t=1.1)       #   (Cluster engines only)
          .set_frontend_policy(t=1.0, policy="least_loaded")
          .rebalance(t=1.2)
          .scale_to(n=2, t=1.5)             # or .autoscale(Autoscaler(...))
          )
    result = sc.run(engine)                 # engine OR Cluster

Arrival processes are inhomogeneous Poisson, sampled by Lewis–Shedler
thinning from a seeded generator — the trace depends only on
(seed, rate schedule, horizon), never on engine state.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.metrics import ServingMetrics
from repro.serving.request import Request, SamplingParams

RateFn = Callable[[float], float]


# ------------------------------------------------------------ traffic skew

def zipf_bias(num_experts: int, alpha: float, scale: float = 2.0,
              seed: int = 0, rotation: int = 0) -> np.ndarray:
    """Router-logit bias tilting expert traffic toward a Zipf(``alpha``)
    profile over a seeded expert permutation.

    Rank r (0-based) of the permutation gets bias ``scale * log p_r`` with
    ``p_r ∝ (r+1)^-alpha`` (normalized so the hottest expert sits at 0 and
    everything else is negative).  ``scale`` sets how hard the bias
    dominates the natural router logits: ~0.5 nudges, ≥3 concentrates
    traffic onto the top-k hottest experts.  ``rotation`` rolls the
    permutation — the shifting-hot-set trace rotates it every period, the
    regime where frozen placement is always chasing stale traffic.
    ``alpha=0`` is the uniform profile: an all-zero bias, bit-identical to
    unbiased routing.
    """
    ranks = np.arange(1, num_experts + 1, dtype=np.float64) ** (-alpha)
    p = ranks / ranks.sum()
    perm = np.roll(np.random.default_rng(seed).permutation(num_experts),
                   rotation)
    bias = np.empty(num_experts, np.float64)
    bias[perm] = scale * np.log(p)
    return (bias - bias.max()).astype(np.float32)


# --------------------------------------------------------------- rate shapes

def constant_rate(rate: float) -> RateFn:
    return lambda t: rate


def bursty_rate(base: float, peak: float, period: float,
                duty: float = 0.2) -> RateFn:
    """Square-wave bursts: ``peak`` req/s for the first ``duty`` fraction of
    every ``period``, ``base`` otherwise (flash-crowd traffic)."""
    def fn(t: float) -> float:
        return peak if (t % period) < duty * period else base
    return fn


def diurnal_rate(mean: float, amplitude: float = 0.5,
                 period: float = 1.0) -> RateFn:
    """Sinusoidal day/night cycle: mean * (1 + amplitude*sin(2πt/period))."""
    def fn(t: float) -> float:
        return max(0.0, mean * (1.0 + amplitude *
                                np.sin(2.0 * np.pi * t / period)))
    return fn


def sample_arrival_times(rate_fn: RateFn, horizon: float,
                         rng: np.random.Generator,
                         rate_max: Optional[float] = None) -> np.ndarray:
    """Inhomogeneous-Poisson arrival times on [0, horizon) by thinning."""
    if rate_max is None:
        grid = np.linspace(0.0, horizon, 4096, endpoint=False)
        rate_max = max(float(max(rate_fn(t) for t in grid)), 1e-9)
    times = []
    t = 0.0
    while True:
        t += rng.exponential(1.0 / rate_max)
        if t >= horizon:
            break
        if rng.random() < rate_fn(t) / rate_max:
            times.append(t)
    return np.asarray(times)


# ------------------------------------------------------------------- events

@dataclass(frozen=True)
class ScenarioEvent:
    t: float
    # fail | recover | rebalance | scale_to | set_policy | set_skew |
    # slow_server | fail_client | recover_client | set_frontend_policy |
    # set_elastic
    kind: str
    value: Optional[object] = None     # rank / client / pool size / policy


@dataclass
class ScenarioResult:
    metrics: ServingMetrics
    requests: List[Request]
    applied: List[Dict]                      # events in application order
    server_trace: List[Tuple[float, int]]    # (t, pool size) samples

    def summary(self) -> Dict:
        out = self.metrics.summary()
        out["events_applied"] = len(self.applied)
        if self.server_trace:
            out["final_servers"] = self.server_trace[-1][1]
        return out


class Scenario:
    """A scripted, seeded timeline of traffic + faults + scaling.

    ``clients`` declares the cluster shape the timeline is written for
    (how many attention clients share the expert tier); it is carried as
    trace metadata — benchmark drivers build a
    :class:`~repro.serving.cluster.Cluster` of that width — and validated
    against the engine the timeline replays on when client-level events
    (``fail_client`` / ``recover_client`` / ``set_frontend_policy``) are
    present."""

    def __init__(self, horizon: float, seed: int = 0, prompt_len: int = 8,
                 max_new: int = 16, vocab: int = 512, clients: int = 1):
        self.horizon = float(horizon)
        self.seed = seed
        self.prompt_len = prompt_len
        self.max_new = max_new
        self.vocab = vocab
        self.clients = int(clients)
        self.events: List[ScenarioEvent] = []
        self._base_rate: RateFn = constant_rate(0.0)
        self._rate_overrides: List[Tuple[float, float]] = []  # set_rate pts
        self._autoscaler = None
        self._shared_prefix: Optional[Tuple[int, int, int]] = None

    # ------------------------------------------------------------- traffic
    def poisson(self, rate: float) -> "Scenario":
        self._base_rate = constant_rate(rate)
        return self

    def bursty(self, base: float, peak: float, period: float,
               duty: float = 0.2) -> "Scenario":
        self._base_rate = bursty_rate(base, peak, period, duty)
        return self

    def diurnal(self, mean: float, amplitude: float = 0.5,
                period: float = 1.0) -> "Scenario":
        self._base_rate = diurnal_rate(mean, amplitude, period)
        return self

    def set_rate(self, t: float, rate: float) -> "Scenario":
        """Override the arrival rate from time ``t`` on (rate step)."""
        self._rate_overrides.append((float(t), float(rate)))
        self._rate_overrides.sort()
        return self

    def rate_at(self, t: float) -> float:
        r = self._base_rate(t)
        for t0, rate in self._rate_overrides:
            if t >= t0:
                r = rate
        return r

    # -------------------------------------------------------------- faults
    def fail(self, rank: int, t: float) -> "Scenario":
        self.events.append(ScenarioEvent(float(t), "fail", rank))
        return self

    def recover(self, rank: int, t: float) -> "Scenario":
        self.events.append(ScenarioEvent(float(t), "recover", rank))
        return self

    def slow_server(self, rank: int, t: float,
                    factor: float = 4.0) -> "Scenario":
        """Expert server ``rank`` becomes a straggler at ``t``: its compute
        runs ``factor``× slower until reset (``factor=1.0``).  Lockstep
        engines wait for the slowest server every decode step; the async
        tier slows only that server's micro-batch queue — the tail-latency
        asymmetry the differential tests pin."""
        self.events.append(ScenarioEvent(
            float(t), "slow_server", (int(rank), float(factor))))
        return self

    def rebalance(self, t: float) -> "Scenario":
        self.events.append(ScenarioEvent(float(t), "rebalance"))
        return self

    def scale_to(self, n: int, t: float) -> "Scenario":
        self.events.append(ScenarioEvent(float(t), "scale_to", n))
        return self

    def set_policy(self, t: float, policy: str) -> "Scenario":
        """Switch the engine's scheduling policy mid-run (e.g. flip to
        ``fair`` when a burst of long prompts is about to land)."""
        self.events.append(ScenarioEvent(float(t), "set_policy", policy))
        return self

    # ------------------------------------------------- cluster-level events
    def fail_client(self, i: int, t: float) -> "Scenario":
        """An ATTENTION client (not an expert server) dies at ``t``: its
        in-flight requests strand while the shared expert tier keeps
        serving every other client — the cluster half of the paper's
        partial-rank-failure story."""
        self.events.append(ScenarioEvent(float(t), "fail_client", int(i)))
        return self

    def recover_client(self, i: int, t: float) -> "Scenario":
        self.events.append(ScenarioEvent(float(t), "recover_client", int(i)))
        return self

    def set_frontend_policy(self, t: float, policy: str) -> "Scenario":
        """Swap the cluster's request-routing policy mid-run (e.g. flip to
        ``session_affinity`` when shared-prefix traffic starts)."""
        self.events.append(
            ScenarioEvent(float(t), "set_frontend_policy", policy))
        return self

    # ---------------------------------------------------------- skew events
    def set_skew(self, t: float, alpha: float, scale: float = 2.0,
                 rotation: int = 0) -> "Scenario":
        """From time ``t``, bias the engine's router toward a Zipf(alpha)
        expert profile (:func:`zipf_bias` over this scenario's seed).
        ``alpha=0`` clears the skew.  Applied at t=0 the skew is constant
        over the run, so routing stays a pure function of request content —
        engines with different placements (frozen vs rebalanced) still
        produce bitwise-identical greedy token streams."""
        self.events.append(ScenarioEvent(
            float(t), "set_skew",
            (float(alpha), float(scale), int(rotation))))
        return self

    def zipf_skew(self, alpha: float, scale: float = 2.0) -> "Scenario":
        """Constant Zipf-skewed expert traffic for the whole run (the
        hot-expert regime MegaScale-Infer targets)."""
        return self.set_skew(0.0, alpha, scale)

    def shifting_hot_set(self, alpha: float, period: float,
                         scale: float = 2.0) -> "Scenario":
        """Rotate the Zipf hot set every ``period`` seconds: each shift
        re-rolls which experts are hot, so a frozen placement is always
        provisioned for the *previous* hot set while a live rebalancer
        chases the traffic."""
        t, rotation = 0.0, 0
        while t < self.horizon:
            self.set_skew(t, alpha, scale, rotation=rotation)
            t += float(period)
            rotation += 1
        return self

    def autoscale(self, autoscaler, min_clients: int = None,
                  max_clients: int = None) -> "Scenario":
        """Attach an :class:`~repro.serving.autoscale.Autoscaler` policy loop
        (observed each step; scaling decisions become engine.scale_to /
        engine.scale_clients / engine.page_out_experts).  ``min_clients`` /
        ``max_clients`` bound the attention-tier controller inline —
        scenario-level overrides of the autoscaler config."""
        if min_clients is not None:
            autoscaler.cfg.min_clients = int(min_clients)
        if max_clients is not None:
            autoscaler.cfg.max_clients = int(max_clients)
        self._autoscaler = autoscaler
        return self

    def set_elastic(self, t: float, enabled: bool = True) -> "Scenario":
        """Freeze/unfreeze the attached autoscaler at ``t`` (all three
        controllers: servers, clients, expert paging).  A scenario can
        script a static warm-up phase, then flip elasticity on."""
        self.events.append(ScenarioEvent(float(t), "set_elastic",
                                         bool(enabled)))
        return self

    def shared_prefix(self, n_prefixes: int, prefix_len: int,
                      suffix_len: int) -> "Scenario":
        """Multi-tenant system-prompt traffic: request ``i`` is one of
        ``n_prefixes`` shared prefixes (drawn once from the scenario seed)
        followed by a unique suffix — the workload where paged KV prefix
        caching pays (``prompt_len`` is ignored; prompts become
        ``prefix_len + suffix_len`` tokens).  Align ``prefix_len`` to the
        engine's ``kv_block_size`` for full cache hits."""
        self._shared_prefix = (int(n_prefixes), int(prefix_len),
                               int(suffix_len))
        return self

    # ------------------------------------------------------------ sampling
    def build_arrivals(self) -> List[Request]:
        """Materialize the request trace — deterministic in ``seed``."""
        rng = np.random.default_rng(self.seed)
        times = sample_arrival_times(self.rate_at, self.horizon, rng)
        prefixes = None
        if self._shared_prefix is not None:
            n_pre, pre_len, _ = self._shared_prefix
            prefixes = [rng.integers(0, self.vocab,
                                     size=pre_len).astype(np.int32)
                        for _ in range(n_pre)]
        reqs = []
        for i, t in enumerate(times):
            if prefixes is not None:
                n_pre, _, suf_len = self._shared_prefix
                prompt = np.concatenate([
                    prefixes[i % n_pre],
                    rng.integers(0, self.vocab,
                                 size=suf_len).astype(np.int32)])
            else:
                prompt = rng.integers(0, self.vocab,
                                      size=self.prompt_len).astype(np.int32)
            reqs.append(Request(i, prompt,
                                SamplingParams(max_new_tokens=self.max_new),
                                arrival_time=float(t)))
        return reqs

    # ----------------------------------------------------------- execution
    def run(self, engine, max_steps: int = 20_000,
            drain: bool = True) -> ScenarioResult:
        """Replay the timeline against ``engine`` (its clock is the time
        base).  With ``drain`` the engine runs on past the horizon until all
        admitted work completes."""
        arrivals = self.build_arrivals()
        pending = sorted(self.events, key=lambda e: e.t)
        applied: List[Dict] = []
        trace: List[Tuple[float, int]] = []
        ai = ei = 0

        def pool_size() -> int:
            return engine.pool.num_servers if engine.pool else 1

        while engine.step_idx < max_steps:
            t = engine.clock
            while ai < len(arrivals) and arrivals[ai].arrival_time <= t:
                engine.submit(arrivals[ai])
                if self._autoscaler is not None:
                    self._autoscaler.observe_arrival(t)
                ai += 1
            while ei < len(pending) and pending[ei].t <= t:
                self._apply(pending[ei], engine)
                applied.append(dataclasses.asdict(pending[ei]))
                ei += 1
            # the policy loop runs only while the scripted timeline is live;
            # drain time would read as a rate collapse and scale to min
            if self._autoscaler is not None and t < self.horizon:
                self._autoscaler.step(engine, t)
            trace.append((t, pool_size()))
            exhausted = ai >= len(arrivals) and ei >= len(pending)
            busy = engine.queue or any(s is not None for s in engine.slots)
            if exhausted and not busy:
                break
            if t >= self.horizon and not drain and not busy:
                break
            engine.step()

        engine.metrics.wall_time = engine.clock
        return ScenarioResult(metrics=engine.metrics, requests=arrivals,
                              applied=applied, server_trace=trace)

    def _apply(self, ev: ScenarioEvent, engine) -> None:
        if ev.kind == "fail":
            engine.inject_server_failure(ev.value)
        elif ev.kind == "recover":
            engine.recover_server(ev.value)
        elif ev.kind == "rebalance":
            engine.rebalance()
        elif ev.kind == "scale_to":
            engine.scale_to(ev.value)
        elif ev.kind == "set_policy":
            engine.set_policy(ev.value)
        elif ev.kind == "slow_server":
            engine.set_server_speed(*ev.value)
        elif ev.kind in ("fail_client", "recover_client",
                         "set_frontend_policy"):
            if not hasattr(engine, "fail_client"):
                raise ValueError(
                    f"scenario event {ev.kind!r} needs a Cluster engine "
                    "(N attention clients); got a single-client engine — "
                    "wrap it in repro.serving.Cluster")
            getattr(engine, ev.kind)(ev.value)
        elif ev.kind == "set_elastic":
            if self._autoscaler is None:
                raise ValueError("set_elastic needs an attached autoscaler "
                                 "(call .autoscale(...) first)")
            self._autoscaler.enabled = bool(ev.value)
        elif ev.kind == "set_skew":
            if engine.cfg.moe is None:
                return
            alpha, scale, rotation = ev.value
            engine.set_skew(zipf_bias(engine.cfg.moe.num_experts, alpha,
                                      scale=scale, seed=self.seed,
                                      rotation=rotation))
        else:
            raise ValueError(f"unknown scenario event {ev.kind!r}")
