"""Paper Fig. 11 — weak scaling at fine granularity.

EAAS scales the expert-server pool one server at a time; monolithic EP only
at group multiples.  We sweep server counts (incl. counts a monolithic EP
deployment cannot use) and report throughput + the provisioning saving for
a fixed traffic level (the paper's 37.5% number comes from scaling 64 → 40
GPUs at reduced traffic)."""

from __future__ import annotations

from typing import Dict, List

from benchmarks.common import (bench_model_cfg, csv_row, make_requests,
                               run_engine, save_result)
from repro.core.elastic import provision, resource_saving
from repro.serving import EngineConfig


def run(server_counts: List[int] = (2, 4, 8), load: int = 24,
        max_new: int = 12) -> Dict:
    cfg = bench_model_cfg()
    E = cfg.moe.num_experts
    pts = []
    for s in server_counts:
        if E % s:                       # EAAS would use uneven placement;
            continue                    # reduced config keeps it divisible
        ecfg = EngineConfig(mode="eaas", num_servers=s, max_batch=4,
                            max_seq=64, n_redundant=1)
        reqs = make_requests(load, max_new=max_new, vocab=cfg.vocab_size)
        _, m = run_engine(cfg, ecfg, reqs)
        pts.append({"servers": s, "tok_per_s": m.decode_throughput})

    # provisioning curve (the 37.5% story): traffic drops from 8192 to 5120
    # req/s; monolithic must keep 64 GPUs (group granularity 64), EAAS can
    # shrink to ceil(5120/128)=40.
    rate_per_server = 8192 / 64
    saving = resource_saving(5120, rate_per_server, monolithic_group=64)
    prov = {
        "traffic_8192": {"eaas": provision(8192, rate_per_server, 1),
                         "monolithic": provision(8192, rate_per_server, 64)},
        "traffic_5120": {"eaas": provision(5120, rate_per_server, 1),
                         "monolithic": provision(5120, rate_per_server, 64)},
        "resource_saving_pct": 100 * saving,
    }
    out = {"figure": "fig11_scaling", "weak_scaling": pts,
           "provisioning": prov}
    save_result("fig11_scaling", out)
    return out


def main() -> List[str]:
    res = run()
    rows = []
    for p in res["weak_scaling"]:
        rows.append(csv_row(f"fig11_servers_{p['servers']}", 0.0,
                            f"tok_per_s={p['tok_per_s']:.2f}"))
    rows.append(csv_row(
        "fig11_saving", 0.0,
        f"saving_pct={res['provisioning']['resource_saving_pct']:.1f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
