"""Cluster front-end battery: N attention clients over one expert tier.

Everything runs under the virtual clock — deterministic, no wall time:

* **scale-out identity**: one seeded trace replayed at N=1 and N=4 clients
  produces bitwise-identical per-request token streams (the front-end
  changes *where* a request runs, never *what* it computes);
* **determinism**: same seed ⇒ identical ClusterMetrics fingerprint;
* **client fault containment**: killing one of 4 clients strands only its
  in-flight requests, and the cluster throughput dip is strictly smaller
  than the monolithic single-engine stall on the same trace;
* **session affinity**: shared-prefix traffic routed by prefix hash beats
  round_robin's prefix-cache hit rate;
* **shared tier consistency**: cluster-level rebalancing migrates every
  client's expert weights in lockstep; expert-server failures are observed
  by all clients through the one shared mapping;
* router policy units, admission backpressure, the Engine deprecation
  shim, and the cluster-member guard rails.
"""

import numpy as np
import pytest

import repro.serving as serving
from repro.configs import get_config
from repro.serving import (Cluster, ClusterConfig, EngineConfig, Scenario,
                           ServingEngine, VirtualClock)
from repro.serving.frontend import (LeastLoaded, RoundRobin,
                                    SessionAffinity, make_frontend_router)


@pytest.fixture(scope="module")
def cfg():
    return get_config("deepseek-r1").reduced()


def _ecfg(**kw):
    kw.setdefault("mode", "eaas")
    kw.setdefault("num_servers", 4)
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_seq", 64)
    kw.setdefault("n_redundant", 2)
    # drop-free dispatch: the identity pins require placement/routing to
    # never change which tokens reach their experts
    kw.setdefault("pool_tokens_per_client", 16)
    return EngineConfig(**kw)


def _cluster(cfg, n, policy="round_robin", max_client_queue=0,
             charge_contention=False, **ekw):
    return Cluster(cfg, ClusterConfig(clients=n, frontend_policy=policy,
                                      max_client_queue=max_client_queue,
                                      charge_contention=charge_contention,
                                      engine=_ecfg(**ekw)),
                   seed=0, clock_factory=VirtualClock)


def _trace(cfg, horizon=0.15, rate=100, max_new=6, seed=7, clients=1):
    return Scenario(horizon=horizon, seed=seed, max_new=max_new,
                    vocab=cfg.vocab_size, clients=clients).poisson(rate)


def _tokens(res):
    return {r.request_id: tuple(r.output_tokens) for r in res.requests}


# --------------------------------------------------------------- identity

def test_n1_vs_n4_bitwise_token_identity(cfg):
    """The acceptance pin: 4 clients on a seeded trace produce the same
    per-request token stream as 1 client, bit for bit."""
    res1 = _trace(cfg, clients=1).run(_cluster(cfg, 1))
    res4 = _trace(cfg, clients=4).run(_cluster(cfg, 4))
    t1, t4 = _tokens(res1), _tokens(res4)
    assert t1 == t4
    assert res1.metrics.completed == res1.metrics.total_requests > 0
    assert res4.metrics.completed == res4.metrics.total_requests


def test_cluster_run_deterministic(cfg):
    def one():
        cl = _cluster(cfg, 3)
        res = _trace(cfg, clients=3).run(cl)
        return cl.metrics.fingerprint(), _tokens(res)

    f1, t1 = one()
    f2, t2 = one()
    assert f1 == f2
    assert t1 == t2


def test_contention_charges_time_not_tokens(cfg):
    """The shared-tier contention charge stretches the timeline but never
    touches what is computed."""
    plain = _trace(cfg, clients=2).run(_cluster(cfg, 2))
    charged = _trace(cfg, clients=2).run(
        _cluster(cfg, 2, charge_contention=True))
    assert _tokens(plain) == _tokens(charged)
    assert charged.metrics.wall_time > plain.metrics.wall_time


# ----------------------------------------------------------- fault model

def test_client_failure_strands_only_inflight(cfg):
    """A dead client's in-flight requests are lost; every request routed
    to a surviving client completes; the expert tier never blinks."""
    cl = _cluster(cfg, 4)
    sc = (_trace(cfg, horizon=0.4, rate=250, max_new=16, clients=4)
          .fail_client(i=0, t=0.2))
    res = sc.run(cl)
    m = cl.metrics
    assert m.failed_requests > 0
    assert m.completed == m.total_requests - m.failed_requests
    # nothing halted anywhere: the failure is contained to client 0
    assert all(not e.get("halted") for c in cl.clients
               for e in c.metrics.timeline)
    assert not cl.client_alive[0]
    ev = [e for e in m.events if e["event"] == "client_fail"]
    assert len(ev) == 1 and ev[0]["stranded"] == m.failed_requests
    assert res.metrics is m


def test_client_failure_dip_smaller_than_monolithic_stall(cfg):
    """The acceptance ordering: cluster throughput dip under a client
    failure < the monolithic whole-engine stall on the same trace."""
    horizon, t_fail = 0.4, 0.2

    def dip(metrics):
        curve = metrics.throughput_curve(horizon / 10)
        pre = [v for t, v in curve if 0.1 * horizon <= t < t_fail]
        post = [v for t, v in curve if t_fail <= t < horizon]
        return 1.0 - min(post) / max(np.mean(pre), 1e-9)

    cl = _cluster(cfg, 4)
    (_trace(cfg, horizon=horizon, rate=250, max_new=16, clients=4)
     .fail_client(i=0, t=t_fail).recover_client(i=0, t=0.35)).run(cl)
    d_cluster = dip(cl.metrics)

    mono = ServingEngine(cfg, _ecfg(mode="monolithic_ep", restart_steps=50),
                         seed=0, clock=VirtualClock())
    _trace(cfg, horizon=horizon, rate=250, max_new=16).fail(
        rank=1, t=t_fail).run(mono)
    d_mono = dip(mono.metrics)

    assert 0.0 < d_cluster < d_mono
    # a quarter of the attention tier died; the dip is a capacity share,
    # not a stall
    assert d_cluster < 0.75 and d_mono > 0.9


def test_total_client_loss_sheds_ingress_with_accounting(cfg):
    """When the LAST client dies, ingress-held (never-routed) requests are
    counted as failed too — completed == total - failed survives total
    loss, and post-mortem submits fail fast instead of piling up."""
    cl = _cluster(cfg, 2, max_client_queue=1)
    for i in range(8):
        cl.submit(serving.Request(
            i, np.arange(8, dtype=np.int32),
            serving.SamplingParams(max_new_tokens=4)))
    cl._route_ingress()                      # 2 routed, 6 held in ingress
    assert len(cl.ingress) == 6
    cl.fail_client(0)
    cl.fail_client(1)
    m = cl.metrics
    assert not cl.ingress
    assert m.ingress_failed == 6
    assert m.failed_requests == 8
    assert m.completed == m.total_requests - m.failed_requests == 0
    cl.submit(serving.Request(99, np.arange(8, dtype=np.int32),
                              serving.SamplingParams(max_new_tokens=4)))
    assert m.failed_requests == 9 and not cl.ingress
    with pytest.raises(ValueError, match="no client"):
        cl.fail_client(5)


def test_recovered_client_serves_again(cfg):
    cl = _cluster(cfg, 2)
    sc = (_trace(cfg, horizon=0.3, rate=150, max_new=8, clients=2)
          .fail_client(i=1, t=0.1).recover_client(i=1, t=0.15))
    sc.run(cl)
    assert cl.client_alive[1]
    # client 1 received fresh work after recovery: routed > what it had
    # completed+stranded at failure time
    assert cl.metrics.routed[1] > 0
    assert cl.clients[1].metrics.completed > 0


# ------------------------------------------------------- session affinity

def test_session_affinity_beats_round_robin_prefix_hits(cfg):
    """Shared-prefix traffic: affinity pins each prefix to one home client
    whose BlockPool caches it; round_robin smears every prefix cold over
    every client."""
    def run(policy):
        cl = _cluster(cfg, 4, policy=policy, kv_mode="paged",
                      kv_block_size=8, prefill_chunk=8)
        sc = _trace(cfg, horizon=0.3, rate=120, max_new=6, clients=4) \
            .shared_prefix(n_prefixes=3, prefix_len=16, suffix_len=8)
        sc.run(cl)
        return cl

    aff = run("session_affinity")
    rr = run("round_robin")
    assert aff.metrics.prefix_hit_rate > rr.metrics.prefix_hit_rate
    # affinity actually pinned: every request of one prefix went to the
    # same client, so at most n_prefixes clients received traffic
    assert sum(1 for n in aff.metrics.routed if n > 0) <= 3


# ------------------------------------------------ shared tier consistency

def test_rebalance_fans_out_to_every_client(cfg):
    """Cluster-level rebalancing keeps every client's expert weights
    bitwise identical — the shared tier has ONE placement."""
    import dataclasses as dc
    import jax

    wide = cfg.replace(moe=dc.replace(cfg.moe, num_experts=16))
    cl = Cluster(wide, ClusterConfig(clients=2, engine=_ecfg(
        max_batch=8, pool_tokens_per_client=32,
        rebalance_interval=0.02, charge_imbalance=True)),
        seed=0, clock_factory=VirtualClock)
    sc = (_trace(wide, horizon=0.4, rate=80, max_new=16, clients=2)
          .zipf_skew(1.2, scale=1.0))
    sc.run(cl)
    assert cl.metrics.rebalances >= 1
    assert cl.metrics.migrated_experts > 0
    p0 = cl.clients[0].executor.params
    p1 = cl.clients[1].executor.params
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), p0, p1)


def test_expert_server_failure_observed_by_all_clients(cfg):
    cl = _cluster(cfg, 3)
    cl.inject_server_failure(1)
    for eng in cl.clients:
        assert not bool(eng.pool.smap.alive[1])
    cl.recover_server(1)
    for eng in cl.clients:
        assert bool(eng.pool.smap.alive[1])


def test_per_client_mask_is_local(cfg):
    """One client masking a server it observed misbehaving does not change
    what the siblings route to (the shared table is untouched)."""
    cl = _cluster(cfg, 2)
    view0 = cl.clients[0].pool
    view0.mask_server(2)
    assert not view0.runtime().alive[2]
    assert cl.clients[1].pool.runtime().alive[2]
    assert bool(cl.pool.smap.alive[2])          # shared liveness untouched
    view0.unmask_server(2)
    assert bool(view0.runtime().alive[2])


def test_shared_ema_aggregates_all_clients(cfg):
    """Every client's router traffic lands in the ONE pool EMA."""
    cl = _cluster(cfg, 2)
    _trace(cfg, clients=2).run(cl)
    decode_steps = sum(
        sum(1 for e in c.metrics.timeline
            if not e.get("halted") and e["tokens"] > 0)
        for c in cl.clients)
    assert cl.pool.stats.updates >= decode_steps > 0


# ------------------------------------------------------ admission control

def test_backpressure_holds_ingress(cfg):
    cl = _cluster(cfg, 2, max_client_queue=2)
    for i in range(12):
        cl.submit(serving.Request(
            i, np.arange(8, dtype=np.int32),
            serving.SamplingParams(max_new_tokens=4)))
    cl._route_ingress()
    # each client: 2 queued (cap); the rest wait in ingress
    assert all(len(eng.queue) == 2 for eng in cl.clients)
    assert len(cl.ingress) == 12 - 4
    cl.run(max_steps=4000)
    assert cl.metrics.completed == 12
    assert not cl.ingress


def test_set_frontend_policy_event(cfg):
    cl = _cluster(cfg, 2)
    sc = (_trace(cfg, horizon=0.2, rate=100, clients=2)
          .set_frontend_policy(t=0.1, policy="least_loaded"))
    sc.run(cl)
    assert cl.router.name == "least_loaded"
    assert any(e["event"] == "set_frontend_policy"
               for e in cl.metrics.events)


def test_client_event_needs_cluster(cfg):
    eng = ServingEngine(cfg, _ecfg(), seed=0, clock=VirtualClock())
    sc = _trace(cfg).fail_client(i=0, t=0.05)
    with pytest.raises(ValueError, match="Cluster"):
        sc.run(eng)


# ----------------------------------------------------------- router units

def test_round_robin_cycles_and_skips():
    r = RoundRobin(4)
    cands = [(0, None), (1, None), (2, None), (3, None)]
    assert [r.pick(None, cands) for _ in range(5)] == [0, 1, 2, 3, 0]
    r2 = RoundRobin(3)
    assert [r2.pick(None, [(0, None), (2, None)]) for _ in range(4)] \
        == [0, 2, 0, 2]


def test_least_loaded_scores():
    class Fake:
        def __init__(self, backlog, free):
            self._b, self._f = backlog, free

        def pending_prefill_tokens(self):
            return self._b

        def free_kv_tokens(self):
            return self._f

    r = LeastLoaded(3)
    cands = [(0, Fake(100, 10)), (1, Fake(0, 50)), (2, Fake(0, 50))]
    assert r.pick(None, cands) == 1              # least loaded, tie -> low
    cands = [(0, Fake(0, 500)), (1, Fake(0, 50))]
    assert r.pick(None, cands) == 0              # most free memory


def test_session_affinity_stable_home_and_fallback():
    r = SessionAffinity(4, block_size=8)
    p = np.arange(24, dtype=np.int32)
    home = r.home(p)
    assert home == r.home(p)                     # deterministic
    # identical leading block, different suffix -> same home
    q = np.concatenate([p[:8], np.full(16, 99, np.int32)])
    assert r.home(q) == home

    # home inadmissible -> deterministic fall-forward around the ring
    cands = [(i, None) for i in range(4) if i != home]
    assert r.pick(serving.Request(0, p), cands) == (home + 1) % 4
    # home admissible -> home wins
    assert r.pick(serving.Request(0, p), [(i, None) for i in range(4)]) \
        == home


def test_make_frontend_router_rejects_unknown():
    with pytest.raises(ValueError, match="unknown frontend policy"):
        make_frontend_router("hash_ring", 4)


# ------------------------------------------------------------ guard rails

def test_cluster_member_engines_reject_local_placement_changes(cfg):
    cl = _cluster(cfg, 2)
    with pytest.raises(RuntimeError, match="cluster"):
        cl.clients[0].scale_to(2)
    with pytest.raises(RuntimeError, match="cluster"):
        cl.clients[0].rebalance()


def test_cluster_scale_to_resizes_every_executor(cfg):
    cl = _cluster(cfg, 2)
    _trace(cfg, clients=2).run(cl)
    cl.scale_to(2)
    assert cl.pool.num_servers == 2
    for eng in cl.clients:
        assert eng.pool.num_servers == 2
        assert eng.executor._rt0.num_servers == 2


def test_engine_deprecation_shim(cfg):
    with pytest.warns(DeprecationWarning, match="Cluster"):
        cls = serving.Engine
    assert cls is ServingEngine
    with pytest.raises(AttributeError):
        serving.NoSuchThing


def test_cluster_rejects_bad_shapes(cfg):
    with pytest.raises(ValueError, match="at least one client"):
        Cluster(cfg, ClusterConfig(clients=0, engine=_ecfg()))
    with pytest.raises(ValueError, match="not disaggregated"):
        Cluster(cfg, ClusterConfig(clients=2, engine=_ecfg(mode="tp")))
