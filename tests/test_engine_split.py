"""Scheduler/executor engine split: semantics preservation and the
overlap/chunking performance pins (acceptance criteria of the refactor).

* pipelined and serialized two-microbatch decode produce greedy outputs
  token-identical to the lockstep (pre-split) engine on a seeded scenario;
* the overlap-aware VirtualClock puts pipelined decode throughput strictly
  above the serialized ablation;
* chunked prefill keeps the max decode gap (ITL) below the unchunked
  engine's on a bursty long-prompt trace;
* TTFT is tracked per request; per-request SamplingParams are honored.

All under the virtual clock — deterministic, no wall time.
"""

import numpy as np
import pytest

from repro.configs import get_config
from repro.serving import (Autoscaler, AutoscalerConfig, EngineConfig,
                           Request, SamplingParams, Scenario, Scheduler,
                           SchedulerConfig, ServingEngine, VirtualClock)
from repro.serving.scheduler import DecodeBatch, Idle, PrefillChunk


@pytest.fixture(scope="module")
def cfg():
    return get_config("deepseek-r1").reduced()


def _engine(cfg, **kw):
    # dispatch buffers sized for the longest prefill step so no variant
    # drops tokens — greedy outputs stay bitwise comparable across modes
    kw.setdefault("pool_tokens_per_client", 128)
    ecfg = EngineConfig(mode="eaas", num_servers=4, max_batch=4,
                        max_seq=128, n_redundant=2, **kw)
    return ServingEngine(cfg, ecfg, clock=VirtualClock())


def _run(cfg, scenario_kw=None, **engine_kw):
    sc_kw = dict(horizon=0.15, seed=7, prompt_len=8, max_new=5)
    sc_kw.update(scenario_kw or {})
    eng = _engine(cfg, **engine_kw)
    sc = Scenario(vocab=cfg.vocab_size, **sc_kw).poisson(rate=100)
    res = sc.run(eng)
    assert res.metrics.completed == res.metrics.total_requests > 0
    return eng, res


def _token_streams(res):
    return {r.request_id: tuple(r.output_tokens) for r in res.requests}


# ------------------------------------------------ semantics preservation

def test_pipelined_decode_token_identical_on_scenario(cfg):
    """The acceptance pin: pipelining changes *when* work runs, not *what*
    it computes — greedy outputs match the lockstep engine on a seeded
    scenario."""
    _, res_lock = _run(cfg, decode_mode="lockstep")
    _, res_pipe = _run(cfg, decode_mode="pipelined")
    _, res_ser = _run(cfg, decode_mode="serialized")
    assert _token_streams(res_lock) == _token_streams(res_pipe) \
        == _token_streams(res_ser)


def test_pipelined_decode_throughput_beats_serialized(cfg):
    """Same pre-submitted batch (identical step sequence across modes): the
    overlap-aware clock charges pipelined decode max(attn, expert)+ε per
    step instead of the sum, so its throughput is strictly higher."""
    def run(mode):
        eng = _engine(cfg, decode_mode=mode)
        rng = np.random.default_rng(1)
        for i in range(8):
            eng.submit(Request(
                i, rng.integers(0, cfg.vocab_size, size=8).astype(np.int32),
                SamplingParams(max_new_tokens=8)))
        return eng.run(max_steps=500)

    m_lock, m_pipe, m_ser = run("lockstep"), run("pipelined"), run("serialized")
    assert m_lock.completed == m_pipe.completed == m_ser.completed == 8
    assert m_pipe.wall_time < m_ser.wall_time
    assert m_pipe.decode_throughput > m_ser.decode_throughput
    # the split alone is free on the clock: serialized == lockstep cost
    assert m_ser.wall_time == pytest.approx(m_lock.wall_time)


def test_chunked_prefill_token_identical(cfg):
    """Chunk composition reproduces whole-prompt prefill bit-for-bit (the
    staging cache holds the same rotated keys), so greedy outputs match."""
    _, res_un = _run(cfg, scenario_kw=dict(prompt_len=12))
    _, res_ch = _run(cfg, scenario_kw=dict(prompt_len=12),
                     prefill_chunk=5, policy="fair")
    assert _token_streams(res_un) == _token_streams(res_ch)
    _, res_pp = _run(cfg, scenario_kw=dict(prompt_len=12),
                     prefill_chunk=4, policy="prefill-priority")
    assert _token_streams(res_un) == _token_streams(res_pp)


def test_determinism_with_pipeline_and_chunking(cfg):
    kw = dict(decode_mode="pipelined", prefill_chunk=4, policy="fair")
    _, r1 = _run(cfg, **kw)
    _, r2 = _run(cfg, **kw)
    assert r1.metrics.fingerprint() == r2.metrics.fingerprint()


# ------------------------------------------------------- latency pins

def test_chunked_prefill_bounds_max_itl(cfg):
    """Bursty long prompts: unchunked prefill stalls every decoding request
    for a whole prompt; fair chunking bounds the gap to one chunk."""
    def run(**kw):
        eng = _engine(cfg, **kw)
        sc = (Scenario(horizon=0.3, seed=0, prompt_len=32, max_new=8,
                       vocab=cfg.vocab_size)
              .bursty(base=20, peak=200, period=0.15, duty=0.3))
        res = sc.run(eng)
        assert res.metrics.completed == res.metrics.total_requests > 4
        return res.metrics

    m_un = run()
    m_ch = run(prefill_chunk=8, policy="fair")
    assert m_ch.itl_stats()["max"] < m_un.itl_stats()["max"]


def test_ttft_tracked(cfg):
    eng, res = _run(cfg)
    m = res.metrics
    assert len(m.ttfts) == m.completed
    assert all(t > 0 for t in m.ttfts)
    st = m.ttft_stats()
    assert 0 < st["p50"] <= st["p99"] <= st["max"]
    assert "ttft" in m.summary()
    # per-request view agrees with the metric and the timeline events
    by_rid = {e["rid"]: e["ttft"] for e in m.events
              if e["event"] == "prefill"}
    for r in res.requests:
        assert r.ttft == pytest.approx(by_rid[r.request_id])
    # prefill-priority admits eagerly; fcfs batches run to completion
    # first, so arrivals wait longer for their first token
    _, res_fcfs = _run(cfg, policy="fcfs")
    assert res_fcfs.metrics.ttft_stats()["mean"] > st["mean"]


def test_set_policy_scenario_event(cfg):
    eng = _engine(cfg)
    sc = (Scenario(horizon=0.15, seed=3, max_new=4, vocab=cfg.vocab_size)
          .poisson(rate=100).set_policy(t=0.05, policy="fair"))
    res = sc.run(eng)
    assert eng.scheduler.cfg.policy == "fair"
    evs = [e for e in res.metrics.events if e["event"] == "set_policy"]
    assert evs and evs[0]["policy"] == "fair"
    assert any(a["kind"] == "set_policy" for a in res.applied)


# ---------------------------------------------------- per-request sampling

def test_per_request_sampling_params(cfg):
    """Decode honors each slot's temperature and folds the request seed in:
    greedy rows stay greedy, sampled rows are reproducible and seed-keyed."""
    def tokens(rid_temp_seed):
        eng = _engine(cfg)
        rng = np.random.default_rng(0)
        prompt = rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
        reqs = {rid: Request(rid, prompt.copy(),
                             SamplingParams(temperature=temp,
                                            max_new_tokens=8, seed=seed))
                for rid, temp, seed in rid_temp_seed}
        for r in reqs.values():
            eng.submit(r)
        eng.run(max_steps=200)
        assert all(r.done for r in reqs.values())
        return {rid: tuple(r.output_tokens) for rid, r in reqs.items()}

    a = tokens([(0, 0.0, 0), (1, 0.8, 1), (2, 0.8, 2)])
    b = tokens([(0, 0.0, 0), (1, 0.8, 1), (2, 0.8, 2)])
    assert a == b                      # bit-deterministic
    assert a[0] != a[1]                # sampling leaves the greedy path
    assert a[1] != a[2]                # ...and the request seed is folded in
    # same request (id, seed, prompt) ⇒ same stream regardless of the slot
    # it lands in or the batch composition around it
    c = tokens([(1, 0.8, 1), (0, 0.0, 0), (2, 0.8, 2)])
    assert c == a


# ----------------------------------------------------- scheduler unit level

def _req(i, n=10, max_new=4):
    return Request(i, np.arange(n, dtype=np.int32),
                   SamplingParams(max_new_tokens=max_new))


def test_scheduler_chunk_planning():
    s = Scheduler(SchedulerConfig(max_batch=2, prefill_chunk=4))
    s.submit(_req(0, n=10))
    plans = []
    for _ in range(3):
        p = s.next_plan()
        assert isinstance(p, PrefillChunk)
        plans.append((p.start, p.length, p.is_first, p.is_last))
        s.prefill_advanced(p.slot, p.length)
    assert plans == [(0, 4, True, False), (4, 4, False, False),
                     (8, 2, False, True)]
    assert isinstance(s.next_plan(), DecodeBatch)


def test_scheduler_policies_interleave():
    def mk(policy):
        s = Scheduler(SchedulerConfig(max_batch=2, prefill_chunk=4,
                                      policy=policy))
        # slot 0 decode-ready, slot 1 queued (8 tokens = 2 chunks)
        s.submit(_req(0, n=4))
        p = s.next_plan()
        s.prefill_advanced(p.slot, p.length)
        s.submit(_req(1, n=8))
        return s

    s = mk("prefill-priority")         # drain all chunks first
    kinds = []
    for _ in range(3):
        p = s.next_plan()
        kinds.append(type(p).__name__)
        if isinstance(p, PrefillChunk):
            s.prefill_advanced(p.slot, p.length)
    assert kinds == ["PrefillChunk", "PrefillChunk", "DecodeBatch"]

    s = mk("fair")                     # strict alternation; the setup's
    kinds = []                         # last step was a prefill, so decode
    for _ in range(4):                 # goes first
        p = s.next_plan()
        kinds.append(type(p).__name__)
        if isinstance(p, PrefillChunk):
            s.prefill_advanced(p.slot, p.length)
    assert kinds == ["DecodeBatch", "PrefillChunk", "DecodeBatch",
                     "PrefillChunk"]

    s = mk("fcfs")                     # in-flight decode precedes prefill
    assert isinstance(s.next_plan(), DecodeBatch)


def test_scheduler_backlog_and_release():
    s = Scheduler(SchedulerConfig(max_batch=1, prefill_chunk=3))
    s.submit(_req(0, n=6))
    s.submit(_req(1, n=5))             # no free slot yet
    assert s.pending_prefill_tokens() == 11
    p = s.next_plan()
    s.prefill_advanced(p.slot, p.length)
    assert s.pending_prefill_tokens() == 8
    s.prefill_advanced(p.slot, 3)      # slot 0 fully prefilled
    assert s.pending_prefill_tokens() == 5
    s.release(0)
    p = s.next_plan()                  # request 1 admitted into slot 0
    assert isinstance(p, PrefillChunk)
    assert p.request.request_id == 1 and p.length == 3


def test_scheduler_idle_when_empty():
    s = Scheduler(SchedulerConfig(max_batch=2))
    assert isinstance(s.next_plan(), Idle)


# ------------------------------------------------------- autoscaler signal

def test_autoscaler_prefill_pressure_signal():
    asc = Autoscaler(AutoscalerConfig(rate_per_server=100, min_servers=1,
                                      max_servers=8, window=0.1,
                                      prefill_tokens_per_server=64))
    for t in (0.0, 0.01, 0.02):
        asc.observe_arrival(t)
    base = asc.desired_servers(0.05, queue_depth=0, prefill_backlog=0)
    loaded = asc.desired_servers(0.05, queue_depth=0, prefill_backlog=256)
    assert loaded == min(8, base + 4)
