"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape (head_dim // 2,), fp32."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def rope_cos_sin(positions: jax.Array, head_dim: int, theta: float
                 ) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables for integer positions.

    positions: (..., seq) int32 -> cos,sin: (..., seq, head_dim//2) fp32
    """
    inv = rope_freqs(head_dim, theta)
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Rotate pairs (x[..., ::2], x[..., 1::2]).

    x: (..., seq, heads, head_dim); cos/sin broadcastable to
    (..., seq, 1, head_dim//2).
    """
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = x[..., ::2], x[..., 1::2]
    cos = cos[..., :, None, :]
    sin = sin[..., :, None, :]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(dtype)


def mrope_cos_sin(positions_thw: jax.Array, head_dim: int, theta: float,
                  sections: Tuple[int, ...]) -> Tuple[jax.Array, jax.Array]:
    """Qwen2-VL multimodal RoPE.

    positions_thw: (3, ..., seq) int32 — temporal/height/width position ids.
    ``sections`` partitions the head_dim//2 frequency slots into (t, h, w)
    groups; each group rotates by its own position stream.
    Returns cos/sin of shape (..., seq, head_dim//2).
    """
    assert sum(sections) == head_dim // 2, (sections, head_dim)
    inv = rope_freqs(head_dim, theta)                     # (hd/2,)
    # angle per stream: (3, ..., seq, hd/2)
    ang = positions_thw.astype(jnp.float32)[..., None] * inv
    # per-frequency-slot stream selection via one-hot contraction
    sec_id = jnp.repeat(jnp.arange(len(sections)),
                        jnp.array(sections), total_repeat_length=head_dim // 2)
    onehot = jax.nn.one_hot(sec_id, len(sections), dtype=jnp.float32)  # (hd/2, 3)
    ang = jnp.einsum("s...j,js->...j", ang, onehot)
    return jnp.cos(ang), jnp.sin(ang)


def text_mrope_positions(positions: jax.Array) -> jax.Array:
    """For pure-text tokens all three M-RoPE streams share the position."""
    return jnp.broadcast_to(positions[None], (3,) + positions.shape)
