"""Data pipeline: deterministic synthetic LM streams (offline container) with
a ShareGPT-like length distribution for the serving benchmarks, plus a
sharded host-batch loader for training.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


def synthetic_lm_batches(cfg: ModelConfig, batch: int, seq: int,
                         seed: int = 0) -> Iterator[Dict]:
    """Infinite stream of {tokens, labels} with a learnable bigram structure
    (so a few hundred steps of training visibly reduce loss)."""
    rng = np.random.default_rng(seed)
    V = cfg.vocab_size
    # a sparse random bigram transition table makes next-token predictable
    fanout = 4
    table = rng.integers(0, V, size=(V, fanout))
    while True:
        toks = np.empty((batch, seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, V, size=batch)
        choices = rng.integers(0, fanout, size=(batch, seq))
        for t in range(seq):
            toks[:, t + 1] = table[toks[:, t], choices[:, t]]
        batch_dict = {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
        }
        if cfg.is_encoder_decoder:
            frames = rng.standard_normal(
                (batch, cfg.encoder_seq_len, cfg.d_model)).astype(np.float32)
            batch_dict["frames"] = jnp.asarray(frames)
        yield batch_dict


@dataclasses.dataclass
class ShareGPTLike:
    """Prompt/response length sampler matching the paper's workload shape:
    lognormal prompts, responses capped at 768 tokens (paper §5.1)."""

    seed: int = 0
    prompt_mu: float = 5.3       # median ~200 tokens
    prompt_sigma: float = 0.9
    response_mu: float = 5.0     # median ~150 tokens
    response_sigma: float = 0.8
    response_cap: int = 768
    prompt_cap: int = 4096

    def sample(self, n: int) -> Tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng(self.seed)
        p = np.clip(rng.lognormal(self.prompt_mu, self.prompt_sigma, n),
                    1, self.prompt_cap).astype(np.int32)
        r = np.clip(rng.lognormal(self.response_mu, self.response_sigma, n),
                    1, self.response_cap).astype(np.int32)
        return p, r
