#!/usr/bin/env python
"""CI benchmark-regression gate: compare a benchmark's JSON against its
committed baseline.

Contract: the benchmark JSON carries a top-level ``gate`` object::

    "gate": {
        "exact":     {"<key>": <value>, ...},   # must match bit-for-bit
        "tolerance": {"<key>": <number>, ...}   # relative tolerance
    }

``exact`` holds token-identity fingerprints, equivalence booleans and the
smoke flag — anything whose change means the benchmark no longer computes
the same thing.  ``tolerance`` holds throughput-like numbers that may
drift with the environment; they must stay within ``--tolerance`` relative
error of the baseline (default 20%, and one-sided checks make no sense for
a virtual clock — both directions flag, a silent speedup usually means the
benchmark stopped measuring what it did).

Every key present in the *baseline* must be present and conforming in the
current run; extra keys in the current run are reported but pass (so a
benchmark can grow new metrics before its baseline is refreshed).

Usage::

    python tools/check_bench.py \
        --current experiments/bench/expert_balance.json \
        --baseline experiments/baselines/expert_balance.json

    # refresh a baseline after an intentional change:
    python tools/check_bench.py --current ... --baseline ... \
        --write-baseline

Exit status: 0 = pass, 1 = regression, 2 = bad invocation / missing file.
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
from typing import Dict, List, Tuple


def load_gate(path: str) -> Tuple[Dict, Dict]:
    with open(path) as f:
        doc = json.load(f)
    gate = doc.get("gate")
    if not isinstance(gate, dict):
        raise ValueError(f"{path}: no 'gate' object — the benchmark does "
                         "not participate in the regression lane")
    return gate.get("exact", {}), gate.get("tolerance", {})


def compare(base_exact: Dict, base_tol: Dict, cur_exact: Dict,
            cur_tol: Dict, tolerance: float) -> Tuple[List[str], List[str]]:
    """Returns (failures, notes)."""
    failures: List[str] = []
    notes: List[str] = []
    for key, want in base_exact.items():
        if key not in cur_exact:
            failures.append(f"exact '{key}': missing from current run")
        elif cur_exact[key] != want:
            failures.append(f"exact '{key}': baseline {want!r} != "
                            f"current {cur_exact[key]!r}")
    for key, want in base_tol.items():
        if key not in cur_tol:
            failures.append(f"tolerance '{key}': missing from current run")
            continue
        have = cur_tol[key]
        denom = max(abs(float(want)), 1e-12)
        rel = abs(float(have) - float(want)) / denom
        line = (f"tolerance '{key}': baseline {want:.6g}, "
                f"current {have:.6g} (drift {rel * 100:.1f}%)")
        if rel > tolerance:
            failures.append(line + f" > {tolerance * 100:.0f}% allowed")
        else:
            notes.append(line)
    for key in cur_exact.keys() - base_exact.keys():
        notes.append(f"exact '{key}': new (not in baseline) — ignored")
    for key in cur_tol.keys() - base_tol.keys():
        notes.append(f"tolerance '{key}': new (not in baseline) — ignored")
    return failures, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="benchmark JSON regression gate")
    ap.add_argument("--current", required=True,
                    help="JSON written by the benchmark run under test")
    ap.add_argument("--baseline", required=True,
                    help="committed baseline JSON "
                         "(experiments/baselines/*.json)")
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="max relative drift for tolerance keys "
                         "(default 0.2)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="copy the current JSON over the baseline "
                         "(intentional-change update flow) and exit 0")
    args = ap.parse_args(argv)

    if not os.path.exists(args.current):
        print(f"check_bench: current run {args.current} not found "
              "(did the benchmark run?)", file=sys.stderr)
        return 2

    if args.write_baseline:
        os.makedirs(os.path.dirname(args.baseline) or ".", exist_ok=True)
        shutil.copyfile(args.current, args.baseline)
        print(f"check_bench: baseline {args.baseline} refreshed from "
              f"{args.current}")
        return 0

    if not os.path.exists(args.baseline):
        print(f"check_bench: baseline {args.baseline} not found — commit "
              "one with --write-baseline", file=sys.stderr)
        return 2

    try:
        base_exact, base_tol = load_gate(args.baseline)
        cur_exact, cur_tol = load_gate(args.current)
    except (ValueError, json.JSONDecodeError) as e:
        print(f"check_bench: {e}", file=sys.stderr)
        return 2

    failures, notes = compare(base_exact, base_tol, cur_exact, cur_tol,
                              args.tolerance)
    name = os.path.basename(args.baseline)
    for line in notes:
        print(f"  [ok] {line}")
    if failures:
        print(f"check_bench: {name}: {len(failures)} regression(s):")
        for line in failures:
            print(f"  [FAIL] {line}")
        return 1
    print(f"check_bench: {name}: pass ({len(base_exact)} exact, "
          f"{len(base_tol)} toleranced keys)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
