"""Whisper frontend stub (DESIGN.md: modality frontends are stubs).

The real model converts 30 s of audio to a log-mel spectrogram and runs two
conv layers producing 1500 frame embeddings.  Per the assignment, the
backbone is what counts: ``frame_embeddings`` fabricates deterministic
(batch, 1500, d_model) inputs, matching ``input_specs()`` in the dry-run.
The transformer itself lives in models/transformer.py (`_build_encdec`).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def frame_embeddings(cfg: ModelConfig, batch: int, seed: int = 0) -> jax.Array:
    """Precomputed conv-frontend output stand-in: (B, 1500, d_model)."""
    key = jax.random.PRNGKey(seed)
    return jax.random.normal(
        key, (batch, cfg.encoder_seq_len, cfg.d_model), jnp.float32) * 0.1


def frame_spec(cfg: ModelConfig, batch: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((batch, cfg.encoder_seq_len, cfg.d_model),
                                jnp.bfloat16)
