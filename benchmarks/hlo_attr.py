"""HLO collective attribution: group collective bytes by source op_name.

The perf-iteration microscope: for a dry-run cell, compile a 1-unit probe
and report which *source operations* (from HLO metadata) the all-gathers /
all-reduces / a2a traffic come from.  This is how hypotheses in
EXPERIMENTS.md §Perf are formed and validated.

Usage:  PYTHONPATH=src:. python -m benchmarks.hlo_attr <arch> <shape>
"""

from __future__ import annotations

import re
import sys
from collections import defaultdict
from typing import Dict, Tuple

OP_RE = re.compile(
    r"(?<![%\w-])(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start|-done)?(?:\.\d+)?\s*\(")
SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|s64|f64)\[([\d,]*)\]")
META_RE = re.compile(r'op_name="([^"]*)"')
DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "s64": 8, "f64": 8}


def attribute(hlo_text: str, top: int = 20) -> Dict[Tuple[str, str], float]:
    groups = defaultdict(float)
    counts = defaultdict(int)
    for line in hlo_text.splitlines():
        m = OP_RE.search(line)
        if not m or m.group(2) == "-done":
            continue
        kind = m.group(1)
        lhs = line[:m.start()]
        if "=" not in lhs:
            continue
        nbytes = 0
        for dm in SHAPE_RE.finditer(lhs):
            n = 1
            for d in dm.group(2).split(","):
                if d:
                    n *= int(d)
            nbytes += n * DTYPE_BYTES[dm.group(1)]
        meta = META_RE.search(line)
        src = meta.group(1) if meta else "<no-metadata>"
        # trim jit scopes to the interesting tail
        src = "/".join(src.split("/")[-3:])[-90:]
        groups[(kind, src)] += nbytes
        counts[(kind, src)] += 1
    return groups, counts


def report(arch: str, shape_name: str, multi_pod: bool = False,
           top: int = 20) -> None:
    from repro.configs import get_config, shape_by_name
    from repro.launch.dryrun import build_cell, probe_plan
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    probes, _ = probe_plan(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    shape = shape_by_name(shape_name)
    fn, args = build_cell(arch, shape, mesh, cfg=probes[0], unroll=True)
    compiled = fn.lower(*args).compile()
    groups, counts = attribute(compiled.as_text())
    print(f"== {arch} × {shape_name} (1-unit probe) — "
          f"collective bytes by source ==")
    for (kind, src), b in sorted(groups.items(), key=lambda kv: -kv[1])[:top]:
        print(f"  {b/2**20:10.1f} MiB  ×{counts[(kind, src)]:<4d} {kind:18s} {src}")


if __name__ == "__main__":
    report(sys.argv[1], sys.argv[2],
           multi_pod="--multi-pod" in sys.argv)
