"""Train a small MoE LM end-to-end with the EAAS expert tier in the loss
path, demonstrating the training substrate: Adafactor/AdamW, gradient
clipping, int8 gradient compression with error feedback, async fault-
tolerant checkpointing, restart-resume.

Default config is CI-sized (~3M params, 60 steps, ~1 min on CPU);
``--full`` trains a ~100M-param model for 300 steps.

Run:  PYTHONPATH=src python examples/train_small.py [--full] [--restore]
"""

import argparse
import os

import jax

from repro.configs import get_config
from repro.core.moe_layer import default_runtime
from repro.models.transformer import ParallelCtx, build_model
from repro.training.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.training.data import synthetic_lm_batches
from repro.training.optimizer import adamw, cosine_schedule
from repro.training.train_loop import init_train_state, make_train_step

CKPT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "ckpt_train_small")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M params, 300 steps")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--restore", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    base = get_config("kimi-k2-1t-a32b").reduced()
    if args.full:
        cfg = base.replace(num_layers=8, d_model=512, num_heads=8,
                           num_kv_heads=4, d_head=64, d_ff=1024,
                           vocab_size=32768)
        cfg = cfg.replace(moe=cfg.moe and base.moe.__class__(
            num_experts=16, top_k=2, d_expert=1024, num_shared_experts=1,
            first_k_dense=1))
        steps = args.steps or 300
        batch, seq = 8, 256
    else:
        cfg = base
        steps = args.steps or 60
        batch, seq = 8, 64

    S = 4
    model = build_model(cfg, num_servers=S)
    n_params = cfg.num_params()
    print(f"training {cfg.arch_id}: ~{n_params/1e6:.1f}M params, "
          f"{steps} steps, batch {batch} × seq {seq}")

    rt = default_runtime(cfg, S, batch * seq, gemm_impl="xla_ragged")
    ctx = ParallelCtx(remat=False, moe_runtime=rt, ce_chunk=64)
    opt = adamw(lr=cosine_schedule(3e-3, warmup=20, total=steps))
    data = synthetic_lm_batches(cfg, batch, seq, seed=0)

    ckpt = AsyncCheckpointer(CKPT_DIR, keep=2)
    state = init_train_state(model, opt, jax.random.PRNGKey(0),
                             compression=args.compress_grads)
    start = 0
    if args.restore and latest_step(CKPT_DIR) is not None:
        restored, start = restore_checkpoint(CKPT_DIR, state)
        state = restored
        print(f"resumed from checkpoint step {start}")

    step_fn = jax.jit(make_train_step(model, opt, ctx,
                                      compression=args.compress_grads))
    first = last = None
    for i in range(start, steps):
        state, m = step_fn(state, next(data))
        loss = float(m["loss"])
        first = first if first is not None else loss
        last = loss
        if i % 10 == 0 or i == steps - 1:
            print(f"step {i:4d}  loss {loss:.4f}  "
                  f"gnorm {float(m['grad_norm']):.3f}  "
                  f"dropped {int(m['dropped'])}")
        if (i + 1) % 25 == 0:
            ckpt.save(i + 1, state)
    ckpt.wait()
    print(f"loss {first:.3f} -> {last:.3f}")
    assert last < first, "training must reduce loss"
    print("train_small OK")


if __name__ == "__main__":
    main()
