"""Dry-run machinery unit tests (no 512-device compile): collective parsing,
probe extrapolation, input specs, cache sharding specs, applicability."""

import jax
import jax.numpy as jnp

from repro.configs import ALL_SHAPES, ASSIGNED_ARCHS, applicable, get_config, shape_by_name


def _dry():
    # import inside a helper: module sets XLA_FLAGS before jax import, which
    # is a no-op here because jax is already initialized with 1 device
    from repro.launch import dryrun
    return dryrun


def test_parse_collective_bytes_tuple_and_async():
    dr = _dry()
    hlo = """
  %all-to-all.3 = (f32[2,32]{1,0}, f32[2,32]{1,0}) all-to-all(%a, %b), dims={0}
  %ag = bf16[4,8]{1,0} all-gather(%x), dimensions={0}
  %ar-start = f32[16]{0} all-reduce-start(%y), to_apply=%add
  %ar-done = f32[16]{0} all-reduce-done(%ar-start)
  %gte = f32[2,32]{1,0} get-tuple-element(%all-to-all.3), index=0
"""
    got = dr.parse_collective_bytes(hlo)
    assert got["all-to-all"] == 2 * 2 * 32 * 4
    assert got["all-gather"] == 4 * 8 * 2
    assert got["all-reduce"] == 16 * 4          # start counted, done skipped
    assert got["_counts"]["all-to-all"] == 1


def test_probe_plan_covers_all_archs():
    dr = _dry()
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        probes, comb = dr.probe_plan(cfg)
        assert len(probes) >= 2
        for p in probes:
            assert p.num_layers <= cfg.num_layers
        # linear extrapolation sanity: identical costs -> same total
        c = {"flops": 10.0, "bytes": 4.0}
        out = comb([c] * len(probes))
        assert out["flops"] >= 10.0


def test_probe_extrapolation_linear():
    dr = _dry()
    c1 = {"flops": 100.0, "coll_total": 10.0}
    c2 = {"flops": 160.0, "coll_total": 13.0}
    total = dr._lin(c1, c2, units=5)
    assert total["flops"] == 100.0 + 60.0 * 4
    assert total["coll_total"] == 10.0 + 3.0 * 4


def test_input_specs_per_kind():
    dr = _dry()
    cfg = get_config("whisper-base")
    tr = dr.input_specs(cfg, shape_by_name("train_4k"))
    assert set(tr) == {"tokens", "labels", "frames"}
    assert tr["tokens"].shape == (256, 4096)
    de = dr.input_specs(cfg, shape_by_name("decode_32k"))
    assert de["tokens"].shape == (128, 1)
    vl = dr.input_specs(get_config("qwen2-vl-2b"), shape_by_name("train_4k"))
    assert vl["mrope_positions"].shape == (3, 256, 4096)


def test_cache_sharding_specs_decode_and_long():
    dr = _dry()
    sds = jax.ShapeDtypeStruct
    # decode_32k KV leaf: (L, B, slots, KV, hd)
    leaf = sds((40, 128, 32768, 8, 128), jnp.bfloat16)
    spec = dr._cache_sharding_specs(
        {"k": leaf}, batch=128, dp=("data",), seq_axes=("model",),
        seq_len=32768)["k"]
    assert spec[2] == "model" and spec[1] == "data"   # P() unwraps 1-tuples
    # long_500k: batch 1, slots over data+model
    leaf = sds((40, 1, 524288, 8, 128), jnp.bfloat16)
    spec = dr._cache_sharding_specs(
        {"k": leaf}, batch=1, dp=("data",), seq_axes=("data", "model"),
        seq_len=524288)["k"]
    assert spec[2] == ("data", "model")
    # window cache (no seq dim): falls back to batch
    leaf = sds((5, 128, 1024, 4, 256), jnp.bfloat16)
    spec = dr._cache_sharding_specs(
        {"k": leaf}, batch=128, dp=("data",), seq_axes=("model",),
        seq_len=32768)["k"]
    assert spec[1] == "data"


def test_applicability_matrix():
    skips = {(a, s.name) for a in ASSIGNED_ARCHS for s in ALL_SHAPES
             if not applicable(get_config(a), s)[0]}
    # exactly the pure full-attention archs skip long_500k
    assert skips == {(a, "long_500k") for a in
                     ["granite-3-2b", "minitron-8b", "phi3-medium-14b",
                      "arctic-480b", "kimi-k2-1t-a32b", "whisper-base",
                      "qwen2-vl-2b"]}


def test_model_flops_accounting():
    import importlib
    roof = importlib.import_module("benchmarks.roofline")
    mf_train = roof.model_flops("granite-3-2b", "train_4k", 256)
    cfg = get_config("granite-3-2b")
    expected = 6 * cfg.num_params() * 256 * 4096 / 256
    assert abs(mf_train - expected) / expected < 1e-9
    mf_dec = roof.model_flops("kimi-k2-1t-a32b", "decode_32k", 256)
    cfgk = get_config("kimi-k2-1t-a32b")
    assert abs(mf_dec - 2 * cfgk.num_active_params() * 128 / 256) < 1e-3 * mf_dec
