"""Token sampling from logits."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(logits: jax.Array, temperature: float, key) -> jax.Array:
    """logits: (B, V) fp32 -> (B,) int32."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(
        jnp.int32)


@jax.jit
def sample_batch(logits: jax.Array, temperatures: jax.Array,
                 keys: jax.Array, steps: jax.Array) -> jax.Array:
    """Per-slot sampling honoring each request's SamplingParams.

    logits: (B, V) fp32; temperatures: (B,) — ``<= 0`` rows are greedy;
    keys: (B, 2) uint32 per-slot base keys (the request seed folded with the
    request id at admission); steps: (B,) int32 tokens generated so far.
    Each row's key is ``fold_in(key_b, step_b)``, so the sampled stream is a
    pure function of (request seed, request id, token index) — replayable
    regardless of batch composition or scheduling order.
    """
    step_keys = jax.vmap(jax.random.fold_in)(keys, steps)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    safe_t = jnp.where(temperatures > 0.0, temperatures, 1.0)
    sampled = jax.vmap(
        lambda lg, t, k: jax.random.categorical(k, lg / t))(
            logits, safe_t, step_keys).astype(jnp.int32)
    return jnp.where(temperatures > 0.0, sampled, greedy)
