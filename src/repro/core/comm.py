"""Buffer transfer between attention clients and expert servers.

This is the TPU adaptation of the paper's IBGDA one-sided RDMA library
(DESIGN.md §2).  Each (client, server) buffer slot from
:class:`~repro.core.types.DispatchBuffers` rides one collective:

* ``mode="a2a"``        — tokens are sharded over the server axis too
  (train / prefill): one `all_to_all` moves every slot to its owner.  On ICI
  this lowers to the same one-sided remote-DMA transfers IBGDA issues, but
  scheduled by XLA so it can overlap with compute (double-batch-overlap).
* ``mode="replicated"`` — decode: activations are already replicated across
  the server axis after the attention TP all-reduce, so *no request transfer
  is needed at all*; each server reads its own slot locally and the combine
  is a single psum of the (tiny) per-token outputs.  This is a beyond-paper
  optimization available only because of the disaggregated buffer layout.
* ``mode="local"``      — single-device simulation (tests / CPU examples):
  the identity transfer; servers are vmapped.

The asymmetry of the paper's protocol ("the server does not initiate any
communication") is preserved structurally: transfers appear only in
client-side code; server code (expert_server.py) is a pure function from its
received slots to its result slots.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.types import DispatchBuffers


def send_to_servers(buffers: DispatchBuffers, axis_name: Optional[str],
                    mode: str):
    """Client half of the request transfer.

    Returns (hidden, expert_id, score, counts) as seen by the local server:
      a2a/local:  hidden (S, C, d) — dim0 = source client
      replicated: hidden (1, C, d) — this server's own slot (selected locally)
    """
    if mode == "local" or axis_name is None:
        return buffers.hidden, buffers.expert_id, buffers.score, buffers.counts

    if mode == "a2a":
        a2a = lambda x: jax.lax.all_to_all(
            x, axis_name, split_axis=0, concat_axis=0, tiled=True)
        return (a2a(buffers.hidden), a2a(buffers.expert_id),
                a2a(buffers.score), a2a(buffers.counts))

    if mode == "replicated":
        rank = jax.lax.axis_index(axis_name)
        sel = lambda x: jax.lax.dynamic_slice_in_dim(x, rank, 1, axis=0)
        return (sel(buffers.hidden), sel(buffers.expert_id),
                sel(buffers.score), sel(buffers.counts))

    raise ValueError(mode)


def return_to_clients(result_hidden: jax.Array, axis_name: Optional[str],
                      mode: str) -> jax.Array:
    """Server→client response transfer (the read-result half of the slot).

    result_hidden: (S_src, C, d) for a2a/local (dim0 = source client, i.e.
    where each slot must go back to), or (1, C, d) for replicated.
    Returns (S, C, d) per client — dim0 = responding server.
    """
    if mode == "local" or axis_name is None:
        return result_hidden
    if mode == "a2a":
        return jax.lax.all_to_all(
            result_hidden, axis_name, split_axis=0, concat_axis=0, tiled=True)
    if mode == "replicated":
        # Place my slice at my rank; combine()'s masked gather + psum does the
        # rest (dispatch.combine is linear in the result buffer).
        S = jax.lax.axis_size(axis_name)
        rank = jax.lax.axis_index(axis_name)
        C, d = result_hidden.shape[1:]
        buf = jnp.zeros((S, C, d), result_hidden.dtype)
        return jax.lax.dynamic_update_slice_in_dim(buf, result_hidden, rank, 0)
    raise ValueError(mode)


def finalize_combine(y_partial: jax.Array, axis_name: Optional[str],
                     mode: str) -> jax.Array:
    """Cross-server reduction of the combined output (replicated mode only)."""
    if mode == "replicated" and axis_name is not None:
        return jax.lax.psum(y_partial, axis_name)
    return y_partial
