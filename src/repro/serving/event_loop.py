"""Per-expert-server micro-batch queues — the async expert tier's data plane.

The paper's disaggregation claim is that expert servers are *independent
services*: attention clients enqueue micro-batches and servers drain them
continuously, so one slow or busy server delays only the work routed to it
instead of barriering the whole step.  This module is the host-side model
of that tier:

* :class:`MicroBatch` — one client wave's routed share on one server:
  ``tokens`` of routed load, ``work`` seconds of compute at speed 1,
  enqueue/start/finish times filled in by the queue simulation;
* :class:`ServerQueue` — one expert server: a ``busy_until`` frontier plus
  a per-server ``slowdown`` factor (scenario ``slow_server`` events) and a
  liveness flag.  Service is work-conserving FIFO in dispatch order;
* :class:`AsyncExpertTier` — the shared tier: dispatch, failure
  re-dispatch (queued micro-batches of a dead server move to the
  least-busy surviving server — no token is lost, the paper's replica
  failover), recovery, migration occupancy (rebalance weight-copy chunks
  busy the servers, not the clients), and conservation counters
  (``enqueued == completed + cancelled + in_flight()`` — the invariant the
  property tests pin).

The tier computes *when* modeled work finishes; it never touches arrays —
the engine computes values eagerly at dispatch (decode outputs are bitwise
independent of batch composition and of placement, so timing and values
decouple) and posts the finish times onto its
:class:`~repro.serving.clock.EventTimeline`.  Under a cluster the tier is
shared: every client's micro-batches queue on the same ``busy_until``
frontiers, so cross-client contention emerges from queueing instead of an
analytic stretch factor.

Re-dispatch bookkeeping: each micro-batch carries a ``generation`` bumped
when it moves servers.  Completion events posted for the old placement
carry the stale generation and are ignored (:meth:`AsyncExpertTier.
is_current`) — the standard DES trick for revising an eagerly scheduled
future.  A server's ``slowdown`` applies to micro-batches dispatched from
then on; already-queued work keeps its committed finish time (the model's
service commitment, kept for determinism).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np


@dataclass
class MicroBatch:
    """One wave's routed share on one expert server (modeled timing)."""

    mb_id: int
    client_id: int
    wave_id: int
    server: int
    tokens: float              # routed load share (diagnostic)
    work: float                # seconds of compute at slowdown 1.0
    enqueue_t: float
    start_t: float = 0.0
    finish_t: float = 0.0
    generation: int = 0        # bumped on failure re-dispatch
    done: bool = False
    cancelled: bool = False


@dataclass
class ServerQueue:
    """One expert server's service frontier (work-conserving FIFO)."""

    rank: int
    slowdown: float = 1.0      # >1 = straggler (scenario slow_server)
    alive: bool = True
    busy_until: float = 0.0
    enqueued: int = 0
    drained: int = 0

    def schedule(self, mb: MicroBatch, now: float) -> None:
        """Append ``mb`` to this server's queue: it starts when the server
        frees up and runs for ``work * slowdown`` seconds."""
        mb.server = self.rank
        mb.start_t = max(float(now), self.busy_until)
        mb.finish_t = mb.start_t + mb.work * self.slowdown
        self.busy_until = mb.finish_t
        self.enqueued += 1


class AsyncExpertTier:
    """The shared micro-batch queue tier over ``num_servers`` servers."""

    def __init__(self, num_servers: int):
        self.queues: List[ServerQueue] = [ServerQueue(s)
                                          for s in range(num_servers)]
        # in-flight micro-batches only: retired (done/cancelled) entries
        # are pruned at retirement, so memory stays bounded by in-flight
        # work and the failure/cancel scans are O(in-flight), not
        # O(all-time micro-batches)
        self.mbs: Dict[int, MicroBatch] = {}
        self._next_id = 0
        self.enqueued = 0
        self.completed = 0
        self.cancelled = 0
        self.redispatched = 0
        self.migration_busy = 0.0          # seconds of migrate occupancy

    @property
    def num_servers(self) -> int:
        return len(self.queues)

    def in_flight(self) -> int:
        """Micro-batches dispatched but neither completed nor cancelled —
        the conservation counter (enqueued == completed + cancelled +
        in_flight)."""
        return self.enqueued - self.completed - self.cancelled

    # ----------------------------------------------------------- dispatch
    def dispatch(self, client_id: int, wave_id: int, work: np.ndarray,
                 now: float, tokens: Optional[np.ndarray] = None
                 ) -> List[MicroBatch]:
        """Enqueue one wave: ``work[s]`` seconds of expert compute on
        server ``s`` (zero entries skipped).  Returns the micro-batches
        with committed start/finish times."""
        work = np.asarray(work, np.float64)
        out: List[MicroBatch] = []
        for s in range(min(len(work), self.num_servers)):
            w = float(work[s])
            if w <= 0.0:
                continue
            mb = MicroBatch(
                mb_id=self._next_id, client_id=client_id, wave_id=wave_id,
                server=s, tokens=float(tokens[s]) if tokens is not None
                else w, work=w, enqueue_t=float(now))
            self._next_id += 1
            self.queues[s].schedule(mb, now)
            self.mbs[mb.mb_id] = mb
            self.enqueued += 1
            out.append(mb)
        return out

    def is_current(self, mb_id: int, generation: int) -> bool:
        """True when a completion event for (mb_id, generation) is still
        valid — not re-dispatched since, not cancelled, not already done
        (retired entries are pruned, so a missing id is simply stale)."""
        mb = self.mbs.get(mb_id)
        return (mb is not None and not mb.cancelled and not mb.done
                and mb.generation == generation)

    def mark_done(self, mb: MicroBatch) -> None:
        mb.done = True
        self.queues[mb.server].drained += 1
        self.completed += 1
        # retire: any duplicate/stale-generation event still in a timeline
        # resolves to "not current" via the missing id
        self.mbs.pop(mb.mb_id, None)

    # ------------------------------------------------------------- faults
    def fail_server(self, rank: int, now: float) -> List[MicroBatch]:
        """A server dies mid-drain: every unfinished micro-batch queued on
        it is re-dispatched to the least-busy surviving server (FIFO order
        preserved; no token loss).  Returns the moved micro-batches — the
        owning engines post fresh completion events from the new finish
        times (old events are stale by generation)."""
        if rank >= self.num_servers:
            return []
        q = self.queues[rank]
        q.alive = False
        q.busy_until = min(q.busy_until, float(now))
        victims = sorted(
            (mb for mb in self.mbs.values()
             if mb.server == rank and not mb.done and not mb.cancelled),
            key=lambda m: (m.start_t, m.mb_id))
        moved: List[MicroBatch] = []
        for mb in victims:
            survivors = [t for t in self.queues if t.alive]
            if not survivors:
                # nobody can serve it: the wave will be completed by the
                # engine's degenerate path; count the loss explicitly and
                # retire the entry (engines see the missing id as
                # cancelled when reconciling their waves)
                mb.cancelled = True
                self.cancelled += 1
                self.mbs.pop(mb.mb_id, None)
                continue
            target = min(survivors, key=lambda t: (t.busy_until, t.rank))
            mb.generation += 1
            target.schedule(mb, now)
            self.redispatched += 1
            moved.append(mb)
        return moved

    def recover_server(self, rank: int, now: float) -> None:
        if rank >= self.num_servers:
            return
        q = self.queues[rank]
        q.alive = True
        q.busy_until = max(q.busy_until, float(now))

    def set_slowdown(self, rank: int, factor: float) -> None:
        """Scenario ``slow_server``: future micro-batches on ``rank`` run
        ``factor``× slower (already-queued work keeps its committed finish
        time).  ``factor=1.0`` restores full speed."""
        if rank >= self.num_servers:
            return
        if factor <= 0:
            raise ValueError(f"slowdown factor must be > 0, got {factor}")
        self.queues[rank].slowdown = float(factor)

    def cancel_client(self, client_id: int) -> int:
        """A client died: its in-flight micro-batches are abandoned (the
        servers finish the dispatched compute and discard the results —
        dispatched work cannot be clawed back, so the occupancy stays)."""
        n = 0
        for mb in list(self.mbs.values()):
            if mb.client_id == client_id and not mb.done \
                    and not mb.cancelled:
                mb.cancelled = True
                self.cancelled += 1
                self.mbs.pop(mb.mb_id, None)
                n += 1
        return n

    # ----------------------------------------------------------- control
    def occupy_all(self, now: float, dt: float) -> None:
        """A migration chunk busies every alive server for ``dt`` (the
        weight copy lands on the servers, not the clients): in-flight
        micro-batches keep their committed times, the *next* dispatches
        queue behind the copy — migration interleaves with decoding
        instead of stalling the clients."""
        for q in self.queues:
            if q.alive:
                q.busy_until = max(q.busy_until, float(now)) + float(dt)
        self.migration_busy += float(dt)

    def resize(self, num_servers: int, now: float) -> None:
        """Elastic pool resize (the engine drains in-flight waves first —
        re-sharding quiesces the tier): fresh queues at full speed, all
        free from ``now``."""
        self.queues = [ServerQueue(s, busy_until=float(now))
                       for s in range(num_servers)]
