"""Dispatch invariants: packing conservation, method equivalence, combine."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install "
    "hypothesis); dispatch invariants are also covered hypothesis-free in "
    "test_scenario.py")
from hypothesis import given, settings, strategies as st

from repro.core import dispatch


def _random_routing(rng, T, k, S):
    x = rng.normal(size=(T, 8)).astype(np.float32)
    eids = rng.integers(0, 100, size=(T, k)).astype(np.int32)
    scores = rng.random(size=(T, k)).astype(np.float32)
    servers = rng.integers(0, S, size=(T, k)).astype(np.int32)
    return x, eids, scores, servers


@pytest.mark.parametrize("method", ["sort", "onehot"])
def test_pack_conservation(method, rng):
    T, k, S, C = 32, 4, 4, 64          # ample capacity: nothing dropped
    x, eids, scores, servers = _random_routing(rng, T, k, S)
    buf = dispatch.pack(jnp.asarray(x), jnp.asarray(eids),
                        jnp.asarray(scores), jnp.asarray(servers), S, C,
                        method=method)
    assert int(buf.dropped) == 0
    assert int(jnp.sum(buf.counts)) == T * k
    # every (token, k) appears at its combine_slot with the right payload
    hid = np.asarray(buf.hidden).reshape(S * C, -1)
    eid = np.asarray(buf.expert_id).reshape(S * C)
    sc = np.asarray(buf.score).reshape(S * C)
    cs = np.asarray(buf.combine_slot)
    for t in range(T):
        for j in range(k):
            slot = cs[t, j]
            assert slot >= 0
            np.testing.assert_allclose(hid[slot], x[t], rtol=1e-6)
            assert eid[slot] == eids[t, j]
            np.testing.assert_allclose(sc[slot], scores[t, j], rtol=1e-6)


def test_pack_methods_equivalent(rng):
    T, k, S, C = 48, 2, 3, 16          # tight capacity: drops happen
    x, eids, scores, servers = _random_routing(rng, T, k, S)
    a = dispatch.pack(jnp.asarray(x), jnp.asarray(eids), jnp.asarray(scores),
                      jnp.asarray(servers), S, C, method="sort")
    b = dispatch.pack(jnp.asarray(x), jnp.asarray(eids), jnp.asarray(scores),
                      jnp.asarray(servers), S, C, method="onehot")
    for fa, fb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))


def test_capacity_drop_counted(rng):
    T, k, S, C = 16, 2, 2, 4
    x, eids, scores, _ = _random_routing(rng, T, k, S)
    servers = np.zeros((T, k), np.int32)       # everything to server 0
    buf = dispatch.pack(jnp.asarray(x), jnp.asarray(eids),
                        jnp.asarray(scores), jnp.asarray(servers), S, C)
    assert int(buf.dropped) == T * k - C
    assert int(buf.counts[0]) == C
    assert int(buf.counts[1]) == 0


def test_combine_weighted_sum(rng):
    T, k, S, C, d = 8, 2, 2, 16, 4
    x = rng.normal(size=(T, d)).astype(np.float32)
    scores = rng.random(size=(T, k)).astype(np.float32)
    eids = np.zeros((T, k), np.int32)
    servers = rng.integers(0, S, size=(T, k)).astype(np.int32)
    buf = dispatch.pack(jnp.asarray(x), jnp.asarray(eids),
                        jnp.asarray(scores), jnp.asarray(servers), S, C)
    # a server that multiplies by 2 and pre-weights by score
    result = buf.hidden * 2.0 * buf.score[..., None]
    y = dispatch.combine(result, buf.combine_slot)
    expected = 2.0 * x * scores.sum(axis=1, keepdims=True)
    np.testing.assert_allclose(y, expected, rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(T=st.integers(1, 40), k=st.integers(1, 4), S=st.integers(1, 6),
       C=st.integers(1, 32), seed=st.integers(0, 999))
def test_pack_properties(T, k, S, C, seed):
    """Hypothesis: counts ≤ C; dropped = total - delivered; slots unique."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(T, 3)).astype(np.float32)
    eids = rng.integers(0, 50, size=(T, k)).astype(np.int32)
    scores = rng.random(size=(T, k)).astype(np.float32)
    servers = rng.integers(0, S, size=(T, k)).astype(np.int32)
    buf = dispatch.pack(jnp.asarray(x), jnp.asarray(eids),
                        jnp.asarray(scores), jnp.asarray(servers), S, C)
    counts = np.asarray(buf.counts)
    assert (counts <= C).all()
    delivered = int(counts.sum())
    assert delivered + int(buf.dropped) == T * k
    slots = np.asarray(buf.combine_slot).reshape(-1)
    live = slots[slots >= 0]
    assert len(np.unique(live)) == len(live)          # no slot collisions
    assert len(live) == delivered
