"""Double-batch-overlap (paper §4.2).

Client pipelining: while microbatch A's expert round-trip is in flight, the
client computes microbatch B's attention.  On TPU the overlap is realized by
XLA's latency-hiding scheduler: we split the batch and express the two
microbatches' dense compute and dispatch collectives as *independent*
subgraphs, so the a2a of A can be hoisted behind the attention FLOPs of B.
The host-level engine gets the same effect by keeping two batches in flight
(serving/engine.py).
"""

from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp


def double_batch_overlap(dense_fn: Callable, moe_fn: Callable,
                         x: jax.Array, *, enabled: bool = True):
    """y = moe_fn(dense_fn(x)) computed as two interleaved microbatches.

    dense_fn/moe_fn must be batch-elementwise (true for transformer blocks).
    With ``enabled=False`` the same split runs sequentially chained, which
    pins the collectives on the critical path (the ablation baseline).
    """
    B = x.shape[0]
    assert B % 2 == 0, "double-batch overlap needs an even batch"
    x0, x1 = jnp.split(x, 2, axis=0)

    if enabled:
        # independent subgraphs: scheduler may overlap a2a(0) with dense(1)
        a0 = dense_fn(x0)
        a1 = dense_fn(x1)
        y0 = moe_fn(a0)
        y1 = moe_fn(a1)
    else:
        # serialized: artificial dependency chains mb1 behind mb0's combine
        a0 = dense_fn(x0)
        y0 = moe_fn(a0)
        # the zero-valued coupling forces a data dependency without changing
        # the math (ablation: communication is exposed)
        a1 = dense_fn(x1 + 0 * jnp.sum(y0).astype(x1.dtype))
        y1 = moe_fn(a1)
    return jnp.concatenate([y0, y1], axis=0)


def microbatch_schedule(n: int) -> Tuple[Tuple[int, str], ...]:
    """The steady-state two-batch schedule (for the engine + docs):
    (mb, phase) pairs — attention(i+1) overlaps expert(i)."""
    steps = []
    for i in range(n):
        steps.append((i, "attention"))
        if i > 0:
            steps.append((i - 1, "combine"))
        steps.append((i, "dispatch"))
    steps.append((n - 1, "combine"))
    return tuple(steps)
