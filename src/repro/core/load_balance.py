"""Dynamic expert load balancing (paper §4.5).

EAAS widens the load-balancing action space beyond EPLB's reorder+replicate:
(1) non-uniform expert counts per server, (2) scaling service instances of
hot experts up/down, (3) heterogeneous server capacity.  This module
implements the statistics pipeline and an EPLB-style greedy replication
planner producing the (mapping, redundant_table) pair consumed by
core.mapping / core.expert_server.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np


@dataclass
class ExpertStats:
    """EMA of per-expert token traffic (fed from MoEStats.expert_load)."""

    num_experts: int
    decay: float = 0.9
    ema: Optional[np.ndarray] = None

    def update(self, load: np.ndarray) -> None:
        load = np.asarray(load, np.float64)
        if self.ema is None:
            self.ema = load.copy()
        else:
            self.ema = self.decay * self.ema + (1 - self.decay) * load

    def hot_experts(self, top: int) -> np.ndarray:
        assert self.ema is not None
        return np.argsort(-self.ema)[:top]


def primary_owner(num_experts: int, num_servers: int) -> np.ndarray:
    """Block-ish primary placement.  Uniform when S | E; otherwise servers
    host ⌈E/S⌉ or ⌊E/S⌋ experts — EAAS does NOT require equal counts
    (paper §4.5: non-uniform experts per server is a balancing degree of
    freedom monolithic EP lacks)."""
    return (np.arange(num_experts) * num_servers // num_experts).astype(
        np.int32)


def eplb_plan(load: np.ndarray, num_servers: int, n_redundant: int,
              max_replicas: int = 4) -> Tuple[np.ndarray, np.ndarray]:
    """Greedy EPLB-style replication plan.

    load: (E,) expected tokens per expert.  Returns
      mapping (E, max_replicas) int32 — candidate servers per expert,
      redundant_table (S, n_redundant) int32 — extra experts per server.

    Primary placement stays block-contiguous (primary_owner) so the weight
    shards never move; hot experts gain replicas on the least-loaded
    servers.  Expected per-server load is balanced under the EAAS client
    policy of spreading tokens uniformly over alive replicas.
    """
    load = np.asarray(load, np.float64)
    E = load.shape[0]
    S = num_servers

    mapping = np.full((E, max_replicas), -1, np.int32)
    mapping[:, 0] = primary_owner(E, S)

    red_table = np.full((S, n_redundant), -1, np.int32)
    red_used = np.zeros(S, np.int32)

    # effective load per server given current replica sets
    replicas = {e: [int(mapping[e, 0])] for e in range(E)}
    server_load = np.zeros(S, np.float64)
    for e in range(E):
        server_load[mapping[e, 0]] += load[e]

    total_slots = S * n_redundant
    order = np.argsort(-load)                      # hottest first
    for _ in range(total_slots):
        # pick the expert whose replication most reduces the max load
        best_e, best_gain, best_s = -1, 0.0, -1
        for e in order[:max(32, 4 * S)]:
            reps = replicas[int(e)]
            if len(reps) >= max_replicas:
                continue
            share = load[e] / len(reps)
            new_share = load[e] / (len(reps) + 1)
            # candidate server: least loaded with a free redundant slot
            cand = -1
            for s in np.argsort(server_load):
                if red_used[s] < n_redundant and s not in reps:
                    cand = int(s)
                    break
            if cand < 0:
                continue
            gain = share - new_share - 1e-12
            # prioritize by current load pressure of the expert's servers
            pressure = max(server_load[s] for s in reps)
            score = gain * (1 + pressure)
            if score > best_gain:
                best_e, best_gain, best_s = int(e), score, cand
        if best_e < 0:
            break
        reps = replicas[best_e]
        old_share = load[best_e] / len(reps)
        new_share = load[best_e] / (len(reps) + 1)
        for s in reps:
            server_load[s] -= old_share - new_share
        server_load[best_s] += new_share
        red_table[best_s, red_used[best_s]] = best_e
        red_used[best_s] += 1
        mapping[best_e, len(reps)] = best_s
        reps.append(best_s)

    return mapping, red_table


def imbalance(load: np.ndarray, mapping: np.ndarray,
              num_servers: int) -> float:
    """max/mean per-server load under uniform replica spreading."""
    load = np.asarray(load, np.float64)
    server_load = np.zeros(num_servers, np.float64)
    for e in range(load.shape[0]):
        reps = mapping[e][mapping[e] >= 0]
        if len(reps) == 0:
            continue
        for s in reps:
            server_load[s] += load[e] / len(reps)
    mean = server_load.mean()
    return float(server_load.max() / max(mean, 1e-12))
