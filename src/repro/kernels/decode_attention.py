"""Pallas TPU flash-decode kernels: one-token GQA attention over a KV cache.

The attention client's hot loop during decoding.  Online-softmax over KV
blocks; grid = (batch, kv_heads, seq_blocks) with the sequence dimension
innermost so the (G, hd) accumulator lives in VMEM scratch across blocks.
Sequence lengths arrive via scalar prefetch; padded cache slots are masked.

Two variants share the kernel body:

* :func:`flash_decode_pallas` — dense per-sequence cache
  ``(B, S, KV, hd)``; KV block ``s`` of sequence ``b`` is just the
  contiguous slice at ``s``.
* :func:`paged_flash_decode_pallas` — block-pool cache: all sequences share
  one pool ``(num_blocks, bs, KV, hd)`` and each sequence names its blocks
  through a ``(B, max_blocks)`` block table.  The table rides scalar
  prefetch, so the *index map* gathers: grid step ``(b, kv, s)`` DMAs pool
  block ``tables[b, s]`` — the kernel body never sees the indirection.

VMEM per step: TS·hd (k) + TS·hd (v) + G·hd (q) + G·hd·4 (acc) — for
TS=512, hd=128, G=8: ~0.5 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params

NEG = -1e30


def _kernel(lengths, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, ts: int, n_s: int, scale: float):
    b = pl.program_id(0)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                  # (G, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)               # (TS, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)               # (TS, hd)

    span = s * ts + jax.lax.broadcasted_iota(jnp.int32, (1, ts), 1)
    valid = span < lengths[b]                            # (1, TS)

    scores = (q @ k.T) * scale                           # (G, TS)
    scores = jnp.where(valid, scores, NEG)

    m_prev = m_ref[...]                                  # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
    # explicit mask: a fully-invalid block must contribute nothing
    p = jnp.where(valid, jnp.exp(scores - m_new), 0.0)   # (G, TS)
    alpha = jnp.exp(m_prev - m_new)                      # (G, 1)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + p @ v
    m_ref[...] = m_new

    @pl.when(s == n_s - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def flash_decode_pallas(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                        lengths: jax.Array, *, ts: int = 512,
                        interpret: bool = False) -> jax.Array:
    """q: (B, H, hd); k/v_cache: (B, S, KV, hd); lengths: (B,) >= 1.

    Returns (B, H, hd).  S must be a multiple of ts.
    """
    B, H, hd = q.shape
    _, S, KV, _ = k_cache.shape
    G = H // KV
    assert G * KV == H and S % ts == 0, (H, KV, S, ts)
    qg = q.reshape(B, KV, G, hd)

    n_s = S // ts
    kernel = functools.partial(_kernel, ts=ts, n_s=n_s,
                               scale=1.0 / np.sqrt(hd))
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, KV, n_s),
            in_specs=[
                pl.BlockSpec((1, 1, G, hd), lambda b, kv, s, L: (b, kv, 0, 0)),
                pl.BlockSpec((1, ts, 1, hd), lambda b, kv, s, L: (b, s, kv, 0)),
                pl.BlockSpec((1, ts, 1, hd), lambda b, kv, s, L: (b, s, kv, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, hd),
                                   lambda b, kv, s, L: (b, kv, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, hd), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        compiler_params=compiler_params(
            ("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, k_cache, v_cache)
    return out.reshape(B, H, hd)


def _paged_kernel(tables, lengths, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, ts: int, n_s: int, scale: float):
    # the block table is consumed by the index maps; the body is the dense
    # online-softmax kernel (view lane j of sequence b == position j)
    _kernel(lengths, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            ts=ts, n_s=n_s, scale=scale)


def paged_flash_decode_pallas(q: jax.Array, k_pool: jax.Array,
                              v_pool: jax.Array, block_tables: jax.Array,
                              lengths: jax.Array, *,
                              interpret: bool = False) -> jax.Array:
    """q: (B, H, hd); k/v_pool: (num_blocks, bs, KV, hd);
    block_tables: (B, max_blocks) int32; lengths: (B,) >= 1.

    Sequence ``b``'s position ``p`` lives in pool block
    ``block_tables[b, p // bs]`` at offset ``p % bs``; positions at or past
    ``lengths[b]`` are masked.  Returns (B, H, hd) — numerically the dense
    :func:`flash_decode_pallas` over the gathered view.
    """
    B, H, hd = q.shape
    _, bs, KV, _ = k_pool.shape
    _, n_s = block_tables.shape
    G = H // KV
    assert G * KV == H, (H, KV)
    qg = q.reshape(B, KV, G, hd)

    kernel = functools.partial(_paged_kernel, ts=bs, n_s=n_s,
                               scale=1.0 / np.sqrt(hd))
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, KV, n_s),
            in_specs=[
                pl.BlockSpec((1, 1, G, hd),
                             lambda b, kv, s, T, L: (b, kv, 0, 0)),
                # the paged gather: block s of sequence b is pool block
                # T[b, s] — the DMA indirection lives in the index map
                pl.BlockSpec((1, bs, 1, hd),
                             lambda b, kv, s, T, L: (T[b, s], 0, kv, 0)),
                pl.BlockSpec((1, bs, 1, hd),
                             lambda b, kv, s, T, L: (T[b, s], 0, kv, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, hd),
                                   lambda b, kv, s, T, L: (b, kv, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, hd), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        compiler_params=compiler_params(
            ("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      qg, k_pool, v_pool)
    return out.reshape(B, H, hd)
