"""EaasMoELayer end-to-end: vs a direct dense-MoE oracle, replication
invariance, failover correctness, monolithic equivalence."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import expert_server, moe_layer as eaas
from repro.core.monolithic import monolithic_ep_apply, monolithic_runtime
from repro.core import load_balance, mapping as emap


def _setup(S=4, n_red=0, seed=0, redundant_table=None):
    cfg = get_config("kimi-k2-1t-a32b").reduced()   # 8 experts top-2 +shared
    key = jax.random.PRNGKey(seed)
    params = eaas.init_eaas_moe(key, cfg, S, redundant_table=redundant_table)
    T = 24
    x = jax.random.normal(jax.random.PRNGKey(seed + 1),
                          (T, cfg.d_model), jnp.float32) * 0.3
    rt = eaas.default_runtime(cfg, S, T, redundant_table=redundant_table)
    rt = rt._replace(capacity=T * cfg.moe.top_k, gemm_impl="xla_ragged")
    return cfg, params, x, rt


def _dense_oracle(cfg, params, x):
    """sum_k score_k · expert_k(x) + shared — no dispatch machinery."""
    from repro.core import router
    from repro.models.mlp import mlp

    m = cfg.moe
    r = router.route(params["router"], x, m)
    # reassemble the global expert bank from per-server primaries
    S, L = params["servers"]["w_gate"].shape[:2]
    per = m.num_experts // S
    wg = params["servers"]["w_gate"][:, :per].reshape(m.num_experts, *params["servers"]["w_gate"].shape[2:])
    wu = params["servers"]["w_up"][:, :per].reshape(m.num_experts, *params["servers"]["w_up"].shape[2:])
    wd = params["servers"]["w_down"][:, :per].reshape(m.num_experts, *params["servers"]["w_down"].shape[2:])
    out = jnp.zeros_like(x)
    for t in range(x.shape[0]):
        acc = jnp.zeros((x.shape[1],), jnp.float32)
        for j in range(m.top_k):
            e = int(r.expert_ids[t, j])
            h = jax.nn.silu(x[t] @ wg[e]) * (x[t] @ wu[e])
            acc = acc + r.scores[t, j] * (h @ wd[e])
        out = out.at[t].set(acc.astype(x.dtype))
    if "shared" in params:
        out = out + mlp(params["shared"], x, cfg.activation)
    return out


def test_eaas_matches_dense_oracle():
    cfg, params, x, rt = _setup(S=4)
    y, stats = eaas.eaas_moe_apply(params, x, cfg.moe, rt,
                                   activation=cfg.activation)
    y_ref = _dense_oracle(cfg, params, x)
    assert int(stats.dropped) == 0 and int(stats.miss) == 0
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


def test_replicas_do_not_change_output():
    """Replicated experts are bit-equivalent services: adding replicas (and
    spreading traffic over them) must not change the math."""
    cfg, params0, x, rt0 = _setup(S=4)
    y0, _ = eaas.eaas_moe_apply(params0, x, cfg.moe, rt0,
                                activation=cfg.activation)
    E, S = cfg.moe.num_experts, 4
    mapping, red = load_balance.eplb_plan(np.ones(E), S, n_redundant=2)
    cfg2, params2, x2, rt2 = _setup(S=4, redundant_table=red)
    # copy the SAME bank weights into the replicated layout
    for k in ("w_gate", "w_up", "w_down"):
        per = E // S
        bank = params0["servers"][k][:, :per].reshape(
            E, *params0["servers"][k].shape[2:])
        params2["servers"][k] = expert_server.build_server_weights(
            {"w_gate": bank, "w_up": bank, "w_down": bank}, S, red)[k]
    params2["router"] = params0["router"]
    if "shared" in params0:
        params2["shared"] = params0["shared"]
    rt2 = rt2._replace(mapping=jnp.asarray(mapping))
    y2, st2 = eaas.eaas_moe_apply(params2, x, cfg.moe, rt2,
                                  activation=cfg.activation)
    assert int(st2.miss) == 0
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)


def test_failover_preserves_output():
    """Killing a server whose experts all have live replicas must leave the
    output unchanged (transparent failover, paper §3.4)."""
    E, S = 8, 4
    # give EVERY expert a replica on (primary+1) % S
    mapping = emap.default_mapping(E, S, max_replicas=2)
    red = np.zeros((S, 2), np.int32) - 1
    per = E // S
    for e in range(E):
        p = mapping[e, 0]
        q = (p + 1) % S
        slot = np.argmax(red[q] < 0)
        red[q, slot] = e
        mapping[e, 1] = q
    cfg, params, x, rt = _setup(S=S, redundant_table=red)
    rt = rt._replace(mapping=jnp.asarray(mapping))
    y_before, st_b = eaas.eaas_moe_apply(params, x, cfg.moe, rt,
                                         activation=cfg.activation)
    rt_dead = rt._replace(alive=rt.alive.at[2].set(False))
    y_after, st_a = eaas.eaas_moe_apply(params, x, cfg.moe, rt_dead,
                                        activation=cfg.activation)
    assert int(st_a.miss) == 0
    np.testing.assert_allclose(np.asarray(y_before), np.asarray(y_after),
                               rtol=2e-4, atol=2e-4)


def test_monolithic_ep_equivalent_when_healthy():
    """EAAS degenerates exactly to monolithic EP with a primary-only map."""
    cfg, params, x, rt = _setup(S=4)
    y_eaas, _ = eaas.eaas_moe_apply(params, x, cfg.moe, rt,
                                    activation=cfg.activation)
    rt_mono = monolithic_runtime(cfg, 4, x.shape[0], "xla_ragged")
    rt_mono = rt_mono._replace(capacity=rt.capacity)
    y_mono, _ = monolithic_ep_apply(params, x, cfg, rt_mono)
    np.testing.assert_allclose(np.asarray(y_eaas), np.asarray(y_mono),
                               rtol=1e-5, atol=1e-5)


def test_miss_counted_on_inconsistent_mapping():
    """Routing to a server that does not host the expert is counted."""
    cfg, params, x, rt = _setup(S=4)
    bad = rt.mapping.at[:, 0].set((rt.mapping[:, 0] + 1) % 4)
    rt_bad = rt._replace(mapping=bad)
    _, stats = eaas.eaas_moe_apply(params, x, cfg.moe, rt_bad,
                                   activation=cfg.activation)
    assert int(stats.miss) == x.shape[0] * cfg.moe.top_k
