"""gemma3-4b — Google Gemma 3 (5:1 local:global attention, 128k context).

[hf:google/gemma-3-1b-pt; unverified]  dense, GQA kv=4, sliding-window locals.

The 5:1 local:global pattern makes 5/6 of layers sliding-window (1024); KV for
local layers is bounded by the window, so the arch is treated as sub-quadratic
for the long_500k decode shape (global layers keep a full cache; see DESIGN.md).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    d_ff=10240,
    vocab_size=262144,
    d_head=256,
    rope_theta=1_000_000.0,
    sliding_window=1024,
    local_global_pattern=5,       # 5 local layers per 1 global layer
    attn_logit_softcap=None,
    tie_embeddings=True,
    activation="swiglu",
    max_seq_len=131072,
    subquadratic=True,
    source="hf:google/gemma-3-1b-pt",
)
