"""Jit-ready wrappers around the Pallas kernels with CPU-friendly lowerings.

Every op has several implementations:

* ``pallas`` / ``pallas_interpret`` — the TPU kernel (interpret=True runs the
  kernel body in Python on CPU; used by the allclose tests).
* ``xla_ragged`` — ``jax.lax.ragged_dot``: exact, executes fast on CPU; its
  HLO flop count on CPU over-counts by G× (XLA decomposes into masked dots),
  so it is NOT used for the roofline dry-run.
* ``xla_dense`` — per-expert-capacity batched matmul (GShard-style): the
  flop-honest XLA lowering used by the dry-run; FLOPs = 2·L·cap·d·f which at
  the configured capacity factor equals the ideal grouped-GEMM work.
* ``ref`` — the oracle from :mod:`repro.kernels.ref`.

``set_default_impl`` lets the launch layer pick one globally (the dry-run
sets ``xla_dense``; tests pin impls explicitly).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref
from repro.kernels.combine import combine_weighted_pallas
from repro.kernels.decode_attention import (flash_decode_pallas,
                                            paged_flash_decode_pallas)
from repro.kernels.grouped_gemm import grouped_gemm_pallas

_DEFAULT_IMPL: Optional[str] = None


def set_default_impl(impl: Optional[str]) -> None:
    global _DEFAULT_IMPL
    _DEFAULT_IMPL = impl


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def _resolve(impl: str) -> str:
    if impl == "auto":
        if _DEFAULT_IMPL is not None:
            return _DEFAULT_IMPL
        return "pallas" if _on_tpu() else "xla_ragged"
    return impl


# --------------------------------------------------------------- grouped gemm

def grouped_gemm_dense(x_sorted: jax.Array, w: jax.Array,
                       group_sizes: jax.Array, capacity: int) -> jax.Array:
    """GShard-style per-expert-capacity batched matmul.

    Scatters the group-sorted rows into (G, capacity, K), one batched matmul
    per weight, gathers back.  Rows beyond an expert's capacity are dropped
    (the launch layer sizes ``capacity`` from the dispatch capacity factor so
    this only triggers under extreme imbalance).
    """
    M, K = x_sorted.shape
    G, _, N = w.shape
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(group_sizes).astype(jnp.int32)])
    rows = jnp.arange(M, dtype=jnp.int32)
    gid = jnp.searchsorted(offsets[1:], rows, side="right").astype(jnp.int32)
    live = rows < offsets[-1]
    gid_c = jnp.minimum(gid, G - 1)
    pos = rows - offsets[gid_c]
    ok = live & (pos < capacity)
    idx = jnp.where(ok, gid_c * capacity + pos, G * capacity)
    xg = jnp.zeros((G * capacity, K), x_sorted.dtype).at[idx].set(
        x_sorted, mode="drop").reshape(G, capacity, K)
    yg = jnp.einsum("gck,gkn->gcn", xg, w,
                    preferred_element_type=jnp.float32)
    y = yg.reshape(G * capacity, N)
    safe = jnp.minimum(idx, G * capacity - 1)
    out = jnp.where(ok[:, None], y[safe], 0)
    return out.astype(x_sorted.dtype)


def grouped_gemm(x_sorted: jax.Array, w: jax.Array, group_sizes: jax.Array,
                 *, impl: str = "auto", expert_capacity: Optional[int] = None,
                 tm: int = 128, tn: int = 128, tk: int = 128) -> jax.Array:
    """out[i] = x_sorted[i] @ w[g(i)] — see module docstring for impls."""
    impl = _resolve(impl)
    if impl == "ref":
        return kref.grouped_gemm_ref(x_sorted, w, group_sizes)
    if impl == "xla_ragged":
        y = jax.lax.ragged_dot(x_sorted, w, group_sizes.astype(jnp.int32))
        # ragged_dot leaves rows past sum(group_sizes) unspecified: mask them
        live = jnp.arange(x_sorted.shape[0]) < jnp.sum(group_sizes)
        return jnp.where(live[:, None], y, 0).astype(x_sorted.dtype)
    if impl == "xla_dense":
        M, G = x_sorted.shape[0], w.shape[0]
        cap = expert_capacity or max(_ceil_mult(2 * M // max(G, 1) + 1, 8), 8)
        return grouped_gemm_dense(x_sorted, w, group_sizes, cap)
    if impl in ("pallas", "pallas_interpret"):
        return grouped_gemm_pallas(
            x_sorted, w, group_sizes, tm=tm, tn=tn, tk=tk,
            interpret=(impl == "pallas_interpret"))
    raise ValueError(f"unknown grouped_gemm impl {impl!r}")


def _ceil_mult(x: int, m: int) -> int:
    return (x + m - 1) // m * m


# --------------------------------------------------------------- flash decode

def flash_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                 lengths: jax.Array, *, impl: str = "auto",
                 ts: int = 512) -> jax.Array:
    impl = _resolve(impl)
    if impl in ("ref", "xla_ragged", "xla_dense"):
        return kref.flash_decode_ref(q, k_cache, v_cache, lengths)
    if impl in ("pallas", "pallas_interpret"):
        return flash_decode_pallas(q, k_cache, v_cache, lengths, ts=ts,
                                   interpret=(impl == "pallas_interpret"))
    raise ValueError(f"unknown flash_decode impl {impl!r}")


def paged_flash_decode(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                       block_tables: jax.Array, lengths: jax.Array, *,
                       impl: str = "auto") -> jax.Array:
    """Flash decode over a shared block pool gathered through block tables."""
    impl = _resolve(impl)
    if impl in ("ref", "xla_ragged", "xla_dense"):
        return kref.paged_flash_decode_ref(q, k_pool, v_pool, block_tables,
                                           lengths)
    if impl in ("pallas", "pallas_interpret"):
        return paged_flash_decode_pallas(
            q, k_pool, v_pool, block_tables, lengths,
            interpret=(impl == "pallas_interpret"))
    raise ValueError(f"unknown paged_flash_decode impl {impl!r}")


# -------------------------------------------------------------------- combine

def combine_weighted(x: jax.Array, w: jax.Array, *, impl: str = "auto",
                     tt: int = 128, td: int = 512) -> jax.Array:
    impl = _resolve(impl)
    if impl in ("ref", "xla_ragged", "xla_dense"):
        return kref.combine_weighted_ref(x, w)
    if impl in ("pallas", "pallas_interpret"):
        return combine_weighted_pallas(x, w, tt=tt, td=td,
                                       interpret=(impl == "pallas_interpret"))
    raise ValueError(f"unknown combine impl {impl!r}")
