"""Benchmark harness entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Heavy figures can be skipped with
REPRO_BENCH_FAST=1 (CI smoke).
"""

import os
import sys
import traceback


def main() -> None:
    fast = os.environ.get("REPRO_BENCH_FAST", "0") == "1"
    from benchmarks import (ablation, async_tier, comm, expert_balance,
                            fault_tolerance, frontend_routing, latency,
                            overlap_ablation, paged_kv, roofline, scaling,
                            throughput)

    suites = [("fig12_comm", comm.main),
              ("fig13_ablation", ablation.main),
              ("roofline", roofline.main)]
    if not fast:
        suites = [("fig8_throughput", throughput.main),
                  ("fig8_overlap_ablation", overlap_ablation.main),
                  ("fig9_latency", latency.main),
                  ("fig10_fault_tolerance", fault_tolerance.main),
                  ("fig11_scaling", scaling.main),
                  ("paged_kv", paged_kv.main),
                  ("expert_balance", expert_balance.main),
                  ("frontend_routing", frontend_routing.main),
                  ("async_tier", async_tier.main)] + suites

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        try:
            for row in fn():
                print(row)
        except Exception as e:
            failures += 1
            print(f"{name},nan,ERROR:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark suites failed")


if __name__ == "__main__":
    main()
