"""Paper Fig. 10 — decoding throughput under repeated server failures.

Failures are injected one at a time (with recovery between them, as in the
paper's experiment: 10 sequential GPU failures).  EAAS reroutes to replicas
(expected <2% throughput loss); monolithic EP halts for a full group
restart; TP halts one unit.
"""

from __future__ import annotations

from typing import Dict, List

from benchmarks.common import (bench_model_cfg, csv_row, make_requests,
                               run_engine, save_result)
from repro.serving import EngineConfig


def run(n_failures: int = 4, load: int = 24, max_new: int = 16) -> Dict:
    cfg = bench_model_cfg()
    out = {"figure": "fig10_fault_tolerance", "modes": {}}

    baseline = {}
    for mode in ("eaas", "monolithic_ep", "tp"):
        ecfg = EngineConfig(mode=mode, num_servers=4, max_batch=4,
                            max_seq=64, tp_batch_cap=2, n_redundant=2)
        reqs = make_requests(load, max_new=max_new, vocab=cfg.vocab_size)
        _, m = run_engine(cfg, ecfg, reqs)
        baseline[mode] = m.decode_throughput

    for mode in ("eaas", "monolithic_ep", "tp"):
        ecfg = EngineConfig(mode=mode, num_servers=4, max_batch=4,
                            max_seq=64, tp_batch_cap=2, n_redundant=2,
                            restart_steps=40, tp_restart_steps=10)
        reqs = make_requests(load, max_new=max_new, vocab=cfg.vocab_size)
        fail_steps = {10 + 30 * i: i % 3 for i in range(n_failures)}
        recover_steps = {25 + 30 * i: i % 3 for i in range(n_failures)}

        def on_step(eng):
            if eng.step_idx in fail_steps:
                eng.inject_server_failure(fail_steps[eng.step_idx])
            if eng.step_idx in recover_steps:
                eng.recover_server(recover_steps[eng.step_idx])

        _, m = run_engine(cfg, ecfg, reqs, on_step=on_step)
        thr = m.decode_throughput
        out["modes"][mode] = {
            "baseline_tok_per_s": baseline[mode],
            "under_failures_tok_per_s": thr,
            "throughput_drop_pct": 100 * (1 - thr / max(baseline[mode],
                                                        1e-9)),
            "timeline": m.timeline[:200],
        }
    save_result("fig10_fault_tolerance", out)
    return out


def main() -> List[str]:
    res = run()
    rows = []
    for mode, r in res["modes"].items():
        rows.append(csv_row(
            f"fig10_{mode}", 0.0,
            f"drop_pct={r['throughput_drop_pct']:.1f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
