"""Training substrate: optimizers, train step, checkpointing, data pipeline,
gradient compression.  Self-contained (no optax/orbax dependency)."""

from repro.training.optimizer import (adafactor, adamw, OptimizerBundle)  # noqa: F401
from repro.training.train_loop import make_train_step, TrainState  # noqa: F401
