"""Paper Fig. 8 — end-to-end decoding throughput vs request load, for two
cluster sizes, EAAS vs SGL-EP (monolithic) vs SGL-TP.

CPU-scale reproduction on the reduced DeepSeek-R1-family config.  The TP
baseline's weight replication is modeled by capping its slot pool (the
paper: TP must replicate the model per 16-GPU unit, halving usable batch).
"""

from __future__ import annotations

from typing import Dict, List

from benchmarks.common import (bench_model_cfg, csv_row, make_requests,
                               run_engine, save_result)
from repro.serving import EngineConfig


def run(loads: List[int] = (8, 16, 32), clusters: Dict[str, Dict] = None,
        max_new: int = 12) -> Dict:
    cfg = bench_model_cfg()
    clusters = clusters or {
        "large": dict(num_servers=8, max_batch=8),
        "small": dict(num_servers=4, max_batch=4),
    }
    out = {"figure": "fig8_throughput", "clusters": {}}
    for cname, cparams in clusters.items():
        rows = {}
        for mode in ("eaas", "monolithic_ep", "tp"):
            pts = []
            for load in loads:
                ecfg = EngineConfig(
                    mode=mode, num_servers=cparams["num_servers"],
                    max_batch=cparams["max_batch"], max_seq=64,
                    tp_batch_cap=max(cparams["max_batch"] // 2, 1),
                    n_redundant=2)
                reqs = make_requests(load, max_new=max_new,
                                     vocab=cfg.vocab_size)
                _, m = run_engine(cfg, ecfg, reqs)
                pts.append({"load": load,
                            "tok_per_s": m.decode_throughput,
                            "completed": m.completed})
            rows[mode] = pts
        out["clusters"][cname] = rows
    save_result("fig8_throughput", out)
    return out


def main() -> List[str]:
    res = run()
    rows = []
    for cname, modes in res["clusters"].items():
        for mode, pts in modes.items():
            peak = max(p["tok_per_s"] for p in pts)
            us = 1e6 / max(peak, 1e-9)
            rows.append(csv_row(f"fig8_{cname}_{mode}", us,
                                f"peak_tok_per_s={peak:.2f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
