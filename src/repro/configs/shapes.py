"""Assigned input shapes.

Every LM-family architecture is exercised on the same four shapes.  ``decode_*``
and ``long_*`` lower ``serve_step`` (one new token against a KV cache of
``seq_len``), not ``train_step``.  ``long_500k`` requires sub-quadratic
attention and is skipped for pure full-attention archs (the skip is recorded
by the dry-run, see DESIGN.md §4).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32768, 128, "decode")
LONG_500K = InputShape("long_500k", 524288, 1, "decode")

ALL_SHAPES: Tuple[InputShape, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shape_by_name(name: str) -> InputShape:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}; have {[s.name for s in ALL_SHAPES]}")


def applicable(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """Whether (arch, shape) is a valid dry-run cell; reason if not."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k skipped: pure full-attention arch (see DESIGN.md)"
    return True, ""


def reduced_shape(shape: InputShape) -> InputShape:
    """CPU-sized version of a shape for smoke tests."""
    return InputShape(
        name=shape.name + "-reduced",
        seq_len=min(shape.seq_len, 64),
        global_batch=min(shape.global_batch, 4),
        kind=shape.kind,
    )
