"""Unit battery for the discrete-event primitives under the async tier:
the ``EventTimeline`` heap (ordering, tie-break, cancel, fingerprint) and
the ``AsyncExpertTier`` micro-batch queues (FIFO service, conservation,
failure re-dispatch, migration occupancy, resize).  The hypothesis
property sweep over the same invariants lives in
``test_property_event_loop.py``; this module keeps hypothesis-free
coverage of every code path."""

import numpy as np
import pytest

from repro.serving import AsyncExpertTier, EventTimeline

# ---------------------------------------------------------------- timeline


def test_timeline_pops_in_time_order():
    tl = EventTimeline()
    tl.post(0.3, "c")
    tl.post(0.1, "a")
    tl.post(0.2, "b")
    assert [tl.pop().kind for _ in range(3)] == ["a", "b", "c"]
    assert tl.pop() is None
    assert tl.peek_time() is None


def test_timeline_breaks_ties_by_post_order():
    """Simultaneous events fire in the deterministic order they were
    scheduled — the (time, seq) contract."""
    tl = EventTimeline()
    for i in range(5):
        tl.post(1.0, f"k{i}")
    assert [tl.pop().kind for _ in range(5)] == [f"k{i}" for i in range(5)]


def test_timeline_cancel_skips_silently():
    tl = EventTimeline()
    keep = tl.post(0.1, "keep")
    drop = tl.post(0.05, "drop")
    tl.cancel(drop)
    assert len(tl) == 1
    assert tl.peek_time() == 0.1
    assert tl.pop() is keep
    # the log only records fired events
    assert [e["kind"] for e in tl.log] == ["keep"]


def test_timeline_clear_pending_keeps_log_and_seq():
    tl = EventTimeline()
    tl.post(0.1, "a")
    tl.pop()
    tl.post(0.2, "gone")
    tl.clear_pending()
    assert len(tl) == 0 and tl.pop() is None
    assert [e["kind"] for e in tl.log] == ["a"]
    # the seq counter survives the drop: later posts keep globally unique,
    # monotone seqs (determinism across a client failure)
    ev = tl.post(0.3, "b")
    assert ev.seq == 2


def test_timeline_fingerprint_replay_and_sensitivity():
    def play(t_second):
        tl = EventTimeline()
        tl.post(0.1, "a", slot=1)
        tl.post(t_second, "b", slot=2)
        while tl.pop() is not None:
            pass
        return tl.fingerprint()

    assert play(0.2) == play(0.2)            # same schedule, same hash
    assert play(0.2) != play(0.25)           # a moved event changes it


def test_timeline_log_keeps_scalar_payload_only():
    tl = EventTimeline()
    tl.post(0.1, "a", slot=3, req=object(), arr=np.zeros(2))
    tl.pop()
    assert tl.log[0]["slot"] == 3
    assert "req" not in tl.log[0] and "arr" not in tl.log[0]


# -------------------------------------------------------------------- tier


def test_dispatch_skips_zero_work_servers():
    tier = AsyncExpertTier(4)
    mbs = tier.dispatch(0, 0, [1e-3, 0.0, 2e-3, 0.0], now=0.0)
    assert [mb.server for mb in mbs] == [0, 2]
    assert tier.enqueued == 2 and tier.in_flight() == 2


def test_queue_is_fifo_and_work_conserving():
    tier = AsyncExpertTier(1)
    (a,) = tier.dispatch(0, 0, [1e-3], now=0.0)
    (b,) = tier.dispatch(0, 1, [1e-3], now=0.0)
    assert a.start_t == 0.0 and a.finish_t == pytest.approx(1e-3)
    assert b.start_t == pytest.approx(a.finish_t)       # queued behind a
    assert b.finish_t == pytest.approx(2e-3)
    # an idle gap is not billed: dispatch after the frontier starts at now
    (c,) = tier.dispatch(0, 2, [1e-3], now=5e-3)
    assert c.start_t == 5e-3


def test_slowdown_applies_to_new_work_only():
    tier = AsyncExpertTier(1)
    (a,) = tier.dispatch(0, 0, [1e-3], now=0.0)
    tier.set_slowdown(0, 4.0)
    (b,) = tier.dispatch(0, 1, [1e-3], now=0.0)
    assert a.finish_t == pytest.approx(1e-3)            # committed time kept
    assert b.finish_t == pytest.approx(1e-3 + 4e-3)     # stretched 4x
    with pytest.raises(ValueError):
        tier.set_slowdown(0, 0.0)
    tier.set_slowdown(0, 1.0)                            # reset restores
    (c,) = tier.dispatch(0, 2, [1e-3], now=b.finish_t)
    assert c.finish_t - c.start_t == pytest.approx(1e-3)


def test_fail_server_moves_queue_to_least_busy_survivor():
    tier = AsyncExpertTier(3)
    tier.dispatch(0, 0, [1e-3, 5e-3, 1e-3], now=0.0)
    victims = [mb for mb in tier.mbs.values() if mb.server == 1]
    (victim,) = victims
    old_gen = victim.generation
    moved = tier.fail_server(1, now=0.0)
    assert moved == [victim]
    assert victim.server in (0, 2)          # least busy survivor, tie -> 0
    assert victim.server == 0 or victim.start_t > 0.0
    assert victim.generation == old_gen + 1
    # the stale completion event (old generation) is no longer current
    assert not tier.is_current(victim.mb_id, old_gen)
    assert tier.is_current(victim.mb_id, victim.generation)
    assert tier.redispatched == 1
    assert tier.in_flight() == 3            # nothing lost, nothing done


def test_fail_without_survivors_cancels_explicitly():
    tier = AsyncExpertTier(1)
    tier.dispatch(0, 0, [1e-3], now=0.0)
    moved = tier.fail_server(0, now=0.0)
    assert moved == []
    assert tier.cancelled == 1 and tier.in_flight() == 0
    assert tier.mbs == {}                   # retired entry pruned


def test_conservation_counters_balance():
    tier = AsyncExpertTier(2)
    mbs = tier.dispatch(0, 0, [1e-3, 1e-3], now=0.0)
    tier.mark_done(mbs[0])
    tier.fail_server(1, now=0.0)            # moves mbs[1] to server 0
    assert tier.enqueued == 2
    assert tier.enqueued == tier.completed + tier.cancelled \
        + tier.in_flight()
    tier.mark_done(mbs[1])
    assert tier.in_flight() == 0
    assert tier.queues[0].drained == 2      # both ultimately served by 0
    # retired entries are pruned: mbs holds in-flight work only, so
    # memory stays bounded and fault scans are O(in-flight)
    assert tier.mbs == {}


def test_occupy_all_busies_alive_servers_only():
    tier = AsyncExpertTier(2)
    tier.fail_server(1, now=0.0)
    tier.occupy_all(now=1.0, dt=0.5)
    assert tier.queues[0].busy_until == pytest.approx(1.5)
    assert tier.queues[1].busy_until == 0.0           # dead: not occupied
    assert tier.migration_busy == pytest.approx(0.5)
    # the next dispatch queues behind the weight copy
    (mb,) = tier.dispatch(0, 1, [1e-3, 0.0], now=1.0)
    assert mb.start_t == pytest.approx(1.5)


def test_resize_grow_reconciles_instead_of_resetting():
    """Growing the pool keeps the survivors' committed frontiers, speeds
    and in-flight work; only the new ranks start fresh from now."""
    tier = AsyncExpertTier(2)
    mbs = tier.dispatch(0, 0, [1e-3, 1e-3], now=0.0)
    tier.set_slowdown(0, 4.0)
    moved = tier.resize(3, now=2.0)
    assert tier.num_servers == 3 and moved == []
    assert tier.queues[0].slowdown == 4.0           # survivor keeps speed
    assert tier.queues[0].busy_until == pytest.approx(1e-3)
    assert tier.queues[2].alive and tier.queues[2].free_at() == 2.0
    assert all(mb.mb_id in tier.mbs for mb in mbs)  # nothing dropped
    tier.reset_speeds()                             # wholesale-replan path
    assert all(q.slowdown == 1.0 for q in tier.queues)


def test_resize_shrink_redispatches_inflight_to_survivors():
    """Shrinking while waves are in flight re-dispatches the dropped
    ranks' unfinished micro-batches like a failure and returns them so
    the owning engines can re-post completion events."""
    tier = AsyncExpertTier(3)
    tier.dispatch(0, 0, [1e-3, 0.0, 5e-3], now=0.0)
    victim = next(mb for mb in tier.mbs.values() if mb.server == 2)
    old_gen = victim.generation
    moved = tier.resize(2, now=0.0)
    assert tier.num_servers == 2 and len(tier.queues) == 2
    assert moved == [victim]
    assert victim.server == 1                       # idle survivor wins
    assert victim.generation == old_gen + 1
    assert not tier.is_current(victim.mb_id, old_gen)
    assert tier.is_current(victim.mb_id, victim.generation)
    assert tier.in_flight() == 2                    # nothing lost
    assert tier.enqueued == tier.completed + tier.cancelled \
        + tier.in_flight()


def test_recover_server_clamps_stale_frontiers_to_now():
    """Recovery reconciles a dead rank's stale lane/stream frontiers up
    to now, so new work can't start in the past."""
    tier = AsyncExpertTier(2)
    tier.dispatch(0, 0, [1e-3, 1e-3], now=0.0)
    tier.fail_server(1, now=0.0)
    tier.recover_server(1, now=3.0)
    assert tier.queues[1].alive
    assert tier.queues[1].free_at() == 3.0
    (mb,) = tier.dispatch(0, 1, [0.0, 1e-3], now=3.0)
    assert mb.start_t == 3.0


# ------------------------------------------------------------------- lanes


def test_tier_validates_queue_mode_and_budget():
    with pytest.raises(ValueError):
        AsyncExpertTier(2, queue_mode="bogus")
    with pytest.raises(ValueError):
        AsyncExpertTier(2, lane_budget=0)


def test_legacy_dispatch_funnels_through_aggregate_lane():
    from repro.serving.event_loop import AGGREGATE_LANE
    tier = AsyncExpertTier(2)
    mbs = tier.dispatch(0, 0, [1e-3, 1e-3], now=0.0)
    assert all(mb.expert == AGGREGATE_LANE for mb in mbs)
    assert {ln.expert for ln in tier.lanes()} == {AGGREGATE_LANE}


def test_lane_fifo_with_budget_overlaps_cold_lane():
    """A hot expert serializes in its own lane even when a second service
    stream is free; a cold expert flows through that stream meanwhile —
    the per-expert-lane win over the single per-server FIFO."""
    tier = AsyncExpertTier(1, lane_budget=2)
    (hot1,) = tier.dispatch_lanes(0, 0, [(0, 7, 4e-3)], now=0.0)
    (hot2,) = tier.dispatch_lanes(0, 1, [(0, 7, 4e-3)], now=0.0)
    assert hot1.start_t == 0.0
    assert hot2.start_t == pytest.approx(4e-3)      # lane FIFO binds
    (cold,) = tier.dispatch_lanes(0, 2, [(0, 3, 1e-3)], now=0.0)
    assert cold.start_t == 0.0                      # free stream, free lane
    assert cold.finish_t == pytest.approx(1e-3)


def test_fail_server_redispatch_is_lane_aware():
    """Re-dispatch targets the survivor with the earliest start for the
    victim's own expert lane, not the globally least-busy server."""
    tier = AsyncExpertTier(3, lane_budget=2)
    tier.dispatch_lanes(
        0, 0, [(0, 5, 10e-3), (2, 7, 2e-3), (1, 7, 1e-3)], now=0.0)
    victim = next(mb for mb in tier.mbs.values() if mb.server == 1)
    moved = tier.fail_server(1, now=0.0)
    assert moved == [victim]
    # server 2 is globally less busy, but its expert-7 lane is occupied;
    # server 0 has a free stream and an idle expert-7 lane
    assert victim.server == 0
    assert victim.start_t == 0.0
    # the hop is attributed to the failed rank's lane counters
    assert tier.queues[1].moved == 1
    assert tier.queues[1].lanes[7].moved == 1


def test_lane_conservation_counters_balance():
    tier = AsyncExpertTier(2, lane_budget=2)
    mbs = tier.dispatch_lanes(
        0, 0, [(0, 1, 1e-3), (0, 2, 1e-3), (1, 1, 1e-3)], now=0.0)
    tier.mark_done(mbs[0])
    tier.fail_server(1, now=0.0)        # moves mbs[2] into server 0's lane
    tier.dispatch_lanes(1, 0, [(0, 2, 1e-3)], now=0.0)
    assert tier.cancel_client(1) == 1
    for q in tier.queues:
        for ln in q.lanes.values():
            assert ln.enqueued == ln.drained + ln.cancelled + ln.moved \
                + ln.in_flight()
        # server counters are exactly the sum of their lanes'
        assert q.enqueued == sum(ln.enqueued for ln in q.lanes.values())
        assert q.moved == sum(ln.moved for ln in q.lanes.values())
    assert sum(ln.in_flight() for ln in tier.lanes()) == tier.in_flight()


def test_queue_signals_report_lane_backlog():
    tier = AsyncExpertTier(2)
    tier.dispatch_lanes(0, 0, [(0, 3, 2e-3), (1, 5, 1e-3)], now=0.0)
    sig = tier.queue_signals(now=0.0)
    assert sig["alive"] == 2
    assert sig["server_backlog"][0] == pytest.approx(2e-3)
    assert sig["max_backlog"] == pytest.approx(2e-3)
    assert sig["total_backlog"] == pytest.approx(3e-3)
    assert sig["lane_backlog"][(0, 3)] == pytest.approx(2e-3)
    assert sig["lane_depth"][(1, 5)] == 1
    # dead servers report zero: their work re-dispatched to survivors
    tier.fail_server(1, now=0.0)
    sig = tier.queue_signals(now=0.0)
    assert sig["alive"] == 1
    assert sig["server_backlog"][1] == 0.0
    assert sig["max_backlog"] == pytest.approx(3e-3)


def test_cancel_client_abandons_only_that_clients_work():
    tier = AsyncExpertTier(2)
    mbs0 = tier.dispatch(0, 0, [1e-3, 1e-3], now=0.0)
    mbs1 = tier.dispatch(1, 1, [1e-3, 1e-3], now=0.0)
    assert tier.cancel_client(0) == 2
    assert tier.cancelled == 2 and tier.in_flight() == 2
    assert all(not mb.cancelled for mb in mbs1)
    # a cancelled micro-batch is retired outright: its entry is pruned
    # and its still-queued completion event resolves to "not current"
    assert all(mb.cancelled for mb in mbs0)
    assert all(mb.mb_id not in tier.mbs for mb in mbs0)
    assert all(not tier.is_current(mb.mb_id, mb.generation) for mb in mbs0)
    assert all(mb.mb_id in tier.mbs for mb in mbs1)
