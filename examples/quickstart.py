"""Quickstart: the EAAS MoE layer as a composable module.

Builds a reduced Kimi-K2-family MoE layer, routes a batch of tokens through
the full client→server→client pipeline, then demonstrates the two runtime
superpowers of the service architecture — failover and replication — as
pure *data* changes (no recompilation).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import eaas_moe_apply, init_eaas_moe
from repro.core.moe_layer import default_runtime
from repro.core import load_balance
from repro.core.expert_server import build_server_weights, make_local_table


def main():
    cfg = get_config("kimi-k2-1t-a32b").reduced()
    m = cfg.moe
    print(f"arch={cfg.arch_id}  experts={m.num_experts} top-{m.top_k}")

    S = 4                                    # logical expert servers
    key = jax.random.PRNGKey(0)
    params = init_eaas_moe(key, cfg, num_servers=S)

    T = 64
    x = jax.random.normal(jax.random.PRNGKey(1), (T, cfg.d_model),
                          jnp.float32) * 0.3
    rt = default_runtime(cfg, S, T, gemm_impl="xla_ragged")

    # --- 1. the layer is a drop-in FFN --------------------------------
    fn = jax.jit(lambda p, xx, mapping, alive: eaas_moe_apply(
        p, xx, m, rt._replace(mapping=mapping, alive=alive),
        activation=cfg.activation))
    y, stats = fn(params, x, rt.mapping, rt.alive)
    print(f"output {y.shape}  dropped={int(stats.dropped)} "
          f"miss={int(stats.miss)}")
    print("expert load:", np.asarray(stats.expert_load))

    # --- 2. failover is a data change (same compiled fn!) --------------
    # first replicate everything so each expert has 2 homes
    mapping, red = load_balance.eplb_plan(
        np.ones(m.num_experts), S, n_redundant=m.num_experts // S,
        max_replicas=2)
    bank = {k: params["servers"][k][:, :m.num_experts // S].reshape(
        m.num_experts, *params["servers"][k].shape[2:])
        for k in ("w_gate", "w_up", "w_down")}
    params["servers"].update(build_server_weights(bank, S, red))
    # headroom: failover concentrates traffic on survivors, so buffer slots
    # get capacity for the worst case (paper §3.2 capacity-factor sizing)
    rt2 = rt._replace(mapping=jnp.asarray(mapping),
                      capacity=T * m.top_k,
                      local_table=jnp.asarray(
                          make_local_table(m.num_experts, S, red)))
    fn2 = jax.jit(lambda p, xx, mapping, alive: eaas_moe_apply(
        p, xx, m, rt2._replace(mapping=mapping, alive=alive),
        activation=cfg.activation))

    y_healthy, _ = fn2(params, x, rt2.mapping, rt2.alive)
    alive_dead = rt2.alive.at[2].set(False)      # server 2 dies
    y_failover, st = fn2(params, x, rt2.mapping, alive_dead)
    err = float(jnp.max(jnp.abs(y_healthy - y_failover)))
    print(f"server 2 killed: max output delta = {err:.2e} "
          f"(transparent failover), miss={int(st.miss)}")
    assert err < 1e-3

    print("quickstart OK")


if __name__ == "__main__":
    main()
