"""Pluggable engine clocks (paper §5 methodology).

The serving engine never reads ``time.perf_counter()`` directly any more —
it brackets every jitted step with ``clock.start()`` / ``clock.stop(...)``
and advances its logical time by whatever the clock returns.  Two
implementations:

* :class:`WallClock` — real timing.  ``stop`` blocks on the step's output
  array first, so the measured window covers actual device execution (the
  seed behaviour: meaningful *relative* curves on CPU).
* :class:`VirtualClock` — a deterministic analytic cost model.  ``stop``
  does **not** block or measure; it charges a modeled duration from the
  step-shape hints the engine passes in.  Runs become bit-deterministic
  (same seed ⇒ identical metrics timeline) and fast on CPU, which is what
  the scenario harness (``repro.serving.scenario``) and the fault/scaling
  tests run under.

The virtual cost model is deliberately simple but captures the two effects
the paper's claims hinge on:

* step time grows affinely with the token work in the step
  (``base + per_token * tokens``);
* in EAAS mode a dead server's traffic is absorbed by the surviving
  replicas, so decode steps slow by the *lost compute share* — the engine
  passes ``alive_frac`` and the step is charged ``dt / alive_frac``
  (paper Fig. 10: a 1/64 loss ⇒ <2% dip).  Monolithic EP instead halts
  whole steps, which the engine models independently of the clock.
"""

from __future__ import annotations

import hashlib
import heapq
import json
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


class Clock:
    """Interface: bracket one engine step, return its duration in seconds."""

    def start(self) -> None:
        raise NotImplementedError

    def stop(self, kind: str, *, result=None, tokens: int = 0,
             servers: int = 1, alive_frac: float = 1.0,
             overlap: bool = False, imbalance: float = 1.0,
             contention: float = 1.0, straggle: float = 1.0) -> float:
        """End the bracket opened by :meth:`start`.

        kind: "prefill" | "decode" | "migrate" | "cold_start"; result: a
        jax array to block on (wall clocks only); tokens: token work in the
        step (chunk length for prefill — chunked prefill is charged per
        chunk, base included — active slots for decode, expert-weight
        copies for migrate, experts paged back in for cold_start);
        servers: expert-server pool size (the token work
        parallelizes over it); alive_frac: alive share of the pool (EAAS
        failover slowdown); overlap: the step ran as two pipelined
        microbatches (client pipelining, paper §4.2) — virtual clocks
        charge ``max(attention, expert) + ε`` instead of the sum;
        imbalance: max/mean per-server expert load (≥ 1) — a lockstep
        expert phase finishes with its hottest server, so virtual clocks
        stretch the expert share of a decode step by this factor (the cost
        hot-expert skew actually exacts; 1.0 = balanced, the default,
        reproduces the unstretched model bit-exactly); contention: how
        many attention clients are currently sharing the expert tier (the
        cluster front-end sets this) — the expert share of a decode step
        stretches by it, exactly like imbalance, while the attention/client
        share is the client's own hardware and never contends.  1.0 (the
        default, and any single-engine run) reproduces the pre-cluster
        model bit-exactly; straggle: slowdown factor of the slowest alive
        expert server (scenario ``slow_server`` events) — a lockstep
        expert phase finishes with its slowest server, so the expert share
        stretches by it exactly like imbalance/contention (1.0, the
        default, is bit-identical to the pre-straggler model).
        """
        raise NotImplementedError

    def idle(self) -> float:
        """Duration charged to a step with nothing to do."""
        raise NotImplementedError


class WallClock(Clock):
    """Real step timing (the seed engine behaviour)."""

    def __init__(self) -> None:
        self._t0 = 0.0

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self, kind: str, *, result=None, tokens: int = 0,
             servers: int = 1, alive_frac: float = 1.0,
             overlap: bool = False, imbalance: float = 1.0,
             contention: float = 1.0, straggle: float = 1.0) -> float:
        if result is not None:
            result.block_until_ready()
        return time.perf_counter() - self._t0

    def idle(self) -> float:
        return 1e-4


@dataclass
class VirtualClock(Clock):
    """Deterministic analytic step-cost model (no wall time, no blocking)."""

    prefill_base: float = 4e-3
    prefill_per_token: float = 2e-4
    decode_base: float = 2e-3
    decode_per_token: float = 2e-4
    # EAAS failover: surviving replicas absorb the dead servers' traffic,
    # so steps slow by the lost compute share.  Disable to model an
    # over-provisioned pool where failover is free.
    degrade_with_dead: bool = True
    # overlap-aware decode: the per-token term splits into an expert
    # round-trip share and an attention/client share; a pipelined step
    # (two microbatches, paper §4.2) charges max of the two plus a small
    # pipeline-fill ε instead of their sum.  Chunked prefill needs no extra
    # knob — each chunk is its own stop(), so it pays prefill_base per
    # chunk (the chunking overhead) with the per-token term split across
    # chunks.
    expert_share: float = 0.5
    overlap_eps: float = 1e-5
    # live expert migration (rebalance chunks): a fixed control round-trip
    # plus a per-expert weight-copy cost — charged between decode steps, so
    # the chunk size trades adaptation speed against decode interference
    migrate_base: float = 1e-3
    migrate_per_expert: float = 2e-3
    # lane-granular busy accounting (async tier, queue_mode="expert"): a
    # fixed per-lane-micro-batch dispatch overhead added to each expert
    # lane's service time when a wave splits into more than one lane on a
    # server.  Finer lanes buy overlap but are not free — 0.0 (the
    # default) keeps lane-mode timings bit-identical to the aggregate
    # per-server dispatch at lane_budget=1.
    lane_overhead: float = 0.0
    # scale-to-zero experts (serverless paging à la MoEless): the first
    # token routed to a paged-out expert stalls the dispatching step while
    # the weights page back in — charged per expert via a stop("cold_start",
    # tokens=n_paged_in).  0.0 (the default) keeps elastic timelines
    # bit-identical to non-elastic ones, which is the identity contract
    # benchmarks/elasticity.py gates on.
    cold_start_base: float = 0.0

    def start(self) -> None:  # nothing to measure
        pass

    def stop(self, kind: str, *, result=None, tokens: int = 0,
             servers: int = 1, alive_frac: float = 1.0,
             overlap: bool = False, imbalance: float = 1.0,
             contention: float = 1.0, straggle: float = 1.0) -> float:
        if kind == "migrate":
            # weight movement doesn't parallelize over the pool (each copy
            # lands on one server) and is unaffected by liveness
            return self.migrate_base + self.migrate_per_expert * tokens
        if kind == "cold_start":
            # expert page-ins are sequential weight fetches on the critical
            # path of the step that routed to them; liveness is irrelevant
            return self.cold_start_base * tokens
        # token work parallelizes over the expert-server pool (weak scaling);
        # the base covers attention/client work that does not.
        work = tokens / max(servers, 1)
        if kind == "prefill":
            dt = self.prefill_base + self.prefill_per_token * work
        else:
            var = self.decode_per_token * work
            if overlap or imbalance > 1.0 or contention > 1.0 \
                    or straggle > 1.0:
                # the expert phase finishes with its hottest server: skew
                # stretches the expert share by max/mean server load, N
                # front-end clients sharing the tier stretch it N-fold
                # (their attention shares run on private hardware), and a
                # straggler server stretches it by its slowdown factor —
                # lockstep waits for the slowest server every step
                expert = (self.expert_share * var * max(imbalance, 1.0)
                          * max(contention, 1.0) * max(straggle, 1.0))
                client = (1.0 - self.expert_share) * var
                var = (max(expert, client) + self.overlap_eps if overlap
                       else expert + client)
            dt = self.decode_base + var
        if self.degrade_with_dead:
            dt /= max(min(alive_frac, 1.0), 1e-3)
        return dt

    def decode_split(self, *, tokens: int, servers: int = 1,
                     alive_frac: float = 1.0) -> Tuple[float, float]:
        """Client/expert decomposition of one *unstretched* decode step —
        the async expert tier's cost primitives.

        Returns ``(client_dt, expert_dt)``: the attention/dispatch/combine
        share the client is busy for, and the expert-tier share at perfect
        balance.  ``client_dt + expert_dt`` equals ``stop("decode", ...)``
        with no overlap/imbalance/contention/straggle stretch, so a fully
        synchronous wave costs exactly one lockstep step.  The expert share
        is NOT divided by ``alive_frac`` — the async tier concentrates the
        per-server micro-batch work onto the surviving replicas instead
        (``expert_dt * servers * share_s`` server-seconds each), which
        reproduces the same 1/alive_frac stretch physically.
        """
        var = self.decode_per_token * tokens / max(servers, 1)
        client = self.decode_base + (1.0 - self.expert_share) * var
        if self.degrade_with_dead:
            client /= max(min(alive_frac, 1.0), 1e-3)
        return client, self.expert_share * var

    def idle(self) -> float:
        # idle steps sweep the clock forward to the next scheduled arrival;
        # one decode-quantum keeps the sweep resolution at step granularity.
        return self.decode_base


# ----------------------------------------------------------- event timeline

@dataclass
class Event:
    """One scheduled completion on the discrete-event timeline.

    Ordering is ``(time, seq)`` — ``seq`` is a monotone counter assigned at
    post time, so simultaneous events fire in the deterministic order they
    were scheduled (the tie-break the async determinism contract needs).
    """

    time: float
    seq: int
    kind: str            # prefill_done | mb_done | wave_done | ...
    payload: Dict = field(default_factory=dict)
    cancelled: bool = False


class EventTimeline:
    """A deterministic event heap: dispatch/compute/combine/migrate
    completions posted at absolute engine-clock times, popped in
    nondecreasing ``(time, seq)`` order.

    This generalizes the per-step :class:`VirtualClock` charges into a
    discrete-event timeline: instead of the engine adding one opaque ``dt``
    per step, the async engine posts each phase's completion as an event
    and advances its clock event-to-event.  Every fired event is recorded
    in ``log`` (scalar payload fields only), and :meth:`fingerprint` hashes
    the log — two replays of one seeded scenario must match bit-for-bit.
    """

    def __init__(self) -> None:
        self._heap: List[Tuple[float, int, Event]] = []
        self._seq = 0
        self.log: List[Dict] = []

    def __len__(self) -> int:
        return sum(1 for _, _, ev in self._heap if not ev.cancelled)

    def post(self, time: float, kind: str, **payload) -> Event:
        """Schedule ``kind`` at absolute time ``time``; returns the event
        (keep it to :meth:`cancel` later)."""
        ev = Event(float(time), self._seq, kind, payload)
        self._seq += 1
        heapq.heappush(self._heap, (ev.time, ev.seq, ev))
        return ev

    def cancel(self, ev: Event) -> None:
        """Invalidate a scheduled event (it will be silently skipped)."""
        ev.cancelled = True

    def peek_time(self) -> Optional[float]:
        while self._heap and self._heap[0][2].cancelled:
            heapq.heappop(self._heap)
        return self._heap[0][0] if self._heap else None

    def pop(self) -> Optional[Event]:
        """Next live event in (time, seq) order; logs it as fired."""
        while self._heap:
            _, _, ev = heapq.heappop(self._heap)
            if ev.cancelled:
                continue
            entry = {"t": ev.time, "seq": ev.seq, "kind": ev.kind}
            for k, v in ev.payload.items():
                if isinstance(v, (str, bool, int, float)):
                    entry[k] = v
            self.log.append(entry)
            return ev
        return None

    def clear_pending(self) -> None:
        """Drop every scheduled-but-unfired event (client failure): the log
        and the seq counter survive, so determinism across the drop holds."""
        self._heap = []

    def fingerprint(self, ndigits: int = 9) -> str:
        """sha256 of the fired-event log (times rounded to ``ndigits``) —
        the async determinism contract: same seed ⇒ same fingerprint."""
        def clean(v):
            return round(v, ndigits) if isinstance(v, float) else v
        payload = [{k: clean(v) for k, v in sorted(e.items())}
                   for e in self.log]
        blob = json.dumps(payload, sort_keys=True).encode()
        return hashlib.sha256(blob).hexdigest()
