"""Serving runtime, split into scheduler / executor / engine layers:
admission + step policy (``scheduler``, memory-aware over the paged-KV
``kv_pool`` block manager: prefix caching, copy-on-write, preemption),
params + caches + jitted step variants incl. chunked prefill, paged
block-pool caches and two-microbatch pipelined decode (``executor``), and
the orchestrating ``ServingEngine`` with the failover/rebalance/scale
control plane.  Plus the host-level physically-disaggregated engine
(paper-literal buffer protocol) and the deterministic scenario/autoscaling
harness the paper's timeline claims are tested with."""

from repro.serving.engine import ServingEngine, EngineConfig  # noqa: F401
from repro.serving.executor import Executor  # noqa: F401
from repro.serving.kv_pool import BlockPool, block_hashes  # noqa: F401
from repro.serving.request import Request, SamplingParams  # noqa: F401
from repro.serving.clock import Clock, VirtualClock, WallClock  # noqa: F401
from repro.serving.scenario import (Scenario, ScenarioResult,  # noqa: F401
                                    zipf_bias)
from repro.serving.scheduler import Scheduler, SchedulerConfig  # noqa: F401
from repro.serving.autoscale import Autoscaler, AutoscalerConfig  # noqa: F401
from repro.serving.rebalance import (RebalanceConfig,  # noqa: F401
                                     RebalanceController)
