import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable (e)): prove the distribution config is
coherent for every (architecture × input shape × mesh) cell.

For each cell this lowers + compiles the real step function — ``train_step``
for train shapes, ``prefill_step`` / ``serve_step`` for inference shapes —
against abstract inputs (ShapeDtypeStruct, zero allocation) on the
production meshes (16×16 single-pod; 2×16×16 multi-pod), then records

* ``memory_analysis()``   — bytes per device (does it fit 16 GB HBM?)
* ``cost_analysis()``     — per-device HLO FLOPs / bytes (roofline terms)
* collective bytes        — parsed from the post-SPMD HLO text

Results land in ``experiments/dryrun/*.json``; ``benchmarks/roofline.py``
turns them into EXPERIMENTS.md §Roofline.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch kimi-k2-1t-a32b \
        --shape train_4k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse
import json
import re
import time
import traceback
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (ALL_SHAPES, ASSIGNED_ARCHS, InputShape, applicable,
                           get_config, shape_by_name)
from repro.configs.base import ModelConfig
from repro.distributed.sharding_rules import (batch_shardings,
                                              param_shardings, to_named)
from repro.kernels import ops as kops
from repro.launch.mesh import data_axes, make_production_mesh
from repro.models.transformer import ParallelCtx, build_model
from repro.core.moe_layer import MoERuntime, default_capacity
from repro.core import mapping as emap
from repro.training.optimizer import adafactor
from repro.training.train_loop import TrainState, make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")

# op *applications* (name followed by '('), not references (%name)
OP_RE = re.compile(
    r"(?<![%\w-])(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start|-done)?(?:\.\d+)?\s*\(")
SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|s64|f64)\[([\d,]*)\]")

DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "s64": 8, "f64": 8}


def parse_collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum output bytes of every collective op in the partitioned HLO
    (per-device bytes, matching cost_analysis conventions).  Handles
    tuple-shaped results (all-to-all) and async -start/-done pairs."""
    totals: Dict[str, float] = {}
    counts: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = OP_RE.search(line)
        if not m:
            continue
        if m.group(2) == "-done":      # counted at the -start
            continue
        kind = m.group(1)
        lhs = line[:m.start()]
        if "=" not in lhs:
            continue
        nbytes = 0
        for dm in SHAPE_RE.finditer(lhs):
            dt, dims = dm.group(1), dm.group(2)
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * DTYPE_BYTES[dt]
        totals[kind] = totals.get(kind, 0) + nbytes
        counts[kind] = counts.get(kind, 0) + 1
    totals["_counts"] = counts
    return totals


# ---------------------------------------------------------------------------
# Abstract inputs per (arch, shape)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    sds = jax.ShapeDtypeStruct
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        specs = {"tokens": sds((B, S), jnp.int32),
                 "labels": sds((B, S), jnp.int32)}
    elif shape.kind == "prefill":
        specs = {"tokens": sds((B, S), jnp.int32)}
    else:  # decode: ONE new token against a cache of S tokens
        specs = {"tokens": sds((B, 1), jnp.int32)}
    if cfg.is_encoder_decoder and shape.kind == "train":
        specs["frames"] = sds((B, cfg.encoder_seq_len, cfg.d_model),
                              jnp.bfloat16)
    if cfg.mrope_sections is not None and shape.kind == "train":
        specs["mrope_positions"] = sds((3, B, S), jnp.int32)
    return specs


def _cache_sharding_specs(cache_abs, batch: int, dp: Tuple[str, ...],
                          seq_axes: Tuple[str, ...], seq_len: int):
    """Shard cache slots (dim == seq_len) over ``seq_axes`` and the batch
    dim over the data axes (when batch > 1 and data isn't used for slots).
    Leaves without either dim (ring windows, SSM states, cross-attn K/V)
    stay batch-sharded or replicated."""
    from jax.sharding import PartitionSpec as P

    batch_ok = batch > 1 and not set(dp) & set(seq_axes or ())

    def one(leaf):
        shape = leaf.shape
        spec = [None] * len(shape)
        start = 1 if len(shape) >= 3 else 0     # batch is never dim0 there
        if seq_axes:
            for i, d in enumerate(shape):
                if d == seq_len:
                    spec[i] = seq_axes
                    if batch_ok:
                        for j in range(start, len(shape)):
                            if j != i and shape[j] == batch:
                                spec[j] = dp
                                break
                    return P(*spec)
        if batch > 1:
            for i in range(start, len(shape)):
                if shape[i] == batch:
                    spec[i] = dp
                    return P(*spec)
        return P(*spec)

    return jax.tree.map(one, cache_abs)


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------

def moe_runtime_for(cfg: ModelConfig, mesh, shape: InputShape,
                    mode: str) -> Optional[MoERuntime]:
    if cfg.moe is None:
        return None
    S = mesh.shape["model"]
    dp_total = int(np.prod([mesh.shape[a] for a in data_axes(mesh)]))
    if mode == "a2a":
        tokens_per_client = shape.global_batch * shape.seq_len // (
            dp_total * S)
    else:
        tokens_per_client = max(shape.global_batch // dp_total, 1)
    from repro.core import expert_server
    table = emap.default_mapping(cfg.moe.num_experts, S, max_replicas=2)
    local = expert_server.make_local_table(
        cfg.moe.num_experts, S, np.zeros((S, 0), np.int32))
    return MoERuntime(
        mapping=jnp.asarray(table),
        alive=jnp.ones((S,), bool),
        local_table=jnp.asarray(local),
        num_servers=S,
        capacity=default_capacity(tokens_per_client, cfg.moe.top_k, S,
                                  cfg.moe.capacity_factor),
        gemm_impl="xla_dense",
    )


def build_cell(arch: str, shape: InputShape, mesh, cfg=None,
               unroll: bool = False):
    """Returns (jitted_fn, abstract_args) for one dry-run cell."""
    cfg = cfg or get_config(arch)
    dp = data_axes(mesh)
    S_servers = mesh.shape["model"]
    model = build_model(cfg, num_servers=S_servers if cfg.moe else 1)
    kops.set_default_impl("xla_dense")

    from repro.distributed.sharding_rules import train_phase_for
    mode = "a2a" if shape.kind in ("train", "prefill") else "replicated"
    rt = moe_runtime_for(cfg, mesh, shape, mode)
    # SP residual only where training is capacity-blocked (ZeRO-3 class):
    # small models fit without it and the per-layer reshards slow compile
    zero3 = train_phase_for(cfg.num_params(), mesh.shape["model"]) == "train"
    # decode: slot-shard the KV cache — over (data+model) for batch-1 long
    # context, over model otherwise (attention weights replicated; see
    # sharding_rules phase "decode" and EXPERIMENTS.md §Perf iter 1)
    seq_shard = shape.kind == "decode"
    seq_axes = ()
    if seq_shard:
        seq_axes = (*dp, "model") if shape.global_batch == 1 else ("model",)
    ctx = ParallelCtx(mesh=mesh, axis_data=dp, moe_runtime=rt,
                      moe_mode=mode, gemm_impl="xla_dense",
                      seq_shard_cache=seq_shard, seq_shard_axes=seq_axes,
                      sp_residual=(shape.kind == "train" and zero3),
                      remat=True, ce_chunk=512, unroll_scans=unroll)

    params_abs = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    phase = {"train": "train" if zero3 else "train_tp",
             "prefill": "serve", "decode": "decode"}[shape.kind]
    pspecs = param_shardings(params_abs, mesh, phase, dp=dp, mp="model")
    pshard = to_named(pspecs, mesh)

    specs = input_specs(cfg, shape)
    bshard = batch_shardings(mesh, dp)
    from jax.sharding import NamedSharding, PartitionSpec as P
    repl = NamedSharding(mesh, P())

    def batch_shd(name, spec):
        if name == "mrope_positions":
            return NamedSharding(mesh, P(None, dp, None))
        if name == "frames":
            return NamedSharding(mesh, P(dp, None, None))
        if spec.shape[0] == 1:           # batch 1 (long_500k): replicate
            return repl
        return bshard

    if shape.kind == "train":
        from repro.distributed.sharding_rules import adafactor_state_shardings
        opt = adafactor(lr=1e-3)
        state_abs = jax.eval_shape(
            lambda p: TrainState(params=p, opt_state=opt.init(p),
                                 step=jnp.zeros((), jnp.int32)),
            params_abs)
        opt_shard = to_named(
            adafactor_state_shardings(params_abs, pspecs), mesh)
        state_shard = TrainState(params=pshard, opt_state=opt_shard,
                                 step=repl, ef_residual=None)
        step = make_train_step(model, opt, ctx)
        in_shardings = (state_shard,
                        {k: batch_shd(k, v) for k, v in specs.items()})
        fn = jax.jit(step, in_shardings=in_shardings,
                     out_shardings=(state_shard, None),
                     donate_argnums=(0,))
        args = (state_abs, specs)
        return fn, args

    if shape.kind == "prefill":
        def prefill_step(params, tokens):
            logits, cache = model.prefill(params, tokens, ctx,
                                          max_slots=shape.seq_len)
            return logits, cache
        cache_abs = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                     abstract=True))
        cache_spec = _cache_sharding_specs(
            cache_abs, shape.global_batch, dp, (), shape.seq_len)
        cache_shard = jax.tree.map(
            lambda s: NamedSharding(mesh, s), cache_spec,
            is_leaf=lambda x: isinstance(x, P))
        fn = jax.jit(prefill_step,
                     in_shardings=(pshard, batch_shd("tokens",
                                                     specs["tokens"])),
                     out_shardings=(None, cache_shard))
        return fn, (params_abs, specs["tokens"])

    # decode: serve_step — one token against a seq_len cache
    def serve_step(params, token, cache):
        logits, cache, _ = model.decode_step(params, token, cache, ctx)
        next_tok = jnp.argmax(logits, axis=-1, keepdims=True).astype(
            jnp.int32)
        return next_tok, cache

    cache_abs = model.init_cache(shape.global_batch, shape.seq_len,
                                 abstract=True)
    cache_spec = _cache_sharding_specs(
        cache_abs, shape.global_batch, dp, seq_axes, shape.seq_len)
    cache_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), cache_spec,
                               is_leaf=lambda x: isinstance(x, P))
    tok_shard = (repl if shape.global_batch == 1
                 else batch_shd("tokens", specs["tokens"]))
    fn = jax.jit(serve_step,
                 in_shardings=(pshard, tok_shard, cache_shard),
                 out_shardings=(tok_shard, cache_shard),
                 donate_argnums=(2,))
    return fn, (params_abs, specs["tokens"], cache_abs)


# ---------------------------------------------------------------------------
# Cost probes: XLA cost_analysis counts while-loop bodies ONCE, so layer
# scans hide depth.  We therefore compile 1-unit and 2-unit *unrolled*
# variants of each cell and extrapolate: total = C1 + (C2 - C1)·(units - 1).
# Every roofline number still comes from a compiled HLO artifact.
# ---------------------------------------------------------------------------

def probe_plan(cfg: ModelConfig):
    """Returns (probe_cfgs, combine(costs) -> cost_dict)."""

    def rep(**kw):
        return cfg.replace(**kw)

    if cfg.family == "audio":
        units = cfg.num_layers          # enc and dec both scale 1:1
        probes = [rep(num_layers=1, num_encoder_layers=1),
                  rep(num_layers=2, num_encoder_layers=2)]
        comb = lambda c: _lin(c[0], c[1], units)
    elif cfg.family == "hybrid":
        per = cfg.shared_block_every
        units = cfg.num_layers // per
        probes = [rep(num_layers=per), rep(num_layers=2 * per)]
        comb = lambda c: _lin(c[0], c[1], units)
    elif cfg.local_global_pattern:
        g = cfg.local_global_pattern + 1
        n_groups = cfg.num_layers // g
        remn = cfg.num_layers - n_groups * g
        probes = [rep(num_layers=g), rep(num_layers=2 * g)]
        if remn:
            probes.append(rep(num_layers=g + remn))
            comb = lambda c: _add(_lin(c[0], c[1], n_groups),
                                  _sub(c[2], c[0]))
        else:
            comb = lambda c: _lin(c[0], c[1], n_groups)
    else:
        k0 = cfg.moe.first_k_dense if cfg.moe else 0
        units = cfg.num_layers - k0
        probes = [rep(num_layers=k0 + 1), rep(num_layers=k0 + 2)]
        comb = lambda c: _lin(c[0], c[1], units)
    return probes, comb


def _lin(c1, c2, units):
    return {k: c1.get(k, 0) + (c2.get(k, 0) - c1.get(k, 0)) * (units - 1)
            for k in set(c1) | set(c2)}


def _add(a, b):
    return {k: a.get(k, 0) + b.get(k, 0) for k in set(a) | set(b)}


def _sub(a, b):
    return {k: a.get(k, 0) - b.get(k, 0) for k in set(a) | set(b)}


def _cost_dict(compiled) -> Dict[str, float]:
    cost = compiled.cost_analysis()
    coll = parse_collective_bytes(compiled.as_text())
    out = {"flops": float(cost.get("flops", 0.0)),
           "bytes": float(cost.get("bytes accessed", 0.0))}
    for k, v in coll.items():
        if k == "_counts":
            for kk, vv in v.items():
                out[f"n_{kk}"] = vv
        else:
            out[f"coll_{k}"] = v
    out["coll_total"] = sum(v for k, v in out.items()
                            if k.startswith("coll_"))
    return out


def run_probes(arch: str, shape: InputShape, mesh) -> Dict[str, float]:
    cfg = get_config(arch)
    probes, comb = probe_plan(cfg)
    costs = []
    for pc in probes:
        fn, args = build_cell(arch, shape, mesh, cfg=pc, unroll=True)
        compiled = fn.lower(*args).compile()
        costs.append(_cost_dict(compiled))
    return comb(costs)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save: bool = True) -> Dict:
    shape = shape_by_name(shape_name)
    cfg = get_config(arch)
    ok, reason = applicable(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "status": "skip", "reason": reason}
    if not ok:
        _save(result, save)
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        fn, args = build_cell(arch, shape, mesh)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = parse_collective_bytes(hlo)
        n_dev = int(np.prod(list(mesh.shape.values())))

        t0 = time.time()
        try:
            corrected = run_probes(arch, shape, mesh)
        except Exception as e:
            corrected = {"error": f"{type(e).__name__}: {e}"}
        t_probe = time.time() - t0

        result.update({
            "status": "ok",
            "probe_s": round(t_probe, 2),
            "roofline_corrected": corrected,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "num_devices": n_dev,
            "flops_per_device": float(cost.get("flops", 0.0)),
            "bytes_per_device": float(cost.get("bytes accessed", 0.0)),
            "collective_bytes_per_device": {
                k: v for k, v in coll.items() if k != "_counts"},
            "collective_counts": coll.get("_counts", {}),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "generated_code_bytes": mem.generated_code_size_in_bytes,
                "peak_bytes_per_device": (
                    mem.argument_size_in_bytes + mem.temp_size_in_bytes
                ) // n_dev if hasattr(mem, "argument_size_in_bytes") else None,
            },
        })
        print(f"[dryrun] {arch} × {shape_name} × {mesh_name}: OK "
              f"(lower {t_lower:.1f}s, compile {t_compile:.1f}s, "
              f"{result['flops_per_device']:.3e} flops/dev)")
    except Exception as e:  # a failing cell is a bug — record it loudly
        result.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]})
        print(f"[dryrun] {arch} × {shape_name} × {mesh_name}: "
              f"FAILED — {type(e).__name__}: {e}")
    _save(result, save)
    return result


def _save(result: Dict, save: bool) -> None:
    if not save:
        return
    os.makedirs(RESULTS_DIR, exist_ok=True)
    name = f"{result['arch']}_{result['shape']}_{result['mesh']}.json"
    with open(os.path.join(RESULTS_DIR, name), "w") as f:
        json.dump(result, f, indent=2)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = ([s.name for s in ALL_SHAPES] if (args.all or not args.shape)
              else [args.shape])

    failures = 0
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                r = run_cell(arch, shape, mp)
                failures += r["status"] == "error"
    if failures:
        raise SystemExit(f"{failures} dry-run cells FAILED")
    print("dry-run: all requested cells passed")


if __name__ == "__main__":
    main()
