"""Client-side token dispatch: pack (token, expert, score) triples into the
per-server shared-buffer slots, and the inverse combine.

Two packing algorithms (both produce identical buffers):

* ``method="sort"``   — stable sort by destination server, O(Tk log Tk).
* ``method="onehot"`` — cumsum-of-onehot ranking, O(Tk · S); no sort, better
  on the VPU when S is small (it is: S = model-axis size, 16).  This is a
  beyond-paper optimization knob; the two methods' buffer-for-buffer
  equivalence is pinned down in tests/test_dispatch.py (property form) and
  tests/test_scenario.py (hypothesis-free form).

Capacity semantics follow the paper's fixed-size buffer slots: at most
``capacity`` tokens per (client, server) pair per layer; overflow tokens are
dropped (counted) exactly as capacity-factor MoE implementations do.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core.types import DispatchBuffers


def pack(x: jax.Array, expert_ids: jax.Array, scores: jax.Array,
         server_ids: jax.Array, num_servers: int, capacity: int,
         method: str = "onehot") -> DispatchBuffers:
    """Build request buffers for every destination server.

    x: (T, d); expert_ids/scores/server_ids: (T, k).
    """
    T, d = x.shape
    k = expert_ids.shape[1]
    Tk = T * k
    S, C = num_servers, capacity

    flat_server = server_ids.reshape(Tk)
    flat_expert = expert_ids.reshape(Tk)
    flat_score = scores.reshape(Tk)
    flat_token = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)

    if method == "sort":
        order = jnp.argsort(flat_server, stable=True)
        s_sorted = flat_server[order]
        counts = jnp.bincount(flat_server, length=S)
        starts = jnp.concatenate(
            [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
        slot_sorted = jnp.arange(Tk, dtype=jnp.int32) - starts[s_sorted].astype(jnp.int32)
        # un-sort the slot assignment back to flat order
        slot = jnp.zeros((Tk,), jnp.int32).at[order].set(slot_sorted)
    elif method == "onehot":
        onehot = jax.nn.one_hot(flat_server, S, dtype=jnp.int32)   # (Tk, S)
        ranks = jnp.cumsum(onehot, axis=0) - onehot                # exclusive
        slot = jnp.take_along_axis(
            ranks, flat_server[:, None].astype(jnp.int32), axis=1)[:, 0]
        counts = jnp.sum(onehot, axis=0)
    else:
        raise ValueError(method)

    valid = slot < C
    flat_idx = jnp.where(valid, flat_server * C + slot, S * C)     # OOB drops

    hidden = jnp.zeros((S * C, d), x.dtype).at[flat_idx].set(
        x[flat_token], mode="drop")
    eid = jnp.full((S * C,), -1, jnp.int32).at[flat_idx].set(
        flat_expert, mode="drop")
    sc = jnp.zeros((S * C,), jnp.float32).at[flat_idx].set(
        flat_score, mode="drop")

    combine_slot = jnp.where(valid, flat_idx, -1).reshape(T, k)
    dropped = jnp.sum(jnp.maximum(counts - C, 0))

    return DispatchBuffers(
        hidden=hidden.reshape(S, C, d),
        expert_id=eid.reshape(S, C),
        score=sc.reshape(S, C),
        counts=jnp.minimum(counts, C).astype(jnp.int32),
        combine_slot=combine_slot,
        dropped=dropped.astype(jnp.int32),
    )


def combine(result_hidden: jax.Array, combine_slot: jax.Array,
            out_dtype=None) -> jax.Array:
    """Sum the k score-weighted expert outputs back per token.

    result_hidden: (S, C, d) server responses (already score-weighted);
    combine_slot: (T, k) flat indices into S*C (-1 = dropped).
    """
    S, C, d = result_hidden.shape
    flat = result_hidden.reshape(S * C, d)
    T, k = combine_slot.shape
    safe = jnp.maximum(combine_slot, 0)
    gathered = flat[safe.reshape(-1)].reshape(T, k, d)
    gathered = jnp.where((combine_slot >= 0)[..., None], gathered, 0)
    out = jnp.sum(gathered.astype(jnp.float32), axis=1)
    return out.astype(out_dtype or result_hidden.dtype)
