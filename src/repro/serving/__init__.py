"""Serving runtime.

Public entrypoint: the :class:`Cluster` front-end — N attention clients
(each a scheduler/executor/KV-pool ``ServingEngine``) sharing ONE
disaggregated expert tier (``ServerPool``), with pluggable request routing
(``FrontendRouter``: round_robin / least_loaded / session_affinity),
per-client admission backpressure, and the cluster-owned placement control
plane (live rebalancing, elastic scaling).  ``Cluster(clients=1)`` is the
single-client special case; ``ServingEngine`` remains available as the
per-client engine and for single-engine experiments.

Layers underneath: admission + step policy (``scheduler``, memory-aware
over the paged-KV ``kv_pool`` block manager: prefix caching, copy-on-write,
preemption), params + caches + jitted step variants incl. chunked prefill,
paged block-pool caches and two-microbatch pipelined decode (``executor``),
the per-client ``ServingEngine`` orchestrator, and the deterministic
scenario/autoscaling harness the paper's timeline claims are tested with
(now cluster-aware: ``fail_client`` / ``recover_client`` /
``set_frontend_policy`` events, plus ``slow_server`` stragglers).

Execution modes: ``EngineConfig.exec_mode`` selects ``lockstep`` (default,
synchronous steps) or ``async`` — the event-driven expert tier
(``event_loop.AsyncExpertTier`` micro-batch queues + the
``clock.EventTimeline`` discrete-event heap) where decode completions post
back asynchronously and prefill overlaps in-flight expert phases.  Both
modes produce bitwise-identical per-request token streams from the same
seed; only timing moves.

Deprecated: ``repro.serving.Engine`` (alias of ``ServingEngine``) — the
pre-cluster name for "the system"; use ``Cluster`` (or ``ServingEngine``
explicitly for one client).  Kept for one release.
"""

import warnings

from repro.serving.engine import ServingEngine, EngineConfig  # noqa: F401
from repro.serving.cluster import Cluster, ClusterConfig  # noqa: F401
from repro.serving.executor import Executor  # noqa: F401
from repro.serving.frontend import (FrontendRouter,  # noqa: F401
                                    FRONTEND_POLICIES, make_frontend_router)
from repro.serving.kv_pool import BlockPool, block_hashes  # noqa: F401
from repro.serving.request import Request, SamplingParams  # noqa: F401
from repro.serving.clock import (Clock, Event,  # noqa: F401
                                 EventTimeline, VirtualClock, WallClock)
from repro.serving.event_loop import (AsyncExpertTier,  # noqa: F401
                                      MicroBatch, ServerQueue)
from repro.serving.metrics import (ClusterMetrics,  # noqa: F401
                                   ServingMetrics)
from repro.serving.scenario import (Scenario, ScenarioResult,  # noqa: F401
                                    zipf_bias)
from repro.serving.scheduler import Scheduler, SchedulerConfig  # noqa: F401
from repro.serving.autoscale import Autoscaler, AutoscalerConfig  # noqa: F401
from repro.serving.rebalance import (RebalanceConfig,  # noqa: F401
                                     RebalanceController)


def __getattr__(name):
    if name == "Engine":
        warnings.warn(
            "repro.serving.Engine is deprecated: the public serving API is "
            "repro.serving.Cluster (N attention clients sharing one expert "
            "tier); import ServingEngine explicitly if you want a single "
            "client engine.  This alias will be removed next release.",
            DeprecationWarning, stacklevel=2)
        return ServingEngine
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
