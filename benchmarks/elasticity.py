"""Full-system elasticity benchmark: scale-to-zero experts + client autoscaling.

One seeded diurnal request trace (sinusoidal arrival rate, peak-to-trough
swing of 19x) with a rotating hot expert set, replayed three ways on an
async-execution :class:`~repro.serving.cluster.Cluster`:

* ``static``        — fixed fleet: every attention client and expert server
  provisioned for the peak stays up through the trough;
* ``elastic``       — the :class:`~repro.serving.autoscale.Autoscaler`
  drives all three controllers (expert-server count, attention-client
  count, scale-to-zero expert paging) off the observed arrival rate, with
  ``cold_start_base = 0``: paging is free, so the token streams must be
  **bitwise identical** to the static run — elasticity is pure resource
  policy, never a model change;
* ``elastic_cold``  — the same elastic run with a modeled page-in penalty
  (``cold_start_base > 0``): the charged cold-start stalls become visible
  in the wall clock.  The penalty only moves *time*, never values — but
  against a time-scripted trace (the rotating hot set flips route bias at
  fixed virtual times) shifted time can legitimately realign a request's
  decode steps with a rotation boundary and reroute it, so value identity
  is pinned only at ``cold_start_base = 0`` (``tokens_identical_cold`` is
  reported for visibility, not gated).

The headline gate is the paper's §6.4 claim: resource-seconds consumed
inside the off-peak trough window (the quarter-period centred on the rate
minimum) must drop by more than 37.5% versus static provisioning —
the saving EAAS pins against whole-group EP scaling.  Resource-seconds
integrate the provisioned-unit curve (in-fleet clients + expert servers
weighted by the resident expert fraction) over virtual time, so the number
is deterministic and exactly reproducible.

The full (non-smoke) run replays the same trace over a longer horizon and
adds a lockstep static/elastic pair: the identity contract is per
execution mode (timing shifts *when* a decode step lands relative to the
scripted skew rotation, which legitimately reroutes tokens across modes),
so each mode pins its own elastic-vs-static identity.

``gate`` is consumed by ``tools/check_bench.py`` against
``experiments/baselines/elasticity.json``.
"""

from __future__ import annotations

import argparse
import hashlib
from typing import Dict, List

from benchmarks.common import bench_model_cfg, csv_row, save_result
from repro.serving import (Cluster, ClusterConfig, EngineConfig,
                           VirtualClock)
from repro.serving.autoscale import Autoscaler, AutoscalerConfig
from repro.serving.scenario import Scenario

NUM_SERVERS = 4
MAX_BATCH = 4
CLIENTS = 2
MEAN_RATE = 40.0          # diurnal mean (req/s); amplitude 0.9 -> 19x swing
AMPLITUDE = 0.9
HOT_ALPHA, HOT_SCALE = 1.2, 3.0   # rotating Zipf hot set: cold experts
HOT_PERIOD = 0.4                  # exist AND page back in (cold starts)
COLD_START_BASE = 5e-3            # modeled page-in penalty (s per expert)
PAPER_TROUGH_SAVING = 0.375       # the EAAS §6.4 resource-saving claim


def _autoscaler() -> Autoscaler:
    return Autoscaler(AutoscalerConfig(
        rate_per_server=12.0, min_servers=1, max_servers=NUM_SERVERS,
        window=0.1, cooldown=0.1,
        # attention tier: client count follows the same observed rate
        rate_per_client=20.0, min_clients=1, max_clients=CLIENTS,
        # scale-to-zero: page experts under half their fair traffic share
        expert_idle_fraction=0.5, page_in_protect=0.2,
        min_resident_fraction=0.25))


def _cluster(cfg, exec_mode: str, cold_start_base: float) -> Cluster:
    ecfg = EngineConfig(
        mode="eaas", num_servers=NUM_SERVERS, max_batch=MAX_BATCH,
        max_seq=64, n_redundant=2,
        # drop-free dispatch capacity (the bitwise-identity contract)
        pool_tokens_per_client=MAX_BATCH * NUM_SERVERS,
        exec_mode=exec_mode, async_depth=2)
    return Cluster(
        cfg, ClusterConfig(clients=CLIENTS, engine=ecfg,
                           max_clients=CLIENTS),
        seed=0,
        clock_factory=lambda: VirtualClock(cold_start_base=cold_start_base))


def _token_fingerprint(tokens: Dict[int, tuple]) -> str:
    blob = repr(sorted(tokens.items())).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def _measure(cfg, horizon: float, exec_mode: str, elastic: bool,
             cold_start_base: float = 0.0) -> Dict:
    cl = _cluster(cfg, exec_mode, cold_start_base)
    sc = (Scenario(horizon=horizon, seed=1, prompt_len=8, max_new=8,
                   vocab=cfg.vocab_size)
          .diurnal(MEAN_RATE, amplitude=AMPLITUDE, period=horizon)
          .shifting_hot_set(HOT_ALPHA, period=HOT_PERIOD, scale=HOT_SCALE))
    if elastic:
        sc.autoscale(_autoscaler())
    res = sc.run(cl, max_steps=40_000)
    m = cl.metrics
    # off-peak trough: diurnal rate = mean*(1 + A*sin(2*pi*t/T)) bottoms
    # at 0.75T — integrate provisioned units over the quarter-period
    # window centred there
    w0, w1 = 0.625 * horizon, 0.875 * horizon
    tokens = {r.request_id: tuple(r.output_tokens) for r in res.requests}
    return {
        "requests": m.total_requests,
        "completed": m.completed,
        "failed": m.failed_requests,
        "decode_tok_per_s": m.decode_throughput,
        "p99_itl_s": m.p99_itl,
        "wall_s": m.wall_time,
        "resource_seconds": m.resource_seconds,
        "trough_resource_seconds": m.resource_seconds_in(w0, w1),
        "client_spawns": m.client_spawns,
        "client_drains": m.client_drains,
        "expert_page_outs": m.expert_page_outs,
        "cold_starts": m.cold_starts,
        "cold_start_time_s": m.cold_start_time,
        "token_fingerprint": _token_fingerprint(tokens),
        "_tokens": tokens,
    }


def _saving(static: Dict, elastic: Dict, key: str) -> float:
    return 1.0 - elastic[key] / max(static[key], 1e-12)


def run(horizon: float = 2.0, smoke: bool = False) -> Dict:
    if smoke:
        horizon = 1.0
    cfg = bench_model_cfg()

    variants: Dict[str, Dict] = {}
    variants["static"] = _measure(cfg, horizon, "async", elastic=False)
    variants["elastic"] = _measure(cfg, horizon, "async", elastic=True)
    variants["elastic_cold"] = _measure(cfg, horizon, "async", elastic=True,
                                        cold_start_base=COLD_START_BASE)
    if not smoke:
        variants["static_lockstep"] = _measure(cfg, horizon, "lockstep",
                                               elastic=False)
        variants["elastic_lockstep"] = _measure(cfg, horizon, "lockstep",
                                                elastic=True)

    st, el, ec = (variants["static"], variants["elastic"],
                  variants["elastic_cold"])
    out: Dict = {
        "figure": "elasticity", "smoke": smoke,
        "num_servers": NUM_SERVERS, "clients": CLIENTS,
        "horizon_s": horizon,
        "trace": {"mean_rate": MEAN_RATE, "amplitude": AMPLITUDE,
                  "hot_alpha": HOT_ALPHA, "hot_period": HOT_PERIOD},
        "cold_start_base": COLD_START_BASE,
        "paper_trough_saving": PAPER_TROUGH_SAVING,
        "variants": {},
    }
    out["tokens_identical_elastic"] = el["_tokens"] == st["_tokens"]
    out["tokens_identical_cold"] = ec["_tokens"] == st["_tokens"]
    out["trough_saving"] = _saving(st, el, "trough_resource_seconds")
    out["overall_saving"] = _saving(st, el, "resource_seconds")
    for name, v in variants.items():
        out["variants"][name] = {k: val for k, val in v.items()
                                 if k != "_tokens"}

    out["gate"] = {
        "exact": {
            "smoke": smoke,
            # elasticity is resource policy, never a model change: with
            # cold_start_base = 0 the token streams are bit-identical
            # (the cold variant's identity is NOT gated — see the module
            # docstring: the penalty shifts time against a time-scripted
            # skew rotation, which may legitimately reroute)
            "tokens_identical_elastic": out["tokens_identical_elastic"],
            "token_fingerprint_static": st["token_fingerprint"],
            "token_fingerprint_elastic": el["token_fingerprint"],
            # the paper's off-peak claim, pinned as a boolean
            "trough_saving_beats_paper":
                out["trough_saving"] > PAPER_TROUGH_SAVING,
            # every controller actually fired
            "expert_page_outs_occurred": el["expert_page_outs"] > 0,
            "client_drains_occurred": el["client_drains"] > 0,
            "cold_starts_occurred": ec["cold_starts"] > 0,
            "cold_penalty_charged": ec["cold_start_time_s"] > 0,
            # drain finishes in-flight waves: nothing is ever dropped
            "no_failed_requests": el["failed"] == 0,
            "all_completed": el["completed"] == st["completed"],
        },
        "tolerance": {
            "trough_saving_pct": 100.0 * out["trough_saving"],
            "overall_saving_pct": 100.0 * out["overall_saving"],
            "resource_seconds_static": st["resource_seconds"],
            "resource_seconds_elastic": el["resource_seconds"],
            "tok_per_s_static": st["decode_tok_per_s"],
            "tok_per_s_elastic": el["decode_tok_per_s"],
            "p99_itl_static": st["p99_itl_s"],
            "p99_itl_elastic": el["p99_itl_s"],
            "cold_start_time_s": ec["cold_start_time_s"],
        },
    }
    if not smoke:
        sl, elk = (variants["static_lockstep"],
                   variants["elastic_lockstep"])
        out["gate"]["exact"]["tokens_identical_lockstep"] = \
            elk["_tokens"] == sl["_tokens"]
        out["gate"]["exact"]["lockstep_trough_saving_beats_paper"] = \
            _saving(sl, elk, "trough_resource_seconds") \
            > PAPER_TROUGH_SAVING
    save_result("elasticity", out)
    return out


def main() -> List[str]:
    res = run()
    rows = []
    for name, v in res["variants"].items():
        rows.append(csv_row(
            f"elasticity_{name}", 0.0,
            f"tok_per_s={v['decode_tok_per_s']:.1f}"
            f";p99_itl={v['p99_itl_s']:.5f}"
            f";res_sec={v['resource_seconds']:.3f}"
            f";completed={v['completed']}"))
    beats = res["gate"]["exact"]["trough_saving_beats_paper"]
    rows.append(csv_row(
        "elasticity_summary", 0.0,
        f"trough_saving={100 * res['trough_saving']:.1f}%"
        f";overall_saving={100 * res['overall_saving']:.1f}%"
        f";identical={int(res['tokens_identical_elastic'])}"
        f";beats_paper={int(beats)}"))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single short configuration (CI regression gate)")
    args = ap.parse_args()
    res = run(smoke=args.smoke)
    for name, v in res["variants"].items():
        print(f"{name}: res_sec={v['resource_seconds']:.3f} "
              f"tok_per_s={v['decode_tok_per_s']:.1f} "
              f"completed={v['completed']} "
              f"page_outs={v['expert_page_outs']} "
              f"drains={v['client_drains']} "
              f"cold_starts={v['cold_starts']}")
    print(f"trough saving {100 * res['trough_saving']:.1f}% "
          f"(paper {100 * PAPER_TROUGH_SAVING:.1f}%), overall "
          f"{100 * res['overall_saving']:.1f}%, identical="
          f"{res['tokens_identical_elastic']}/"
          f"{res['tokens_identical_cold']}")
