"""Admission and step planning — the scheduler half of the engine split.

The :class:`Scheduler` owns the request queue and the slot table and decides
what the next engine step *is*: a prefill chunk, a decode step over the
decode-ready slots, or idle.  It never touches params, caches or jitted
functions — that is the :class:`~repro.serving.executor.Executor`'s side of
the line — so policies stay pure host logic, trivially swappable and
deterministic under a virtual clock.

Chunked prefill (bounded TTFT *and* bounded ITL): a prompt is split into
chunks of at most ``prefill_chunk`` tokens and each chunk is one engine
step, so decode steps can interleave with a long prompt's admission instead
of stalling behind it.  ``prefill_chunk=0`` reproduces the pre-split
engine: whole prompts in one step.

Policies (what runs when both prefill work and decode-ready slots exist):

* ``prefill-priority`` (default, the pre-split behaviour): drain every
  pending prefill chunk before decoding.  Best TTFT; under bursty arrivals
  decode gaps grow with the whole prefill backlog.
* ``fair``: strictly alternate — at most one prefill chunk between
  consecutive decode steps, so the worst-case decode gap is one chunk, not
  one backlog.  This is what makes chunked prefill's ITL bound real.
* ``fcfs``: run-to-completion in arrival order — in-flight requests decode
  to completion before any queued prompt is prefilled (the static-batching
  baseline: best ITL, worst TTFT).
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.serving.request import Request

POLICIES = ("prefill-priority", "fair", "fcfs")


@dataclass
class SchedulerConfig:
    max_batch: int
    prefill_chunk: int = 0             # 0 = whole prompt in one step
    policy: str = "prefill-priority"   # prefill-priority | fair | fcfs
    batch_cap: Optional[int] = None    # TP weight-replication slot cap


def _check_policy(policy: str) -> None:
    if policy not in POLICIES:
        raise ValueError(
            f"unknown scheduling policy {policy!r}; expected one of "
            f"{POLICIES}")


# ------------------------------------------------------------------- plans

@dataclass(frozen=True)
class PrefillChunk:
    """Run prompt positions [start, start+length) of ``request`` (slot b)."""
    slot: int
    request: Request
    start: int
    length: int
    is_first: bool
    is_last: bool


@dataclass(frozen=True)
class DecodeBatch:
    """One decode step over the decode-ready slots."""
    slots: Tuple[int, ...]


@dataclass(frozen=True)
class Idle:
    """Nothing to do — sweep the clock forward."""


# --------------------------------------------------------------- scheduler

class Scheduler:
    """Slot admission + step planning over a fixed slot pool."""

    def __init__(self, cfg: SchedulerConfig):
        _check_policy(cfg.policy)
        self.cfg = cfg
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * cfg.max_batch
        # per-slot sampling keys: fold_in(PRNGKey(sampling.seed), request_id)
        self.slot_keys = np.zeros((cfg.max_batch, 2), np.uint32)
        # slot -> prompt tokens already prefilled (present = mid-prefill,
        # i.e. NOT decode-ready); insertion order = admission order
        self._progress: Dict[int, int] = {}
        self._last_was_prefill = False

    # ------------------------------------------------------------ control
    def set_policy(self, policy: str) -> None:
        _check_policy(policy)
        self.cfg.policy = policy

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def release(self, slot: int) -> None:
        """Free a slot whose request completed."""
        self.slots[slot] = None
        self._progress.pop(slot, None)

    # ------------------------------------------------------------ signals
    def decode_ready(self) -> List[int]:
        return [b for b, r in enumerate(self.slots)
                if r is not None and b not in self._progress]

    def pending_prefill_tokens(self) -> int:
        """Prompt tokens not yet prefilled (queued + mid-chunk backlog) —
        the autoscaler's prefill-pressure signal."""
        queued = sum(len(r.prompt) for r in self.queue)
        inflight = sum(len(self.slots[b].prompt) - done
                       for b, done in self._progress.items())
        return queued + inflight

    # ----------------------------------------------------------- planning
    def _admit(self) -> None:
        cap = self.cfg.batch_cap
        for b in range(len(self.slots)):
            if cap is not None and b >= cap:
                break
            if self.slots[b] is None and self.queue:
                req = self.queue.popleft()
                self.slots[b] = req
                self._progress[b] = 0
                self.slot_keys[b] = np.asarray(jax.random.fold_in(
                    jax.random.PRNGKey(req.sampling.seed), req.request_id))

    def _chunk_plan(self) -> PrefillChunk:
        b, done = next(iter(self._progress.items()))
        req = self.slots[b]
        total = len(req.prompt)
        chunk = self.cfg.prefill_chunk or total
        length = min(chunk, total - done)
        return PrefillChunk(slot=b, request=req, start=done, length=length,
                            is_first=(done == 0),
                            is_last=(done + length >= total))

    def next_plan(self):
        """Admit what fits, then pick the next step per the active policy."""
        self._admit()
        pending = bool(self._progress)
        ready = self.decode_ready()
        policy = self.cfg.policy
        if pending and ready:
            if policy == "prefill-priority":
                do_prefill = True
            elif policy == "fcfs":
                do_prefill = False
            else:                        # fair: strict alternation
                do_prefill = not self._last_was_prefill
        else:
            do_prefill = pending
        if do_prefill:
            self._last_was_prefill = True
            return self._chunk_plan()
        self._last_was_prefill = False
        if ready:
            return DecodeBatch(slots=tuple(ready))
        return Idle()

    def prefill_advanced(self, slot: int, length: int) -> bool:
        """Record chunk completion; True when the slot became decode-ready."""
        self._progress[slot] += length
        if self._progress[slot] >= len(self.slots[slot].prompt):
            del self._progress[slot]
            return True
        return False
