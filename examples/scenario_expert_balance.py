"""Live traffic-adaptive expert rebalancing tour (paper §4.5, Fig. 10).

One seeded Zipf(1.2)-skewed traffic trace replayed twice under the virtual
clock with imbalance-aware step costs:

* frozen placement — the initial uniform-load EPLB plan never moves; the
  two hot experts share one server each and max/mean server load pins at
  ~2x, stretching every decode step;
* live rebalancing — per-step router statistics feed the traffic EMA, the
  controller re-plans, and chunked expert-weight migrations interleave
  with decode steps until the hot experts are replicated pool-wide.

Both runs produce bitwise-identical greedy token streams — placement moves
*where* experts run, never *what* they compute.

Run:  PYTHONPATH=src python examples/scenario_expert_balance.py
Same seed ⇒ identical output, every run, on any machine.
"""

import dataclasses

from repro.configs import get_config
from repro.serving import (EngineConfig, Scenario, ServingEngine,
                           VirtualClock)

NUM_EXPERTS, NUM_SERVERS, MAX_BATCH = 16, 4, 8


def build_engine(cfg, live_rebalance: bool) -> ServingEngine:
    ecfg = EngineConfig(
        mode="eaas", num_servers=NUM_SERVERS, max_batch=MAX_BATCH,
        max_seq=64, n_redundant=2,
        pool_tokens_per_client=MAX_BATCH * NUM_SERVERS,  # drop-free dispatch
        charge_imbalance=True,
        rebalance_interval=0.02 if live_rebalance else 0.0)
    clock = VirtualClock(decode_base=2e-4, decode_per_token=2e-3,
                         expert_share=0.8)
    return ServingEngine(cfg, ecfg, seed=0, clock=clock)


def main():
    cfg = get_config("deepseek-r1").reduced()
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                              num_experts=NUM_EXPERTS))

    def scenario():
        return (Scenario(horizon=0.6, seed=7, prompt_len=8, max_new=24,
                         vocab=cfg.vocab_size)
                .poisson(rate=60)
                .zipf_skew(alpha=1.2, scale=1.0))

    results = {}
    for name, live in (("frozen placement", False), ("live rebalance", True)):
        eng = build_engine(cfg, live)
        res = scenario().run(eng)
        m = res.metrics
        results[name] = (m, {r.request_id: tuple(r.output_tokens)
                             for r in res.requests})
        print(f"== {name}")
        print(f"   decode throughput: {m.decode_throughput:7.1f} tok/s")
        print(f"   server imbalance (max/mean): {m.expert_imbalance:.3f} "
              f"(peak {m.peak_expert_imbalance:.3f})")
        print(f"   rebalances committed: {m.rebalances}  "
              f"expert weights migrated: {m.migrated_experts}  "
              f"migration time: {m.migration_time * 1e3:.1f}ms")
        for e in m.events:
            if e["event"] == "rebalance_plan":
                print(f"   t={e['t']:.3f}s  plan: {e['updates']} slot moves, "
                      f"imbalance {e['imbalance']:.2f} -> "
                      f"{e['planned_imbalance']:.2f}")
            elif e["event"] == "rebalance_commit":
                print(f"   t={e['t']:.3f}s  commit (converged="
                      f"{e['converged']})")

    (m_f, tok_f), (m_r, tok_r) = results.values()
    print(f"== rebalance speedup: "
          f"x{m_r.decode_throughput / m_f.decode_throughput:.3f}  "
          f"(token streams identical: {tok_f == tok_r})")


if __name__ == "__main__":
    main()
