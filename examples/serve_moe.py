"""End-to-end serving driver (the paper's deployment kind): serve a reduced
DeepSeek-R1-family MoE through the cluster front-end — N attention clients
sharing one expert tier — inject a hardware failure mid-run, rebalance hot
experts, and print throughput / inter-token-latency metrics.

Run:  PYTHONPATH=src python examples/serve_moe.py [--requests 16]
      PYTHONPATH=src python examples/serve_moe.py --clients 4 \
          --frontend-policy least_loaded     # the M:N attention:expert shape
      PYTHONPATH=src python examples/serve_moe.py --kv-mode paged \
          [--kv-blocks 13]    # paged KV; small pools exercise preemption
      PYTHONPATH=src python examples/serve_moe.py --clients 4 \
          --fail-client 1     # strand one client's work mid-run
      PYTHONPATH=src python examples/serve_moe.py --exec-mode async \
          --async-depth 4     # event-driven expert tier, depth-K waves
                              # (switches to the deterministic VirtualClock)
      PYTHONPATH=src python examples/serve_moe.py --clients 2 --elastic
                              # full-system elasticity: servers, clients and
                              # the resident expert set follow traffic
"""

import argparse

import numpy as np

from repro.configs import get_config
from repro.serving import (Cluster, ClusterConfig, EngineConfig, Request,
                           SamplingParams, VirtualClock)
from repro.serving.frontend import FRONTEND_POLICIES
from repro.training.data import ShareGPTLike


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--mode", default="eaas",
                    choices=["eaas", "monolithic_ep"])
    ap.add_argument("--clients", type=int, default=1,
                    help="attention clients sharing the expert tier")
    ap.add_argument("--frontend-policy", default="round_robin",
                    choices=list(FRONTEND_POLICIES),
                    help="request routing across clients")
    ap.add_argument("--fail-client", type=int, default=None,
                    help="kill this attention client mid-run (its in-flight "
                         "requests strand; everyone else keeps serving)")
    ap.add_argument("--kv-mode", default="dense", choices=["dense", "paged"],
                    help="paged = block-pool KV cache with prefix caching")
    ap.add_argument("--kv-blocks", type=int, default=None,
                    help="pool size in blocks (default: no memory pressure; "
                         "shrink to exercise admission gating + preemption)")
    ap.add_argument("--exec-mode", default="lockstep",
                    choices=["lockstep", "async"],
                    help="async = event-driven expert tier (per-expert "
                         "queue lanes, depth-K wave pipelining); runs under "
                         "the deterministic VirtualClock — token streams "
                         "are bitwise identical to lockstep")
    ap.add_argument("--async-depth", type=int, default=2,
                    help="decode waves in flight under --exec-mode async "
                         "(1 = lockstep cadence, 2 = ping-pong, K = deeper "
                         "speculative pipelining)")
    ap.add_argument("--elastic", action="store_true",
                    help="attach the full-system autoscaler: expert-server "
                         "count, attention-client count and scale-to-zero "
                         "expert paging all follow observed traffic (the "
                         "batch draining scales the system down under you; "
                         "token streams never change)")
    args = ap.parse_args()

    cfg = get_config("deepseek-r1").reduced()
    ecfg = EngineConfig(mode=args.mode, num_servers=4, max_batch=4,
                        max_seq=96, n_redundant=2,
                        kv_mode=args.kv_mode, kv_block_size=8,
                        kv_num_blocks=args.kv_blocks,
                        exec_mode=args.exec_mode,
                        async_depth=args.async_depth,
                        # paged prefill runs the chunk path; chunking also
                        # bounds decode gaps while long prompts admit
                        prefill_chunk=(8 if args.kv_mode == "paged" else 0))
    if args.exec_mode == "async" and args.kv_mode != "dense":
        ap.error("--exec-mode async supports --kv-mode dense only")
    # the async event timeline is defined against the deterministic
    # virtual cost model; lockstep keeps the wall clock (the seed default)
    clock_factory = VirtualClock if args.exec_mode == "async" else None
    cluster = Cluster(cfg, ClusterConfig(clients=args.clients,
                                         frontend_policy=args.frontend_policy,
                                         engine=ecfg,
                                         max_clients=args.clients), seed=0,
                      clock_factory=clock_factory)

    scaler = None
    if args.elastic:
        from repro.serving.autoscale import Autoscaler, AutoscalerConfig
        scaler = Autoscaler(AutoscalerConfig(
            rate_per_server=12.0, min_servers=1, max_servers=4,
            window=0.1, cooldown=0.1,
            rate_per_client=24.0, min_clients=1, max_clients=args.clients,
            expert_idle_fraction=0.5))

    # ShareGPT-like workload (bucketed prompt lengths bound prefill compiles)
    dist = ShareGPTLike(seed=0)
    plens, rlens = dist.sample(args.requests)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = int(np.clip(2 ** int(np.log2(max(plens[i] // 64, 1)) + 3), 8, 32))
        if scaler is not None:
            scaler.observe_arrival(cluster.clock)
        cluster.submit(Request(
            i, rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32),
            SamplingParams(max_new_tokens=int(min(rlens[i] // 32 + 8, 24)))))

    def chaos(c):
        if scaler is not None:
            scaler.step(c, c.clock)
        if c.step_idx == 12:
            print(f"[t={c.clock:.2f}s] *** injecting failure of expert "
                  f"server 1 (mode={args.mode}) ***")
            c.inject_server_failure(1)
        if c.step_idx == 30:
            print(f"[t={c.clock:.2f}s] server 1 recovers + EPLB rebalance")
            c.recover_server(1)
            c.rebalance()
        if args.fail_client is not None and c.step_idx == 40:
            print(f"[t={c.clock:.2f}s] *** attention client "
                  f"{args.fail_client} dies (in-flight work strands) ***")
            c.fail_client(args.fail_client)

    metrics = cluster.run(max_steps=4000, on_step=chaos)
    print("\n=== serving summary ===")
    for k, v in metrics.summary().items():
        print(f"  {k}: {v}")
    halted = sum(1 for c in cluster.clients
                 for t in c.metrics.timeline if t.get("halted"))
    print(f"  halted steps: {halted}")
    for i, eng in enumerate(cluster.clients):
        if eng.kv_pool is not None:
            print(f"  client {i} kv pool: {eng.kv_pool.usable_blocks} blocks"
                  f" x {eng.kv_pool.block_size} tokens, "
                  f"free fraction {eng.kv_pool.free_fraction():.2f}")
    expect = args.requests - metrics.failed_requests
    assert metrics.completed == expect, (metrics.completed, expect)


if __name__ == "__main__":
    main()
