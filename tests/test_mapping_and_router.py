"""Mapping lookup (service discovery + failover) and router invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install "
    "hypothesis); mapping liveness edge cases are also covered "
    "hypothesis-free in test_elastic_edges.py")
from hypothesis import given, settings, strategies as st

from repro.configs.base import MoEConfig
from repro.core import mapping as emap
from repro.core import router


def test_lookup_primary_only():
    table = emap.default_mapping(8, 4)          # e // 2
    alive = jnp.ones((4,), bool)
    eids = jnp.array([[0, 3], [5, 7]], jnp.int32)
    salt = jnp.zeros_like(eids)
    s = emap.lookup(jnp.asarray(table), alive, eids, salt)
    np.testing.assert_array_equal(np.asarray(s), [[0, 1], [2, 3]])


def test_lookup_failover_to_replica():
    smap = emap.ExpertServerMap(emap.default_mapping(8, 4), 4)
    smap.register_replica(0, 3)                 # expert 0 also on server 3
    table, alive = smap.device_arrays()
    eids = jnp.array([[0]], jnp.int32)
    salt = jnp.zeros_like(eids)
    assert int(emap.lookup(table, alive, eids, salt)[0, 0]) == 0
    smap.mark_dead(0)                           # primary dies
    table, alive = smap.device_arrays()
    assert int(emap.lookup(table, alive, eids, salt)[0, 0]) == 3


def test_lookup_spreads_over_replicas():
    smap = emap.ExpertServerMap(emap.default_mapping(4, 2), 2)
    smap.register_replica(0, 1)
    table, alive = smap.device_arrays()
    eids = jnp.zeros((16, 1), jnp.int32)
    salt = jnp.arange(16, dtype=jnp.int32)[:, None]
    s = np.asarray(emap.lookup(table, alive, eids, salt))[:, 0]
    assert set(s) == {0, 1}
    assert abs((s == 0).sum() - 8) <= 1          # ~uniform spread


@settings(max_examples=25, deadline=None)
@given(E=st.integers(2, 32), S=st.integers(1, 8), dead=st.integers(0, 3),
       seed=st.integers(0, 99))
def test_lookup_never_returns_dead(E, S, dead, seed):
    E = (E // S + 1) * S                         # divisible
    rng = np.random.default_rng(seed)
    smap = emap.ExpertServerMap(emap.default_mapping(E, S), S)
    for e in rng.integers(0, E, size=8):
        s = int(rng.integers(0, S))
        row = smap.table[e]
        if s not in row[row >= 0] and (row < 0).any():
            smap.register_replica(int(e), s)
    kill = rng.choice(S, size=min(dead, S - 1), replace=False)
    for s in kill:
        smap.mark_dead(int(s))
    table, alive = smap.device_arrays()
    eids = jnp.asarray(rng.integers(0, E, size=(20, 2)), jnp.int32)
    salt = jnp.asarray(rng.integers(0, 1000, size=(20, 2)), jnp.int32)
    out = np.asarray(emap.lookup(table, alive, eids, salt))
    counts = smap.alive_replica_count()
    for (e, s) in zip(np.asarray(eids).reshape(-1), out.reshape(-1)):
        if counts[e] > 0:
            assert smap.alive[s], (e, s)


# ----------------------------------------------------------------- router

@pytest.mark.parametrize("score_fn", ["softmax", "sigmoid"])
def test_router_topk(score_fn, rng):
    cfg = MoEConfig(num_experts=16, top_k=4, d_expert=8,
                    router_score_fn=score_fn)
    params = router.init_router(jax.random.PRNGKey(0), 32, 16)
    x = jnp.asarray(rng.normal(size=(10, 32)), jnp.float32)
    out = router.route(params, x, cfg)
    assert out.expert_ids.shape == (10, 4)
    assert out.scores.shape == (10, 4)
    # normalized scores sum to 1
    np.testing.assert_allclose(np.asarray(out.scores).sum(-1), 1.0,
                               rtol=1e-5)
    # ids are unique per token and within range
    ids = np.asarray(out.expert_ids)
    assert (ids >= 0).all() and (ids < 16).all()
    for row in ids:
        assert len(set(row)) == len(row)
    # selected experts have the highest probs
    probs = np.asarray(out.full_probs)
    for t in range(10):
        thresh = probs[t, ids[t]].min()
        assert (probs[t] <= thresh + 1e-6).sum() >= 16 - 4


def test_router_load_stat(rng):
    ids = jnp.asarray(rng.integers(0, 8, size=(100, 2)), jnp.int32)
    load = router.expert_load(ids, 8)
    assert int(load.sum()) == 200
