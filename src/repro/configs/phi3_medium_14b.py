"""phi3-medium-14b — Microsoft Phi-3 Medium.

[arXiv:2404.14219; unverified]  dense, RoPE + SwiGLU + GQA kv=10.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="phi3-medium-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=10,
    d_ff=17920,
    vocab_size=100352,
    d_head=128,
    rope_theta=10000.0,
    activation="swiglu",
    subquadratic=False,
    source="arXiv:2404.14219",
)
