"""SPMD numerical equivalence, run in subprocesses with 8 forced host
devices (the main pytest process must keep the real single-device view).

Checks:
* the EAAS MoE shard_map island (a2a mode) == the local single-device layer;
* the replicated decode mode == local;
* sequence-parallel decode attention == single-device decode attention.
"""

import os
import subprocess
import sys
import textwrap


REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = REPO_SRC
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, f"STDOUT:{out.stdout}\nSTDERR:{out.stderr}"
    return out.stdout


COMMON = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.configs import get_config
from repro.core.moe_layer import default_runtime
from repro.models.transformer import ParallelCtx, build_model, _moe_apply
mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
cfg = get_config("kimi-k2-1t-a32b").reduced()
model = build_model(cfg, num_servers=4)
params = model.init_params(jax.random.PRNGKey(0))
moe_p = jax.tree.map(lambda x: x, params["blocks"]["moe"])
layer0 = jax.tree.map(lambda x: x[0], moe_p)     # one layer's MoE params
T = 64
x = jax.random.normal(jax.random.PRNGKey(1), (T, cfg.d_model), jnp.float32) * 0.3
rt = default_runtime(cfg, 4, T)._replace(capacity=T * cfg.moe.top_k,
                                         gemm_impl="xla_ragged")
ctx_local = ParallelCtx(moe_runtime=rt, remat=False)
y_local, st_local = _moe_apply(layer0, x, cfg, ctx_local)
"""


def test_moe_island_a2a_matches_local():
    out = _run(COMMON + """
ctx = ParallelCtx(mesh=mesh, axis_data=("data",), moe_runtime=rt,
                  moe_mode="a2a", remat=False)
y, st = jax.jit(lambda p, xx: _moe_apply(p, xx, cfg, ctx))(layer0, x)
err = float(jnp.max(jnp.abs(y - y_local)))
assert err < 2e-4, err
assert int(st.miss) == 0
assert int(st.dropped) == 0
print("A2A OK", err)
""")
    assert "A2A OK" in out


def test_moe_island_replicated_matches_local():
    out = _run(COMMON + """
ctx = ParallelCtx(mesh=mesh, axis_data=("data",), moe_runtime=rt,
                  moe_mode="replicated", remat=False)
y, st = jax.jit(lambda p, xx: _moe_apply(p, xx, cfg, ctx))(layer0, x)
err = float(jnp.max(jnp.abs(y - y_local)))
assert err < 2e-4, err
assert int(st.miss) == 0
print("REPL OK", err)
""")
    assert "REPL OK" in out


def test_moe_island_failover_under_spmd():
    """Kill a server ON THE MESH: output only changes by dropped experts'
    share when no replicas exist; with replicas it is identical."""
    out = _run(COMMON + """
import numpy as _np
from repro.core import load_balance, expert_server
E, S = cfg.moe.num_experts, 4
mapping, red = load_balance.eplb_plan(_np.ones(E), S, n_redundant=E // S,
                                      max_replicas=2)
local = expert_server.make_local_table(E, S, red)
per = E // S
bank = {k: layer0["servers"][k][:, :per].reshape(E, *layer0["servers"][k].shape[2:])
        for k in ("w_gate", "w_up", "w_down")}
layer0["servers"].update(expert_server.build_server_weights(bank, S, red))
rt2 = rt._replace(mapping=jnp.asarray(mapping), local_table=jnp.asarray(local))
ctx = ParallelCtx(mesh=mesh, axis_data=("data",), moe_runtime=rt2,
                  moe_mode="a2a", remat=False)
y_ok, st_ok = jax.jit(lambda p, xx: _moe_apply(p, xx, cfg, ctx))(layer0, x)
rt3 = rt2._replace(alive=rt2.alive.at[1].set(False))
ctx3 = ParallelCtx(mesh=mesh, axis_data=("data",), moe_runtime=rt3,
                   moe_mode="a2a", remat=False)
y_dead, st_dead = jax.jit(lambda p, xx: _moe_apply(p, xx, cfg, ctx3))(layer0, x)
assert int(st_dead.miss) == 0
err = float(jnp.max(jnp.abs(y_ok - y_dead)))
assert err < 2e-4, err
print("FAILOVER OK", err)
""")
    assert "FAILOVER OK" in out


def test_sp_decode_attention_matches_local():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import attention as attn, kv_cache as kvc
from repro.models.transformer import ParallelCtx, _sp_decode_attention
mesh = jax.make_mesh((8,), ("data",),
                     axis_types=(jax.sharding.AxisType.Auto,))
cfg = get_config("granite-3-2b").reduced()
p = attn.init_attention(jax.random.PRNGKey(0), cfg)
B, SLOTS = 1, 64
cache = kvc.init_kv_cache(B, SLOTS, cfg.num_kv_heads, cfg.head_dim,
                          jnp.float32)
# fill 37 tokens
ks = jax.random.normal(jax.random.PRNGKey(1), (B, 37, cfg.num_kv_heads,
                                               cfg.head_dim), jnp.float32)
cache = kvc.write_prefill(cache, ks, ks * 0.5)
x = jax.random.normal(jax.random.PRNGKey(2), (B, 1, cfg.d_model),
                      jnp.float32) * 0.3
y_ref, cache_ref = attn.decode_attention(p, cfg, x, cache)
ctx = ParallelCtx(mesh=mesh, axis_data=("data",), seq_shard_cache=True)
y_sp, cache_sp = jax.jit(lambda pp, xx, cc: _sp_decode_attention(
    pp, cfg, xx, cc, ctx))(p, x, cache)
err = float(jnp.max(jnp.abs(y_sp - y_ref)))
assert err < 2e-4, err
kerr = float(jnp.max(jnp.abs(cache_sp.k - cache_ref.k)))
assert kerr < 1e-5, kerr
print("SP OK", err)
""")
    assert "SP OK" in out
