"""Continuous-batching serving engine — the orchestration layer.

The engine is split in three (the scheduler/executor refactor):

* :class:`~repro.serving.scheduler.Scheduler` — admission, slot
  assignment, chunked prefill and the step policy (what runs next:
  a prefill chunk, a decode step, or idle);
* :class:`~repro.serving.executor.Executor` — params, KV caches and the
  jitted step variants (whole-prompt prefill, chunked-prefill
  continuation, and lockstep / pipelined / serialized decode);
* :class:`ServingEngine` (this module) — wires scheduler → executor →
  metrics around the pluggable :class:`~repro.serving.clock.Clock`, and
  keeps the control plane: failover, rebalancing, elastic ``scale_to``.

One engine class still serves the three system modes (paper §5 baselines):

* ``mode="eaas"``        — EAAS: replicated experts, liveness-masked mapping;
  a server failure re-routes traffic to replicas within the same step
  (throughput dips only by the lost compute share — paper Fig. 10).
* ``mode="monolithic_ep"`` — DeepEP-style: primary-only mapping; a server
  failure halts the WHOLE engine for ``restart_steps`` (the collective-group
  restart) before resuming.
* ``mode="tp"``          — tensor-parallel MoE: failure halts only the
  16-GPU unit (modeled as a shorter stall) but per-unit weight replication
  caps the max batch (``tp_batch_cap``).

The expert→server mapping, liveness mask and local placement table are
**jit arguments**, not compiled constants — failover and rebalancing never
trigger recompilation (the paper's no-group-rebuild property).

Decode can run as two pipelined microbatches (``decode_mode="pipelined"``,
paper §4.2): the expert round-trip of microbatch A overlaps the attention
of microbatch B.  Outputs are bit-identical to the lockstep engine — only
the step cost changes (the overlap-aware
:class:`~repro.serving.clock.VirtualClock` charges ``max(attn, expert)+ε``
instead of the sum; ``decode_mode="serialized"`` is the exposed-collective
ablation).  Chunked prefill (``prefill_chunk=N`` with ``policy="fair"``)
bounds decode gaps to one chunk instead of one prompt.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.elastic import ServerPool
from repro.core.monitor import Monitor
from repro.models.transformer import build_model
from repro.serving.clock import Clock, WallClock
from repro.serving.executor import Executor
from repro.serving.kv_pool import BlockPool
from repro.serving.metrics import ServingMetrics
from repro.serving.rebalance import (RebalanceConfig, RebalanceController,
                                     oneshot_rebalance)
from repro.serving.request import Request
from repro.serving.sampling import sample, sample_batch
from repro.serving.scheduler import (DecodeBatch, PrefillChunk, Scheduler,
                                     SchedulerConfig)


@dataclass
class EngineConfig:
    max_batch: int = 8
    max_seq: int = 256
    mode: str = "eaas"                 # eaas | monolithic_ep | tp
    num_servers: int = 4
    n_redundant: int = 2
    restart_steps: int = 50            # monolithic group restart cost
    tp_restart_steps: int = 12         # one TP unit restart
    tp_batch_cap: Optional[int] = None # TP: weight replication caps batch
    gemm_impl: str = "xla_ragged"
    eos_token: Optional[int] = None
    # --- scheduler knobs -------------------------------------------------
    # max prompt tokens per prefill step (0 = whole prompt, the pre-split
    # behaviour); needs a model family with prefill_chunk support,
    # silently unchunked otherwise
    prefill_chunk: int = 0
    policy: str = "prefill-priority"   # prefill-priority | fair | fcfs
    # --- executor knobs --------------------------------------------------
    # lockstep (pre-split single-batch step) | pipelined (two-microbatch
    # client pipelining, §4.2) | serialized (the ablation: same split,
    # collectives exposed)
    decode_mode: str = "lockstep"
    # dispatch-buffer sizing override (tokens per client step); default is
    # max_batch, the seed behaviour — raise it when prefill chunks carry
    # more tokens than a decode batch so fixed-capacity buffers don't drop
    pool_tokens_per_client: Optional[int] = None
    # --- KV-cache knobs --------------------------------------------------
    # dense (per-slot (batch, max_seq) buffers, the seed behaviour) | paged
    # (shared block pool + per-request block tables, prefix caching,
    # memory-aware admission and preemption)
    kv_mode: str = "dense"
    kv_block_size: int = 16
    # pool size in blocks; default sizes the pool so every slot can reach
    # max_seq (no memory pressure) — shrink it to oversubscribe.  Must hold
    # at least one maximal request (max_seq/kv_block_size blocks + scratch)
    # or preemption could not keep the engine live.
    kv_num_blocks: Optional[int] = None
    kv_prefix_cache: bool = True
    # --- live rebalancing knobs ------------------------------------------
    # seconds between live replan evaluations (0 = off, the seed behaviour:
    # placement only changes through explicit rebalance()/scale_to() calls)
    rebalance_interval: float = 0.0
    # expert-weight copies migrated per engine step once a replan is staged
    rebalance_chunk: int = 2
    # relative imbalance improvement required before migrating (hysteresis)
    rebalance_min_gain: float = 0.05
    # post-placement-change quiet period, shared with the autoscaler
    rebalance_cooldown: float = 0.05
    # charge decode steps for hot-expert skew: the expert share of the
    # virtual step cost stretches by the pool's max/mean alive-server load
    # (a lockstep expert phase finishes with its hottest server).  Off by
    # default — existing virtual timelines stay bit-identical.
    charge_imbalance: bool = False
    # relative per-server capacity weights ((num_servers,) or None)
    server_capacities: Optional[np.ndarray] = None
    # feed chunked-prefill router traffic into the expert-load EMA (decode
    # steps always feed it); prompt-heavy workloads then trigger rebalances
    # from prefill pressure, not only after decoding starts
    prefill_load_feedback: bool = True


class ServingEngine:
    """Scheduler → executor → metrics orchestrator with EAAS failover.

    Standalone this is one complete serving system; under a
    :class:`~repro.serving.cluster.Cluster` it is one *attention client* of
    N — the cluster injects the shared expert-tier ``pool`` (usually a
    per-client :class:`~repro.core.elastic.PoolClient` mapping view) and
    owns the placement control plane (rebalance / scale), while each client
    keeps its own scheduler, executor, KV pool and clock.
    """

    def __init__(self, cfg: ModelConfig, engine_cfg: EngineConfig,
                 params=None, seed: int = 0, clock: Optional[Clock] = None,
                 pool=None, client_id: int = 0):
        self.cfg = cfg
        self.ecfg = engine_cfg
        self.client_id = client_id
        self.clk = clock if clock is not None else WallClock()
        S = engine_cfg.num_servers if engine_cfg.mode != "tp" else 1
        # pool injected = cluster member: the expert tier is shared, its
        # placement is the cluster's to change (scale_to/rebalance here
        # would desync the sibling clients' executors)
        self._shared_pool = pool is not None
        if self._shared_pool:
            if not cfg.moe:
                raise ValueError("shared expert pool needs an MoE config")
            if engine_cfg.mode == "tp":
                raise ValueError("tp mode replicates expert weights per "
                                 "unit — it has no shared expert tier")
            self.pool = pool
            S = pool.num_servers
        elif cfg.moe:
            self.pool = ServerPool(
                cfg, S,
                tokens_per_client=(engine_cfg.pool_tokens_per_client
                                   or engine_cfg.max_batch),
                n_redundant=(engine_cfg.n_redundant
                             if engine_cfg.mode == "eaas" else 0),
                capacities=engine_cfg.server_capacities)
        else:
            self.pool = None
        self.model = build_model(
            cfg, num_servers=S if cfg.moe else 1,
            redundant_table=self.pool.redundant_table if self.pool else None)
        key = jax.random.PRNGKey(seed)
        params = params if params is not None else \
            self.model.init_params(key)
        self.monitor = Monitor(heartbeat_timeout=3.0)
        if self.pool:
            self.monitor.subscribe_server_down(self.pool.server_failed)

        self.kv_pool: Optional[BlockPool] = None
        if engine_cfg.kv_mode == "paged":
            bs = engine_cfg.kv_block_size
            if engine_cfg.max_seq % bs:
                raise ValueError(f"max_seq={engine_cfg.max_seq} must be a "
                                 f"multiple of kv_block_size={bs}")
            per_seq = engine_cfg.max_seq // bs
            nb = (engine_cfg.kv_num_blocks
                  if engine_cfg.kv_num_blocks is not None
                  else engine_cfg.max_batch * per_seq + 1)
            if nb - 1 < per_seq:
                raise ValueError(
                    f"kv_num_blocks={nb} cannot hold one maximal request "
                    f"({per_seq} blocks + 1 scratch) — preemption could "
                    "not keep the engine live")
            self.kv_pool = BlockPool(
                nb, bs, enable_prefix_cache=engine_cfg.kv_prefix_cache)
        self.executor = Executor(
            self.model, params, self.pool,
            max_batch=engine_cfg.max_batch, max_seq=engine_cfg.max_seq,
            gemm_impl=engine_cfg.gemm_impl,
            decode_mode=engine_cfg.decode_mode,
            kv_mode=engine_cfg.kv_mode,
            kv_block_size=engine_cfg.kv_block_size,
            kv_num_blocks=(self.kv_pool.num_blocks if self.kv_pool else 0))
        chunk = (engine_cfg.prefill_chunk
                 if self.executor.supports_chunked_prefill else 0)
        self.scheduler = Scheduler(SchedulerConfig(
            max_batch=engine_cfg.max_batch, prefill_chunk=chunk,
            policy=engine_cfg.policy,
            batch_cap=(engine_cfg.tp_batch_cap
                       if engine_cfg.mode == "tp" else None),
            max_seq=engine_cfg.max_seq), kv_pool=self.kv_pool)

        self.metrics = ServingMetrics()
        self.step_idx = 0
        self.clock = 0.0
        self.halted_until = -1
        self._last_decode_time = 0.01
        # attention clients currently sharing the expert tier (the cluster
        # sets this before each member step; 1.0 = standalone engine, and
        # the virtual cost model is bit-identical to the pre-cluster one)
        self.expert_contention = 1.0
        # compute/surface the pool imbalance gauge each decode step; set
        # below for a local controller, and by the Cluster on its member
        # clients when the CLUSTER-level controller is active
        self.track_imbalance = False
        # shared placement cooldown (rebalance commits + elastic scaling)
        self.last_placement_change = float("-inf")
        self.rebalancer: Optional[RebalanceController] = None
        if (engine_cfg.rebalance_interval > 0 and self.pool is not None
                and not self._shared_pool
                and engine_cfg.mode == "eaas"):
            self.rebalancer = RebalanceController(RebalanceConfig(
                interval=engine_cfg.rebalance_interval,
                chunk=engine_cfg.rebalance_chunk,
                min_gain=engine_cfg.rebalance_min_gain,
                cooldown=engine_cfg.rebalance_cooldown))
        self.track_imbalance = self.rebalancer is not None

    # ------------------------------------------------- back-compat surface
    @property
    def queue(self):
        return self.scheduler.queue

    @property
    def slots(self):
        return self.scheduler.slots

    @property
    def params(self):
        return self.executor.params

    @property
    def cache(self):
        return self.executor.cache

    # ------------------------------------------------------------ helpers
    def _alive_frac(self) -> float:
        """Alive share of the expert-server pool (EAAS failover slowdown)."""
        if self.pool is None or self.ecfg.mode != "eaas":
            return 1.0
        return float(self.pool.smap.alive.mean())

    def _pool_size(self) -> int:
        return self.pool.num_servers if self.pool else 1

    # --------------------------------------------------- front-end signals
    def pending_prefill_tokens(self) -> int:
        """Unprefilled prompt tokens (queued + mid-chunk) — the autoscaler
        and the least-loaded front-end policy read this."""
        return self.scheduler.pending_prefill_tokens()

    def kv_free_fraction(self) -> float:
        return self.scheduler.kv_free_fraction()

    def free_kv_tokens(self) -> int:
        """Token capacity this client can still admit into: free pool
        blocks (paged) or free slots × max_seq (dense) — the memory half of
        the least-loaded routing score."""
        if self.kv_pool is not None:
            return self.kv_pool.available() * self.kv_pool.block_size
        free_slots = sum(1 for s in self.slots if s is None)
        return free_slots * self.ecfg.max_seq

    def abort_inflight(self) -> list:
        """Drop every queued and in-flight request (client failure): slots
        and KV blocks are released, nothing is re-queued.  Returns the
        stranded requests — the cluster counts them as failed.  The expert
        tier is untouched; sibling clients keep serving."""
        stranded = list(self.scheduler.queue)
        self.scheduler.queue.clear()
        for b, r in enumerate(self.scheduler.slots):
            if r is not None:
                stranded.append(r)
                self.scheduler.release(b)
        self.executor._staging.clear()
        return stranded

    # ------------------------------------------------------------- control
    def submit(self, req: Request) -> None:
        self.scheduler.submit(req)
        self.metrics.total_requests += 1

    def set_policy(self, policy: str) -> None:
        """Switch the scheduler policy mid-run (scenario ``set_policy``)."""
        self.scheduler.set_policy(policy)
        self.metrics.events.append(
            {"t": self.clock, "event": "set_policy", "policy": policy})

    def inject_server_failure(self, rank: int) -> None:
        """Simulated hardware failure of one expert server (paper §5.4)."""
        self.metrics.events.append(
            {"t": self.clock, "event": "server_fail", "rank": rank,
             "mode": self.ecfg.mode})
        if self.ecfg.mode == "eaas":
            if self.pool and rank < self.pool.num_servers:
                self.pool.server_failed(rank)     # mapping mask update only
        elif self.ecfg.mode == "monolithic_ep":
            self.halted_until = self.step_idx + self.ecfg.restart_steps
        elif self.ecfg.mode == "tp":
            self.halted_until = self.step_idx + self.ecfg.tp_restart_steps

    def recover_server(self, rank: int) -> None:
        self.metrics.events.append(
            {"t": self.clock, "event": "server_recover", "rank": rank})
        if self.pool and rank < self.pool.num_servers:
            self.pool.server_recovered(rank)

    def apply_migration(self, copies) -> None:
        """Apply one expert-weight migration chunk to this engine's
        executor.  A :class:`~repro.serving.cluster.Cluster` overrides the
        *host* side of this call to fan the same copies out to every
        client's executor — replica weights never diverge across clients."""
        self.executor.migrate_slots(copies)

    def charge_migration(self, dt: float) -> None:
        """Advance the engine clock by a migration chunk's cost.  The
        cluster version charges every client — the shared expert tier is
        busy copying weights, so everyone's next expert phase waits."""
        self.clock += dt

    def rebalance(self) -> None:
        """One-shot EPLB replica re-planning from live traffic (paper
        §4.5) — the scripted/manual path.  Placement-identical plans are
        skipped via ``plan_digest`` (nothing rebuilt); a changed plan
        migrates the replica weights *and* the mapping in one step (the
        weight copies charged as one big ``migrate`` step), so weights and
        local table never disagree.  The live ``rebalance_interval``
        controller spreads the same work over chunked migration steps
        interleaved with decoding instead.
        """
        if self.pool is None:
            return
        if self._shared_pool:
            raise RuntimeError(
                "this engine is a cluster client over a shared expert "
                "tier — call Cluster.rebalance() so every client's "
                "executor migrates in lockstep")
        if self.rebalancer is not None:
            self.rebalancer.abort()      # the one-shot replan supersedes it
        oneshot_rebalance(self)

    def set_skew(self, bias: np.ndarray) -> None:
        """Install a router-logit bias (scenario ``set_skew`` traffic
        shaping).  Pure runtime data — the next jitted step routes under
        the new bias without recompiling."""
        if self.pool is None:
            return
        self.pool.set_route_bias(bias)
        bias = np.asarray(bias, np.float64)
        self.metrics.events.append(
            {"t": self.clock, "event": "set_skew",
             "spread": round(float(bias.max() - bias.min()), 6)})

    def scale_to(self, n: int) -> None:
        """Elastically resize the expert-server pool to ``n`` servers.

        The pool re-plans its EPLB mapping (liveness preserved), the
        executor re-shards the expert weights from the recovered global bank
        and rebuilds its jitted variants for the new static server count
        (the AOT-per-server-count story).  In-flight requests keep their KV
        cache — scaling never drops work (paper §5.3).
        """
        if self.pool is None or n == self.pool.num_servers:
            return
        if self._shared_pool:
            raise RuntimeError(
                "this engine is a cluster client over a shared expert "
                "tier — call Cluster.scale_to() so every client's "
                "executor re-shards in lockstep")
        old = self.pool.num_servers
        if self.rebalancer is not None:
            self.rebalancer.abort()      # a resize replans placement anyway
        self.pool.scale_to(n)
        self.executor.resize(self.pool)
        self.last_placement_change = self.clock
        self.metrics.events.append(
            {"t": self.clock, "event": "scale", "from": old, "to": n})

    # ---------------------------------------------------------------- step
    def step(self) -> None:
        """One engine iteration: run whatever the scheduler plans next —
        a prefill chunk, a decode step over the ready slots, or idle."""
        self.step_idx += 1
        if self.step_idx <= self.halted_until:
            # monolithic restart: time passes, no tokens are produced
            self.clock += self._last_decode_time
            self.metrics.timeline.append(
                {"t": self.clock, "tokens": 0, "halted": True})
            return
        plan = self.scheduler.next_plan()
        if isinstance(plan, PrefillChunk):
            self._step_prefill(plan)
        elif isinstance(plan, DecodeBatch):
            self._step_decode(plan)
        else:
            self.clock += self.clk.idle()
        if self.rebalancer is not None:
            # migration chunks interleave with decode steps — serving
            # never pauses for a replan (paper §4.5 live adaptation)
            self.rebalancer.step(self)
        if self.kv_pool is not None:
            self.metrics.observe_kv(self.kv_pool,
                                    self.scheduler.preemptions)

    def _step_prefill(self, plan: PrefillChunk) -> None:
        req, b = plan.request, plan.slot
        chunk = (plan.tokens if plan.tokens is not None
                 else req.prompt[plan.start:plan.start + plan.length])
        self.clk.start()
        expert_load = None
        if self.kv_pool is not None:
            # paged: every prefill runs the chunk path against the block
            # pool (prefix hits start mid-prompt; the virtual clock is
            # charged only the uncached tokens in ``plan.length``)
            self.executor.copy_blocks(plan.copies)     # pending COW forks
            logits, expert_load = self.executor.prefill_chunk_paged(
                chunk, plan.start, self.scheduler.block_tables[b])
        elif plan.is_first and plan.is_last:
            # whole prompt in one step — the pre-split prefill path
            logits = self.executor.prefill(b, chunk)
        else:
            logits, expert_load = self.executor.prefill_chunk(
                b, chunk, plan.start,
                is_first=plan.is_first, is_last=plan.is_last)
        if (expert_load is not None and self.pool is not None
                and self.ecfg.prefill_load_feedback):
            # chunked-prefill router traffic feeds the same EMA decode
            # feeds — prompt-heavy workloads rebalance from prompt traffic
            self.pool.observe_load(np.asarray(expert_load))
        self.clock += self.clk.stop("prefill", result=logits,
                                    tokens=plan.length,
                                    servers=self._pool_size(),
                                    alive_frac=self._alive_frac())
        self.scheduler.prefill_advanced(b, plan.length)
        if plan.is_last and not req.output_tokens:
            # same per-slot key the decode path uses (stored at admission),
            # folded with token index 0 — one key-derivation site.  A
            # *resumed* (preempted) request already holds its next input
            # token, so recompute prefills skip sampling and TTFT.
            key = jnp.asarray(self.scheduler.slot_keys[b])
            first = int(sample(logits, req.sampling.temperature,
                               jax.random.fold_in(key, 0))[0])
            req.output_tokens.append(first)
            req.prefill_time = self.clock
            self.metrics.ttfts.append(self.clock - req.arrival_time)
            self.metrics.events.append(
                {"t": self.clock, "event": "prefill", "rid": req.request_id,
                 "ttft": self.clock - req.arrival_time})

    def _step_decode(self, plan: DecodeBatch) -> None:
        sch = self.scheduler
        B = len(sch.slots)
        active = list(plan.slots)
        tokens = np.zeros((B, 1), np.int32)
        temps = np.zeros(B, np.float32)
        steps = np.zeros(B, np.int32)
        for b in active:
            r = sch.slots[b]
            tokens[b, 0] = r.output_tokens[-1]
            temps[b] = r.sampling.temperature
            steps[b] = len(r.output_tokens)
        self.clk.start()
        if self.kv_pool is not None:
            logits, expert_load = self.executor.decode_paged(
                tokens, self.scheduler.block_tables,
                self.scheduler.cache_lengths())
        else:
            logits, expert_load = self.executor.decode(tokens)
        imbalance = 1.0
        if self.pool is not None:
            # fold this step's router traffic into the EMA first, so the
            # imbalance charged (and surfaced) reflects current traffic;
            # the gauge itself is only computed when something consumes it
            # (cost model or controller) — it walks the mapping in Python
            self.pool.observe_load(np.asarray(expert_load))
            if self.ecfg.charge_imbalance or self.track_imbalance:
                imbalance = self.pool.current_imbalance()
                self.metrics.observe_balance(imbalance)
        dt = self.clk.stop("decode", result=logits, tokens=len(active),
                           servers=self._pool_size(),
                           alive_frac=self._alive_frac(),
                           overlap=(self.ecfg.decode_mode == "pipelined"),
                           imbalance=(imbalance
                                      if self.ecfg.charge_imbalance
                                      else 1.0),
                           contention=self.expert_contention)
        self._last_decode_time = dt
        self.clock += dt
        next_tokens = np.asarray(sample_batch(logits, temps,
                                              sch.slot_keys, steps))

        produced = 0
        for b in active:
            r = sch.slots[b]
            tok = int(next_tokens[b])
            r.output_tokens.append(tok)
            r.token_times.append(self.clock)
            produced += 1
            self.metrics.total_output_tokens += 1
            done = (len(r.output_tokens) >= r.sampling.max_new_tokens or
                    (self.ecfg.eos_token is not None and
                     tok == self.ecfg.eos_token) or
                    len(r.prompt) + len(r.output_tokens) >=
                    self.ecfg.max_seq - 1)
            if done:
                r.finish_time = self.clock
                self.metrics.completed += 1
                self.metrics.itls.extend(r.itl())
                sch.release(b)
        self.metrics.timeline.append(
            {"t": self.clock, "tokens": produced, "halted": False})

    def run(self, max_steps: int = 10_000,
            on_step: Optional[Callable[["ServingEngine"], None]] = None
            ) -> ServingMetrics:
        """Drive until queue + slots drain (or max_steps)."""
        while (self.queue or any(s is not None for s in self.slots)) \
                and self.step_idx < max_steps:
            if on_step:
                on_step(self)
            self.step()
        self.metrics.wall_time = self.clock
        return self.metrics
