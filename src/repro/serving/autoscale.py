"""Elastic autoscaling control loop (paper §5.3, Fig. 11).

EAAS scales the expert-service tier one server at a time; monolithic EP only
in whole communication-group multiples.  The :class:`Autoscaler` watches the
arrival rate (sliding window over submitted requests) plus queue depth and
drives ``engine.scale_to`` toward the :func:`repro.core.elastic.provision`
target at its configured granularity — the 37.5% saving in the paper is
exactly the gap between granularity 1 and granularity 64 under a traffic
drop.

Full-system elasticity extends the same loop to both tiers:

* **attention tier** — with ``rate_per_client > 0`` the client count
  becomes a controller output too: against a :class:`~repro.serving.
  cluster.Cluster` the loop drives ``scale_clients`` (spawn = join empty
  at cluster time, drain = stop admitting / finish in-flight waves /
  park), with ingress backlog as the backpressure term;
* **scale-to-zero experts** — with ``expert_idle_fraction > 0`` experts
  whose traffic-EMA share decays below the threshold page out of the tier
  entirely (``engine.page_out_experts``); the first token routed back to
  one pays the clock's ``cold_start_base`` and the ``page_in_protect``
  hysteresis window keeps a freshly paged-in expert resident, so bursty
  traffic never flaps an expert in and out.

The three sub-controllers fire at most ONE action per control step and all
share the engine's ``last_placement_change`` cooldown — server resizes,
client churn, expert paging and live migrations never overlap.

The loop is pure host-side policy over engine observables: deterministic
under a virtual clock, and trivially swappable (subclass and override
:meth:`desired_servers` / :meth:`desired_clients`).
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, List, Optional, Tuple

import numpy as np

from repro.core.elastic import provision


@dataclass
class AutoscalerConfig:
    rate_per_server: float            # request/s one expert server sustains
    min_servers: int = 1
    max_servers: int = 8
    granularity: int = 1              # 1 = EAAS; group size = monolithic EP
    window: float = 0.25              # arrival-rate estimation window (s)
    cooldown: float = 0.2             # min time between scaling actions (s)
    # scale-DOWN deadband (hysteresis): only shrink a tier when the
    # observed rate fits the smaller capacity with this much headroom to
    # spare (rate <= down_headroom * target * rate_per_unit).  Scale-up
    # stays immediate.  Without it, Poisson arrival noise around a
    # capacity boundary flaps the size A-B-A every cooldown.
    down_headroom: float = 0.9
    queue_per_server: float = 0.0     # extra server per this much queue
                                      # backlog (0 disables queue pressure)
    # extra server per this many *unprefilled prompt tokens* (queued +
    # mid-chunk backlog) — with chunked prefill a deep prompt backlog is
    # visible before it converts into queue depth (0 disables)
    prefill_tokens_per_server: float = 0.0
    # scale up while the KV block pool's free fraction sits below this
    # threshold (0 disables).  Memory pressure precedes admission stalls:
    # the pool drains *before* the queue backs up, so this knob fires a
    # step earlier than queue/backlog pressure — the paper's point that
    # attention-tier memory, not expert FLOPs, caps admitted traffic.
    kv_pressure_threshold: float = 0.0
    # --- attention-tier autoscaling (0 disables: servers only) -----------
    rate_per_client: float = 0.0      # request/s one attention client takes
    min_clients: int = 1
    max_clients: int = 8
    # extra client per this many requests parked in the cluster INGRESS
    # queue (per-client backpressure pushed them back there) — the
    # spawn-under-backpressure term (0 disables)
    ingress_per_client: float = 0.0
    # --- scale-to-zero experts (0 disables paging) -----------------------
    # page out an expert whose traffic-EMA *share* sits below this fraction
    # of the uniform share 1/E (e.g. 0.5 = pages experts drawing less than
    # half their fair share); its first routed token pages it back in at
    # the clock's cold_start_base penalty
    expert_idle_fraction: float = 0.0
    # hysteresis: an expert paged in less than this long ago never pages
    # back out — with the EMA bump its own page-in traffic causes, this is
    # what keeps a bursty expert from flapping in and out of the tier
    page_in_protect: float = 0.5
    # never page the resident set below this share of all experts
    min_resident_fraction: float = 0.25


class Autoscaler:
    """Traffic-driven pool resizing: observe arrivals, converge on
    ``provision(rate)`` snapped to a feasible pool size; optionally also
    steer the attention-client count and the resident expert set (see the
    module docstring — one action per step, one shared cooldown)."""

    def __init__(self, cfg: AutoscalerConfig):
        self.cfg = cfg
        # scenario `set_elastic` verb: False freezes every controller
        # (servers, clients, expert paging) without detaching the trace
        self.enabled = True
        self._arrivals: Deque[float] = deque()
        self._last_action = -float("inf")
        # (t, observed rate, desired, actual) decision trace
        self.trace: List[Tuple[float, float, int, int]] = []
        # (t, desired clients, active clients) decision trace
        self.client_trace: List[Tuple[float, int, int]] = []
        # (t, experts paged out) action trace
        self.page_trace: List[Tuple[float, int]] = []

    # ------------------------------------------------------------- signals
    def observe_arrival(self, t: float) -> None:
        self._arrivals.append(t)

    def observed_rate(self, t: float) -> float:
        w = self.cfg.window
        while self._arrivals and self._arrivals[0] < t - w:
            self._arrivals.popleft()
        return len(self._arrivals) / max(w, 1e-9)

    # -------------------------------------------------------------- policy
    def desired_servers(self, t: float, queue_depth: int,
                        prefill_backlog: int = 0,
                        kv_free_fraction: float = 1.0) -> int:
        c = self.cfg
        n = provision(self.observed_rate(t), c.rate_per_server,
                      c.granularity)
        if c.queue_per_server > 0 and queue_depth > 0:
            n += int(queue_depth / c.queue_per_server)
        if c.prefill_tokens_per_server > 0 and prefill_backlog > 0:
            n += int(prefill_backlog / c.prefill_tokens_per_server)
        if (c.kv_pressure_threshold > 0
                and kv_free_fraction < c.kv_pressure_threshold):
            n += 1
        return max(c.min_servers, min(c.max_servers, n))

    def desired_clients(self, t: float, ingress_depth: int = 0) -> int:
        """Attention clients the observed rate needs, plus the ingress
        backpressure term (requests the per-client admission caps pushed
        back into the cluster queue mean the fleet is short)."""
        c = self.cfg
        n = provision(self.observed_rate(t), c.rate_per_client, 1)
        if c.ingress_per_client > 0 and ingress_depth > 0:
            n += int(ingress_depth / c.ingress_per_client)
        return max(c.min_clients, min(c.max_clients, n))

    def _down_ok(self, rate: float, target: int,
                 per_unit: float) -> bool:
        """Scale-down deadband: the smaller tier must absorb the observed
        rate with ``down_headroom`` to spare, else hold the current size
        (see the config comment — this is what keeps arrival noise around
        a capacity boundary from flapping the size)."""
        return rate <= self.cfg.down_headroom * target * per_unit

    def _pageable_experts(self, engine, t: float) -> List[int]:
        """Experts cold enough to page out: traffic-EMA share below
        ``expert_idle_fraction / E``, outside the ``page_in_protect``
        hysteresis window, respecting the ``min_resident_fraction`` floor.
        Coldest first, deterministic tie-break on index."""
        pool = engine.pool
        ema = pool.stats.ema
        if ema is None:
            return []
        total = float(np.sum(ema))
        if total <= 0:
            return []
        E = len(ema)
        share = np.asarray(ema, np.float64) / total
        thresh = self.cfg.expert_idle_fraction / E
        floor = max(1, int(np.ceil(self.cfg.min_resident_fraction * E)))
        budget = (E - len(pool.cold)) - floor
        if budget <= 0:
            return []
        out: List[int] = []
        for e in sorted(range(E), key=lambda e: (share[e], e)):
            if len(out) >= budget:
                break
            if e in pool.cold:
                continue
            if share[e] >= thresh:
                break                    # ascending: nothing colder left
            if t - pool.page_in_t.get(e, -float("inf")) \
                    < self.cfg.page_in_protect:
                continue                 # freshly paged in: protected
            out.append(e)
        return out

    # ---------------------------------------------------------------- loop
    def step(self, engine, t: float) -> Optional[int]:
        """One control iteration; returns the new pool size if the server
        controller scaled (client/paging actions return None — read
        ``client_trace`` / ``page_trace``).  At most one action fires per
        step, and every action re-arms both the local and the shared
        ``last_placement_change`` cooldown."""
        if not self.enabled:
            return None
        if engine.pool is None:
            return None
        if t < self.cfg.window:        # warm-up: the rate estimate is not
            return None                # meaningful before one full window
        # coordinate with live rebalancing: expert-level replication acts
        # first (cheap, no recompile) — hold server-count scaling while a
        # migration is in flight or inside the shared placement cooldown
        reb = getattr(engine, "rebalancer", None)
        if reb is not None and reb.migrating:
            return None
        if (t - getattr(engine, "last_placement_change", float("-inf"))
                < self.cfg.cooldown):
            return None
        # engine-level signal methods so one policy loop drives both a
        # standalone engine and a Cluster (which aggregates over clients)
        backlog = 0
        if self.cfg.prefill_tokens_per_server > 0:
            backlog = engine.pending_prefill_tokens()
        kv_free = 1.0
        if self.cfg.kv_pressure_threshold > 0:
            kv_free = engine.kv_free_fraction()
        want = self.desired_servers(t, len(engine.queue), backlog, kv_free)
        # snap up to the nearest pool size the expert layout supports
        feasible = [n for n in engine.pool.feasible_counts()
                    if n <= self.cfg.max_servers]
        snapped = next((n for n in feasible if n >= want),
                       feasible[-1] if feasible else want)
        have = engine.pool.num_servers
        rate = self.observed_rate(t)
        self.trace.append((t, rate, snapped, have))
        if t - self._last_action < self.cfg.cooldown:
            return None
        if snapped < have and not self._down_ok(rate, snapped,
                                                self.cfg.rate_per_server):
            snapped = have             # deadband: hold until it fits
        if snapped != have:
            engine.scale_to(snapped)
            self._last_action = t
            return snapped
        # ---- attention tier (cluster targets only) ----------------------
        if self.cfg.rate_per_client > 0 \
                and hasattr(engine, "scale_clients"):
            ingress = len(getattr(engine, "ingress", ()))
            want_c = self.desired_clients(t, ingress)
            have_c = engine.active_client_count()
            self.client_trace.append((t, want_c, have_c))
            if want_c < have_c and not self._down_ok(
                    rate, want_c, self.cfg.rate_per_client):
                want_c = have_c
            if want_c != have_c:
                engine.scale_clients(want_c)
                self._last_action = t
                return None
        # ---- scale-to-zero experts --------------------------------------
        if self.cfg.expert_idle_fraction > 0 \
                and hasattr(engine, "page_out_experts"):
            cold = self._pageable_experts(engine, t)
            if cold:
                paged = engine.page_out_experts(cold)
                if paged:
                    self.page_trace.append((t, len(paged)))
                    self._last_action = t
        return None
