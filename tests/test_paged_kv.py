"""Paged KV-cache subsystem: kernel correctness, dense/paged token
identity (with and without prefix-cache hits), the deterministic TTFT win
on shared-prefix traces, and preemption liveness under an oversubscribed
pool.

Token-identity pins compare engines with the *same* chunked-prefill
setting: paged prefill always runs the chunk path, and chunk shapes must
match for bitwise-equal attention (a whole-prompt prefill computes the
same values up to matmul-shape LSBs, which MoE top-k routing can amplify
on near-ties — a pre-existing property of the chunk path, not of paging).

All engine runs sit on the virtual clock — deterministic, no wall time.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops, ref
from repro.serving import EngineConfig, Scenario, ServingEngine, VirtualClock


@pytest.fixture(scope="module")
def cfg():
    return get_config("deepseek-r1").reduced()


def _engine(cfg, **kw):
    kw.setdefault("pool_tokens_per_client", 128)
    kw.setdefault("prefill_chunk", 8)
    kw.setdefault("policy", "fair")
    ecfg = EngineConfig(mode="eaas", num_servers=4, max_batch=4,
                        max_seq=128, n_redundant=2, **kw)
    return ServingEngine(cfg, ecfg, clock=VirtualClock())


def _shared_prefix_scenario(cfg, max_new=5, horizon=0.15, rate=100, seed=7):
    # two 16-token system prompts (2 blocks, 2 chunks) + unique suffixes
    return (Scenario(horizon=horizon, seed=seed, max_new=max_new,
                     vocab=cfg.vocab_size)
            .shared_prefix(n_prefixes=2, prefix_len=16, suffix_len=6)
            .poisson(rate=rate))


def _run(cfg, scenario, max_steps=20_000, **kw):
    eng = _engine(cfg, **kw)
    res = scenario.run(eng, max_steps=max_steps)
    assert res.metrics.completed == res.metrics.total_requests > 0
    return eng, res


def _tokens(res):
    return {r.request_id: tuple(r.output_tokens) for r in res.requests}


# ------------------------------------------------------------ paged kernel

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,hd,bs,mb", [
    (2, 8, 2, 32, 16, 4),
    (3, 4, 4, 64, 32, 2),
    (1, 16, 8, 16, 8, 4),
])
def test_paged_flash_decode_vs_ref(b, h, kv, hd, bs, mb, dtype, rng):
    nb = b * mb + 1
    q = jnp.asarray(rng.normal(size=(b, h, hd)), dtype)
    kp = jnp.asarray(rng.normal(size=(nb, bs, kv, hd)), dtype)
    vp = jnp.asarray(rng.normal(size=(nb, bs, kv, hd)), dtype)
    tables = jnp.asarray(
        rng.permutation(np.arange(1, nb)).reshape(b, mb), jnp.int32)
    lengths = jnp.asarray(rng.integers(1, mb * bs + 1, size=b), jnp.int32)
    out = ops.paged_flash_decode(q, kp, vp, tables, lengths,
                                 impl="pallas_interpret")
    exp = ref.paged_flash_decode_ref(q, kp, vp, tables, lengths)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)


def test_paged_ref_matches_dense_ref_on_gathered_view(rng):
    """The paged oracle is the dense oracle over the gathered view."""
    b, h, kv, hd, bs, mb = 2, 4, 2, 16, 8, 3
    nb = b * mb + 1
    q = jnp.asarray(rng.normal(size=(b, h, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(nb, bs, kv, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(nb, bs, kv, hd)), jnp.float32)
    tables = jnp.asarray(np.arange(1, nb).reshape(b, mb), jnp.int32)
    lengths = jnp.asarray([5, 20], jnp.int32)
    kd = kp[tables].reshape(b, mb * bs, kv, hd)
    vd = vp[tables].reshape(b, mb * bs, kv, hd)
    np.testing.assert_array_equal(
        np.asarray(ref.paged_flash_decode_ref(q, kp, vp, tables, lengths)),
        np.asarray(ref.flash_decode_ref(q, kd, vd, lengths)))


# ------------------------------------------------- dense/paged token pins

def test_paged_token_identical_no_prefix(cfg):
    """Paging alone changes where K/V lives, not what is computed: greedy
    outputs match the dense chunked engine bitwise."""
    _, rd = _run(cfg, _shared_prefix_scenario(cfg))
    _, rp = _run(cfg, _shared_prefix_scenario(cfg),
                 kv_mode="paged", kv_block_size=8, kv_prefix_cache=False)
    assert _tokens(rd) == _tokens(rp)


def test_paged_prefix_hits_token_identical_and_ttft_win(cfg):
    """Prefix-cache hits skip the shared system prompt: greedy outputs stay
    token-identical to dense while mean TTFT drops (the VirtualClock
    charges only the uncached suffix — a deterministic, benchmarkable
    win), and the hit-rate counter shows real sharing."""
    _, rd = _run(cfg, _shared_prefix_scenario(cfg))
    eng, rp = _run(cfg, _shared_prefix_scenario(cfg),
                   kv_mode="paged", kv_block_size=8)
    assert _tokens(rd) == _tokens(rp)
    m = rp.metrics
    assert m.prefix_hit_rate > 0.5
    assert m.ttft_stats()["mean"] < rd.metrics.ttft_stats()["mean"]
    kv = m.summary()["kv"]
    assert kv["prefix_hit_blocks"] > 0
    assert 0 < kv["peak_block_util"] <= 1.0


def test_paged_determinism(cfg):
    kw = dict(kv_mode="paged", kv_block_size=8)
    _, r1 = _run(cfg, _shared_prefix_scenario(cfg), **kw)
    _, r2 = _run(cfg, _shared_prefix_scenario(cfg), **kw)
    assert r1.metrics.fingerprint() == r2.metrics.fingerprint()


def test_cow_fork_on_fully_cached_prompt(cfg):
    """Identical prompts (no unique suffix): later admissions hit the whole
    prompt, fork the final shared block (copy-on-write) and recompute just
    one token — streams are identical across all requests."""
    sc = (Scenario(horizon=0.1, seed=3, max_new=6, vocab=cfg.vocab_size)
          .shared_prefix(n_prefixes=1, prefix_len=24, suffix_len=0)
          .poisson(rate=120))
    eng, res = _run(cfg, sc, kv_mode="paged", kv_block_size=8)
    m = res.metrics
    assert m.kv_cow_forks == m.total_requests - 1
    assert m.prefix_hit_rate > 0.9
    assert len({tuple(r.output_tokens) for r in res.requests}) == 1


# ------------------------------------------------ oversubscription / safety

@pytest.mark.slow
def test_preemption_keeps_engine_live_and_tokens_identical(cfg):
    """Pool squeezed to the single-request minimum: the engine admission-
    gates, preempts (release + recompute re-queue) and still completes
    every request with token streams identical to the unconstrained pool —
    no deadlock, no drops, deterministic."""
    sc = lambda: _shared_prefix_scenario(cfg, max_new=24, rate=150)
    eng, r_small = _run(cfg, sc(), kv_mode="paged", kv_block_size=8,
                        kv_num_blocks=17)
    m = r_small.metrics
    assert m.preemptions > 0
    assert m.kv_peak_block_util == pytest.approx(1.0)
    _, r_big = _run(cfg, sc(), kv_mode="paged", kv_block_size=8)
    assert r_big.metrics.preemptions == 0
    assert _tokens(r_small) == _tokens(r_big)
    # preemption delays work: the squeezed pool pays latency, not tokens
    assert m.wall_time > r_big.metrics.wall_time


@pytest.mark.slow
def test_paged_chunked_matches_paged_whole_suffix(cfg):
    """Within paged mode, chunk size is a latency knob, not a semantics
    knob: different chunkings produce identical greedy streams."""
    _, r8 = _run(cfg, _shared_prefix_scenario(cfg), kv_mode="paged",
                 kv_block_size=8, kv_prefix_cache=False, prefill_chunk=8)
    _, r4 = _run(cfg, _shared_prefix_scenario(cfg), kv_mode="paged",
                 kv_block_size=8, kv_prefix_cache=False, prefill_chunk=4)
    assert _tokens(r8) == _tokens(r4)


# ------------------------------------------------------------- validation

def test_paged_config_validation(cfg):
    with pytest.raises(ValueError, match="multiple of"):
        _engine(cfg, kv_mode="paged", kv_block_size=24)
    with pytest.raises(ValueError, match="maximal request"):
        _engine(cfg, kv_mode="paged", kv_block_size=8, kv_num_blocks=8)
    with pytest.raises(ValueError, match="lockstep"):
        _engine(cfg, kv_mode="paged", kv_block_size=8,
                decode_mode="pipelined")
