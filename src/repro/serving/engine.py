"""Continuous-batching serving engine.

One engine class serves three system modes (paper §5 baselines):

* ``mode="eaas"``        — EAAS: replicated experts, liveness-masked mapping;
  a server failure re-routes traffic to replicas within the same step
  (throughput dips only by the lost compute share — paper Fig. 10).
* ``mode="monolithic_ep"`` — DeepEP-style: primary-only mapping; a server
  failure halts the WHOLE engine for ``restart_steps`` (the collective-group
  restart) before resuming.
* ``mode="tp"``          — tensor-parallel MoE: failure halts only the
  16-GPU unit (modeled as a shorter stall) but per-unit weight replication
  caps the max batch (``tp_batch_cap``).

The expert→server mapping, liveness mask and local placement table are
**jit arguments**, not compiled constants — failover and rebalancing never
trigger recompilation (the paper's no-group-rebuild property).

The engine's notion of time is a pluggable :class:`~repro.serving.clock.Clock`:
the default :class:`~repro.serving.clock.WallClock` accumulates real jitted
step wall-times (CPU runs give meaningful *relative* curves), while
:class:`~repro.serving.clock.VirtualClock` charges a deterministic analytic
cost per step so scenario runs are bit-reproducible and fast.  Prompt
lengths are bucketed by the caller to bound prefill recompiles.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import expert_server
from repro.core.elastic import ServerPool
from repro.core.monitor import Monitor
from repro.models.transformer import Model, ParallelCtx, build_model
from repro.serving.clock import Clock, WallClock
from repro.serving.metrics import ServingMetrics
from repro.serving.request import Request
from repro.serving.sampling import sample


@dataclass
class EngineConfig:
    max_batch: int = 8
    max_seq: int = 256
    mode: str = "eaas"                 # eaas | monolithic_ep | tp
    num_servers: int = 4
    n_redundant: int = 2
    restart_steps: int = 50            # monolithic group restart cost
    tp_restart_steps: int = 12         # one TP unit restart
    tp_batch_cap: Optional[int] = None # TP: weight replication caps batch
    gemm_impl: str = "xla_ragged"
    eos_token: Optional[int] = None


class ServingEngine:
    """Continuous batching over a fixed slot pool with EAAS failover."""

    def __init__(self, cfg: ModelConfig, engine_cfg: EngineConfig,
                 params=None, seed: int = 0, clock: Optional[Clock] = None):
        self.cfg = cfg
        self.ecfg = engine_cfg
        self.clk = clock if clock is not None else WallClock()
        S = engine_cfg.num_servers if engine_cfg.mode != "tp" else 1
        self.pool = None
        if cfg.moe:
            self.pool = ServerPool(
                cfg, S, tokens_per_client=engine_cfg.max_batch,
                n_redundant=(engine_cfg.n_redundant
                             if engine_cfg.mode == "eaas" else 0))
        self.model = build_model(
            cfg, num_servers=S if cfg.moe else 1,
            redundant_table=self.pool.redundant_table if self.pool else None)
        key = jax.random.PRNGKey(seed)
        self.params = params if params is not None else \
            self.model.init_params(key)
        self.monitor = Monitor(heartbeat_timeout=3.0)
        if self.pool:
            self.monitor.subscribe_server_down(self.pool.server_failed)

        # static runtime skeleton — arrays swapped per step via jit args
        self._rt0 = self.pool.runtime(engine_cfg.gemm_impl) \
            if self.pool else None

        B, L = engine_cfg.max_batch, engine_cfg.max_seq
        self.cache = self.model.init_cache(B, L)
        self.slots: List[Optional[Request]] = [None] * B
        self.queue: deque = deque()
        self.metrics = ServingMetrics()
        self.step_idx = 0
        self.clock = 0.0
        self.halted_until = -1
        self._last_decode_time = 0.01
        self._key = jax.random.PRNGKey(seed + 1)

        self._build_jits()

    def _build_jits(self) -> None:
        """(Re)build the jitted step functions around the current ``_rt0``.

        Called at init and after :meth:`scale_to` — the static fields of the
        runtime (num_servers, capacity) are baked into the closure, so a pool
        resize needs a fresh jit variant (the AOT-per-server-count story);
        liveness/mapping changes stay jit *arguments* and never recompile.
        """
        model, ecfg, rt0 = self.model, self.ecfg, self._rt0

        def ctx_of(rt_arrays):
            rt = None
            if rt0 is not None:
                mapping, alive, local = rt_arrays
                rt = rt0._replace(mapping=mapping, alive=alive,
                                  local_table=local)
            return ParallelCtx(moe_runtime=rt, gemm_impl=ecfg.gemm_impl,
                               remat=False)

        def prefill_fn(params, tokens, rt_arrays):
            return model.prefill(params, tokens, ctx_of(rt_arrays),
                                 max_slots=ecfg.max_seq)

        def decode_fn(params, tokens, cache, rt_arrays):
            logits, cache, st = model.decode_step(params, tokens, cache,
                                                  ctx_of(rt_arrays))
            # per-expert token counts feed the pool's traffic EMA — this is
            # what rebalance() and traffic-aware scale_to re-plan from
            return logits, cache, st.expert_load

        self._jit_prefill = jax.jit(prefill_fn)
        self._jit_decode = jax.jit(decode_fn)

    # ------------------------------------------------------------ helpers
    def _alive_frac(self) -> float:
        """Alive share of the expert-server pool (EAAS failover slowdown)."""
        if self.pool is None or self.ecfg.mode != "eaas":
            return 1.0
        return float(self.pool.smap.alive.mean())

    def _pool_size(self) -> int:
        return self.pool.num_servers if self.pool else 1

    def _rt_arrays(self):
        if self.pool is None:
            return ()
        rt = self.pool.runtime(self.ecfg.gemm_impl)
        return (rt.mapping, rt.alive, rt.local_table)

    # ------------------------------------------------------------- control
    def submit(self, req: Request) -> None:
        self.queue.append(req)
        self.metrics.total_requests += 1

    def inject_server_failure(self, rank: int) -> None:
        """Simulated hardware failure of one expert server (paper §5.4)."""
        self.metrics.events.append(
            {"t": self.clock, "event": "server_fail", "rank": rank,
             "mode": self.ecfg.mode})
        if self.ecfg.mode == "eaas":
            if self.pool and rank < self.pool.num_servers:
                self.pool.server_failed(rank)     # mapping mask update only
        elif self.ecfg.mode == "monolithic_ep":
            self.halted_until = self.step_idx + self.ecfg.restart_steps
        elif self.ecfg.mode == "tp":
            self.halted_until = self.step_idx + self.ecfg.tp_restart_steps

    def recover_server(self, rank: int) -> None:
        self.metrics.events.append(
            {"t": self.clock, "event": "server_recover", "rank": rank})
        if self.pool and rank < self.pool.num_servers:
            self.pool.server_recovered(rank)

    def rebalance(self) -> None:
        """EPLB-style replica re-planning from live traffic (paper §4.5)."""
        if self.pool:
            self.pool.rebalance()
            self.metrics.events.append({"t": self.clock, "event": "rebalance"})

    def scale_to(self, n: int) -> None:
        """Elastically resize the expert-server pool to ``n`` servers.

        The pool re-plans its EPLB mapping (liveness preserved), the expert
        weights are re-sharded from the recovered global bank, and the jitted
        step variants are rebuilt for the new static server count.  In-flight
        requests keep their KV cache — scaling never drops work (paper §5.3).
        """
        if self.pool is None or n == self.pool.num_servers:
            return
        old = self.pool.num_servers
        self.pool.scale_to(n)
        E = self.cfg.moe.num_experts
        red = self.pool.redundant_table
        self.params = _map_server_weights(
            self.params,
            lambda sw: expert_server.reshard_server_weights(sw, E, n, red))
        self._rt0 = self.pool.runtime(self.ecfg.gemm_impl)
        self._build_jits()
        self.metrics.events.append(
            {"t": self.clock, "event": "scale", "from": old, "to": n})

    # --------------------------------------------------------------- slots
    def _admit(self) -> None:
        cap = self.ecfg.tp_batch_cap if self.ecfg.mode == "tp" else None
        for b in range(len(self.slots)):
            if cap is not None and b >= cap:
                break
            if self.slots[b] is None and self.queue:
                self._prefill_into(b, self.queue.popleft())

    def _prefill_into(self, b: int, req: Request) -> None:
        tokens = jnp.asarray(req.prompt, jnp.int32)[None]
        self.clk.start()
        logits, cache_one = self._jit_prefill(self.params, tokens,
                                              self._rt_arrays())
        self.clock += self.clk.stop("prefill", result=logits,
                                    tokens=tokens.shape[1],
                                    servers=self._pool_size(),
                                    alive_frac=self._alive_frac())
        self.cache = jax.tree.map(
            lambda big, one: _slot_write(big, one, b), self.cache, cache_one)
        self._key, sk = jax.random.split(self._key)
        first = int(sample(logits, req.sampling.temperature, sk)[0])
        req.output_tokens.append(first)
        req.prefill_time = self.clock
        self.slots[b] = req
        self.metrics.events.append(
            {"t": self.clock, "event": "prefill", "rid": req.request_id})

    # ---------------------------------------------------------------- step
    def step(self) -> None:
        """One engine iteration: admit, decode, retire."""
        self.step_idx += 1
        if self.step_idx <= self.halted_until:
            # monolithic restart: time passes, no tokens are produced
            self.clock += self._last_decode_time
            self.metrics.timeline.append(
                {"t": self.clock, "tokens": 0, "halted": True})
            return
        self._admit()
        active = [b for b, r in enumerate(self.slots) if r is not None]
        if not active:
            self.clock += self.clk.idle()
            return
        tokens = np.zeros((len(self.slots), 1), np.int32)
        for b, r in enumerate(self.slots):
            if r is not None:
                tokens[b, 0] = r.output_tokens[-1]
        self.clk.start()
        logits, self.cache, expert_load = self._jit_decode(
            self.params, jnp.asarray(tokens), self.cache, self._rt_arrays())
        dt = self.clk.stop("decode", result=logits, tokens=len(active),
                           servers=self._pool_size(),
                           alive_frac=self._alive_frac())
        self._last_decode_time = dt
        self.clock += dt
        if self.pool is not None:
            self.pool.observe_load(np.asarray(expert_load))
        self._key, sk = jax.random.split(self._key)
        next_tokens = np.asarray(sample(logits, 0.0, sk))

        produced = 0
        for b in active:
            r = self.slots[b]
            tok = int(next_tokens[b])
            r.output_tokens.append(tok)
            r.token_times.append(self.clock)
            produced += 1
            self.metrics.total_output_tokens += 1
            done = (len(r.output_tokens) >= r.sampling.max_new_tokens or
                    (self.ecfg.eos_token is not None and
                     tok == self.ecfg.eos_token) or
                    len(r.prompt) + len(r.output_tokens) >=
                    self.ecfg.max_seq - 1)
            if done:
                r.finish_time = self.clock
                self.metrics.completed += 1
                self.metrics.itls.extend(r.itl())
                self.slots[b] = None
        self.metrics.timeline.append(
            {"t": self.clock, "tokens": produced, "halted": False})

    def run(self, max_steps: int = 10_000,
            on_step: Optional[Callable[["ServingEngine"], None]] = None
            ) -> ServingMetrics:
        """Drive until queue + slots drain (or max_steps)."""
        while (self.queue or any(s is not None for s in self.slots)) \
                and self.step_idx < max_steps:
            if on_step:
                on_step(self)
            self.step()
        self.metrics.wall_time = self.clock
        return self.metrics


def _map_server_weights(params, fn):
    """Apply ``fn`` to every MoE layer's per-server weight dict in a params
    tree (the ``{"moe": {"servers": ...}}`` sub-dicts), leaving everything
    else untouched."""
    if isinstance(params, dict):
        out = {}
        for k, v in params.items():
            if k == "moe" and isinstance(v, dict) and "servers" in v:
                out[k] = dict(v, servers=fn(v["servers"]))
            else:
                out[k] = _map_server_weights(v, fn)
        return out
    return params


def _slot_write(big, one, b: int):
    """Write a batch-1 cache pytree leaf into slot b of the engine cache.

    The batch dim is the first one where `big` and `one` differ with
    ``one == 1``.
    """
    if not hasattr(big, "shape"):
        return big
    if big.shape == getattr(one, "shape", None):
        return one.astype(big.dtype)      # max_batch == 1: replace wholesale
    for axis, (db, do) in enumerate(zip(big.shape, one.shape)):
        if db != do and do == 1:
            idx = [slice(None)] * big.ndim
            idx[axis] = slice(b, b + 1)
            return big.at[tuple(idx)].set(one.astype(big.dtype))
    return big
