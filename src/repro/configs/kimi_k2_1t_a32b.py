"""kimi-k2-1t-a32b — Kimi K2, trillion-parameter fine-grained MoE.

[arXiv:2501.kimi2; unverified]  61L, 384 experts top-8 + 1 shared expert,
first layer dense.  This is the paper's own Table-1 headline model family and
the primary target of the EAAS technique.
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=2048,                     # expert hidden dim (fine-grained experts)
    vocab_size=163840,
    d_head=112,
    rope_theta=50000.0,
    activation="swiglu",
    moe=MoEConfig(
        num_experts=384,
        top_k=8,
        d_expert=2048,
        num_shared_experts=1,
        first_k_dense=1,           # K2: first layer dense
        router_score_fn="sigmoid",  # DeepSeek-V3-style sigmoid gating
        normalize_topk=True,
    ),
    subquadratic=False,
    source="arXiv:2501.kimi2",
)
