"""Model configuration system.

One :class:`ModelConfig` dataclass covers every assigned architecture family
(dense / moe / hybrid / ssm / audio / vlm).  Architecture files under
``repro.configs`` instantiate it with the exact published hyper-parameters and
register themselves in the global registry (see ``__init__.py``).

Every config also knows how to produce a *reduced* version of itself
(``cfg.reduced()``) used by the CPU smoke tests: same family and wiring, tiny
widths.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-Experts sub-config (present when the arch has MoE layers)."""

    num_experts: int
    top_k: int
    d_expert: int                       # hidden dim of each expert FFN
    num_shared_experts: int = 0         # DeepSeek-style always-on experts
    dense_residual: bool = False        # Arctic: dense FFN residual in parallel
    first_k_dense: int = 0              # leading dense layers (DeepSeek/Kimi)
    router_score_fn: str = "softmax"    # "softmax" | "sigmoid"
    normalize_topk: bool = True         # renormalize selected scores to sum 1
    capacity_factor: float = 1.25       # per-(client,server) buffer headroom
    router_aux_loss_coef: float = 0.001
    router_z_loss_coef: float = 0.0


@dataclass(frozen=True)
class SSMConfig:
    """State-space sub-config (mamba2 / rwkv6)."""

    d_state: int = 64
    d_conv: int = 4                     # mamba2 short conv width
    expand: int = 2                     # mamba2 d_inner = expand * d_model
    num_ssm_heads: int = 0              # mamba2 multi-head SSD (0 = derive)
    head_dim: int = 64


@dataclass(frozen=True)
class ModelConfig:
    """Complete architecture description (one per assigned arch)."""

    arch_id: str
    family: str                         # dense|moe|hybrid|ssm|audio|vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: Optional[int] = None        # default: d_model // num_heads

    # --- attention flavour ------------------------------------------------
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None        # local-attention window
    local_global_pattern: int = 0               # gemma3: N local per 1 global
    attn_logit_softcap: Optional[float] = None
    mrope_sections: Optional[Tuple[int, ...]] = None   # qwen2-vl (t,h,w)
    tie_embeddings: bool = False
    rms_norm_eps: float = 1e-6
    activation: str = "swiglu"                  # swiglu | gelu | relu_sq

    # --- family sub-configs -----------------------------------------------
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None

    # hybrid (zamba2): a shared attention block is interleaved every
    # `shared_block_every` mamba layers.
    shared_block_every: int = 0

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq_len: int = 1500          # whisper: 30s of audio frames

    # modality frontend stub: "none" | "audio_frames" | "vision_patches"
    frontend: str = "none"

    # --- serving / distribution defaults ----------------------------------
    max_seq_len: int = 131072
    dtype: str = "bfloat16"
    # long_500k applicability: sub-quadratic attention available?
    subquadratic: bool = False

    # --- citations ----------------------------------------------------------
    source: str = ""

    # ------------------------------------------------------------------ API
    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up so embedding/head shard over the model axis
        (multiple of 256 covers every production mesh).  Logits for padding
        slots are masked to -inf (models/transformer._logits)."""
        return (self.vocab_size + 255) // 256 * 256

    @property
    def is_moe(self) -> bool:
        return self.moe is not None

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def moe_layer_ids(self) -> Tuple[int, ...]:
        """Indices of layers whose FFN is an MoE layer."""
        if self.moe is None:
            return ()
        return tuple(
            i for i in range(self.num_layers) if i >= self.moe.first_k_dense
        )

    def num_params(self) -> int:
        """Analytic total parameter count (embedding + blocks + head)."""
        d, h, kv, dh, ff, v = (
            self.d_model, self.num_heads, self.num_kv_heads,
            self.head_dim, self.d_ff, self.vocab_size,
        )
        emb = v * d if self.tie_embeddings else 2 * v * d
        attn = d * (h * dh) + 2 * d * (kv * dh) + (h * dh) * d
        ffn_dense = 3 * d * ff if self.activation == "swiglu" else 2 * d * ff
        total = emb
        n_layers = self.num_layers
        if self.family == "ssm":
            ssm = self.ssm or SSMConfig()
            d_in = ssm.expand * d
            tmix = 4 * d * d + d * d  # r,k,v,g,o projections (approx, rwkv6)
            cmix = 2 * d * ff // 1 if self.activation != "swiglu" else 2 * d * ff
            total += n_layers * (tmix + cmix)
            return total
        if self.family == "hybrid":
            ssm = self.ssm or SSMConfig()
            d_in = ssm.expand * d
            mamba = d * d_in * 2 + d_in * d + d_in * (2 * ssm.d_state)
            n_shared = (
                n_layers // self.shared_block_every if self.shared_block_every else 0
            )
            total += n_layers * (mamba + ffn_dense)
            total += (attn + ffn_dense)  # one shared block's params
            return total
        # transformer families
        enc_layers = self.num_encoder_layers if self.is_encoder_decoder else 0
        dec_layers = n_layers
        per_layer_dense = attn + ffn_dense
        if self.moe is not None:
            m = self.moe
            expert_ffn = (3 if self.activation == "swiglu" else 2) * d * m.d_expert
            per_moe = attn + m.num_experts * expert_ffn + d * m.num_experts
            per_moe += m.num_shared_experts * expert_ffn
            if m.dense_residual:
                per_moe += ffn_dense
            n_moe = len(self.moe_layer_ids())
            total += n_moe * per_moe + (dec_layers - n_moe) * per_layer_dense
        else:
            total += dec_layers * per_layer_dense
        total += enc_layers * (attn + ffn_dense)
        if self.is_encoder_decoder:
            total += dec_layers * attn  # cross-attention
        return total

    def num_active_params(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if self.moe is None:
            return self.num_params()
        m = self.moe
        d, ff = self.d_model, m.d_expert
        expert_ffn = (3 if self.activation == "swiglu" else 2) * d * ff
        inactive = (m.num_experts - m.top_k) * expert_ffn
        return self.num_params() - len(self.moe_layer_ids()) * inactive

    # ------------------------------------------------------------- reduced
    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw: dict = dict(
            arch_id=self.arch_id + "-reduced",
            family=self.family,
            num_layers=min(self.num_layers, 4 if self.shared_block_every == 0
                           else 2 * self.shared_block_every),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads < self.num_heads else 4,
            d_head=32,
            d_ff=256,
            vocab_size=512,
            rope_theta=self.rope_theta,
            sliding_window=64 if self.sliding_window else None,
            local_global_pattern=self.local_global_pattern,
            attn_logit_softcap=self.attn_logit_softcap,
            mrope_sections=(4, 6, 6) if self.mrope_sections else None,
            tie_embeddings=self.tie_embeddings,
            activation=self.activation,
            shared_block_every=self.shared_block_every,
            is_encoder_decoder=self.is_encoder_decoder,
            num_encoder_layers=min(self.num_encoder_layers, 2),
            encoder_seq_len=32,
            frontend=self.frontend,
            max_seq_len=1024,
            subquadratic=self.subquadratic,
            source=self.source,
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe,
                num_experts=8,
                top_k=min(self.moe.top_k, 2),
                d_expert=128,
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                first_k_dense=min(self.moe.first_k_dense, 1),
            )
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=32, num_ssm_heads=4)
        return ModelConfig(**kw)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
