"""Jitted step execution — the executor half of the engine split.

The :class:`Executor` owns the model params, the batched KV cache, the
per-slot prefill staging caches, and the jitted step variants:

* ``prefill``       — whole-prompt prefill into a fresh batch-1 cache
  (the pre-split path; one compile per bucketed prompt length);
* ``prefill_chunk`` — chunked-prefill continuation against a staging cache
  (decoder family only; one compile per distinct chunk length);
* ``decode``        — one token for the whole slot batch, in one of three
  modes: ``lockstep`` (single full-batch step, the pre-split behaviour),
  ``pipelined`` (two half-batch microbatches as *independent* subgraphs —
  :func:`repro.core.overlap.split_batch_decode` — so the expert round-trip
  of microbatch A overlaps the attention of microbatch B, paper §4.2), or
  ``serialized`` (same split with an artificial dependency: the ablation
  baseline, bit-identical outputs, collectives exposed).

``kv_mode="paged"`` swaps the dense per-slot cache for the block-pool
cache: one shared pool per layer plus per-slot block tables owned by the
host-side scheduler.  Block tables and lengths are jit *arguments* (data,
not structure) — admission, prefix-cache sharing and preemption rewrite
them between steps without recompiling.  All paged prefill goes through the
chunk path (prefix-cache hits start chunks mid-prompt; there is no staging
cache — pool blocks are the real storage), and paged decode is
lockstep-only (the pool is shared across the batch, so a microbatch split
has no batch axis to cut).

The expert→server mapping, liveness mask and local placement table remain
jit *arguments*: failover and rebalancing never recompile.  A pool resize
(:meth:`resize`) re-shards the expert weights and rebuilds the jits for the
new static server count — the AOT-per-server-count story.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import expert_server
from repro.core.overlap import split_batch_decode
from repro.models import kv_cache as kvc
from repro.models.transformer import Model, ParallelCtx


class Executor:
    """Owns params + caches + jitted step variants for one engine."""

    def __init__(self, model: Model, params, pool, *, max_batch: int,
                 max_seq: int, gemm_impl: str = "xla_ragged",
                 decode_mode: str = "lockstep", kv_mode: str = "dense",
                 kv_block_size: int = 16, kv_num_blocks: int = 0):
        assert decode_mode in ("lockstep", "pipelined", "serialized"), \
            decode_mode
        assert kv_mode in ("dense", "paged"), kv_mode
        if decode_mode != "lockstep":
            if model.cache_batch_axis is None:
                raise ValueError(
                    f"decode_mode={decode_mode!r} needs a model family with "
                    "a uniform cache batch axis (decoder-family only)")
            if max_batch % 2:
                raise ValueError(
                    f"decode_mode={decode_mode!r} needs an even max_batch "
                    f"(got {max_batch}) to form two microbatches")
            if kv_mode == "paged":
                raise ValueError(
                    "kv_mode='paged' shares one block pool across the "
                    "batch — microbatch-split decode modes need the dense "
                    "per-slot cache (use decode_mode='lockstep')")
        if kv_mode == "paged":
            if model.init_paged_cache is None or model.prefill_chunk is None:
                raise ValueError(
                    "kv_mode='paged' needs a model family with paged-cache "
                    "and chunked-prefill support (uniform decoder family)")
            if max_seq % kv_block_size:
                raise ValueError(
                    f"max_seq={max_seq} must be a multiple of "
                    f"kv_block_size={kv_block_size}")
        self.model = model
        self.params = params
        self.pool = pool
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.gemm_impl = gemm_impl
        self.decode_mode = decode_mode
        self.kv_mode = kv_mode
        self.kv_block_size = kv_block_size
        self.kv_num_blocks = kv_num_blocks
        if kv_mode == "paged":
            self.cache = model.init_paged_cache(
                kv_num_blocks, kv_block_size, max_batch, max_seq)
        else:
            self.cache = model.init_cache(max_batch, max_seq)
        self._staging: Dict[int, object] = {}     # slot -> batch-1 cache
        self._rt0 = pool.runtime(gemm_impl) if pool else None
        self._build_jits()

    @property
    def supports_chunked_prefill(self) -> bool:
        return self.model.prefill_chunk is not None

    # -------------------------------------------------------------- jits
    def _build_jits(self) -> None:
        """(Re)build the jitted step functions around the current ``_rt0``.

        Static runtime fields (num_servers, capacity) are baked into the
        closures, so a pool resize needs fresh variants; liveness/mapping
        stay jit arguments and never recompile.
        """
        model, rt0 = self.model, self._rt0
        gemm_impl, max_seq = self.gemm_impl, self.max_seq

        def ctx_of(rt_arrays):
            rt = None
            if rt0 is not None:
                mapping, alive, local, route_bias, rweights = rt_arrays
                rt = rt0._replace(mapping=mapping, alive=alive,
                                  local_table=local, route_bias=route_bias,
                                  replica_weights=rweights)
            return ParallelCtx(moe_runtime=rt, gemm_impl=gemm_impl,
                               remat=False)

        def prefill_fn(params, tokens, rt_arrays):
            return model.prefill(params, tokens, ctx_of(rt_arrays),
                                 max_slots=max_seq)

        def decode_step(params, tokens, cache, rt_arrays):
            return model.decode_step(params, tokens, cache,
                                     ctx_of(rt_arrays))

        def decode_fn(params, tokens, cache, rt_arrays):
            if self.decode_mode == "lockstep":
                logits, cache, st = decode_step(params, tokens, cache,
                                                rt_arrays)
            else:
                logits, cache, st = split_batch_decode(
                    lambda t, c: decode_step(params, t, c, rt_arrays),
                    tokens, cache, axis=model.cache_batch_axis,
                    enabled=(self.decode_mode == "pipelined"))
            # per-expert token counts feed the pool's traffic EMA — this is
            # what rebalance() and traffic-aware scale_to re-plan from
            return logits, cache, st.expert_load

        self._jit_prefill = jax.jit(prefill_fn)
        self._jit_decode = jax.jit(decode_fn)
        self._jit_masked = None
        if model.cache_batch_axis is not None:
            # async exec mode decodes a *subset* of slots while others wait
            # on their wave's completion event: the dense decode kernel
            # still runs the full batch (one compile, one shape), but
            # inactive rows' cache writes are masked back to their old
            # values so a later wave resumes them bit-exactly.
            def masked_decode_fn(params, tokens, cache, mask, rt_arrays):
                logits, new_cache, st = decode_step(params, tokens, cache,
                                                    rt_arrays)
                axis = model.cache_batch_axis

                def keep(new, old):
                    if not hasattr(new, "shape") or new.ndim <= axis \
                            or new.shape[axis] != mask.shape[0]:
                        return new
                    shape = [1] * new.ndim
                    shape[axis] = mask.shape[0]
                    return jnp.where(mask.reshape(shape), new, old)

                return logits, jax.tree.map(keep, new_cache, cache), \
                    st.expert_load
            self._jit_masked = jax.jit(masked_decode_fn)
        self._jit_chunk = None
        if model.prefill_chunk is not None:
            def chunk_fn(params, tokens, cache, start, rt_arrays):
                logits, cache, st = model.prefill_chunk(
                    params, tokens, cache, start, ctx_of(rt_arrays))
                # chunked prefill feeds the traffic EMA like decode does —
                # the prompt-heavy-workload rebalance signal
                return logits, cache, st.expert_load
            self._jit_chunk = jax.jit(chunk_fn)

        if self.kv_mode == "paged":
            # block tables / lengths enter as data each call — host-side
            # admission, sharing and preemption never recompile
            def paged_decode_fn(params, tokens, cache, tables, lengths,
                                rt_arrays):
                cache = _with_tables(cache, tables, lengths)
                logits, cache, st = decode_step(params, tokens, cache,
                                                rt_arrays)
                return logits, cache, st.expert_load

            def paged_chunk_fn(params, tokens, cache, row, start, rt_arrays):
                view = _with_tables(cache, row[None],
                                    jnp.broadcast_to(start, (1,)))
                logits, view, st = model.prefill_chunk(
                    params, tokens, view, start, ctx_of(rt_arrays))
                return logits, view, st.expert_load

            def copy_fn(cache, src, dst):
                return {k: kvc.copy_blocks(st, src, dst, stacked=True)
                        for k, st in cache.items()}

            self._jit_paged_decode = jax.jit(paged_decode_fn)
            self._jit_paged_chunk = jax.jit(paged_chunk_fn)
            self._jit_copy = jax.jit(copy_fn)

    def _rt_arrays(self):
        if self.pool is None:
            return ()
        rt = self.pool.runtime(self.gemm_impl)
        return (rt.mapping, rt.alive, rt.local_table, rt.route_bias,
                rt.replica_weights)

    # ------------------------------------------------------------ prefill
    def prefill(self, slot: int, prompt: np.ndarray) -> jax.Array:
        """Whole-prompt prefill straight into ``slot`` of the batch cache."""
        tokens = jnp.asarray(prompt, jnp.int32)[None]
        logits, cache_one = self._jit_prefill(self.params, tokens,
                                              self._rt_arrays())
        self.cache = jax.tree.map(
            lambda big, one: _slot_write(big, one, slot),
            self.cache, cache_one)
        return logits

    def prefill_chunk(self, slot: int, chunk: np.ndarray, start: int,
                      *, is_first: bool, is_last: bool
                      ) -> Tuple[jax.Array, np.ndarray]:
        """One chunked-prefill continuation step for ``slot``; returns
        ``(logits, expert_load)`` — the chunk's router traffic feeds the
        same EMA decode steps do.

        Chunks accumulate in a batch-1 staging cache; the final chunk
        commits the staging cache into the batch cache slot.
        """
        assert self._jit_chunk is not None, "model has no prefill_chunk"
        if is_first:
            self._staging[slot] = self.model.init_cache(1, self.max_seq)
        tokens = jnp.asarray(chunk, jnp.int32)[None]
        logits, staging, expert_load = self._jit_chunk(
            self.params, tokens, self._staging[slot],
            jnp.asarray(start, jnp.int32), self._rt_arrays())
        self._staging[slot] = staging
        if is_last:
            self.cache = jax.tree.map(
                lambda big, one: _slot_write(big, one, slot),
                self.cache, self._staging.pop(slot))
        return logits, expert_load

    # ------------------------------------------------------------- decode
    def decode(self, tokens: np.ndarray) -> Tuple[jax.Array, np.ndarray]:
        """One decode step over the whole slot batch -> (logits, load)."""
        logits, self.cache, expert_load = self._jit_decode(
            self.params, jnp.asarray(tokens), self.cache, self._rt_arrays())
        return logits, expert_load

    def decode_masked(self, tokens: np.ndarray, mask: np.ndarray
                      ) -> Tuple[jax.Array, np.ndarray]:
        """One decode step where only ``mask``-true slots advance their
        cache row; masked rows are restored bit-exactly (the dense
        ``append_decode`` advances length for every row, so the restore is
        what keeps inactive slots resumable).  Active rows' logits are
        bitwise identical to a full-batch :meth:`decode` — decode outputs
        are batch-composition independent, which is what lets the async
        engine reuse lockstep's values with different timing."""
        assert self._jit_masked is not None, \
            "decode_masked needs a uniform cache batch axis"
        logits, self.cache, expert_load = self._jit_masked(
            self.params, jnp.asarray(tokens), self.cache,
            jnp.asarray(mask, bool), self._rt_arrays())
        return logits, expert_load

    # -------------------------------------------------------------- paged
    def prefill_chunk_paged(self, chunk: np.ndarray, start: int,
                            table_row: np.ndarray
                            ) -> Tuple[jax.Array, np.ndarray]:
        """One (chunked or whole-suffix) prefill step through the block
        table; returns ``(logits, expert_load)``.  The pool blocks are the
        real storage — no staging cache — so a prefix-cache hit simply
        starts ``start`` past the cached prefix and the chunk attends over
        blocks an earlier request wrote.
        """
        tokens = jnp.asarray(chunk, jnp.int32)[None]
        logits, view, expert_load = self._jit_paged_chunk(
            self.params, tokens, self.cache,
            jnp.asarray(table_row, jnp.int32),
            jnp.asarray(start, jnp.int32), self._rt_arrays())
        self.cache = _adopt_pools(self.cache, view)
        return logits, expert_load

    def decode_paged(self, tokens: np.ndarray, tables: np.ndarray,
                     lengths: np.ndarray) -> Tuple[jax.Array, np.ndarray]:
        """One decode step with host-authoritative block tables/lengths."""
        logits, cache, expert_load = self._jit_paged_decode(
            self.params, jnp.asarray(tokens), self.cache,
            jnp.asarray(tables, jnp.int32), jnp.asarray(lengths, jnp.int32),
            self._rt_arrays())
        self.cache = cache
        return logits, expert_load

    def copy_blocks(self, pairs) -> None:
        """Apply copy-on-write forks: pool blocks src -> dst, every layer."""
        if not pairs:
            return
        src = jnp.asarray([s for s, _ in pairs], jnp.int32)
        dst = jnp.asarray([d for _, d in pairs], jnp.int32)
        self.cache = self._jit_copy(self.cache, src, dst)

    # ----------------------------------------------------------- rebalance
    def migrate_slots(self, updates) -> None:
        """Apply one incremental expert-weight migration chunk: copy the
        listed experts into their new redundant slots across every MoE
        layer (``updates: [(server, local_slot, expert_id)]``).  Weights
        are jit *arguments*, so the swap never recompiles; the pool drops
        the old replica from the mapping before this copy and commits the
        new mapping/local-table only after it lands (break-before-make)."""
        E = self.model.cfg.moe.num_experts
        self.params = _map_server_weights(
            self.params,
            lambda sw: expert_server.migrate_slots(sw, E, updates))

    # ------------------------------------------------------------- elastic
    def resize(self, pool) -> None:
        """Adopt a resized expert-server pool: re-shard the expert weights
        from the recovered global bank and rebuild the jitted variants for
        the new static server count.  The batch KV cache and any staging
        caches are untouched — scaling never drops in-flight work."""
        self.pool = pool
        E = self.model.cfg.moe.num_experts
        n = pool.num_servers
        red = pool.redundant_table
        self.params = _map_server_weights(
            self.params,
            lambda sw: expert_server.reshard_server_weights(sw, E, n, red))
        self._rt0 = pool.runtime(self.gemm_impl)
        self._build_jits()


# ------------------------------------------------------------------ helpers

def _with_tables(cache, tables, lengths):
    """Rebind block tables / lengths into every stacked PagedKVCache leaf
    (broadcast over the leading layer dim the layer scan expects)."""
    def one(stack):
        n = stack.k.shape[0]
        return dataclasses.replace(
            stack,
            block_tables=jnp.broadcast_to(tables[None],
                                          (n,) + tables.shape),
            length=jnp.broadcast_to(lengths[None], (n,) + lengths.shape))
    return {k: one(v) for k, v in cache.items()}


def _adopt_pools(cache, view):
    """Take the (shared) pool arrays back from a batch-1 prefill view;
    tables/lengths stay host-authoritative."""
    return {k: dataclasses.replace(cache[k], k=view[k].k, v=view[k].v)
            for k in cache}


def _map_server_weights(params, fn):
    """Apply ``fn`` to every MoE layer's per-server weight dict in a params
    tree (the ``{"moe": {"servers": ...}}`` sub-dicts), leaving everything
    else untouched."""
    if isinstance(params, dict):
        out = {}
        for k, v in params.items():
            if k == "moe" and isinstance(v, dict) and "servers" in v:
                out[k] = dict(v, servers=fn(v["servers"]))
            else:
                out[k] = _map_server_weights(v, fn)
        return out
    return params


def _slot_write(big, one, b: int):
    """Write a batch-1 cache pytree leaf into slot b of the engine cache.

    The batch dim is the first one where `big` and `one` differ with
    ``one == 1``.
    """
    if not hasattr(big, "shape"):
        return big
    if big.shape == getattr(one, "shape", None):
        return one.astype(big.dtype)      # max_batch == 1: replace wholesale
    for axis, (db, do) in enumerate(zip(big.shape, one.shape)):
        if db != do and do == 1:
            idx = [slice(None)] * big.ndim
            idx[axis] = slice(b, b + 1)
            return big.at[tuple(idx)].set(one.astype(big.dtype))
    return big
