"""Elastic scaling of the expert-service tier (paper §5.3).

Monolithic EP scales in units of whole communication groups; EAAS scales one
server at a time.  On TPU the *logical* server pool (mapping table) changes
freely at runtime; the *physical* mesh changes through AOT-compiled variants
(jit caches one executable per server-count).  This module provides:

* :class:`ServerPool` — host-side pool with add/remove/rebalance, emitting
  fresh MoERuntime arrays each change (no recompile for liveness/mapping
  changes; recompile only when the physical mesh itself grows).
* :func:`provision` — the traffic→server-count policy used by the weak-
  scaling benchmark (the paper's 37.5% saving comes from this curve).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import load_balance
from repro.core.mapping import ExpertServerMap
from repro.core.moe_layer import MoERuntime, default_capacity


@dataclass
class ServerPool:
    """Logical expert-server pool with liveness + replication state."""

    cfg: ModelConfig
    num_servers: int
    tokens_per_client: int
    n_redundant: int = 2
    max_replicas: int = 4
    # relative per-server capacity weights ((S,) or None = homogeneous);
    # heterogeneous pools tilt replica placement toward the big servers
    capacities: np.ndarray = None
    stats: load_balance.ExpertStats = None
    smap: ExpertServerMap = None
    redundant_table: np.ndarray = None
    route_bias: np.ndarray = None

    def __post_init__(self):
        E = self.cfg.moe.num_experts
        self.stats = load_balance.ExpertStats(E)
        self.route_bias = np.zeros(E, np.float32)
        # scale-to-zero state: experts paged out of the tier (replica slots
        # evicted; the primary shard stays addressable as the page-in
        # source) and, for hysteresis, when each one last paged back in
        self.cold: set = set()
        self.page_in_t: dict = {}
        mapping, red = self.plan(np.ones(E))
        self.smap = self._make_smap(mapping)
        self.redundant_table = red

    def _make_smap(self, mapping: np.ndarray) -> ExpertServerMap:
        """Build the live mapping table with replica-column headroom: an
        in-flight incremental migration registers a new replica before a
        later chunk drops the old one, so an expert can transiently hold up
        to (old + new) replicas — double width absorbs the worst case."""
        E = mapping.shape[0]
        pad = np.full((E, self.max_replicas), -1, np.int32)
        return ExpertServerMap(np.concatenate([mapping, pad], axis=1),
                               self.num_servers)

    # ------------------------------------------------------------- events
    def server_failed(self, rank: int) -> None:
        self.smap.mark_dead(rank)

    def server_recovered(self, rank: int) -> None:
        self.smap.mark_alive(rank)

    def observe_load(self, expert_load: np.ndarray) -> None:
        self.stats.update(expert_load)

    def set_route_bias(self, bias: np.ndarray) -> None:
        """Install a router-logit offset (scenario traffic shaping)."""
        bias = np.asarray(bias, np.float32)
        assert bias.shape == self.route_bias.shape, bias.shape
        self.route_bias = bias

    # ---------------------------------------------------------- balancing
    def plan(self, load: Optional[np.ndarray] = None
             ) -> Tuple[np.ndarray, np.ndarray]:
        """EPLB plan for this pool from ``load`` (default: the traffic EMA,
        uniform when nothing has been observed)."""
        if load is None:
            load = (self.stats.ema if self.stats.ema is not None
                    else np.ones(self.cfg.moe.num_experts))
        if self.cold:
            # paged-out experts must not attract replicas: mask their load
            # so the planner spends the redundant slots on resident experts
            load = np.asarray(load, np.float64).copy()
            for e in self.cold:
                if 0 <= e < load.shape[0]:
                    load[e] = 0.0
        return load_balance.eplb_plan(
            load, self.num_servers, self.n_redundant, self.max_replicas,
            capacities=self.capacities)

    @property
    def plan_digest(self) -> str:
        """Digest of the live placement's replica sets (order-free)."""
        return load_balance.plan_digest(self.smap.table, self.num_servers)

    def current_imbalance(self) -> float:
        """max/mean per-alive-server load of the traffic EMA under the live
        placement — the factor the slowest server stretches a decode step."""
        if self.stats.ema is None:
            return 1.0
        return load_balance.imbalance(
            self.stats.ema, self.smap.table, self.num_servers,
            alive=self.smap.alive, capacities=self.capacities)

    def client_view(self, client_id: int = 0) -> "PoolClient":
        """A per-client handle on this shared pool (cluster front-end)."""
        return PoolClient(self, client_id)

    def apply_plan(self, mapping: np.ndarray, red: np.ndarray) -> None:
        """Adopt a placement wholesale, preserving liveness (the one-shot
        path; the rebalance controller instead converges incrementally via
        drop_replica/register_replica + per-chunk weight migration)."""
        alive = self.smap.alive.copy()
        self.smap = self._make_smap(mapping)
        self.smap.alive = alive
        self.redundant_table = red

    def rebalance(self) -> bool:
        """Re-plan replication from traffic EMA (paper §4.5 / EPLB).

        Skips the runtime rebuild when the new plan is placement-identical
        to the live table (same replica sets — column order is routing-
        invisible); returns whether the placement changed.
        """
        if self.stats.ema is None:
            return False
        mapping, red = self.plan()
        if load_balance.plan_digest(mapping,
                                    self.num_servers) == self.plan_digest:
            return False
        self.apply_plan(mapping, red)
        return True

    # ------------------------------------------------------- scale-to-zero
    def page_out_experts(self, experts
                         ) -> Tuple[List[int], List[Tuple[int, int, int]]]:
        """Page cold experts out of the tier (serverless experts à la
        MoEless): every replica slot is evicted — dropped from the live
        mapping table and zeroed in the redundant weight banks — and the
        expert is marked cold.  The primary shard stays addressable as the
        page-in source, so a token that *does* route to a cold expert still
        computes exactly (the elasticity identity contract); it pays the
        modeled cold-start penalty instead of dropping.

        Returns ``(paged, updates)``: the experts actually paged and the
        ``(server, local_slot, -1)`` weight updates the caller must apply
        through its migration path (``apply_migration`` /
        ``expert_server.migrate_slots``) to physically zero the bank slots.
        """
        from repro.core import expert_server
        E = self.cfg.moe.num_experts
        prim = load_balance.primary_owner(E, self.num_servers)
        paged: List[int] = []
        updates: List[Tuple[int, int, int]] = []
        for e in sorted({int(x) for x in experts}):
            if not 0 <= e < E or e in self.cold:
                continue
            for s, j in expert_server.replica_columns(
                    self.redundant_table, e):
                self.redundant_table[s, j] = -1
                updates.append((s, expert_server.redundant_slot(
                    E, self.num_servers, j), -1))
            row = self.smap.table[e]
            row[:] = -1
            row[0] = prim[e]           # primary only: the page-in source
            self.cold.add(e)
            self.page_in_t.pop(e, None)
            paged.append(e)
        return paged, updates

    def page_in_expert(self, e: int, t: float) -> bool:
        """First token routed to a cold expert: mark it resident again and
        record the page-in time (the autoscaler's hysteresis protects a
        freshly paged-in expert from immediately paging back out).  The
        expert serves from its primary shard until the next rebalance
        re-plans replicas for it — ``plan`` stops masking its load the
        moment it leaves ``cold``.  Returns whether a page-in happened."""
        e = int(e)
        if e not in self.cold:
            return False
        self.cold.discard(e)
        self.page_in_t[e] = float(t)
        return True

    def resident_fraction(self) -> float:
        """Share of experts currently resident in the tier (1.0 = nothing
        paged out) — the expert-tier term of provisioned-resource
        accounting."""
        return 1.0 - len(self.cold) / self.cfg.moe.num_experts

    # ------------------------------------------------------------- elastic
    def feasible_counts(self) -> List[int]:
        """Pool sizes the block-contiguous primary layout supports (E % n == 0)."""
        E = self.cfg.moe.num_experts
        return [n for n in range(1, E + 1) if E % n == 0]

    def scale_to(self, n: int) -> None:
        """Grow/shrink the logical pool to ``n`` servers (paper §5.3).

        Re-plans the EPLB mapping for the new size from the traffic EMA
        (uniform load when no traffic has been observed yet) and preserves
        the liveness mask of surviving ranks; newly added ranks start
        alive.  The caller owns the weight path — see
        :func:`repro.core.expert_server.reshard_server_weights`.
        """
        E = self.cfg.moe.num_experts
        if E % n:
            raise ValueError(
                f"cannot scale to {n} servers: {E} experts need E % n == 0 "
                f"(feasible: {self.feasible_counts()})")
        if n == self.num_servers:
            return
        load = self.stats.ema if self.stats.ema is not None else np.ones(E)
        old_alive = self.smap.alive
        self.num_servers = n
        if self.capacities is not None:     # keep surviving ranks' weights
            caps = np.ones(n, np.float64)
            k = min(len(self.capacities), n)
            caps[:k] = np.asarray(self.capacities, np.float64)[:k]
            self.capacities = caps
        # a resize re-provisions every rank (weights reshard from the master
        # bank), so paged-out experts come back resident; the autoscaler
        # pages them out again once its cooldown re-opens
        self.cold.clear()
        self.page_in_t.clear()
        mapping, red = self.plan(load)
        self.smap = self._make_smap(mapping)
        k = min(len(old_alive), n)
        self.smap.alive[:k] = old_alive[:k]
        self.redundant_table = red

    # ------------------------------------------------------------ runtime
    def runtime(self, gemm_impl: str = "auto") -> MoERuntime:
        from repro.core import expert_server
        table, alive = self.smap.device_arrays()
        m = self.cfg.moe
        local = expert_server.make_local_table(
            m.num_experts, self.num_servers, self.redundant_table)
        return MoERuntime(
            mapping=table,
            alive=alive,
            local_table=jnp.asarray(local),
            num_servers=self.num_servers,
            capacity=default_capacity(self.tokens_per_client, m.top_k,
                                      self.num_servers, m.capacity_factor),
            gemm_impl=gemm_impl,
            route_bias=jnp.asarray(self.route_bias),
            replica_weights=(None if self.capacities is None
                             else jnp.asarray(self.capacities, jnp.float32)),
        )


class PoolClient:
    """One attention client's handle on a *shared* :class:`ServerPool`.

    The paper's clients each keep a local expert-to-server mapping *mask*
    over the shared service-discovery table: the table itself (placement,
    replicas, global liveness) is one object every client reads — so
    expert-replica failures and migrations are observed consistently — while
    a client may additionally mask out servers *it* has locally observed
    misbehaving (e.g. a request timeout) before the monitor confirms the
    failure pool-wide.  Everything except :meth:`runtime` delegates to the
    underlying pool; ``runtime`` ANDs the client mask into the liveness
    array fed to the jitted step (pure data — never recompiles).
    """

    def __init__(self, pool: ServerPool, client_id: int = 0):
        self.pool = pool
        self.client_id = client_id
        self._masked: set = set()      # server ranks this client masked out

    # ------------------------------------------------------- client mask
    def mask_server(self, rank: int) -> None:
        """Locally stop routing to ``rank`` (this client only)."""
        self._masked.add(int(rank))

    def unmask_server(self, rank: int) -> None:
        self._masked.discard(int(rank))

    @property
    def masked_servers(self) -> Tuple[int, ...]:
        return tuple(sorted(self._masked))

    def alive_mask(self) -> np.ndarray:
        """(S,) shared liveness AND the client's local mask."""
        mask = self.pool.smap.alive.copy()
        for r in self._masked:
            if r < mask.shape[0]:
                mask[r] = False
        return mask

    def runtime(self, gemm_impl: str = "auto") -> MoERuntime:
        rt = self.pool.runtime(gemm_impl)
        if not self._masked:
            return rt                  # fast path: the shared view verbatim
        return rt._replace(alive=jnp.asarray(self.alive_mask()))

    # ------------------------------------------------------- delegation
    def __getattr__(self, name):
        return getattr(self.pool, name)


def provision(request_rate: float, rate_per_server: float,
              granularity: int = 1) -> int:
    """Servers needed for a traffic level, at EAAS (1) vs monolithic (group)
    granularity.  The scaling benchmark sweeps this for both."""
    need = max(1, math.ceil(request_rate / max(rate_per_server, 1e-9)))
    return int(math.ceil(need / granularity) * granularity)


def resource_saving(request_rate: float, rate_per_server: float,
                    monolithic_group: int) -> float:
    """Fraction of chips EAAS saves vs group-granular scaling (paper: 37.5%)."""
    fine = provision(request_rate, rate_per_server, 1)
    coarse = provision(request_rate, rate_per_server, monolithic_group)
    return 1.0 - fine / coarse
