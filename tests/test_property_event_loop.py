"""Hypothesis property sweep over the async tier's discrete-event
primitives.

* the event heap fires in nondecreasing ``(time, seq)`` order with the
  deterministic tie-break, under arbitrary post/cancel interleavings;
* micro-batch queues conserve work (``enqueued == completed + cancelled +
  in_flight``) through random dispatch / straggler / failure / recovery /
  drain sequences, and no completion ever precedes its dispatch;
* the per-expert lane refinement: every lane balances ``enqueued ==
  drained + cancelled + moved + in_flight()`` through random lane
  dispatch / failure / resize sequences, lane in-flight sums match the
  tier, and service is FIFO within each lane;
* replaying the same seed yields an identical event-log fingerprint —
  in aggregate mode and in lane mode (expert-keyed payloads included).
"""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install "
    "hypothesis); hypothesis-free coverage of the same invariants lives "
    "in test_event_loop.py")
from hypothesis import given, settings, strategies as st

from repro.serving import AsyncExpertTier, EventTimeline

_times = st.lists(
    st.floats(0.0, 100.0, allow_nan=False, allow_infinity=False, width=32),
    min_size=1, max_size=60)


@settings(max_examples=50, deadline=None)
@given(times=_times)
def test_heap_pops_nondecreasing_with_deterministic_ties(times):
    tl = EventTimeline()
    for i, t in enumerate(times):
        tl.post(t, "ev", idx=i)
    fired = []
    while True:
        ev = tl.pop()
        if ev is None:
            break
        fired.append(ev)
    assert len(fired) == len(times)
    key = [(ev.time, ev.seq) for ev in fired]
    assert key == sorted(key)
    # ties fire in post order: seqs within one timestamp are increasing,
    # and the overall order equals a stable sort of the posts by time
    assert [ev.payload["idx"] for ev in fired] \
        == [i for _, i in sorted(zip(times, range(len(times))),
                                 key=lambda p: p[0])]


@settings(max_examples=50, deadline=None)
@given(times=_times, drop=st.sets(st.integers(0, 59)))
def test_heap_cancellation_never_fires(times, drop):
    tl = EventTimeline()
    evs = [tl.post(t, "ev", idx=i) for i, t in enumerate(times)]
    for i in drop:
        if i < len(evs):
            tl.cancel(evs[i])
    live = {i for i in range(len(times))} - drop
    fired = []
    while True:
        ev = tl.pop()
        if ev is None:
            break
        fired.append(ev.payload["idx"])
    assert sorted(fired) == sorted(live)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), servers=st.integers(1, 6),
       waves=st.integers(1, 25))
def test_tier_conservation_under_random_operations(seed, servers, waves):
    """Random dispatch / slow_server / fail / recover / drain sequences:
    the conservation counter always balances, service is causal (a
    micro-batch never starts before its dispatch nor finishes before it
    starts, even across failure re-dispatch), and the per-server frontier
    never runs backwards past committed work."""
    rng = np.random.default_rng(seed)
    tier = AsyncExpertTier(servers)
    now = 0.0
    for w in range(waves):
        now += float(rng.uniform(0.0, 2e-3))
        work = rng.uniform(0.0, 1e-3, servers) \
            * (rng.random(servers) < 0.8)
        for mb in tier.dispatch(0, w, work, now):
            assert mb.enqueue_t == now
            assert mb.start_t >= mb.enqueue_t
            assert mb.finish_t >= mb.start_t
        op = rng.random()
        if op < 0.15:
            tier.fail_server(int(rng.integers(servers)), now)
        elif op < 0.30:
            tier.recover_server(int(rng.integers(servers)), now)
        elif op < 0.40:
            tier.set_slowdown(int(rng.integers(servers)),
                              float(rng.uniform(0.25, 5.0)))
        elif op < 0.45:
            tier.occupy_all(now, float(rng.uniform(0.0, 1e-3)))
        # drain whatever has finished by now (event order irrelevant to
        # the counters)
        for mb in list(tier.mbs.values()):
            if not mb.done and not mb.cancelled and mb.finish_t <= now:
                tier.mark_done(mb)
        assert tier.in_flight() >= 0
        assert tier.enqueued == tier.completed + tier.cancelled \
            + tier.in_flight()
        # retired entries are pruned at retirement: the mb table holds
        # exactly the in-flight work (bounded memory under any schedule)
        assert len(tier.mbs) == tier.in_flight()
    # every re-dispatched batch still respects causality
    for mb in tier.mbs.values():
        assert mb.finish_t >= mb.start_t >= mb.enqueue_t
    drained = sum(q.drained for q in tier.queues)
    assert drained == tier.completed


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), servers=st.integers(1, 5),
       budget=st.integers(1, 3), waves=st.integers(1, 20))
def test_lane_conservation_under_random_operations(seed, servers, budget,
                                                   waves):
    """The per-lane refinement of the conservation sweep: through random
    lane dispatch / straggler / failure / recovery / resize sequences,
    every lane balances ``enqueued == drained + cancelled + moved +
    in_flight()``, the lanes' in-flight sum equals the tier's, and lane
    service stays causal and FIFO within each lane."""
    rng = np.random.default_rng(seed)
    tier = AsyncExpertTier(servers, lane_budget=budget)
    now = 0.0
    for w in range(waves):
        now += float(rng.uniform(0.0, 2e-3))
        n = tier.num_servers
        entries = [(int(rng.integers(n)), int(rng.integers(4)),
                    float(rng.uniform(0.0, 1e-3)))
                   for _ in range(int(rng.integers(0, 2 * n + 1)))]
        for mb in tier.dispatch_lanes(0, w, entries, now):
            assert mb.finish_t >= mb.start_t >= mb.enqueue_t == now
        op = rng.random()
        if op < 0.15:
            tier.fail_server(int(rng.integers(tier.num_servers)), now)
        elif op < 0.30:
            tier.recover_server(int(rng.integers(tier.num_servers)), now)
        elif op < 0.40:
            tier.set_slowdown(int(rng.integers(tier.num_servers)),
                              float(rng.uniform(0.25, 5.0)))
        elif op < 0.45:
            tier.resize(int(rng.integers(1, servers + 2)), now)
        for mb in list(tier.mbs.values()):
            if not mb.done and not mb.cancelled and mb.finish_t <= now:
                tier.mark_done(mb)
        for ln in tier.lanes():
            assert ln.enqueued == ln.drained + ln.cancelled + ln.moved \
                + ln.in_flight()
            assert ln.in_flight() >= 0
        assert sum(ln.in_flight() for ln in tier.lanes()) \
            == tier.in_flight()
        assert tier.enqueued == tier.completed + tier.cancelled \
            + tier.in_flight()
        # FIFO within each live lane: in-flight start times follow
        # dispatch order (mb_id).  Re-dispatched batches (generation > 0)
        # re-queue at their *arrival* order, not their original mb_id, so
        # the dispatch-order check applies to generation-0 work
        per_lane = {}
        for mb in sorted(tier.mbs.values(), key=lambda m: m.mb_id):
            if mb.generation > 0:
                continue
            key = (mb.server, mb.expert)
            if key in per_lane:
                assert mb.start_t >= per_lane[key]
            per_lane[key] = mb.start_t


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_same_seed_same_lane_event_log_fingerprint(seed):
    """Lane-mode determinism: one seeded lane schedule (expert-keyed
    dispatch, budget 2, random stragglers) replayed twice produces
    bitwise-identical fired-event logs including the lane payloads."""
    def play():
        rng = np.random.default_rng(seed)
        tl = EventTimeline()
        tier = AsyncExpertTier(3, lane_budget=2)
        now = 0.0
        for w in range(12):
            now += float(rng.uniform(0.0, 1e-3))
            entries = [(int(rng.integers(3)), int(rng.integers(4)),
                        float(rng.uniform(0.0, 1e-3)))
                       for _ in range(int(rng.integers(1, 5)))]
            for mb in tier.dispatch_lanes(0, w, entries, now):
                tl.post(mb.finish_t, "mb_done", mb=mb.mb_id,
                        server=mb.server, expert=mb.expert)
            if rng.random() < 0.2:
                tier.set_slowdown(int(rng.integers(3)),
                                  float(rng.uniform(0.5, 3.0)))
        while tl.pop() is not None:
            pass
        return tl.fingerprint()

    assert play() == play()


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_same_seed_same_event_log_fingerprint(seed):
    """The determinism contract at the primitive level: one seeded
    schedule replayed twice produces bitwise-identical fired-event logs
    (hence equal fingerprints)."""
    def play():
        rng = np.random.default_rng(seed)
        tl = EventTimeline()
        tier = AsyncExpertTier(3)
        now = 0.0
        for w in range(12):
            now += float(rng.uniform(0.0, 1e-3))
            for mb in tier.dispatch(0, w, rng.uniform(0.0, 1e-3, 3), now):
                tl.post(mb.finish_t, "mb_done", mb=mb.mb_id,
                        server=mb.server)
            if rng.random() < 0.2:
                tier.set_slowdown(int(rng.integers(3)),
                                  float(rng.uniform(0.5, 3.0)))
        while tl.pop() is not None:
            pass
        return tl.fingerprint()

    assert play() == play()
