"""Front-end request routing over N attention clients (paper §3.1).

EAAS disaggregates attention clients from the expert tier, so "the system"
is M stateless-ish clients fanning into one shared pool of expert servers —
and *request* routing across clients becomes its own policy surface,
orthogonal to the *expert* routing the MoE layer does per token.  A
:class:`FrontendRouter` picks the client for each arriving request; the
:class:`~repro.serving.cluster.Cluster` filters the candidate set first
(alive + under the admission backpressure limit) and holds requests in its
ingress queue when nobody is admissible.

Policies (all deterministic — pure functions of the request stream and the
observable client state, so seeded cluster runs fingerprint-identically):

* ``round_robin``      — cycle over the client ring, skipping inadmissible
  clients; the fairness baseline.
* ``least_loaded``     — score each candidate by its unprefilled prompt
  backlog minus its free KV capacity (both in tokens): the client with the
  most headroom wins, ties to the lowest index.  This is the signal pair
  the autoscaler also watches — queue pressure *and* attention-tier
  memory.
* ``session_affinity`` — hash the prompt's leading block to a home client,
  so shared-prefix traffic (multi-tenant system prompts) lands on the
  client whose BlockPool already caches the prefix; falls forward around
  the ring when the home client is inadmissible.
"""

from __future__ import annotations

import hashlib
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.serving.request import Request

FRONTEND_POLICIES = ("round_robin", "least_loaded", "session_affinity")


class FrontendRouter:
    """Policy interface: pick one client index out of the admissible set.

    ``candidates`` is the cluster-filtered list of ``(index, engine)``
    pairs (alive, under backpressure), always non-empty, in index order.
    ``n_clients`` is the full ring size — affinity hashing must stay a
    function of the ring, not of the momentary admissible subset, or a
    transient backpressure blip would permanently re-home a prefix.
    """

    name = "base"

    def __init__(self, n_clients: int):
        self.n_clients = n_clients

    def pick(self, req: Request, candidates: Sequence[Tuple[int, object]]
             ) -> int:
        raise NotImplementedError


class RoundRobin(FrontendRouter):
    name = "round_robin"

    def __init__(self, n_clients: int):
        super().__init__(n_clients)
        self._next = 0

    def pick(self, req, candidates):
        admissible = {i for i, _ in candidates}
        for j in range(self.n_clients):
            idx = (self._next + j) % self.n_clients
            if idx in admissible:
                self._next = (idx + 1) % self.n_clients
                return idx
        raise AssertionError("pick() called with no admissible client")


class LeastLoaded(FrontendRouter):
    name = "least_loaded"

    def pick(self, req, candidates):
        def score(item):
            idx, eng = item
            # both terms are token-denominated: outstanding prefill work
            # the client still owes vs. KV capacity it can still admit into
            return (eng.pending_prefill_tokens() - eng.free_kv_tokens(),
                    idx)
        return min(candidates, key=score)[0]


class SessionAffinity(FrontendRouter):
    name = "session_affinity"

    def __init__(self, n_clients: int, block_size: int = 16):
        super().__init__(n_clients)
        self.block_size = max(int(block_size), 1)

    def home(self, prompt: np.ndarray) -> int:
        """The prompt's home client: hash of its leading block (the same
        unit the BlockPool prefix cache keys on, so requests that would
        share cached blocks share a home)."""
        head = np.asarray(prompt[:self.block_size], np.int32)
        h = hashlib.sha256(head.tobytes()).digest()
        return int.from_bytes(h[:8], "big") % self.n_clients

    def pick(self, req, candidates):
        admissible = {i for i, _ in candidates}
        home = self.home(req.prompt)
        for j in range(self.n_clients):
            idx = (home + j) % self.n_clients
            if idx in admissible:
                return idx
        raise AssertionError("pick() called with no admissible client")


def make_frontend_router(policy: str, n_clients: int,
                         block_size: Optional[int] = None) -> FrontendRouter:
    if policy == "round_robin":
        return RoundRobin(n_clients)
    if policy == "least_loaded":
        return LeastLoaded(n_clients)
    if policy == "session_affinity":
        return SessionAffinity(n_clients, block_size=block_size or 16)
    raise ValueError(f"unknown frontend policy {policy!r}; expected one of "
                     f"{FRONTEND_POLICIES}")
