"""End-to-end serving driver (the paper's deployment kind): serve a reduced
DeepSeek-R1-family MoE with batched requests through the continuous-batching
engine, inject a hardware failure mid-run, rebalance hot experts, and print
throughput / inter-token-latency metrics.

Run:  PYTHONPATH=src python examples/serve_moe.py [--requests 16]
"""

import argparse

import numpy as np

from repro.configs import get_config
from repro.serving import EngineConfig, Request, SamplingParams, ServingEngine
from repro.training.data import ShareGPTLike


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--mode", default="eaas",
                    choices=["eaas", "monolithic_ep", "tp"])
    args = ap.parse_args()

    cfg = get_config("deepseek-r1").reduced()
    ecfg = EngineConfig(mode=args.mode, num_servers=4, max_batch=4,
                        max_seq=96, n_redundant=2)
    eng = ServingEngine(cfg, ecfg, seed=0)

    # ShareGPT-like workload (bucketed prompt lengths bound prefill compiles)
    dist = ShareGPTLike(seed=0)
    plens, rlens = dist.sample(args.requests)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = int(np.clip(2 ** int(np.log2(max(plens[i] // 64, 1)) + 3), 8, 32))
        eng.submit(Request(
            i, rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32),
            SamplingParams(max_new_tokens=int(min(rlens[i] // 32 + 8, 24)))))

    def chaos(e):
        if e.step_idx == 12:
            print(f"[t={e.clock:.2f}s] *** injecting failure of server 1 "
                  f"(mode={args.mode}) ***")
            e.inject_server_failure(1)
        if e.step_idx == 30:
            print(f"[t={e.clock:.2f}s] server 1 recovers + EPLB rebalance")
            e.recover_server(1)
            e.rebalance()

    metrics = eng.run(max_steps=4000, on_step=chaos)
    print("\n=== serving summary ===")
    for k, v in metrics.summary().items():
        print(f"  {k}: {v}")
    halted = sum(1 for t in metrics.timeline if t.get("halted"))
    print(f"  halted steps: {halted}")
    assert metrics.completed == args.requests


if __name__ == "__main__":
    main()
