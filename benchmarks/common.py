"""Shared benchmark plumbing: reduced serving setups, timing, CSV output."""

from __future__ import annotations

import json
import os
from typing import Dict, Iterable, List

import numpy as np

from repro.configs import get_config
from repro.serving import (Cluster, ClusterConfig, EngineConfig, Request,
                           SamplingParams, Scenario, ServingEngine,
                           VirtualClock, WallClock)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "bench")


def bench_model_cfg(arch: str = "deepseek-r1"):
    """The paper's evaluation model family, reduced to CPU scale."""
    return get_config(arch).reduced()


def make_requests(n: int, prompt_len: int = 8, max_new: int = 16,
                  vocab: int = 512, seed: int = 0) -> List[Request]:
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(0, vocab, size=prompt_len).astype(
        np.int32), SamplingParams(max_new_tokens=max_new)) for i in range(n)]


def make_clock(kind="wall"):
    """Benchmark time base: "wall" (real step times, relative CPU curves),
    "virtual" (deterministic analytic model — same numbers every run), or a
    ready-made Clock instance (custom virtual cost constants)."""
    if kind == "wall":
        return WallClock()
    if kind == "virtual":
        return VirtualClock()
    if hasattr(kind, "stop"):
        return kind
    raise ValueError(kind)


def run_engine(cfg, ecfg: EngineConfig, requests: Iterable[Request],
               on_step=None, warmup: bool = True, seed: int = 0,
               clock: str = "wall"):
    eng = ServingEngine(cfg, ecfg, seed=seed, clock=make_clock(clock))
    if warmup:  # compile prefill+decode outside the measured window
        w = make_requests(1, prompt_len=8, max_new=2, vocab=cfg.vocab_size,
                          seed=99)[0]
        eng.submit(w)
        eng.run(max_steps=10)
        eng.metrics.__init__()
        eng.clock = 0.0
        eng.step_idx = 0
        eng.halted_until = -1
    for r in requests:
        eng.submit(r)
    metrics = eng.run(max_steps=20_000, on_step=on_step)
    return eng, metrics


def run_scenario(cfg, ecfg: EngineConfig, scenario: Scenario, seed: int = 0,
                 clock: str = "virtual", max_steps: int = 20_000):
    """Replay a scripted scenario on a fresh engine (scenario-driven
    benchmarks: one parameterized sweep instead of hand-rolled loops)."""
    eng = ServingEngine(cfg, ecfg, seed=seed, clock=make_clock(clock))
    res = scenario.run(eng, max_steps=max_steps)
    return eng, res


def run_cluster_scenario(cfg, ccfg: ClusterConfig, scenario: Scenario,
                         seed: int = 0, clock: str = "virtual",
                         max_steps: int = 20_000):
    """Replay a scripted scenario on a fresh N-client :class:`Cluster`
    (scenario.clients and ccfg.clients should agree; the front-end routes
    the same seeded trace across the clients)."""
    cl = Cluster(cfg, ccfg, seed=seed,
                 clock_factory=lambda: make_clock(clock))
    res = scenario.run(cl, max_steps=max_steps)
    return cl, res


def bench_env() -> Dict[str, str]:
    """Resolved runtime versions, stamped into every benchmark JSON.  The
    gate fingerprints are only stable within one resolved jax build (see
    ``constraints.txt``); recording the versions lets ``check_bench.py``
    turn a silent-upgrade fingerprint drift into a named failure."""
    import platform

    import jax
    import jaxlib
    return {"jax": jax.__version__, "jaxlib": jaxlib.__version__,
            "python": platform.python_version()}


def save_result(name: str, payload: Dict) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    payload.setdefault("env", bench_env())
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    return path


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
