"""Physically-disaggregated serving: the paper's client/server protocol,
literally (§3.2–§3.4, Fig. 5–7).

Attention clients and expert servers are independent actors that interact
ONLY through :class:`~repro.core.monitor.SharedBuffer` slots (state flag +
header + payload) — the host-level model of one-sided RDMA.  The server
never initiates communication: it polls its buffer slots, aggregates every
ready request into one dynamic batch, reorganizes tokens by expert, runs
the grouped expert computation, writes results back and flips the flags.

Failure handling is the paper's dual path: the monitor's heartbeat timeout
(path ①) or the client's own request timeout (path ②(b)) — whichever
fires first masks the server out of the client's mapping and the request is
re-sent to a replica.

Deterministic cooperative scheduling (tick()) keeps runs replayable; the
protocol itself is agnostic to who drives the actors.

Relation to the engine stack: this module is the *protocol-literal* model
(real buffers, real grouped GEMMs, polling actors), kept as the reference
for the paper's client/server wire contract.  The serving engine models
the same tier at the timing level instead —
:class:`~repro.serving.event_loop.AsyncExpertTier` micro-batch queues
driven by the :class:`~repro.serving.clock.EventTimeline` under
``EngineConfig.exec_mode="async"``.  Stragglers exist in both:
``ExpertServerProc.slow_factor`` here (the server only serves every Nth
tick, so the client's timeout path fires and replicas absorb the rows),
``AsyncExpertTier.set_slowdown`` there (queued micro-batches stretch).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import mapping as emap
from repro.core.monitor import SharedBuffer
from repro.kernels import ops as kops


class ExpertServerProc:
    """A stateless expert service instance (paper §3.3)."""

    def __init__(self, rank: int, cfg: ModelConfig, bank: Dict,
                 expert_ids: List[int], capacity: int, d_model: int,
                 min_batch: int = 1, slow_factor: int = 1):
        self.rank = rank
        self.cfg = cfg
        self.expert_ids = list(expert_ids)
        self.local = {e: i for i, e in enumerate(self.expert_ids)}
        self.w_gate = jnp.stack([bank["w_gate"][e] for e in expert_ids])
        self.w_up = jnp.stack([bank["w_up"][e] for e in expert_ids])
        self.w_down = jnp.stack([bank["w_down"][e] for e in expert_ids])
        self.buffers: Dict[str, SharedBuffer] = {}
        self.capacity = capacity
        self.d_model = d_model
        self.min_batch = min_batch
        # straggler knob: serve only every Nth tick (1 = full speed); the
        # cooperative-tick analogue of AsyncExpertTier.set_slowdown
        self.slow_factor = max(1, int(slow_factor))
        self.alive = True
        self.served_tokens = 0
        self.batches = 0
        self._ticks = 0

    # registration: a client attaches a buffer (paper §4.4 connection setup)
    def attach_client(self, client_id: str) -> SharedBuffer:
        buf = SharedBuffer(self.capacity, self.d_model)
        self.buffers[client_id] = buf
        return buf

    def release_client(self, client_id: str) -> None:
        if client_id in self.buffers:
            self.buffers[client_id].release()

    def tick(self) -> None:
        """Poll flags; aggregate ready slots into ONE dynamic batch.  A
        straggling server (``slow_factor`` > 1) skips all but every Nth
        tick — requests sit in its buffers until the clients' timeout
        path re-routes them to replicas."""
        if not self.alive:
            return
        self._ticks += 1
        if self._ticks % self.slow_factor:
            return
        ready = [(cid, b) for cid, b in self.buffers.items() if b.poll()]
        if len(ready) < self.min_batch:
            return
        hid, eid, sc, spans = [], [], [], []
        for cid, b in ready:
            _, h, e, s = b.take_request()
            spans.append((b, len(h)))
            hid.append(h)
            eid.append(e)
            sc.append(s)
        x = jnp.asarray(np.concatenate(hid))            # (M, d)
        eids = np.concatenate(eid)
        scores = jnp.asarray(np.concatenate(sc))

        # reorganize by local expert + grouped GEMM (Fig. 5)
        slot = np.array([self.local.get(int(e), -1) for e in eids])
        order = np.argsort(slot, kind="stable")
        L = len(self.expert_ids)
        sizes = np.bincount(slot[slot >= 0], minlength=L).astype(np.int32)
        xs = x[jnp.asarray(order)]
        h1 = kops.grouped_gemm(xs, self.w_gate, jnp.asarray(sizes),
                               impl="xla_ragged")
        h2 = kops.grouped_gemm(xs, self.w_up, jnp.asarray(sizes),
                               impl="xla_ragged")
        h = jax.nn.silu(h1.astype(jnp.float32)).astype(h2.dtype) * h2
        y = kops.grouped_gemm(h, self.w_down, jnp.asarray(sizes),
                              impl="xla_ragged")
        out = np.zeros((x.shape[0], self.d_model), np.float32)
        out[order] = np.asarray(y)
        out *= np.asarray(scores)[:, None]              # score-weight
        out[slot < 0] = 0.0                             # not hosted

        off = 0
        for b, n in spans:
            b.write_result(out[off:off + n])
            off += n
        self.served_tokens += int(x.shape[0])
        self.batches += 1


@dataclass
class _Pending:
    server: int
    buf: SharedBuffer
    rows: np.ndarray          # (n,) flat indices into (T*k)
    sent_tick: int


class AttentionClientProc:
    """The MoE-layer client side: route → write slots → poll → combine."""

    def __init__(self, client_id: str, cfg: ModelConfig, router_w: np.ndarray,
                 smap: emap.ExpertServerMap, servers: List[ExpertServerProc],
                 timeout_ticks: int = 3):
        self.client_id = client_id
        self.cfg = cfg
        self.router_w = jnp.asarray(router_w)
        self.smap = smap
        self.servers = servers
        self.timeout = timeout_ticks
        self.buffers = {s.rank: s.attach_client(client_id) for s in servers}
        self.tick_now = 0
        self.retries = 0

    def _route(self, x: np.ndarray):
        from repro.core.router import route
        return route({"w_router": self.router_w}, jnp.asarray(x),
                     self.cfg.moe)

    def moe_layer(self, x: np.ndarray, drive) -> np.ndarray:
        """One full MoE layer through the disaggregated tier.

        ``drive()`` advances servers one tick (the cooperative scheduler).
        Event loop: route unsent rows to alive servers whose slot is free
        (one outstanding request per (client, server) slot — the paper's
        fixed buffer); poll pendings; a response timeout masks the server
        out of the mapping and its rows are re-routed (paper Fig. 6 ②(b)).
        """
        T, d = x.shape
        k = self.cfg.moe.top_k
        r = self._route(x)
        eids = np.asarray(r.expert_ids).reshape(-1)
        scores = np.asarray(r.scores).reshape(-1)
        out = np.zeros((T, d), np.float32)

        unsent = np.arange(T * k)
        pending: List[_Pending] = []
        guard = 0
        while (len(unsent) or pending) and guard < 200:
            guard += 1
            # ---- send phase -------------------------------------------
            if len(unsent):
                table, alive = self.smap.device_arrays()
                sel = np.asarray(emap.lookup(
                    table, alive, jnp.asarray(eids[unsent])[:, None],
                    jnp.asarray(unsent % 1024)[:, None]))[:, 0]
                still_unsent = []
                busy = {p.server for p in pending}
                for s in sorted(set(sel.tolist())):
                    rows = unsent[sel == s]
                    buf = self.buffers[s]
                    if s in busy:
                        still_unsent.extend(rows)      # slot occupied: wait
                        continue
                    if buf.state == 2:                 # stale result: drain
                        buf.try_read_result()
                    if buf.state != 0:                 # stuck slot → dead
                        self.smap.mark_dead(s)
                        self.retries += 1
                        still_unsent.extend(rows)
                        continue
                    buf.write_request(0, x[rows // k], eids[rows],
                                      scores[rows])
                    pending.append(_Pending(s, buf, rows, self.tick_now))
                unsent = np.asarray(still_unsent, dtype=np.int64)
            # ---- poll phase -------------------------------------------
            drive()
            self.tick_now += 1
            still = []
            for p in pending:
                res = p.buf.try_read_result()
                if res is not None:
                    for row, val in zip(p.rows, res):
                        out[row // k] += val
                elif self.tick_now - p.sent_tick > self.timeout:
                    # paper Fig.6 ②(b): timeout → mask server, re-route
                    self.smap.mark_dead(p.server)
                    self.retries += 1
                    unsent = np.concatenate([unsent, p.rows])
                else:
                    still.append(p)
            pending = still
        assert not (len(unsent) or pending), "requests stuck: no live replica"
        return out


def build_cluster(cfg: ModelConfig, n_clients: int, n_servers: int,
                  n_redundant: int = 2, capacity: int = 512, seed: int = 0):
    """Wire up a disaggregated cluster over one weight bank."""
    from repro.core.expert_server import init_expert_weights
    from repro.core.load_balance import eplb_plan
    from repro.core.router import init_router

    from repro.core.load_balance import primary_owner

    m = cfg.moe
    key = jax.random.PRNGKey(seed)
    bank = init_expert_weights(key, cfg)
    mapping, red = eplb_plan(np.ones(m.num_experts), n_servers, n_redundant)
    smap = emap.ExpertServerMap(mapping, n_servers)
    owner = primary_owner(m.num_experts, n_servers)
    servers = []
    for s in range(n_servers):
        hosted = [int(e) for e in np.where(owner == s)[0]] + \
            [int(e) for e in red[s] if e >= 0]
        servers.append(ExpertServerProc(s, cfg, bank, hosted, capacity,
                                        cfg.d_model))
    router_w = np.asarray(
        init_router(jax.random.fold_in(key, 1), cfg.d_model,
                    m.num_experts)["w_router"])
    clients = [AttentionClientProc(f"client{i}", cfg, router_w, smap,
                                   servers) for i in range(n_clients)]
    return clients, servers, smap, bank
