"""Model substrate: attention paths, SSM equivalences, caches, RoPE."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import attention as attn
from repro.models import kv_cache as kvc
from repro.models import mamba, rope, rwkv


# ------------------------------------------------------------- attention

def test_chunked_attention_matches_dense(rng):
    cfg = get_config("granite-3-2b").reduced()
    p = attn.init_attention(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(2, 2048, cfg.d_model)) * 0.1,
                    jnp.float32)
    pos = jnp.arange(2048, dtype=jnp.int32)
    o_chunk = attn.full_attention(p, cfg, x, pos)          # >= threshold
    old = attn.CHUNKED_ATTN_THRESHOLD
    try:
        attn.CHUNKED_ATTN_THRESHOLD = 10 ** 9
        o_dense = attn.full_attention(p, cfg, x, pos)
    finally:
        attn.CHUNKED_ATTN_THRESHOLD = old
    np.testing.assert_allclose(o_chunk, o_dense, rtol=1e-5, atol=1e-5)


def test_sliding_window_mask(rng):
    cfg = get_config("gemma3-4b").reduced()
    p = attn.init_attention(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(1, 128, cfg.d_model)) * 0.1, jnp.float32)
    pos = jnp.arange(128, dtype=jnp.int32)
    o_local = attn.full_attention(p, cfg, x, pos, is_local=True)
    o_global = attn.full_attention(p, cfg, x, pos, is_local=False)
    # early tokens (within the window of everything) agree; late differ
    w = cfg.sliding_window
    np.testing.assert_allclose(o_local[:, :w // 2], o_global[:, :w // 2],
                               rtol=1e-4, atol=1e-4)
    assert not np.allclose(o_local[:, -1], o_global[:, -1])


def test_decode_matches_full(rng):
    cfg = get_config("phi3-medium-14b").reduced()
    p = attn.init_attention(jax.random.PRNGKey(0), cfg)
    S = 16
    x = jnp.asarray(rng.normal(size=(2, S, cfg.d_model)) * 0.2, jnp.float32)
    pos = jnp.arange(S, dtype=jnp.int32)
    o_full, (k, v) = attn.full_attention(p, cfg, x, pos, return_kv=True)
    cache = kvc.init_kv_cache(2, S + 4, cfg.num_kv_heads, cfg.head_dim,
                              jnp.float32)
    cache = kvc.write_prefill(cache, k[:, :-1], v[:, :-1])
    o_dec, cache = attn.decode_attention(p, cfg, x[:, -1:], cache)
    np.testing.assert_allclose(np.asarray(o_dec[:, 0]),
                               np.asarray(o_full[:, -1]),
                               rtol=1e-4, atol=1e-4)


def test_ring_buffer_window_cache(rng):
    cache = kvc.init_kv_cache(1, 100, 2, 4, jnp.float32, window=8)
    assert cache.k.shape[1] == 8
    for t in range(20):
        k = jnp.full((1, 1, 2, 4), float(t))
        cache = kvc.append_decode(cache, k, k)
    assert int(cache.length[0]) == 20
    assert np.asarray(kvc.valid_mask(cache)).all()        # ring full
    # slots hold the last 8 tokens (12..19) in ring order
    vals = sorted(set(np.asarray(cache.k)[0, :, 0, 0].tolist()))
    assert vals == [float(v) for v in range(12, 20)]


# ------------------------------------------------------------------ rope

def test_rope_relative_shift_invariance():
    """RoPE: scores depend only on relative positions."""
    hd = 32
    q = jnp.ones((1, 1, 1, hd))
    k = jnp.ones((1, 1, 1, hd)) * 0.5
    def score(p_q, p_k):
        cq, sq = rope.rope_cos_sin(jnp.array([[p_q]]), hd, 10000.0)
        ck, sk = rope.rope_cos_sin(jnp.array([[p_k]]), hd, 10000.0)
        qr = rope.apply_rope(q, cq, sq)
        kr = rope.apply_rope(k, ck, sk)
        return float(jnp.sum(qr * kr))
    assert abs(score(5, 3) - score(105, 103)) < 1e-4
    assert abs(score(5, 3) - score(6, 3)) > 1e-6


def test_mrope_text_equals_rope():
    """With equal t/h/w positions M-RoPE must reduce to standard RoPE."""
    hd, theta = 32, 10000.0
    pos = jnp.arange(8, dtype=jnp.int32)[None]
    c1, s1 = rope.rope_cos_sin(pos, hd, theta)
    pos3 = rope.text_mrope_positions(pos)
    c2, s2 = rope.mrope_cos_sin(pos3, hd, theta, (4, 6, 6))
    np.testing.assert_allclose(c1, c2, rtol=1e-6)
    np.testing.assert_allclose(s1, s2, rtol=1e-6)


# ------------------------------------------------------------------- ssm

def test_mamba_chunked_equals_scan(rng):
    cfg = get_config("zamba2-2.7b").reduced()
    params = mamba.init_mamba(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(2, 32, cfg.d_model)), jnp.float32)
    y1, s1 = mamba.mamba_forward(params, cfg, x, chunk=8)
    y2, s2 = mamba.mamba_forward(params, cfg, x, use_ref_scan=True)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s1.ssm, s2.ssm, rtol=1e-4, atol=1e-4)


def test_mamba_decode_consistency(rng):
    cfg = get_config("zamba2-2.7b").reduced()
    params = mamba.init_mamba(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(1, 16, cfg.d_model)), jnp.float32)
    y_full, _ = mamba.mamba_forward(params, cfg, x, chunk=8)
    st = None
    y_pre, st = mamba.mamba_forward(params, cfg, x[:, :-1], use_ref_scan=True)
    y_dec, _ = mamba.mamba_decode(params, cfg, x[:, -1:], st)
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]),
                               np.asarray(y_full[:, -1]),
                               rtol=1e-4, atol=1e-4)


def test_rwkv_forward_equals_decode(rng):
    cfg = get_config("rwkv6-7b").reduced()
    tp = rwkv.init_rwkv_tmix(jax.random.PRNGKey(0), cfg)
    cp = rwkv.init_rwkv_cmix(jax.random.PRNGKey(1), cfg)
    norms = (jnp.ones((cfg.d_model,)), jnp.ones((cfg.d_model,)))
    x = jnp.asarray(rng.normal(size=(2, 12, cfg.d_model)) * 0.3, jnp.float32)
    st = rwkv.init_rwkv_state(cfg, 2)
    y_full, _ = rwkv.rwkv_block_forward(tp, cp, cfg, x, st, norms, chunk=4)
    st2 = rwkv.init_rwkv_state(cfg, 2)
    ys = []
    for t in range(12):
        y_t, st2 = rwkv.rwkv_block_decode(tp, cp, cfg, x[:, t:t + 1], st2,
                                          norms)
        ys.append(y_t)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate(ys, 1)),
                               rtol=2e-4, atol=2e-4)
