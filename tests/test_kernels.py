"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels import group_shrink as gs


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n,g,tm", [
    (64, 32, 32, 4, 8),
    (128, 64, 48, 6, 16),
    (96, 128, 128, 3, 32),
    (256, 64, 128, 16, 8),
])
def test_grouped_gemm_pallas_vs_ref(m, k, n, g, tm, dtype, rng):
    sizes = rng.multinomial(m - 8, np.ones(g) / g).astype(np.int32)  # pad 8
    x = jnp.asarray(rng.normal(size=(m, k)), dtype)
    w = jnp.asarray(rng.normal(size=(g, k, n)) * 0.1, dtype)
    gsz = jnp.asarray(sizes)
    out = ops.grouped_gemm(x, w, gsz, impl="pallas_interpret",
                           tm=tm, tn=16, tk=16)
    exp = ref.grouped_gemm_ref(x, w, gsz)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("impl", ["xla_ragged", "xla_dense"])
def test_grouped_gemm_xla_impls(impl, rng):
    m, k, n, g = 96, 32, 24, 5
    sizes = np.array([10, 0, 40, 30, 16], np.int32)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(g, k, n)) * 0.1, jnp.float32)
    out = ops.grouped_gemm(x, w, jnp.asarray(sizes), impl=impl,
                           expert_capacity=48)
    exp = ref.grouped_gemm_ref(x, w, jnp.asarray(sizes))
    np.testing.assert_allclose(out, exp, rtol=1e-5, atol=1e-5)


def test_grouped_gemm_empty_groups(rng):
    """Group-shrink guarantee: all-empty groups produce zeros + no NaN."""
    m, k, n, g = 32, 16, 16, 4
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(g, k, n)), jnp.float32)
    gsz = jnp.zeros((g,), jnp.int32)
    out = ops.grouped_gemm(x, w, gsz, impl="pallas_interpret",
                           tm=8, tn=8, tk=8)
    assert np.allclose(out, 0)


def test_tile_table_shrinks_inactive_groups():
    sizes = jnp.array([16, 0, 0, 8, 0, 24], jnp.int32)
    table = gs.build_tile_table(sizes, m=64, tm=8)
    # active groups: 0 (2 tiles), 3 (1), 5 (3) -> 6 live tiles
    assert int(table.num_tiles) == 6
    live = np.asarray(table.tile_gid)[:6]
    assert list(live) == [0, 0, 3, 5, 5, 5]
    assert int(np.asarray(table.tile_valid).sum()) == 6


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,hd,s,ts", [
    (2, 8, 2, 32, 64, 16),
    (3, 4, 4, 64, 128, 32),
    (1, 16, 8, 16, 32, 8),
])
def test_flash_decode_vs_ref(b, h, kv, hd, s, ts, dtype, rng):
    q = jnp.asarray(rng.normal(size=(b, h, hd)), dtype)
    kc = jnp.asarray(rng.normal(size=(b, s, kv, hd)), dtype)
    vc = jnp.asarray(rng.normal(size=(b, s, kv, hd)), dtype)
    lengths = jnp.asarray(rng.integers(1, s + 1, size=b), jnp.int32)
    out = ops.flash_decode(q, kc, vc, lengths, impl="pallas_interpret",
                           ts=ts)
    exp = ref.flash_decode_ref(q, kc, vc, lengths)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("t,k,d,tt,td", [(64, 2, 128, 16, 32),
                                         (128, 8, 256, 32, 64)])
def test_combine_vs_ref(t, k, d, tt, td, rng):
    x = jnp.asarray(rng.normal(size=(t, k, d)), jnp.float32)
    w = jnp.asarray(rng.random(size=(t, k)), jnp.float32)
    out = ops.combine_weighted(x, w, impl="pallas_interpret", tt=tt, td=td)
    exp = ref.combine_weighted_ref(x, w)
    np.testing.assert_allclose(out, exp, rtol=1e-6, atol=1e-6)
