"""minitron-8b — NVIDIA Minitron 8B (pruned Nemotron-4 15B).

[arXiv:2407.14679; hf]  dense, GQA kv=8.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="minitron-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=256000,
    d_head=128,
    rope_theta=10000.0,
    activation="relu_sq",          # Nemotron uses squared-ReLU MLPs
    subquadratic=False,
    source="arXiv:2407.14679",
)
