"""End-to-end serving driver (the paper's deployment kind): serve a reduced
DeepSeek-R1-family MoE with batched requests through the continuous-batching
engine, inject a hardware failure mid-run, rebalance hot experts, and print
throughput / inter-token-latency metrics.

Run:  PYTHONPATH=src python examples/serve_moe.py [--requests 16]
      PYTHONPATH=src python examples/serve_moe.py --kv-mode paged \
          [--kv-blocks 13]    # paged KV; small pools exercise preemption
"""

import argparse

import numpy as np

from repro.configs import get_config
from repro.serving import EngineConfig, Request, SamplingParams, ServingEngine
from repro.training.data import ShareGPTLike


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--mode", default="eaas",
                    choices=["eaas", "monolithic_ep", "tp"])
    ap.add_argument("--kv-mode", default="dense", choices=["dense", "paged"],
                    help="paged = block-pool KV cache with prefix caching")
    ap.add_argument("--kv-blocks", type=int, default=None,
                    help="pool size in blocks (default: no memory pressure; "
                         "shrink to exercise admission gating + preemption)")
    args = ap.parse_args()

    cfg = get_config("deepseek-r1").reduced()
    ecfg = EngineConfig(mode=args.mode, num_servers=4, max_batch=4,
                        max_seq=96, n_redundant=2,
                        kv_mode=args.kv_mode, kv_block_size=8,
                        kv_num_blocks=args.kv_blocks,
                        # paged prefill runs the chunk path; chunking also
                        # bounds decode gaps while long prompts admit
                        prefill_chunk=(8 if args.kv_mode == "paged" else 0))
    eng = ServingEngine(cfg, ecfg, seed=0)

    # ShareGPT-like workload (bucketed prompt lengths bound prefill compiles)
    dist = ShareGPTLike(seed=0)
    plens, rlens = dist.sample(args.requests)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = int(np.clip(2 ** int(np.log2(max(plens[i] // 64, 1)) + 3), 8, 32))
        eng.submit(Request(
            i, rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32),
            SamplingParams(max_new_tokens=int(min(rlens[i] // 32 + 8, 24)))))

    def chaos(e):
        if e.step_idx == 12:
            print(f"[t={e.clock:.2f}s] *** injecting failure of server 1 "
                  f"(mode={args.mode}) ***")
            e.inject_server_failure(1)
        if e.step_idx == 30:
            print(f"[t={e.clock:.2f}s] server 1 recovers + EPLB rebalance")
            e.recover_server(1)
            e.rebalance()

    metrics = eng.run(max_steps=4000, on_step=chaos)
    print("\n=== serving summary ===")
    for k, v in metrics.summary().items():
        print(f"  {k}: {v}")
    halted = sum(1 for t in metrics.timeline if t.get("halted"))
    print(f"  halted steps: {halted}")
    if eng.kv_pool is not None:
        print(f"  kv pool: {eng.kv_pool.usable_blocks} blocks x "
              f"{eng.kv_pool.block_size} tokens, "
              f"free fraction {eng.kv_pool.free_fraction():.2f}")
    assert metrics.completed == args.requests


if __name__ == "__main__":
    main()
