"""RWKV-6 "Finch" block (rwkv6-7b): attention-free time-mix with
data-dependent decay + squared-ReLU channel-mix.

Per head (key dim D = value dim D), state S: (D, D):

    y_t = r_t · (S_{t-1} + diag(u) k_t ⊗ v_t)
    S_t = diag(w_t) S_{t-1} + k_t ⊗ v_t

with the Finch signature: w_t = exp(-exp(w0 + tanh(x_w A) B)) is a
*data-dependent* per-channel decay.  Training/prefill runs a chunk-
checkpointed scan (outer scan over chunks, inner steps rematerialized) so
backward memory is O(L/chunk · state) instead of O(L · state).

Simplification vs. reference: the token-shift mix coefficients are static
(full Finch low-rank-interpolates them); noted in DESIGN.md.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, rms_norm


class RwkvState(NamedTuple):
    wkv: jax.Array       # (B, H, D, D) fp32
    shift_tmix: jax.Array  # (B, d) last token seen by time-mix
    shift_cmix: jax.Array  # (B, d) last token seen by channel-mix


def dims(cfg: ModelConfig) -> Tuple[int, int]:
    D = cfg.ssm.head_dim if cfg.ssm else cfg.head_dim
    H = cfg.d_model // D
    return H, D


def init_rwkv_tmix(key, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    H, D = dims(cfg)
    lora = max(32, d // 64)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    ks = jax.random.split(key, 8)
    return {
        "mu": (jax.random.uniform(ks[0], (5, d), jnp.float32)).astype(
            jnp.float32),                       # r,k,v,w,g shift mixes
        "w_r": dense_init(ks[1], d, d, dt),
        "w_k": dense_init(ks[2], d, d, dt),
        "w_v": dense_init(ks[3], d, d, dt),
        "w_g": dense_init(ks[4], d, d, dt),
        "w_o": dense_init(ks[5], d, d, dt),
        "decay_w0": jnp.full((d,), -6.0, jnp.float32),
        "decay_A": dense_init(ks[6], d, lora, jnp.float32),
        "decay_B": dense_init(ks[7], lora, d, jnp.float32),
        "bonus_u": jnp.zeros((H, D), jnp.float32),
        "ln_scale": jnp.ones((d,), jnp.float32),  # per-head group norm
    }


def init_rwkv_cmix(key, cfg: ModelConfig) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    ks = jax.random.split(key, 3)
    return {
        "mu": jax.random.uniform(ks[0], (2, d), jnp.float32),   # k, r
        "w_k": dense_init(ks[1], d, f, dt),
        "w_v": dense_init(ks[2], f, d, dt),
        "w_r": dense_init(jax.random.fold_in(key, 3), d, d, dt),
    }


def _mix(x, prev, mu):
    """Token shift: lerp between current and previous token."""
    return x + (prev - x) * mu


def _decay(params: Dict, xw: jax.Array) -> jax.Array:
    """Finch data-dependent decay, (…, d) in (0, 1)."""
    lo = jnp.tanh(xw.astype(jnp.float32) @ params["decay_A"]) @ params["decay_B"]
    return jnp.exp(-jnp.exp(params["decay_w0"] + lo))


def _tmix_step(params, cfg, S, prev_x, x_t):
    """One time-mix token.  x_t: (B, d).  Returns (S', y_t)."""
    H, D = dims(cfg)
    Bsz, d = x_t.shape
    mu = params["mu"]
    xr = _mix(x_t, prev_x, mu[0])
    xk = _mix(x_t, prev_x, mu[1])
    xv = _mix(x_t, prev_x, mu[2])
    xw = _mix(x_t, prev_x, mu[3])
    xg = _mix(x_t, prev_x, mu[4])

    r = (xr @ params["w_r"]).reshape(Bsz, H, D).astype(jnp.float32)
    k = (xk @ params["w_k"]).reshape(Bsz, H, D).astype(jnp.float32)
    v = (xv @ params["w_v"]).reshape(Bsz, H, D).astype(jnp.float32)
    g = jax.nn.silu((xg @ params["w_g"]).astype(jnp.float32))
    w = _decay(params, xw).reshape(Bsz, H, D)

    kv = jnp.einsum("bhi,bhj->bhij", k, v)             # (B,H,D,D)
    y = jnp.einsum("bhi,bhij->bhj", r,
                   S + params["bonus_u"][None, :, :, None] * kv)
    S = w[..., None] * S + kv
    y = y.reshape(Bsz, d)
    # per-head group norm + gate + output proj
    y = y.reshape(Bsz, H, D)
    y = y * jax.lax.rsqrt(jnp.mean(jnp.square(y), -1, keepdims=True) + 1e-6)
    y = y.reshape(Bsz, d) * params["ln_scale"] * g
    out = y.astype(x_t.dtype) @ params["w_o"]
    return S, out


def tmix_forward(params: Dict, cfg: ModelConfig, x: jax.Array,
                 state: RwkvState, *, chunk: int = 64
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Full-sequence time-mix.  x: (B, L, d).

    All projections (r/k/v/g, data-dependent decay) are batched over the
    whole sequence — only the O(d·D)-per-token wkv recurrence runs in the
    chunked scan (rematerialized inner steps bound backward memory).
    Returns (y, wkv_state', last_token).
    """
    Bsz, L, d = x.shape
    _, D = dims(cfg)
    H = params["w_r"].shape[1] // D      # local heads (sliced under SPMD)
    Q = min(chunk, L)
    while L % Q:
        Q -= 1

    mu = params["mu"]
    prev0 = state.shift_tmix.astype(x.dtype)
    shifted = jnp.concatenate([prev0[:, None], x[:, :-1]], axis=1)
    xr = _mix(x, shifted, mu[0])
    xk = _mix(x, shifted, mu[1])
    xv = _mix(x, shifted, mu[2])
    xw = _mix(x, shifted, mu[3])
    xg = _mix(x, shifted, mu[4])

    r = (xr @ params["w_r"]).reshape(Bsz, L, H, D).astype(jnp.float32)
    k = (xk @ params["w_k"]).reshape(Bsz, L, H, D).astype(jnp.float32)
    v = (xv @ params["w_v"]).reshape(Bsz, L, H, D).astype(jnp.float32)
    g = jax.nn.silu((xg @ params["w_g"]).astype(jnp.float32))
    w = _decay(params, xw).reshape(Bsz, L, H, D)
    u = params["bonus_u"]

    def chunk_body(S, slices):
        rq, kq, vq, wq = slices              # (Q, B, H, D)

        def step(Sc, t):
            rt, kt, vt, wt = t
            kv = jnp.einsum("bhi,bhj->bhij", kt, vt)
            y = jnp.einsum("bhi,bhij->bhj", rt,
                           Sc + u[None, :, :, None] * kv)
            Sc = wt[..., None] * Sc + kv
            return Sc, y

        S, ys = jax.lax.scan(step, S, (rq, kq, vq, wq))
        return S, ys

    chunk_body = jax.checkpoint(chunk_body)
    seq_first = lambda a: a.reshape(Bsz, L // Q, Q, *a.shape[2:]).transpose(
        1, 2, 0, *range(3, a.ndim + 1))
    S, ys = jax.lax.scan(chunk_body, state.wkv,
                         (seq_first(r), seq_first(k), seq_first(v),
                          seq_first(w)))
    y = ys.reshape(L, Bsz, H, D).transpose(1, 0, 2, 3)   # (B, L, H, D)

    # per-head group norm + gate + output projection (full sequence)
    y = y * jax.lax.rsqrt(jnp.mean(jnp.square(y), -1, keepdims=True) + 1e-6)
    y = y.reshape(Bsz, L, H * D) * params["ln_scale"] * g
    out = y.astype(x.dtype) @ params["w_o"]
    return out, S, x[:, -1]


def cmix_forward(params: Dict, x: jax.Array, prev_token: jax.Array
                 ) -> Tuple[jax.Array, jax.Array]:
    """Channel-mix over a sequence.  x: (B, L, d); prev_token: (B, d)."""
    shifted = jnp.concatenate([prev_token[:, None].astype(x.dtype), x[:, :-1]],
                              axis=1)
    xk = _mix(x, shifted, params["mu"][0])
    xr = _mix(x, shifted, params["mu"][1])
    k = jnp.square(jax.nn.relu((xk @ params["w_k"]).astype(jnp.float32)))
    v = k.astype(x.dtype) @ params["w_v"]
    r = jax.nn.sigmoid((xr @ params["w_r"]).astype(jnp.float32))
    return (r * v.astype(jnp.float32)).astype(x.dtype), x[:, -1]


def rwkv_block_forward(tparams: Dict, cparams: Dict, cfg: ModelConfig,
                       x: jax.Array, state: RwkvState,
                       norms: Tuple[jax.Array, jax.Array], *,
                       chunk: int = 64) -> Tuple[jax.Array, RwkvState]:
    """One full RWKV layer (pre-norm residual)."""
    n1, n2 = norms
    h = rms_norm(x, n1, cfg.rms_norm_eps)
    y, S, prev_t = tmix_forward(tparams, cfg, h, state, chunk=chunk)
    x = x + y
    h2 = rms_norm(x, n2, cfg.rms_norm_eps)
    y2, prev_c = cmix_forward(cparams, h2, state.shift_cmix)
    x = x + y2
    return x, RwkvState(wkv=S, shift_tmix=prev_t, shift_cmix=prev_c)


def rwkv_block_decode(tparams: Dict, cparams: Dict, cfg: ModelConfig,
                      x: jax.Array, state: RwkvState,
                      norms: Tuple[jax.Array, jax.Array]
                      ) -> Tuple[jax.Array, RwkvState]:
    """One-token decode through a layer.  x: (B, 1, d)."""
    n1, n2 = norms
    h = rms_norm(x, n1, cfg.rms_norm_eps)[:, 0]
    S, y = _tmix_step(tparams, cfg, state.wkv, state.shift_tmix, h)
    x = x + y[:, None]
    h2 = rms_norm(x, n2, cfg.rms_norm_eps)
    y2, prev_c = cmix_forward(cparams, h2, state.shift_cmix)
    x = x + y2
    return x, RwkvState(wkv=S, shift_tmix=h, shift_cmix=prev_c)


def init_rwkv_state(cfg: ModelConfig, batch: int) -> RwkvState:
    H, D = dims(cfg)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return RwkvState(
        wkv=jnp.zeros((batch, H, D, D), jnp.float32),
        shift_tmix=jnp.zeros((batch, cfg.d_model), dt),
        shift_cmix=jnp.zeros((batch, cfg.d_model), dt),
    )


def rwkv_block_spmd(cfg: ModelConfig, mesh, dp_axes, model_axis: str,
                    chunk: int = 64):
    """Explicit tensor-parallel RWKV layer (train/prefill).

    Megatron pairing: every projection is column-sharded over the model
    axis, the wkv recurrence runs entirely on local heads, and exactly ONE
    all-reduce per sub-layer (w_o / w_v row-parallel partial sums) crosses
    the network.  Replaces GSPMD propagation, which re-gathered the fp32
    recurrence operands every layer (EXPERIMENTS.md §Perf iter 2).
    """
    from jax.sharding import PartitionSpec as P
    mp = model_axis

    def island(tp, cp, n1, n2, x, wkv, sh_t, sh_c):
        h = rms_norm(x, n1, cfg.rms_norm_eps)
        state = RwkvState(wkv=wkv, shift_tmix=sh_t, shift_cmix=sh_c)
        y_part, S, prev_t = tmix_forward(tp, cfg, h, state, chunk=chunk)
        y = jax.lax.psum(y_part, mp)            # row-parallel w_o
        x = x + y
        h2 = rms_norm(x, n2, cfg.rms_norm_eps)
        # channel-mix: w_k col-, w_v row-parallel; gate r replicated
        shifted = jnp.concatenate(
            [sh_c[:, None].astype(h2.dtype), h2[:, :-1]], axis=1)
        xk = _mix(h2, shifted, cp["mu"][0])
        xr = _mix(h2, shifted, cp["mu"][1])
        kk = jnp.square(jax.nn.relu((xk @ cp["w_k"]).astype(jnp.float32)))
        v = jax.lax.psum(kk.astype(h2.dtype) @ cp["w_v"], mp)
        rr = jax.nn.sigmoid((xr @ cp["w_r"]).astype(jnp.float32))
        x = x + (rr * v.astype(jnp.float32)).astype(x.dtype)
        return x, S, prev_t, h2[:, -1]

    tmix_specs = {
        "mu": P(None, None), "w_r": P(None, mp), "w_k": P(None, mp),
        "w_v": P(None, mp), "w_g": P(None, mp), "w_o": P(mp, None),
        "decay_w0": P(mp), "decay_A": P(None, None), "decay_B": P(None, mp),
        "bonus_u": P(mp, None), "ln_scale": P(mp),
    }
    cmix_specs = {"mu": P(None, None), "w_k": P(None, mp),
                  "w_v": P(mp, None), "w_r": P(None, None)}
    dp = dp_axes
    return jax.shard_map(
        island, mesh=mesh,
        in_specs=(tmix_specs, cmix_specs, P(None), P(None),
                  P(dp, None, None),                       # x
                  P(dp, mp, None, None),                   # wkv state
                  P(dp, None), P(dp, None)),               # shifts
        out_specs=(P(dp, None, None), P(dp, mp, None, None),
                   P(dp, None), P(dp, None)),
        check_vma=False)
