"""Group-shrink: active-group compaction for the grouped GEMM (paper §4.1).

The paper's CUDA problem: DeepGEMM's scheduler iterates *all* expert groups,
paying a low-throughput global-memory read per group, even though most groups
are empty under fine-grained MoE.  Their fix is a GPU prefix scan that
compacts active-group metadata so the scheduler early-stops.

TPU translation: the Pallas grid must be static, so "early stop" becomes
"inactive groups contribute zero row-tiles".  We prefix-scan the group sizes
into a *tile table* — for each of the (statically bounded) row tiles, the
group it belongs to and whether it is live.  Empty groups simply never
appear in the table; the only residual cost is the per-group tile-alignment
padding (< TM rows per active group), and dead tail tiles are skipped with
``pl.when`` at ~zero cost.  The tile table is consumed by the kernel through
scalar prefetch (SMEM), i.e. loaded once — the analogue of the paper's
"compacted tensor loaded into shared memory once".
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class TileTable(NamedTuple):
    """Static-size, dynamically-valid tile metadata (scalar-prefetch input)."""

    tile_gid: jax.Array       # (T,) int32 group id per row tile (0 if dead)
    tile_valid: jax.Array     # (T,) int32 1 = live tile
    padded_offset: jax.Array  # (G,) int32 first padded row of each group
    num_tiles: jax.Array      # scalar int32 — live tile count (diagnostics)


def max_tiles(m: int, g: int, tm: int) -> int:
    """Static bound on live row tiles: every group wastes < 1 tile."""
    return m // tm + g


def build_tile_table(group_sizes: jax.Array, m: int, tm: int) -> TileTable:
    """group_sizes: (G,) int32, sum <= m (static).  O(G + T) prefix scans."""
    G = group_sizes.shape[0]
    T = max_tiles(m, G, tm)
    tiles_per = (group_sizes + tm - 1) // tm                  # 0 for empty
    num_tiles = jnp.sum(tiles_per)
    # first tile of each group (exclusive prefix scan)
    first_tile = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(tiles_per)[:-1].astype(jnp.int32)])
    # tile -> group: scatter group starts, then max-scan
    tile_gid = jnp.zeros((T,), jnp.int32)
    # mark group boundaries: at first_tile[g] the gid becomes g (only for
    # non-empty groups; empty groups share a start with their successor and
    # the later scatter wins because we scatter in increasing g with max)
    has_tiles = tiles_per > 0
    tile_gid = tile_gid.at[jnp.where(has_tiles, first_tile, T)].max(
        jnp.arange(G, dtype=jnp.int32), mode="drop")
    tile_gid = jax.lax.associative_scan(jnp.maximum, tile_gid)
    tile_valid = (jnp.arange(T) < num_tiles).astype(jnp.int32)
    tile_gid = jnp.where(tile_valid > 0, tile_gid, 0)
    padded_offset = (first_tile * tm).astype(jnp.int32)
    return TileTable(tile_gid=tile_gid, tile_valid=tile_valid,
                     padded_offset=padded_offset,
                     num_tiles=num_tiles.astype(jnp.int32))


def pad_rows_to_tiles(x: jax.Array, group_sizes: jax.Array,
                      table: TileTable, tm: int
                      ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Scatter group-sorted rows into the tile-aligned padded layout.

    Returns (x_padded (T*tm, K), padded_idx (M,), row_live (M,)) where
    padded_idx maps each sorted row to its padded position (for the inverse
    gather) and row_live masks rows beyond sum(group_sizes).
    """
    M = x.shape[0]
    G = group_sizes.shape[0]
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(group_sizes).astype(jnp.int32)])
    rows = jnp.arange(M, dtype=jnp.int32)
    gid = jnp.searchsorted(offsets[1:], rows, side="right").astype(jnp.int32)
    row_live = rows < offsets[-1]
    gid_c = jnp.minimum(gid, G - 1)
    pos = rows - offsets[gid_c]
    padded_idx = jnp.where(
        row_live, table.padded_offset[gid_c] + pos, table.tile_gid.shape[0] * tm)
    T = table.tile_gid.shape[0]
    x_padded = jnp.zeros((T * tm, x.shape[1]), x.dtype).at[padded_idx].set(
        x, mode="drop")
    return x_padded, padded_idx, row_live


def unpad_rows(y_padded: jax.Array, padded_idx: jax.Array,
               row_live: jax.Array) -> jax.Array:
    """Inverse of :func:`pad_rows_to_tiles` for the kernel output."""
    safe = jnp.minimum(padded_idx, y_padded.shape[0] - 1)
    y = y_padded[safe]
    return jnp.where(row_live[:, None], y, 0)
