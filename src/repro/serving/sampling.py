"""Token sampling from logits."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(logits: jax.Array, temperature: float, key) -> jax.Array:
    """logits: (B, V) fp32 -> (B,) int32."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(
        jnp.int32)
