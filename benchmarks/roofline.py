"""Roofline analysis (deliverable (g)).

Reads the dry-run artifacts (experiments/dryrun/*.json) and derives, per
(arch × shape × mesh):

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

(cost_analysis is per-device, so dividing by per-chip rates is equivalent to
the global-FLOPs/(chips × peak) formulation.)  Also reports MODEL_FLOPS =
6·N·D (dense) or 6·N_active·D (MoE) and the useful-compute ratio
MODEL_FLOPS / HLO_FLOPs, the dominant term, and a one-line "what would move
it" note.  Output: markdown table for EXPERIMENTS.md §Roofline.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from benchmarks.hardware import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.configs import get_config, shape_by_name

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def model_flops(arch: str, shape_name: str, num_devices: int) -> float:
    """Per-device useful FLOPs: 6·N·D train (fwd+bwd), 2·N·D inference."""
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    n = cfg.num_active_params() if cfg.is_moe else cfg.num_params()
    if shape.kind == "train":
        d_tokens = shape.global_batch * shape.seq_len
        total = 6 * n * d_tokens
    elif shape.kind == "prefill":
        d_tokens = shape.global_batch * shape.seq_len
        total = 2 * n * d_tokens
    else:  # decode: one token per sequence
        total = 2 * n * shape.global_batch
    return total / num_devices


def _bottleneck_note(dom: str, arch: str, shape: str) -> str:
    notes = {
        "compute": "raise per-chip arithmetic intensity: larger expert "
                   "capacity utilization / fewer remat recomputes",
        "memory": "reduce HBM traffic: fuse dispatch/combine, shard the "
                  "residual stream (SP), bf16 intermediates",
        "collective": "cut bytes on the wire: quantized dispatch payloads, "
                      "overlap a2a with dense compute, fewer ZeRO gathers",
    }
    return notes[dom]


def analyze_cell(path: str) -> Optional[Dict]:
    r = json.load(open(path))
    if r.get("status") != "ok":
        return {"arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
                "status": r.get("status"), "reason": r.get("reason", "")}
    rc = r.get("roofline_corrected", {})
    if not rc or "error" in rc:
        return None
    flops = rc.get("flops", 0.0)
    membytes = rc.get("bytes", 0.0)
    coll = rc.get("coll_total", 0.0)
    t_c = flops / PEAK_FLOPS_BF16
    t_m = membytes / HBM_BW
    t_n = coll / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_n}
    dom = max(terms, key=terms.get)
    mf = model_flops(r["arch"], r["shape"], r["num_devices"])
    bound = max(terms.values())
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "status": "ok",
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
        "dominant": dom,
        "model_flops_per_dev": mf,
        "useful_ratio": mf / flops if flops else 0.0,
        "roofline_fraction": (mf / PEAK_FLOPS_BF16) / bound if bound else 0.0,
        "note": _bottleneck_note(dom, r["arch"], r["shape"]),
        "bytes_per_device_hbm": r["memory"].get("argument_bytes"),
    }


def run(mesh_filter: str = "pod16x16") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        if mesh_filter not in path:
            continue
        row = analyze_cell(path)
        if row:
            rows.append(row)
    return rows


def to_markdown(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| useful ratio | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skip: {r.get('reason','')[:40]} | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} |")
    return hdr + "\n".join(lines)


def main() -> List[str]:
    rows = run()
    ok = [r for r in rows if r.get("status") == "ok"]
    out = []
    for r in ok:
        out.append(f"roofline_{r['arch']}_{r['shape']},0.0,"
                   f"dominant={r['dominant']};frac="
                   f"{r['roofline_fraction']:.3f}")
    if ok:
        md = to_markdown(rows)
        path = os.path.join(os.path.dirname(__file__), "..", "experiments",
                            "roofline_table.md")
        with open(path, "w") as f:
            f.write(md)
    return out


if __name__ == "__main__":
    print("\n".join(main()))
