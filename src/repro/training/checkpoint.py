"""Fault-tolerant checkpointing: sharded save/restore with atomic commit and
optional async (background-thread) writes.

Layout:  <dir>/step_<N>/<flat.param.path>.npy + manifest.json
Atomicity: writes go to ``step_<N>.tmp`` and are renamed only after the
manifest is fsynced — a killed writer never corrupts the latest checkpoint
(restart-after-failure is the paper-scale requirement; see DESIGN.md §5).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_checkpoint(directory: str, step: int, tree, *,
                    keep: int = 3) -> str:
    """Synchronous atomic save.  Returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    manifest = {}
    for key, arr in flat.items():
        fname = key.replace("/", ".") + ".npy"
        # byte-view: np.save degrades extension dtypes (bfloat16) to void
        raw = np.ascontiguousarray(arr).view(np.uint8)
        np.save(os.path.join(tmp, fname), raw)
        manifest[key] = {"file": fname, "shape": list(arr.shape),
                         "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "params": manifest}, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                     # atomic commit
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d))


def _resolve_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, tree_like, step: Optional[int] = None):
    """Restore into the structure of ``tree_like`` (shapes must match)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)["params"]

    leaves_with_path = jax.tree_util.tree_flatten_with_path(tree_like)
    flat, treedef = leaves_with_path
    out = []
    for p, leaf in flat:
        key = "/".join(_path_str(x) for x in p)
        meta = manifest[key]
        raw = np.load(os.path.join(path, meta["file"]))
        dtype = _resolve_dtype(meta["dtype"])
        arr = raw.view(dtype).reshape(meta["shape"])
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape,
                                                       leaf.shape)
        out.append(arr)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(tree_like), out), step


class AsyncCheckpointer:
    """Background-thread writer: training never blocks on I/O.  The device→
    host copy happens on the caller thread (cheap); serialization + fsync on
    the worker.  ``wait()`` drains pending writes (call before exit)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot now

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree,
                                keep=self.keep)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
