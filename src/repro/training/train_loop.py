"""Train step + loop.

``make_train_step(model, opt, ...)`` builds the jittable
``train_step(state, batch) -> (state, metrics)`` used by both the CPU
examples and the multi-pod dry-run.  Optional int8 gradient compression
(error feedback) applies to the data-parallel reduction — a distributed-
optimization knob for scale (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.transformer import Model, ParallelCtx
from repro.training.optimizer import OptimizerBundle, clip_by_global_norm


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array
    # error-feedback residual for compressed gradients (None = off)
    ef_residual: Any = None


def init_train_state(model: Model, opt: OptimizerBundle, key,
                     compression: bool = False) -> TrainState:
    params = model.init_params(key)
    ef = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params) \
        if compression else None
    return TrainState(params=params, opt_state=opt.init(params),
                      step=jnp.zeros((), jnp.int32), ef_residual=ef)


def _compress_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization."""
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def make_train_step(model: Model, opt: OptimizerBundle, ctx: ParallelCtx,
                    *, max_grad_norm: float = 1.0,
                    compression: bool = False) -> Callable:
    """Build train_step.  With ``compression=True`` gradients pass through an
    int8 quantize/dequantize with error feedback before the optimizer —
    modeling a compressed DP all-reduce (the quantization error is carried
    to the next step, preserving convergence)."""

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        def loss_of(p):
            loss, metrics = model.loss_fn(p, batch, ctx)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(
            loss_of, has_aux=True)(state.params)

        ef = state.ef_residual
        if compression:
            def comp(g, r):
                g32 = g.astype(jnp.float32) + r
                q, scale = _compress_int8(g32)
                deq = _decompress_int8(q, scale)
                return deq.astype(g.dtype), g32 - deq
            pairs = jax.tree.map(comp, grads, ef)
            grads = jax.tree.map(lambda pr: pr[0], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
            ef = jax.tree.map(lambda pr: pr[1], pairs,
                              is_leaf=lambda x: isinstance(x, tuple))

        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        updates, opt_state = opt.update(grads, state.opt_state, state.params,
                                        state.step)
        params = jax.tree.map(lambda p, u: p + u.astype(p.dtype),
                              state.params, updates)
        new_state = TrainState(params=params, opt_state=opt_state,
                               step=state.step + 1, ef_residual=ef)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return new_state, metrics

    return train_step


def train_loop(model: Model, opt: OptimizerBundle, ctx: ParallelCtx,
               data_iter, num_steps: int, key, *, log_every: int = 10,
               checkpoint_fn: Optional[Callable] = None,
               checkpoint_every: int = 0, compression: bool = False):
    """CPU-scale driver used by the examples (train a ~100M model)."""
    state = init_train_state(model, opt, key, compression)
    step_fn = jax.jit(make_train_step(model, opt, ctx,
                                      compression=compression))
    history = []
    for i in range(num_steps):
        batch = next(data_iter)
        state, metrics = step_fn(state, batch)
        if i % log_every == 0 or i == num_steps - 1:
            history.append({"step": i, "loss": float(metrics["loss"]),
                            "grad_norm": float(metrics["grad_norm"])})
            print(f"step {i:5d}  loss {history[-1]['loss']:.4f}  "
                  f"gnorm {history[-1]['grad_norm']:.3f}")
        if checkpoint_fn and checkpoint_every and (i + 1) % checkpoint_every == 0:
            checkpoint_fn(state, i + 1)
    return state, history
