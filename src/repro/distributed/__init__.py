"""Distribution layer: sharding rules and collective helpers."""

from repro.distributed.sharding_rules import param_shardings, batch_shardings  # noqa: F401
