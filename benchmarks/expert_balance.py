"""Traffic-adaptive expert rebalancing benchmark (paper §4.5, Fig. 10).

One seeded request trace replayed across engine variants under an
expert-dominated :class:`~repro.serving.clock.VirtualClock` cost model with
``charge_imbalance`` on (a lockstep expert phase finishes with its hottest
server, so hot-expert skew stretches decode steps):

* ``uniform``         — unbiased routing, frozen placement: the reference
  throughput for balanced traffic;
* ``skew_frozen``     — Zipf(1.2)-biased routing, frozen placement: the
  initial uniform-load EPLB plan chases yesterday's traffic and the hot
  servers gate every step;
* ``skew_rebalance``  — the same trace with the live
  :class:`~repro.serving.rebalance.RebalanceController`: per-step router
  stats feed the EMA, the planner re-replicates the hot experts, and
  chunked weight migrations interleave with decode steps.

Skew and placement never change *what* is computed — greedy token streams
are bitwise identical between ``skew_frozen`` and ``skew_rebalance`` (the
equivalence column), and the run is deterministic under the virtual clock.

The full (non-smoke) run adds the shifting-hot-set pair (the hot set
rotates mid-run; the controller re-converges each shift) and a rebalance +
autoscaler coordination variant (expert replication first, server-count
scaling second — the paper's fine-grained resource-saving story riding on
the same loop).

The JSON carries a ``gate`` section consumed by ``tools/check_bench.py``:
token-identity fingerprints compare exact, throughputs within tolerance —
the CI benchmark-regression lane.
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
from typing import Dict, List

from benchmarks.common import bench_model_cfg, csv_row, save_result
from repro.serving import (Autoscaler, AutoscalerConfig, EngineConfig,
                           Scenario, ServingEngine, VirtualClock)

NUM_EXPERTS = 16        # widen the reduced config: room for a cold majority
NUM_SERVERS = 4
MAX_BATCH = 8
ZIPF_ALPHA = 1.2
ZIPF_SCALE = 1.0


def _model_cfg():
    cfg = bench_model_cfg()
    return cfg.replace(moe=dataclasses.replace(cfg.moe,
                                               num_experts=NUM_EXPERTS))


def _clock() -> VirtualClock:
    # expert-dominated decode: the regime where balance matters
    return VirtualClock(decode_base=2e-4, decode_per_token=2e-3,
                        expert_share=0.8)


def _engine(cfg, rebalance: bool, **kw) -> ServingEngine:
    ecfg = EngineConfig(
        mode="eaas", num_servers=NUM_SERVERS, max_batch=MAX_BATCH,
        max_seq=64, n_redundant=2,
        # drop-free dispatch capacity: placement changes must never change
        # which tokens reach their experts (the bitwise-identity contract)
        pool_tokens_per_client=MAX_BATCH * NUM_SERVERS,
        charge_imbalance=True,
        rebalance_interval=(0.02 if rebalance else 0.0), **kw)
    return ServingEngine(cfg, ecfg, seed=0, clock=_clock())


def _scenario(vocab: int, horizon: float, rate: float,
              max_new: int) -> Scenario:
    return Scenario(horizon=horizon, seed=7, prompt_len=8, max_new=max_new,
                    vocab=vocab).poisson(rate=rate)


def _token_fingerprint(tokens: Dict[int, tuple]) -> str:
    blob = repr(sorted(tokens.items())).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def _measure(eng: ServingEngine, sc: Scenario) -> Dict:
    res = sc.run(eng)
    m = res.metrics
    tokens = {r.request_id: tuple(r.output_tokens) for r in res.requests}
    return {
        "requests": m.total_requests,
        "completed": m.completed,
        "decode_tok_per_s": m.decode_throughput,
        "expert_imbalance": m.expert_imbalance,
        "peak_expert_imbalance": m.peak_expert_imbalance,
        "rebalances": m.rebalances,
        "rebalance_noops": m.rebalance_noops,
        "migrated_experts": m.migrated_experts,
        "migration_time_s": m.migration_time,
        "final_servers": res.server_trace[-1][1] if res.server_trace else 0,
        "token_fingerprint": _token_fingerprint(tokens),
        "_tokens": tokens,
    }


def run(horizon: float = 0.6, rate: float = 60.0, max_new: int = 24,
        smoke: bool = False) -> Dict:
    if smoke:
        # long enough that the post-convergence window dominates the
        # pre-rebalance warm-up (the speedup the gate pins is steady-state)
        horizon, rate, max_new = 0.5, 60.0, 24
    cfg = _model_cfg()
    V = cfg.vocab_size

    def scen(alpha=0.0):
        sc = _scenario(V, horizon, rate, max_new)
        return sc.zipf_skew(alpha, scale=ZIPF_SCALE) if alpha else sc

    variants: Dict[str, Dict] = {}
    variants["uniform"] = _measure(_engine(cfg, False), scen())
    variants["skew_frozen"] = _measure(_engine(cfg, False),
                                       scen(ZIPF_ALPHA))
    variants["skew_rebalance"] = _measure(_engine(cfg, True),
                                          scen(ZIPF_ALPHA))

    if not smoke:
        # hot set rotates mid-run: frozen placement is always provisioned
        # for the previous hot set; the controller re-converges per shift
        def shifting():
            return _scenario(V, horizon, rate, max_new).shifting_hot_set(
                ZIPF_ALPHA, period=horizon / 2, scale=ZIPF_SCALE)
        variants["shift_frozen"] = _measure(_engine(cfg, False), shifting())
        variants["shift_rebalance"] = _measure(_engine(cfg, True),
                                               shifting())
        # coordination: replication absorbs the skew, so the autoscaler
        # holds the pool at the provision target instead of over-scaling
        asc = Autoscaler(AutoscalerConfig(
            rate_per_server=rate / NUM_SERVERS, min_servers=1,
            max_servers=NUM_SERVERS, window=0.1, cooldown=0.05))
        variants["skew_rebalance_autoscale"] = _measure(
            _engine(cfg, True), scen(ZIPF_ALPHA).autoscale(asc))

    out: Dict = {"figure": "expert_balance", "smoke": smoke,
                 "num_experts": NUM_EXPERTS, "num_servers": NUM_SERVERS,
                 "zipf_alpha": ZIPF_ALPHA, "zipf_scale": ZIPF_SCALE,
                 "variants": {}}
    frozen = variants["skew_frozen"]
    reb = variants["skew_rebalance"]
    out["rebalance_speedup"] = (reb["decode_tok_per_s"] /
                                max(frozen["decode_tok_per_s"], 1e-9))
    out["rebalance_vs_uniform"] = (
        reb["decode_tok_per_s"] /
        max(variants["uniform"]["decode_tok_per_s"], 1e-9))
    out["tokens_identical_frozen_vs_rebalance"] = (
        frozen["_tokens"] == reb["_tokens"])
    for name, v in variants.items():
        out["variants"][name] = {k: val for k, val in v.items()
                                 if k != "_tokens"}

    out["gate"] = {
        "exact": {
            "smoke": smoke,
            "tokens_identical_frozen_vs_rebalance":
                out["tokens_identical_frozen_vs_rebalance"],
            "token_fingerprint_uniform":
                variants["uniform"]["token_fingerprint"],
            "token_fingerprint_skew":
                reb["token_fingerprint"],
        },
        "tolerance": {
            "tok_per_s_uniform": variants["uniform"]["decode_tok_per_s"],
            "tok_per_s_skew_frozen": frozen["decode_tok_per_s"],
            "tok_per_s_skew_rebalance": reb["decode_tok_per_s"],
            "rebalance_speedup": out["rebalance_speedup"],
        },
    }
    save_result("expert_balance", out)
    return out


def main() -> List[str]:
    res = run()
    rows = []
    for name, v in res["variants"].items():
        rows.append(csv_row(
            f"expert_balance_{name}", 0.0,
            f"tok_per_s={v['decode_tok_per_s']:.1f}"
            f";imbalance={v['expert_imbalance']:.3f}"
            f";rebalances={v['rebalances']}"
            f";migrated={v['migrated_experts']}"))
    rows.append(csv_row("expert_balance_speedup", 0.0,
                        f"x{res['rebalance_speedup']:.3f}"
                        f";identical="
                        f"{int(res['tokens_identical_frozen_vs_rebalance'])}"
                        f";vs_uniform=x{res['rebalance_vs_uniform']:.3f}"))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single short configuration (CI regression gate)")
    args = ap.parse_args()
    res = run(smoke=args.smoke)
    for name, v in res["variants"].items():
        print(f"{name}: tok_per_s={v['decode_tok_per_s']:.1f} "
              f"imbalance={v['expert_imbalance']:.3f} "
              f"rebalances={v['rebalances']} "
              f"migrated={v['migrated_experts']}")
    print(f"rebalance speedup over frozen: "
          f"x{res['rebalance_speedup']:.3f} "
          f"(vs uniform x{res['rebalance_vs_uniform']:.3f}, identical="
          f"{res['tokens_identical_frozen_vs_rebalance']})")
