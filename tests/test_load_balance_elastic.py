"""Load balancing (EPLB-style planner) + elastic provisioning invariants."""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install "
    "hypothesis); elastic/provision edge cases are also covered "
    "hypothesis-free in test_elastic_edges.py")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core import load_balance
from repro.core.elastic import ServerPool, provision, resource_saving
from repro.core.expert_server import make_local_table


def test_eplb_reduces_imbalance_on_skew():
    E, S = 16, 4
    load = np.ones(E)
    load[0] = 50.0                           # one hot expert
    base_map = load_balance.eplb_plan(np.ones(E), S, 0)[0]
    mapping, red = load_balance.eplb_plan(load, S, n_redundant=2)
    before = load_balance.imbalance(load, base_map, S)
    after = load_balance.imbalance(load, mapping, S)
    assert after < before
    # the hot expert got replicas
    assert (mapping[0] >= 0).sum() >= 2


@settings(max_examples=20, deadline=None)
@given(E=st.sampled_from([8, 16, 32]), S=st.sampled_from([2, 4, 8]),
       n_red=st.integers(0, 3), seed=st.integers(0, 99))
def test_eplb_plan_validity(E, S, n_red, seed):
    """Plan invariants: primary block placement intact; replicas point at
    servers that actually host the expert (mapping ⇔ local_table coherent —
    the miss==0 property)."""
    rng = np.random.default_rng(seed)
    load = rng.random(E) * 10
    mapping, red = load_balance.eplb_plan(load, S, n_red)
    per = E // S
    np.testing.assert_array_equal(mapping[:, 0], np.arange(E) // per)
    local = make_local_table(E, S, red)
    for e in range(E):
        reps = mapping[e][mapping[e] >= 0]
        assert len(set(reps.tolist())) == len(reps)     # distinct servers
        for s in reps:
            assert local[s, e] >= 0, (e, s)             # actually hosted


def test_server_pool_failure_and_rebalance():
    cfg = get_config("kimi-k2-1t-a32b").reduced()
    pool = ServerPool(cfg, num_servers=4, tokens_per_client=32,
                      n_redundant=2)
    rt = pool.runtime()
    assert bool(rt.alive.all())
    pool.server_failed(2)
    rt = pool.runtime()
    assert not bool(rt.alive[2])
    # traffic observation + rebalance keeps liveness and coherence
    load = np.ones(cfg.moe.num_experts)
    load[3] = 100.0
    pool.observe_load(load)
    pool.rebalance()
    rt2 = pool.runtime()
    assert not bool(rt2.alive[2])            # liveness preserved
    mapping = np.asarray(rt2.mapping)
    local = np.asarray(rt2.local_table)
    for e in range(cfg.moe.num_experts):
        for s in mapping[e][mapping[e] >= 0]:
            assert local[s, e] >= 0


def test_provisioning_saving_matches_paper():
    """The paper's headline: traffic 8192→5120 saves 37.5% of chips."""
    rate = 8192 / 64
    assert provision(8192, rate, 1) == 64
    assert provision(5120, rate, 1) == 40
    assert provision(5120, rate, 64) == 64
    assert abs(resource_saving(5120, rate, 64) - 0.375) < 1e-9
