"""rwkv6-7b — RWKV-6 "Finch" (attention-free, data-dependent decay).

[arXiv:2404.05892; hf]  32L, d_model=4096, 64 time-mix heads of dim 64,
channel-mix FFN d_ff=14336 (squared-ReLU).
"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,                  # time-mix heads
    num_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    d_head=64,
    activation="relu_sq",          # RWKV channel-mix uses squared ReLU
    ssm=SSMConfig(d_state=64, head_dim=64, num_ssm_heads=64),
    subquadratic=True,             # recurrent state, O(1) in sequence length
    source="arXiv:2404.05892",
)
