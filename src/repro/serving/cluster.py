"""Cluster front-end: N attention clients sharing one expert tier.

This is the paper's deployment shape and the public serving API.  A
:class:`Cluster` owns

* ONE shared :class:`~repro.core.elastic.ServerPool` — the disaggregated
  expert tier: placement table, liveness, traffic EMA, redundant replicas;
* N :class:`~repro.serving.engine.ServingEngine` *clients* — each keeps its
  own scheduler, executor, KV pool and clock, and reads the shared pool
  through a per-client :class:`~repro.core.elastic.PoolClient` mapping
  view, so expert-server failures and replica migrations are observed
  consistently by everyone;
* the placement control plane — the ONE
  :class:`~repro.serving.rebalance.RebalanceController` (expert-weight
  migration chunks fan out to every client's executor so replicas never
  diverge) and elastic ``scale_to`` (every executor re-shards in lockstep);
* a pluggable :class:`~repro.serving.frontend.FrontendRouter` with
  per-client admission backpressure — requests enter through
  :meth:`submit` into the ingress queue and are routed when a client is
  admissible.

Time: each client advances its own clock; :meth:`step` always steps the
*most-behind alive* client (ties to the lowest index), so the interleaving
is a deterministic function of the request trace — a seeded scenario
replayed at N=1 and N=4 routes differently but computes the same
per-request token streams bitwise (drop-free dispatch; replicas carry
identical weights).  Under ``charge_contention`` the
:class:`~repro.serving.clock.VirtualClock` stretches the expert share of
every decode step by the number of clients with live work — the shared
expert tier serves everyone, the attention share stays private.

Fault model ("Surviving Partial Rank Failures", client side): a client
failure strands only its in-flight requests — the expert tier and every
other client keep serving, so cluster throughput dips by roughly the dead
client's share instead of the monolithic whole-engine stall.  The
per-request work is lost (counted in ``metrics.failed_requests``), never
silently retried.

Migration note: ``ServingEngine`` remains the single-client engine and is
what a ``Cluster(clients=1)`` wraps; ``repro.serving.Engine`` is a
deprecated alias kept for one release.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional

from collections import deque

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.elastic import ServerPool
from repro.serving.clock import Clock, WallClock
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.event_loop import AsyncExpertTier
from repro.serving.frontend import (FrontendRouter, make_frontend_router)
from repro.serving.metrics import ClusterMetrics
from repro.serving.rebalance import (RebalanceConfig, RebalanceController,
                                     oneshot_rebalance)
from repro.serving.request import Request


@dataclass
class ClusterConfig:
    """Front-end shape + the per-client engine template."""

    clients: int = 1
    frontend_policy: str = "round_robin"
    # per-client admission backpressure: a client whose local queue holds
    # this many requests is closed to new routed work; requests wait in the
    # cluster ingress queue until somebody drains (0 = unbounded)
    max_client_queue: int = 0
    # stretch the expert share of decode steps by the number of clients
    # with live work (virtual clocks; the shared-tier contention charge).
    # Off by default: per-client timelines are then bit-identical to the
    # same engine running standalone.
    charge_contention: bool = False
    # attention-tier autoscaling ceiling: ``scale_clients`` may build NEW
    # engines (join empty at cluster time) up to this many total.  None =
    # the initial ``clients`` count — scale-up then only revives drained
    # clients, never jit-builds mid-run.
    max_clients: Optional[int] = None
    # the per-client engine template (mode must be eaas or monolithic_ep;
    # rebalance_interval > 0 enables the CLUSTER-level controller)
    engine: EngineConfig = field(default_factory=EngineConfig)


class Cluster:
    """N attention clients + one shared expert tier + a front-end router.

    The public entrypoints mirror the single-engine surface —
    ``submit`` / ``step`` / ``run`` / ``metrics`` plus the scenario control
    verbs — so :class:`~repro.serving.scenario.Scenario` timelines replay
    against a cluster unchanged.
    """

    def __init__(self, cfg: ModelConfig, ccfg: ClusterConfig,
                 seed: int = 0,
                 clock_factory: Optional[Callable[[], Clock]] = None):
        if ccfg.clients < 1:
            raise ValueError(f"need at least one client, got {ccfg.clients}")
        ecfg = ccfg.engine
        if ecfg.mode not in ("eaas", "monolithic_ep"):
            raise ValueError(
                f"cluster clients share one expert tier — mode {ecfg.mode!r}"
                " is not disaggregated (use eaas or monolithic_ep)")
        if not cfg.moe:
            raise ValueError("Cluster serves MoE configs (the expert tier "
                             "is the shared resource)")
        self.cfg = cfg
        self.ccfg = ccfg
        clock_factory = clock_factory or WallClock
        # kept for lazily spawned clients (attention-tier scale-up past
        # the initial fleet)
        self._clock_factory = clock_factory
        self._seed = seed
        # ---- the ONE expert tier ----------------------------------------
        self.pool = ServerPool(
            cfg, ecfg.num_servers,
            tokens_per_client=(ecfg.pool_tokens_per_client
                               or ecfg.max_batch),
            n_redundant=(ecfg.n_redundant if ecfg.mode == "eaas" else 0),
            capacities=ecfg.server_capacities)
        # ---- the shared async tier (exec_mode="async") ------------------
        # ONE micro-batch queue set for the whole cluster: every client's
        # waves queue on the same per-server busy frontiers, so cross-
        # client expert contention emerges from queueing physically
        # (charge_contention's analytic stretch is not applied on top)
        self._tier: Optional[AsyncExpertTier] = None
        if ecfg.exec_mode == "async":
            self._tier = AsyncExpertTier(ecfg.num_servers,
                                         queue_mode=ecfg.queue_mode,
                                         lane_budget=ecfg.lane_budget)
        # ---- N clients over per-client mapping views --------------------
        # all clients share the initial params (same seed -> the cluster is
        # N replicas of one model; migrations keep every copy in lockstep
        # through apply_migration)
        self.clients: List[ServingEngine] = []
        params = None
        for i in range(ccfg.clients):
            eng = ServingEngine(cfg, ecfg, params=params, seed=seed,
                                clock=clock_factory(),
                                pool=self.pool.client_view(i), client_id=i,
                                tier=self._tier)
            params = eng.executor.params
            self.clients.append(eng)
        self.client_alive = [True] * ccfg.clients
        # attention-tier elasticity state, orthogonal to the failure flag:
        # draining = stop admitting, finish in-flight work, then park;
        # parked = deprovisioned (not failed) — excluded from routing,
        # stepping and the cluster time base until a spawn revives it
        self.client_draining = [False] * ccfg.clients
        self.client_parked = [False] * ccfg.clients
        # ---- front-end --------------------------------------------------
        self.router: FrontendRouter = make_frontend_router(
            ccfg.frontend_policy, ccfg.clients,
            block_size=(ecfg.kv_block_size
                        if ecfg.kv_mode == "paged" else None))
        self.ingress: Deque[Request] = deque()
        # ---- control plane ----------------------------------------------
        self.clk = clock_factory()       # charges shared-tier migrations
        self.rebalancer: Optional[RebalanceController] = None
        if ecfg.rebalance_interval > 0 and ecfg.mode == "eaas":
            self.rebalancer = RebalanceController(RebalanceConfig(
                interval=ecfg.rebalance_interval,
                chunk=ecfg.rebalance_chunk,
                min_gain=ecfg.rebalance_min_gain,
                cooldown=ecfg.rebalance_cooldown,
                queue_aware=ecfg.rebalance_queue_aware))
            for eng in self.clients:
                # members surface the pool imbalance gauge the cluster's
                # controller plans from (their own rebalancer stays None)
                eng.track_imbalance = True
        self.last_placement_change = float("-inf")
        self.metrics = ClusterMetrics(
            per_client=[c.metrics for c in self.clients],
            routed=[0] * ccfg.clients)
        self.step_idx = 0
        # provisioned-resource accounting (the elasticity saving metric):
        # integrate active clients + servers x resident-expert fraction
        # over cluster time, change-points traced for windowed integrals
        self._res_t = 0.0
        self._res_units = self._provisioned_units()
        self.metrics.resource_trace.append((0.0, self._res_units))

    # ------------------------------------------------------------- time
    def _in_fleet(self, i: int) -> bool:
        """Alive and not parked — the clients that step, route and gate
        cluster time (a draining client is still in the fleet until its
        in-flight work finishes)."""
        return self.client_alive[i] and not self.client_parked[i]

    @property
    def clock(self) -> float:
        """The cluster time base: the most-behind in-fleet client (that is
        the next client to act).  With no survivors, the latest client
        time.  Parked clients are excluded — their clocks froze when they
        drained out and must not hold cluster time back."""
        alive = [c.clock for i, c in enumerate(self.clients)
                 if self._in_fleet(i)]
        if alive:
            return min(alive)
        return max((c.clock for c in self.clients), default=0.0)

    # ------------------------------------------------- engine-like surface
    @property
    def queue(self) -> List[Request]:
        """Every request not yet in a slot (ingress + client queues) — the
        scenario harness's busy signal."""
        out = list(self.ingress)
        for c in self.clients:
            out.extend(c.queue)
        return out

    @property
    def slots(self) -> List[Optional[Request]]:
        return [s for c in self.clients for s in c.slots]

    def pending_prefill_tokens(self) -> int:
        """Cluster-wide unprefilled backlog (ingress + every client) — the
        autoscaler's prefill-pressure signal."""
        pending = sum(c.pending_prefill_tokens() for c in self.clients)
        pending += sum(len(r.prompt) for r in self.ingress)
        return pending

    def kv_free_fraction(self) -> float:
        """The tightest client's free KV fraction — memory pressure on ANY
        client throttles what the cluster can admit there."""
        fracs = [c.kv_free_fraction()
                 for c, ok in zip(self.clients, self.client_alive) if ok]
        return min(fracs) if fracs else 1.0

    # ------------------------------------------------------------ ingress
    def submit(self, req: Request) -> None:
        if not any(self.client_alive):
            # no client will ever route this: fail fast, keep the
            # completed == total - failed invariant under continued traffic
            self.metrics.ingress_failed += 1
            self.metrics.failed_requests += 1
            return
        self.ingress.append(req)

    def _admissible(self) -> List:
        cap = self.ccfg.max_client_queue
        out = []
        for i, eng in enumerate(self.clients):
            if not self._in_fleet(i) or self.client_draining[i]:
                # draining clients stop admitting: they finish their
                # in-flight work and park (the elastic scale-down path)
                continue
            if cap > 0 and len(eng.queue) >= cap:
                continue
            out.append((i, eng))
        return out

    def _route_ingress(self) -> None:
        """Drain the ingress queue head-of-line through the router until
        nobody is admissible (per-client backpressure holds the rest)."""
        while self.ingress:
            candidates = self._admissible()
            if not candidates:
                return
            req = self.ingress.popleft()
            idx = self.router.pick(req, candidates)
            self.clients[idx].submit(req)
            self.metrics.routed[idx] += 1

    # --------------------------------------------------------------- step
    @staticmethod
    def _has_work(eng: ServingEngine) -> bool:
        return bool(eng.queue) or any(s is not None for s in eng.slots)

    def _next_client(self) -> Optional[int]:
        """The most-behind alive client WITH work (ties to the lowest
        index).  Clients with nothing to do never gate cluster time: they
        are fast-forwarded to the busy frontier instead of burning idle
        sweeps — under a wall clock this also absorbs per-client
        compile-time spikes without starving anyone.  When nobody has
        work, the most-behind client takes an idle step so time still
        advances toward the next scheduled arrival."""
        alive = [i for i in range(len(self.clients)) if self._in_fleet(i)]
        if not alive:
            return None
        busy = [i for i in alive if self._has_work(self.clients[i])]
        if not busy:
            return min(alive, key=lambda i: (self.clients[i].clock, i))
        frontier = min(self.clients[i].clock for i in busy)
        for i in alive:
            if i not in busy and self.clients[i].clock < frontier:
                self.clients[i].clock = frontier
        return min(busy, key=lambda i: (self.clients[i].clock, i))

    def _active_clients(self) -> int:
        """Clients with live work — the shared-tier contention factor."""
        n = sum(1 for i, eng in enumerate(self.clients)
                if self._in_fleet(i) and self._has_work(eng))
        return max(n, 1)

    def step(self) -> None:
        """One cluster iteration: route what the front-end can place, then
        advance the most-behind in-fleet client by one engine step."""
        self.step_idx += 1
        self._route_ingress()
        i = self._next_client()
        if i is None:
            return                       # every client is dead
        eng = self.clients[i]
        eng.expert_contention = (float(self._active_clients())
                                 if self.ccfg.charge_contention else 1.0)
        eng.step()
        if self.rebalancer is not None:
            # ONE controller for the shared tier: migration chunks
            # interleave with whichever client steps next
            self.rebalancer.step(self)
        self._retire_drained()
        self._account_resources()

    def has_work(self) -> bool:
        """Anything outstanding anywhere (ingress, queues, slots) — the
        cheap busy probe (no list materialization, early exit)."""
        return bool(self.ingress) or any(self._has_work(c)
                                         for c in self.clients)

    def run(self, max_steps: int = 10_000,
            on_step: Optional[Callable[["Cluster"], None]] = None
            ) -> ClusterMetrics:
        """Drive until ingress + client queues + slots drain."""
        while self.has_work() and self.step_idx < max_steps:
            if not any(self.client_alive):
                break                    # nobody left to serve the backlog
            if on_step:
                on_step(self)
            self.step()
        self._account_resources()
        self.metrics.wall_time = self.clock
        return self.metrics

    # --------------------------------------------- resource accounting
    def _provisioned_units(self) -> float:
        """Resource units currently provisioned: in-fleet attention
        clients (draining ones still hold their hardware) plus expert
        servers weighted by the resident (non-paged-out) expert fraction.
        The statically provisioned baseline holds this constant; the
        elasticity saving is one minus the ratio of the two integrals."""
        clients = sum(1 for i in range(len(self.clients))
                      if self._in_fleet(i))
        return float(clients
                     + self.pool.num_servers * self.pool.resident_fraction())

    def _account_resources(self) -> None:
        """Integrate provisioned resource-units up to cluster time and
        record a change-point whenever the provisioning level moved (the
        interval since the last accounting is charged at the PREVIOUS
        level — changes take effect from their change-point on)."""
        now = self.clock
        if now > self._res_t:
            self.metrics.resource_seconds += \
                (now - self._res_t) * self._res_units
            self._res_t = now
        units = self._provisioned_units()
        if units != self._res_units:
            self._res_units = units
            self.metrics.resource_trace.append((now, units))

    # --------------------------------------------- shared-tier control
    def _pool_event(self, event: str, **kw) -> None:
        self.metrics.events.append(dict({"t": self.clock, "event": event},
                                        **kw))

    def inject_server_failure(self, rank: int) -> None:
        """An EXPERT server dies: one shared liveness flip that every
        client's next step observes (the consistent-mask property).  In
        monolithic mode every client is one collective group — they all
        stall."""
        self._pool_event("server_fail", rank=rank,
                         mode=self.ccfg.engine.mode)
        if self.ccfg.engine.mode == "eaas":
            if rank < self.pool.num_servers:
                self.pool.server_failed(rank)
            if self._tier is not None:
                # shared tier: re-dispatch the dead server's queue once,
                # then fan each moved micro-batch's fresh completion event
                # to the client that owns it
                moved = self._tier.fail_server(rank, self.clock)
                for mb in moved:
                    self.clients[mb.client_id]._post_redispatch(mb)
                if moved:
                    self._pool_event("redispatch", rank=rank,
                                     count=len(moved))
                for eng in self.clients:
                    eng._reconcile_waves()
        else:
            for eng in self.clients:
                eng.halted_until = (eng.step_idx
                                    + self.ccfg.engine.restart_steps)

    def recover_server(self, rank: int) -> None:
        self._pool_event("server_recover", rank=rank)
        if rank < self.pool.num_servers:
            self.pool.server_recovered(rank)
        if self._tier is not None and rank < self._tier.num_servers:
            self._tier.recover_server(rank, self.clock)

    def set_server_speed(self, rank: int, factor: float) -> None:
        """Mark one expert server as a straggler (scenario
        ``slow_server``): every client's lockstep decode charge sees it;
        under async only that server's shared micro-batch queue slows."""
        if rank >= self.pool.num_servers:
            return
        if factor <= 0:
            raise ValueError(f"server speed factor must be > 0: {factor}")
        for eng in self.clients:
            if rank < len(eng.server_speed):
                eng.server_speed[rank] = float(factor)
        if self._tier is not None and rank < self._tier.num_servers:
            self._tier.set_slowdown(rank, factor)
        self._pool_event("slow_server", rank=rank, factor=float(factor))

    def set_skew(self, bias: np.ndarray) -> None:
        self.pool.set_route_bias(bias)
        bias = np.asarray(bias, np.float64)
        self._pool_event("set_skew",
                         spread=round(float(bias.max() - bias.min()), 6))

    def set_policy(self, policy: str) -> None:
        """Scheduler policy on every client (scenario ``set_policy``)."""
        for eng in self.clients:
            eng.scheduler.set_policy(policy)
        self._pool_event("set_policy", policy=policy)

    def apply_migration(self, copies) -> None:
        """Fan one expert-weight migration chunk out to every client's
        executor — the shared tier has ONE placement, so every client's
        weight copy moves together (dead clients included: they must be
        current if they recover)."""
        for eng in self.clients:
            eng.executor.migrate_slots(copies)

    def charge_migration(self, dt: float) -> None:
        """The shared tier is busy copying weights: every alive client's
        next expert phase waits behind it.  (The caller accounts the
        ``migration_time`` metric.)  Under async the copy occupies the
        shared micro-batch queues instead — clients keep running
        attention/prefill and only their next dispatches queue behind the
        copy (migration interleaves with in-flight micro-batches)."""
        if self._tier is not None:
            self._tier.occupy_all(self.clock, dt)
            return
        for i, eng in enumerate(self.clients):
            if self.client_alive[i]:
                eng.clock += dt

    def queue_signals(self) -> Optional[Dict]:
        """Live queue signals of the SHARED async tier at cluster time —
        the cluster-level queue-aware rebalance gate reads this (None
        under lockstep)."""
        if self._tier is None:
            return None
        return self._tier.queue_signals(self.clock)

    def rebalance(self) -> None:
        """One-shot EPLB replan of the shared tier (scenario event)."""
        if self.rebalancer is not None:
            self.rebalancer.abort()
        oneshot_rebalance(self)

    def scale_to(self, n: int) -> None:
        """Elastically resize the shared expert tier: one pool replan, then
        every client's executor re-shards from the recovered global bank."""
        if n == self.pool.num_servers:
            return
        old = self.pool.num_servers
        if self.rebalancer is not None:
            self.rebalancer.abort()
        for eng in self.clients:
            eng._drain_async()           # quiesce in-flight waves first
        self.pool.scale_to(n)
        for eng in self.clients:
            eng.executor.resize(eng.pool)    # the client's PoolClient view
            eng.server_speed = np.ones(n)
        if self._tier is not None:
            # reconcile the shared tier: work still queued on dropped
            # ranks re-dispatches to survivors, and each moved
            # micro-batch's fresh completion event is fanned to the
            # client that owns it (mirrors inject_server_failure)
            moved = self._tier.resize(n, self.clock)
            for mb in moved:
                self.clients[mb.client_id]._post_redispatch(mb)
            for eng in self.clients:
                eng._reconcile_waves()
            self._tier.reset_speeds()        # match the server_speed reset
        self.last_placement_change = self.clock
        self._pool_event("scale", **{"from": old, "to": n})

    # ------------------------------------------------- client fault model
    def _check_client(self, i: int) -> None:
        if not 0 <= i < len(self.clients):
            raise ValueError(f"no client {i}: this cluster has "
                             f"{len(self.clients)} clients")

    def fail_client(self, i: int) -> None:
        """An ATTENTION client dies.  Only its in-flight requests strand
        (queued + slotted — lost, counted as failed); the expert tier and
        the other clients never notice beyond the routed-traffic shift.
        If the LAST client dies, ingress-held requests strand too — a
        later ``recover_client`` starts from a clean slate, it does not
        resurrect dropped work."""
        self._check_client(i)
        if not self.client_alive[i]:
            return
        self.client_alive[i] = False
        self.client_draining[i] = False  # a dead client drains nothing
        stranded = self.clients[i].abort_inflight()
        if not any(self.client_alive) and self.ingress:
            # nobody left to route to: the front-end sheds its ingress
            # queue rather than silently losing it from the accounting
            self.metrics.ingress_failed += len(self.ingress)
            stranded.extend(self.ingress)
            self.ingress.clear()
        self.metrics.failed_requests += len(stranded)
        self._pool_event("client_fail", client=i, stranded=len(stranded))

    def recover_client(self, i: int) -> None:
        """The client rejoins empty (its KV state died with it) and
        fast-forwards to cluster time — it was not accumulating work while
        dead."""
        self._check_client(i)
        if self.client_alive[i]:
            return
        self.client_alive[i] = True
        now = self._fleet_frontier(default=self.clients[i].clock)
        self.clients[i].clock = max(self.clients[i].clock, now)
        self._pool_event("client_recover", client=i)

    # --------------------------------------------- attention-tier elastic
    def active_client_count(self) -> int:
        """Clients serving AND admitting (not draining) — what the
        autoscaler's client controller steers."""
        return sum(1 for i in range(len(self.clients))
                   if self._in_fleet(i) and not self.client_draining[i])

    def _fleet_frontier(self, default: float = 0.0) -> float:
        """The most-ahead in-fleet client's clock — where departing and
        joining clients fast-forward to (join empty at cluster time)."""
        return max((c.clock for i, c in enumerate(self.clients)
                    if self._in_fleet(i)), default=default)

    def _retire_drained(self) -> None:
        """Park any draining client whose in-flight work has finished —
        async waves complete through the normal event path (never
        cancelled, so a drain loses zero tokens)."""
        for i in range(len(self.clients)):
            if self.client_draining[i] and self._in_fleet(i) \
                    and not self._has_work(self.clients[i]):
                self._park_client(i)

    def _park_client(self, i: int) -> None:
        self.client_draining[i] = False
        self.client_parked[i] = True
        # fast-forward the departing client to the cluster frontier so a
        # later spawn rejoins at cluster time, never in the past
        self.clients[i].clock = max(self.clients[i].clock,
                                    self._fleet_frontier())
        self.metrics.client_drains += 1
        self._pool_event("client_drain", client=i)
        self._account_resources()

    def drain_client(self, i: int) -> bool:
        """Elastically scale the attention tier DOWN by one client: ``i``
        stops admitting immediately, finishes its queued requests and
        in-flight async waves (completion events keep firing — nothing is
        cancelled or stranded, unlike :meth:`fail_client`), then parks
        fast-forwarded to the cluster frontier.  The last active client
        never drains (someone must serve the ingress).  Returns whether
        the drain started."""
        self._check_client(i)
        if not self._in_fleet(i) or self.client_draining[i]:
            return False
        if self.active_client_count() <= 1:
            return False
        self.client_draining[i] = True
        self._pool_event("client_drain_begin", client=i)
        if not self._has_work(self.clients[i]):
            self._park_client(i)         # nothing in flight: park now
        return True

    def spawn_client(self) -> Optional[int]:
        """Elastically scale the attention tier UP by one client: revive
        the lowest-index parked client (it rejoins empty at cluster time),
        or build a fresh engine over the shared params/pool/tier when the
        fleet is still below ``max_clients``.  The front-end ring grows
        deterministically — existing clients keep their indices, the new
        index extends the ring.  Returns the client index, or None at the
        ceiling."""
        for i in range(len(self.clients)):
            if self.client_parked[i] and self.client_alive[i]:
                self.client_parked[i] = False
                self.client_draining[i] = False
                self.clients[i].clock = max(self.clients[i].clock,
                                            self._fleet_frontier())
                self.metrics.client_spawns += 1
                self._pool_event("client_spawn", client=i)
                self._account_resources()
                return i
        limit = self.ccfg.max_clients or self.ccfg.clients
        if len(self.clients) >= limit:
            return None
        i = len(self.clients)
        eng = ServingEngine(self.cfg, self.ccfg.engine,
                            params=self.clients[0].executor.params,
                            seed=self._seed, clock=self._clock_factory(),
                            pool=self.pool.client_view(i), client_id=i,
                            tier=self._tier)
        if self.rebalancer is not None:
            eng.track_imbalance = True
        eng.clock = self._fleet_frontier()
        # adopt the live straggler state (scenario slow_server events)
        if self.clients:
            eng.server_speed = self.clients[0].server_speed.copy()
        self.clients.append(eng)
        self.client_alive.append(True)
        self.client_draining.append(False)
        self.client_parked.append(False)
        self.metrics.per_client.append(eng.metrics)
        self.metrics.routed.append(0)
        self.router.n_clients = len(self.clients)
        self.metrics.client_spawns += 1
        self._pool_event("client_spawn", client=i, built=True)
        self._account_resources()
        return i

    def scale_clients(self, n: int) -> int:
        """Drive the active client count toward ``n`` (the autoscaler's
        attention-tier output): spawn parked/new clients to grow, drain
        the highest-index active clients to shrink.  Bounded below by one
        active client and above by ``max_clients``.  Any change stamps
        ``last_placement_change`` so client churn, migrations and expert
        page-ins coordinate through one cooldown.  Returns the active
        count after the action."""
        n = max(1, int(n))
        changed = False
        while self.active_client_count() < n:
            if self.spawn_client() is None:
                break
            changed = True
        active = [i for i in range(len(self.clients))
                  if self._in_fleet(i) and not self.client_draining[i]]
        for i in sorted(active, reverse=True)[:max(len(active) - n, 0)]:
            changed |= self.drain_client(i)
        if changed:
            self.last_placement_change = self.clock
        return self.active_client_count()

    def page_out_experts(self, experts) -> List[int]:
        """Scale-to-zero on the SHARED tier: evict cold experts' replica
        slots from every client's executor in lockstep (the weight path is
        the same fan-out migrations use).  Experts with in-flight work on
        the shared tier lanes are skipped this round — eviction waits for
        the lanes to drain.  Returns the experts actually paged out."""
        ready = [e for e in experts
                 if self._tier is None
                 or not self._tier.expert_in_flight(e)]
        paged, updates = self.pool.page_out_experts(ready)
        if updates:
            self.apply_migration(updates)
        if paged:
            self.last_placement_change = self.clock
            self.metrics.expert_page_outs += len(paged)
            self._pool_event("page_out", experts=len(paged))
            self._account_resources()
        return paged

    def set_frontend_policy(self, policy: str) -> None:
        """Swap the request-routing policy mid-run (fresh router state)."""
        self.router = make_frontend_router(
            policy, len(self.clients),
            block_size=(self.ccfg.engine.kv_block_size
                        if self.ccfg.engine.kv_mode == "paged" else None))
        self._pool_event("set_frontend_policy", policy=policy)

    # ----------------------------------------------------------- summary
    def summary(self) -> Dict:
        self.metrics.wall_time = self.clock
        return self.metrics.summary()
