"""qwen2-vl-2b — Qwen2-VL 2B backbone (M-RoPE, dynamic-resolution vision).

[arXiv:2409.12191; hf]  Transformer backbone only; the ViT patch frontend is a
stub — ``input_specs()`` supplies precomputed patch embeddings.  M-RoPE splits
head_dim rotary sections across (temporal, height, width) position ids.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    d_head=128,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),   # t/h/w rotary sections (sum = d_head/2)
    activation="swiglu",
    tie_embeddings=True,
    frontend="vision_patches",
    subquadratic=False,
    source="arXiv:2409.12191",
)
