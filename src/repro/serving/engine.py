"""Continuous-batching serving engine — the orchestration layer.

The engine is split in three (the scheduler/executor refactor):

* :class:`~repro.serving.scheduler.Scheduler` — admission, slot
  assignment, chunked prefill and the step policy (what runs next:
  a prefill chunk, a decode step, or idle);
* :class:`~repro.serving.executor.Executor` — params, KV caches and the
  jitted step variants (whole-prompt prefill, chunked-prefill
  continuation, and lockstep / pipelined / serialized decode);
* :class:`ServingEngine` (this module) — wires scheduler → executor →
  metrics around the pluggable :class:`~repro.serving.clock.Clock`, and
  keeps the control plane: failover, rebalancing, elastic ``scale_to``.

One engine class still serves the three system modes (paper §5 baselines):

* ``mode="eaas"``        — EAAS: replicated experts, liveness-masked mapping;
  a server failure re-routes traffic to replicas within the same step
  (throughput dips only by the lost compute share — paper Fig. 10).
* ``mode="monolithic_ep"`` — DeepEP-style: primary-only mapping; a server
  failure halts the WHOLE engine for ``restart_steps`` (the collective-group
  restart) before resuming.
* ``mode="tp"``          — tensor-parallel MoE: failure halts only the
  16-GPU unit (modeled as a shorter stall) but per-unit weight replication
  caps the max batch (``tp_batch_cap``).

The expert→server mapping, liveness mask and local placement table are
**jit arguments**, not compiled constants — failover and rebalancing never
trigger recompilation (the paper's no-group-rebuild property).

Decode can run as two pipelined microbatches (``decode_mode="pipelined"``,
paper §4.2): the expert round-trip of microbatch A overlaps the attention
of microbatch B.  Outputs are bit-identical to the lockstep engine — only
the step cost changes (the overlap-aware
:class:`~repro.serving.clock.VirtualClock` charges ``max(attn, expert)+ε``
instead of the sum; ``decode_mode="serialized"`` is the exposed-collective
ablation).  Chunked prefill (``prefill_chunk=N`` with ``policy="fair"``)
bounds decode gaps to one chunk instead of one prompt.

Execution modes (``exec_mode``): ``lockstep`` (default, bit-identical to
the pre-async engine) advances one synchronous step at a time — every step
blocks on the full expert round-trip.  ``async`` kills that barrier: the
engine computes step *values* eagerly (decode outputs are
batch-composition independent, so values and timing decouple) but posts
their completions onto a discrete-event timeline
(:class:`~repro.serving.clock.EventTimeline`).  A decode wave's expert
share is dispatched as per-server micro-batches into the
:class:`~repro.serving.event_loop.AsyncExpertTier` and the wave completes
when its last micro-batch drains; while the wave's expert phase is in
flight the client is free to run prefill chunks — the overlap lockstep
structurally cannot express.  Same seed ⇒ bitwise-identical per-request
token streams in both modes; only timing (TTFT/ITL/throughput) moves.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Callable, Deque, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.elastic import ServerPool
from repro.core.load_balance import lane_loads, server_loads
from repro.core.monitor import Monitor
from repro.models.transformer import build_model
from repro.serving.clock import (Clock, Event, EventTimeline, VirtualClock,
                                 WallClock)
from repro.serving.event_loop import AsyncExpertTier, MicroBatch
from repro.serving.executor import Executor
from repro.serving.kv_pool import BlockPool
from repro.serving.metrics import ServingMetrics
from repro.serving.rebalance import (RebalanceConfig, RebalanceController,
                                     oneshot_rebalance)
from repro.serving.request import Request
from repro.serving.sampling import sample, sample_batch
from repro.serving.scheduler import (DecodeBatch, PrefillChunk, Scheduler,
                                     SchedulerConfig)


@dataclass
class EngineConfig:
    max_batch: int = 8
    max_seq: int = 256
    mode: str = "eaas"                 # eaas | monolithic_ep | tp
    num_servers: int = 4
    n_redundant: int = 2
    restart_steps: int = 50            # monolithic group restart cost
    tp_restart_steps: int = 12         # one TP unit restart
    tp_batch_cap: Optional[int] = None # TP: weight replication caps batch
    gemm_impl: str = "xla_ragged"
    eos_token: Optional[int] = None
    # --- scheduler knobs -------------------------------------------------
    # max prompt tokens per prefill step (0 = whole prompt, the pre-split
    # behaviour); needs a model family with prefill_chunk support,
    # silently unchunked otherwise
    prefill_chunk: int = 0
    policy: str = "prefill-priority"   # prefill-priority | fair | fcfs
    # --- executor knobs --------------------------------------------------
    # lockstep (pre-split single-batch step) | pipelined (two-microbatch
    # client pipelining, §4.2) | serialized (the ablation: same split,
    # collectives exposed)
    decode_mode: str = "lockstep"
    # lockstep (default: synchronous per-step advancement, bit-identical to
    # the pre-async engine) | async (event-driven expert tier: decode waves
    # dispatch per-server micro-batches whose completions post back through
    # a discrete-event timeline; prefill overlaps in-flight expert phases).
    # async needs mode="eaas" + MoE, kv_mode="dense",
    # decode_mode="lockstep", a VirtualClock and a decoder-family model.
    exec_mode: str = "lockstep"
    # decode waves in flight under exec_mode="async" (depth-K speculative
    # pipelining): wave k+K dispatches on wave k+K-1's eagerly-sampled
    # tokens before the elder combines land, so the client's attention
    # share overlaps up to K expert phases.  1 = strict wave-at-a-time
    # (the cadence then equals lockstep exactly; useful for ablation),
    # 2 = the classic ping-pong double buffer.  Token streams stay
    # bitwise identical to lockstep at every depth — the _slot_exhausted
    # eager done-predicate plus event cancellation keep deep pipelines
    # from running a slot past its final token.
    async_depth: int = 2
    # async-tier queueing discipline: "expert" (default) drains per-expert
    # lanes — a Zipf-hot expert queues only in its own lane while cold
    # co-located experts keep flowing; "server" funnels each server's
    # whole share through one aggregate FIFO (the pre-lane behaviour).
    queue_mode: str = "expert"
    # per-server service-stream budget (queue lanes overlap up to this
    # width on one server).  1 (default) keeps service order — and hence
    # every committed timing — bit-identical to the single-FIFO tier.
    lane_budget: int = 1
    # let the live rebalance controller read the async tier's measured
    # queue backlog: migrations are gated on a modeled queue-delay
    # reduction instead of the routed-count imbalance alone.  No-op under
    # lockstep (there is no tier to observe).
    rebalance_queue_aware: bool = True
    # dispatch-buffer sizing override (tokens per client step); default is
    # max_batch, the seed behaviour — raise it when prefill chunks carry
    # more tokens than a decode batch so fixed-capacity buffers don't drop
    pool_tokens_per_client: Optional[int] = None
    # --- KV-cache knobs --------------------------------------------------
    # dense (per-slot (batch, max_seq) buffers, the seed behaviour) | paged
    # (shared block pool + per-request block tables, prefix caching,
    # memory-aware admission and preemption)
    kv_mode: str = "dense"
    kv_block_size: int = 16
    # pool size in blocks; default sizes the pool so every slot can reach
    # max_seq (no memory pressure) — shrink it to oversubscribe.  Must hold
    # at least one maximal request (max_seq/kv_block_size blocks + scratch)
    # or preemption could not keep the engine live.
    kv_num_blocks: Optional[int] = None
    kv_prefix_cache: bool = True
    # --- live rebalancing knobs ------------------------------------------
    # seconds between live replan evaluations (0 = off, the seed behaviour:
    # placement only changes through explicit rebalance()/scale_to() calls)
    rebalance_interval: float = 0.0
    # expert-weight copies migrated per engine step once a replan is staged
    rebalance_chunk: int = 2
    # relative imbalance improvement required before migrating (hysteresis)
    rebalance_min_gain: float = 0.05
    # post-placement-change quiet period, shared with the autoscaler
    rebalance_cooldown: float = 0.05
    # charge decode steps for hot-expert skew: the expert share of the
    # virtual step cost stretches by the pool's max/mean alive-server load
    # (a lockstep expert phase finishes with its hottest server).  Off by
    # default — existing virtual timelines stay bit-identical.
    charge_imbalance: bool = False
    # relative per-server capacity weights ((num_servers,) or None)
    server_capacities: Optional[np.ndarray] = None
    # feed chunked-prefill router traffic into the expert-load EMA (decode
    # steps always feed it); prompt-heavy workloads then trigger rebalances
    # from prefill pressure, not only after decoding starts
    prefill_load_feedback: bool = True


class ServingEngine:
    """Scheduler → executor → metrics orchestrator with EAAS failover.

    Standalone this is one complete serving system; under a
    :class:`~repro.serving.cluster.Cluster` it is one *attention client* of
    N — the cluster injects the shared expert-tier ``pool`` (usually a
    per-client :class:`~repro.core.elastic.PoolClient` mapping view) and
    owns the placement control plane (rebalance / scale), while each client
    keeps its own scheduler, executor, KV pool and clock.
    """

    def __init__(self, cfg: ModelConfig, engine_cfg: EngineConfig,
                 params=None, seed: int = 0, clock: Optional[Clock] = None,
                 pool=None, client_id: int = 0, tier=None):
        self.cfg = cfg
        self.ecfg = engine_cfg
        self.client_id = client_id
        self.clk = clock if clock is not None else WallClock()
        if engine_cfg.exec_mode not in ("lockstep", "async"):
            raise ValueError(
                f"unknown exec_mode {engine_cfg.exec_mode!r}; expected "
                "'lockstep' or 'async'")
        if engine_cfg.exec_mode == "async":
            if engine_cfg.mode != "eaas" or not cfg.moe:
                raise ValueError(
                    "exec_mode='async' models the EAAS expert tier — it "
                    "needs mode='eaas' and an MoE config")
            if engine_cfg.kv_mode != "dense":
                raise ValueError(
                    "exec_mode='async' supports kv_mode='dense' only "
                    "(paged preemption is defined against the lockstep "
                    "step loop)")
            if engine_cfg.decode_mode != "lockstep":
                raise ValueError(
                    "exec_mode='async' overlaps at the wave level — "
                    "decode_mode must stay 'lockstep'")
            if not isinstance(self.clk, VirtualClock):
                raise ValueError(
                    "exec_mode='async' needs a VirtualClock: the event "
                    "timeline is a deterministic modeled-cost timeline")
            if (not isinstance(engine_cfg.async_depth, (int, np.integer))
                    or engine_cfg.async_depth < 1):
                raise ValueError(
                    f"async_depth must be an integer >= 1, got "
                    f"{engine_cfg.async_depth!r}")
            if engine_cfg.queue_mode not in ("expert", "server"):
                raise ValueError(
                    f"unknown queue_mode {engine_cfg.queue_mode!r}; "
                    "expected 'expert' or 'server'")
            if engine_cfg.lane_budget < 1:
                raise ValueError(
                    f"lane_budget must be >= 1, got "
                    f"{engine_cfg.lane_budget}")
        S = engine_cfg.num_servers if engine_cfg.mode != "tp" else 1
        # pool injected = cluster member: the expert tier is shared, its
        # placement is the cluster's to change (scale_to/rebalance here
        # would desync the sibling clients' executors)
        self._shared_pool = pool is not None
        if self._shared_pool:
            if not cfg.moe:
                raise ValueError("shared expert pool needs an MoE config")
            if engine_cfg.mode == "tp":
                raise ValueError("tp mode replicates expert weights per "
                                 "unit — it has no shared expert tier")
            self.pool = pool
            S = pool.num_servers
        elif cfg.moe:
            self.pool = ServerPool(
                cfg, S,
                tokens_per_client=(engine_cfg.pool_tokens_per_client
                                   or engine_cfg.max_batch),
                n_redundant=(engine_cfg.n_redundant
                             if engine_cfg.mode == "eaas" else 0),
                capacities=engine_cfg.server_capacities)
        else:
            self.pool = None
        self.model = build_model(
            cfg, num_servers=S if cfg.moe else 1,
            redundant_table=self.pool.redundant_table if self.pool else None)
        if engine_cfg.exec_mode == "async" \
                and self.model.cache_batch_axis is None:
            raise ValueError(
                "exec_mode='async' needs a model family with a uniform "
                "cache batch axis (decoder family) — wave decodes mask "
                "inactive slot rows")
        key = jax.random.PRNGKey(seed)
        params = params if params is not None else \
            self.model.init_params(key)
        self.monitor = Monitor(heartbeat_timeout=3.0)
        if self.pool:
            self.monitor.subscribe_server_down(self.pool.server_failed)

        self.kv_pool: Optional[BlockPool] = None
        if engine_cfg.kv_mode == "paged":
            bs = engine_cfg.kv_block_size
            if engine_cfg.max_seq % bs:
                raise ValueError(f"max_seq={engine_cfg.max_seq} must be a "
                                 f"multiple of kv_block_size={bs}")
            per_seq = engine_cfg.max_seq // bs
            nb = (engine_cfg.kv_num_blocks
                  if engine_cfg.kv_num_blocks is not None
                  else engine_cfg.max_batch * per_seq + 1)
            if nb - 1 < per_seq:
                raise ValueError(
                    f"kv_num_blocks={nb} cannot hold one maximal request "
                    f"({per_seq} blocks + 1 scratch) — preemption could "
                    "not keep the engine live")
            self.kv_pool = BlockPool(
                nb, bs, enable_prefix_cache=engine_cfg.kv_prefix_cache)
        self.executor = Executor(
            self.model, params, self.pool,
            max_batch=engine_cfg.max_batch, max_seq=engine_cfg.max_seq,
            gemm_impl=engine_cfg.gemm_impl,
            decode_mode=engine_cfg.decode_mode,
            kv_mode=engine_cfg.kv_mode,
            kv_block_size=engine_cfg.kv_block_size,
            kv_num_blocks=(self.kv_pool.num_blocks if self.kv_pool else 0))
        chunk = (engine_cfg.prefill_chunk
                 if self.executor.supports_chunked_prefill else 0)
        self.scheduler = Scheduler(SchedulerConfig(
            max_batch=engine_cfg.max_batch, prefill_chunk=chunk,
            policy=engine_cfg.policy,
            batch_cap=(engine_cfg.tp_batch_cap
                       if engine_cfg.mode == "tp" else None),
            max_seq=engine_cfg.max_seq), kv_pool=self.kv_pool)

        self.metrics = ServingMetrics()
        self.step_idx = 0
        self.clock = 0.0
        self.halted_until = -1
        self._last_decode_time = 0.01
        # per-server straggler factors (scenario slow_server): lockstep
        # charges the max alive factor as an expert-share stretch; the
        # async tier applies them per micro-batch queue
        self.server_speed = np.ones(self._pool_size())
        # --- async exec state -------------------------------------------
        self.timeline = EventTimeline()
        self.tier: Optional[AsyncExpertTier] = None
        self._client_free_at = 0.0       # attention client busy-until
        # in-flight decode waves, FIFO in dispatch order (completion is
        # FIFO too: combine is in-order, so a younger wave that drains
        # early waits for its elders)
        self._waves: Deque[dict] = deque()
        self._wave_counter = 0
        # pending mb_done completion events by micro-batch id: superseded
        # events (failure re-dispatch, reconcile after a lost server) are
        # cancelled on the timeline outright — generation staleness stays
        # as the second guard — so a depth-K pipeline never accumulates
        # dead events
        self._mb_events: dict = {}
        if engine_cfg.exec_mode == "async":
            # a cluster injects the shared tier; standalone owns its own
            self.tier = tier if tier is not None else AsyncExpertTier(
                S, queue_mode=engine_cfg.queue_mode,
                lane_budget=engine_cfg.lane_budget)
        # attention clients currently sharing the expert tier (the cluster
        # sets this before each member step; 1.0 = standalone engine, and
        # the virtual cost model is bit-identical to the pre-cluster one)
        self.expert_contention = 1.0
        # compute/surface the pool imbalance gauge each decode step; set
        # below for a local controller, and by the Cluster on its member
        # clients when the CLUSTER-level controller is active
        self.track_imbalance = False
        # shared placement cooldown (rebalance commits + elastic scaling)
        self.last_placement_change = float("-inf")
        self.rebalancer: Optional[RebalanceController] = None
        if (engine_cfg.rebalance_interval > 0 and self.pool is not None
                and not self._shared_pool
                and engine_cfg.mode == "eaas"):
            self.rebalancer = RebalanceController(RebalanceConfig(
                interval=engine_cfg.rebalance_interval,
                chunk=engine_cfg.rebalance_chunk,
                min_gain=engine_cfg.rebalance_min_gain,
                cooldown=engine_cfg.rebalance_cooldown,
                queue_aware=engine_cfg.rebalance_queue_aware))
        self.track_imbalance = self.rebalancer is not None

    # ------------------------------------------------- back-compat surface
    @property
    def queue(self):
        return self.scheduler.queue

    @property
    def slots(self):
        return self.scheduler.slots

    @property
    def params(self):
        return self.executor.params

    @property
    def cache(self):
        return self.executor.cache

    # ------------------------------------------------------------ helpers
    def _alive_frac(self) -> float:
        """Alive share of the expert-server pool (EAAS failover slowdown)."""
        if self.pool is None or self.ecfg.mode != "eaas":
            return 1.0
        return float(self.pool.smap.alive.mean())

    def _pool_size(self) -> int:
        return self.pool.num_servers if self.pool else 1

    def _alive_mask(self) -> np.ndarray:
        """This client's view of server liveness ((S,) bool)."""
        if self.pool is None:
            return np.ones(1, bool)
        if hasattr(self.pool, "alive_mask"):
            return np.asarray(self.pool.alive_mask(), bool)
        return np.asarray(self.pool.smap.alive, bool)

    def _straggle(self) -> float:
        """Slowdown factor of the slowest *alive* expert server — a
        lockstep expert phase finishes with its slowest server."""
        if self.pool is None or self.ecfg.mode != "eaas":
            return 1.0
        alive = self._alive_mask()
        n = min(len(alive), len(self.server_speed))
        sp = self.server_speed[:n][alive[:n]]
        return float(sp.max()) if sp.size else 1.0

    # --------------------------------------------------- front-end signals
    def pending_prefill_tokens(self) -> int:
        """Unprefilled prompt tokens (queued + mid-chunk) — the autoscaler
        and the least-loaded front-end policy read this."""
        return self.scheduler.pending_prefill_tokens()

    def kv_free_fraction(self) -> float:
        return self.scheduler.kv_free_fraction()

    def queue_signals(self) -> Optional[dict]:
        """Live async-tier queue signals (per-server backlog seconds, the
        per-lane depth/backlog breakdown) at the current engine clock —
        what the queue-aware rebalance gate reads.  None under lockstep:
        there is no tier to observe."""
        if self.tier is None:
            return None
        return self.tier.queue_signals(self.clock)

    def free_kv_tokens(self) -> int:
        """Token capacity this client can still admit into: free pool
        blocks (paged) or free slots × max_seq (dense) — the memory half of
        the least-loaded routing score."""
        if self.kv_pool is not None:
            return self.kv_pool.available() * self.kv_pool.block_size
        free_slots = sum(1 for s in self.slots if s is None)
        return free_slots * self.ecfg.max_seq

    def abort_inflight(self) -> list:
        """Drop every queued and in-flight request (client failure): slots
        and KV blocks are released, nothing is re-queued.  Returns the
        stranded requests — the cluster counts them as failed.  The expert
        tier is untouched; sibling clients keep serving."""
        stranded = list(self.scheduler.queue)
        self.scheduler.queue.clear()
        for b, r in enumerate(self.scheduler.slots):
            if r is not None:
                stranded.append(r)
                self.scheduler.release(b)
        self.executor._staging.clear()
        if self.ecfg.exec_mode == "async":
            # strand only this client's queued tier work: its in-flight
            # micro-batches are abandoned (the servers finish the already
            # dispatched compute — occupancy stays — and discard results);
            # sibling clients' queues are untouched
            if self.tier is not None:
                self.tier.cancel_client(self.client_id)
            self.timeline.clear_pending()
            self._waves.clear()
            self._mb_events.clear()
            self._client_free_at = self.clock
        return stranded

    # ------------------------------------------------------------- control
    def submit(self, req: Request) -> None:
        self.scheduler.submit(req)
        self.metrics.total_requests += 1

    def set_policy(self, policy: str) -> None:
        """Switch the scheduler policy mid-run (scenario ``set_policy``)."""
        self.scheduler.set_policy(policy)
        self.metrics.events.append(
            {"t": self.clock, "event": "set_policy", "policy": policy})

    def inject_server_failure(self, rank: int) -> None:
        """Simulated hardware failure of one expert server (paper §5.4)."""
        self.metrics.events.append(
            {"t": self.clock, "event": "server_fail", "rank": rank,
             "mode": self.ecfg.mode})
        if self.ecfg.mode == "eaas":
            if self.pool and rank < self.pool.num_servers:
                self.pool.server_failed(rank)     # mapping mask update only
            if self.tier is not None:
                # re-dispatch the dead server's queued micro-batches to
                # survivors: fresh completion events from the new finish
                # times; the old events are stale by generation
                moved = self.tier.fail_server(rank, self.clock)
                for mb in moved:
                    self._post_redispatch(mb)
                if moved:
                    self.metrics.events.append(
                        {"t": self.clock, "event": "redispatch",
                         "rank": rank, "count": len(moved)})
                self._reconcile_waves()
        elif self.ecfg.mode == "monolithic_ep":
            self.halted_until = self.step_idx + self.ecfg.restart_steps
        elif self.ecfg.mode == "tp":
            self.halted_until = self.step_idx + self.ecfg.tp_restart_steps

    def recover_server(self, rank: int) -> None:
        self.metrics.events.append(
            {"t": self.clock, "event": "server_recover", "rank": rank})
        if self.pool and rank < self.pool.num_servers:
            self.pool.server_recovered(rank)
        if self.tier is not None and rank < self.tier.num_servers:
            self.tier.recover_server(rank, self.clock)

    def set_server_speed(self, rank: int, factor: float) -> None:
        """Mark expert server ``rank`` as running ``factor``× slower
        (scenario ``slow_server``; 1.0 restores full speed).  Lockstep
        charges every decode step the max alive factor — the whole tier
        waits for its slowest server; the async tier slows only that
        server's micro-batch queue, which is exactly the tail-latency
        asymmetry the differential tests pin."""
        if self.pool is None or rank >= len(self.server_speed):
            return
        if factor <= 0:
            raise ValueError(f"server speed factor must be > 0: {factor}")
        self.server_speed[rank] = float(factor)
        if self.tier is not None and rank < self.tier.num_servers:
            self.tier.set_slowdown(rank, factor)
        self.metrics.events.append(
            {"t": self.clock, "event": "slow_server", "rank": rank,
             "factor": float(factor)})

    def apply_migration(self, copies) -> None:
        """Apply one expert-weight migration chunk to this engine's
        executor.  A :class:`~repro.serving.cluster.Cluster` overrides the
        *host* side of this call to fan the same copies out to every
        client's executor — replica weights never diverge across clients."""
        self.executor.migrate_slots(copies)

    def charge_migration(self, dt: float) -> None:
        """Advance the engine clock by a migration chunk's cost.  The
        cluster version charges every client — the shared expert tier is
        busy copying weights, so everyone's next expert phase waits.

        Under ``exec_mode='async'`` the copy occupies the *expert tier*
        instead: in-flight micro-batches keep their committed finish
        times, subsequent dispatches queue behind the copy, and the client
        keeps running attention/prefill — migration chunks interleave with
        in-flight work rather than stalling the world."""
        if self.tier is not None:
            self.tier.occupy_all(self.clock, dt)
            return
        self.clock += dt

    def page_out_experts(self, experts) -> list:
        """Scale-to-zero: page cold experts out of the expert tier (their
        replica bank slots are zeroed through the migration weight path,
        the mapping keeps only the primary as the page-in source).  An
        expert with in-flight work on its tier lanes is skipped this round
        — eviction waits for the lanes to drain, never cancels them.  The
        first token later routed to a paged-out expert pages it back in
        and pays the clock's ``cold_start_base`` penalty.  Returns the
        experts actually paged out."""
        if self.pool is None:
            return []
        if self._shared_pool:
            raise RuntimeError(
                "this engine is a cluster client over a shared expert "
                "tier — call Cluster.page_out_experts() so every client's "
                "executor evicts in lockstep")
        ready = [e for e in experts
                 if self.tier is None or not self.tier.expert_in_flight(e)]
        paged, updates = self.pool.page_out_experts(ready)
        if updates:
            self.apply_migration(updates)
        if paged:
            self.last_placement_change = self.clock
            self.metrics.expert_page_outs += len(paged)
            self.metrics.events.append(
                {"t": self.clock, "event": "page_out",
                 "experts": len(paged)})
        return paged

    def _charge_cold_starts(self, expert_load) -> float:
        """Page in every cold expert this step's routed load touched and
        return the modeled stall (``cold_start_base`` per expert; 0.0 —
        the default — keeps elastic timelines bit-identical to non-elastic
        ones).  Values never depend on residency: the primary shard stayed
        addressable, so the tokens already computed exactly — only time
        passes here."""
        pool = self.pool
        if pool is None:
            return 0.0
        cold = getattr(pool, "cold", None)
        if not cold:
            return 0.0
        load = np.asarray(expert_load)
        hits = sorted(e for e in cold if e < load.shape[0] and load[e] > 0)
        if not hits:
            return 0.0
        for e in hits:
            pool.page_in_expert(e, self.clock)
        self.clk.start()
        dt = self.clk.stop("cold_start", tokens=len(hits))
        self.metrics.cold_starts += len(hits)
        self.metrics.cold_start_time += dt
        self.metrics.events.append(
            {"t": self.clock, "event": "cold_start", "experts": len(hits),
             "dt": dt})
        return dt

    def rebalance(self) -> None:
        """One-shot EPLB replica re-planning from live traffic (paper
        §4.5) — the scripted/manual path.  Placement-identical plans are
        skipped via ``plan_digest`` (nothing rebuilt); a changed plan
        migrates the replica weights *and* the mapping in one step (the
        weight copies charged as one big ``migrate`` step), so weights and
        local table never disagree.  The live ``rebalance_interval``
        controller spreads the same work over chunked migration steps
        interleaved with decoding instead.
        """
        if self.pool is None:
            return
        if self._shared_pool:
            raise RuntimeError(
                "this engine is a cluster client over a shared expert "
                "tier — call Cluster.rebalance() so every client's "
                "executor migrates in lockstep")
        if self.rebalancer is not None:
            self.rebalancer.abort()      # the one-shot replan supersedes it
        oneshot_rebalance(self)

    def set_skew(self, bias: np.ndarray) -> None:
        """Install a router-logit bias (scenario ``set_skew`` traffic
        shaping).  Pure runtime data — the next jitted step routes under
        the new bias without recompiling."""
        if self.pool is None:
            return
        self.pool.set_route_bias(bias)
        bias = np.asarray(bias, np.float64)
        self.metrics.events.append(
            {"t": self.clock, "event": "set_skew",
             "spread": round(float(bias.max() - bias.min()), 6)})

    def scale_to(self, n: int) -> None:
        """Elastically resize the expert-server pool to ``n`` servers.

        The pool re-plans its EPLB mapping (liveness preserved), the
        executor re-shards the expert weights from the recovered global bank
        and rebuilds its jitted variants for the new static server count
        (the AOT-per-server-count story).  In-flight requests keep their KV
        cache — scaling never drops work (paper §5.3).
        """
        if self.pool is None or n == self.pool.num_servers:
            return
        if self._shared_pool:
            raise RuntimeError(
                "this engine is a cluster client over a shared expert "
                "tier — call Cluster.scale_to() so every client's "
                "executor re-shards in lockstep")
        old = self.pool.num_servers
        if self.rebalancer is not None:
            self.rebalancer.abort()      # a resize replans placement anyway
        self._drain_async()              # quiesce in-flight waves first
        self.pool.scale_to(n)
        self.executor.resize(self.pool)
        self.server_speed = np.ones(n)   # fresh pool, fresh speeds
        if self.tier is not None:
            # _drain_async quiesced the waves, so a standalone resize has
            # nothing in flight — but the reconcile contract holds anyway:
            # work still queued on dropped ranks re-dispatches and its
            # completion events are re-posted
            for mb in self.tier.resize(n, self.clock):
                self._post_redispatch(mb)
            self._reconcile_waves()
            self.tier.reset_speeds()     # match the server_speed reset
        self.last_placement_change = self.clock
        self.metrics.events.append(
            {"t": self.clock, "event": "scale", "from": old, "to": n})

    # ---------------------------------------------------------------- step
    def step(self) -> None:
        """One engine iteration: run whatever the scheduler plans next —
        a prefill chunk, a decode step over the ready slots, or idle."""
        self.step_idx += 1
        if self.step_idx <= self.halted_until:
            # monolithic restart: time passes, no tokens are produced
            self.clock += self._last_decode_time
            self.metrics.timeline.append(
                {"t": self.clock, "tokens": 0, "halted": True})
            return
        if self.ecfg.exec_mode == "async":
            self._step_async()
        else:
            plan = self.scheduler.next_plan()
            if isinstance(plan, PrefillChunk):
                self._step_prefill(plan)
            elif isinstance(plan, DecodeBatch):
                self._step_decode(plan)
            else:
                self.clock += self.clk.idle()
        if self.rebalancer is not None:
            # migration chunks interleave with decode steps — serving
            # never pauses for a replan (paper §4.5 live adaptation)
            self.rebalancer.step(self)
        if self.kv_pool is not None:
            self.metrics.observe_kv(self.kv_pool,
                                    self.scheduler.preemptions)

    def _step_prefill(self, plan: PrefillChunk) -> None:
        req, b = plan.request, plan.slot
        chunk = (plan.tokens if plan.tokens is not None
                 else req.prompt[plan.start:plan.start + plan.length])
        self.clk.start()
        expert_load = None
        if self.kv_pool is not None:
            # paged: every prefill runs the chunk path against the block
            # pool (prefix hits start mid-prompt; the virtual clock is
            # charged only the uncached tokens in ``plan.length``)
            self.executor.copy_blocks(plan.copies)     # pending COW forks
            logits, expert_load = self.executor.prefill_chunk_paged(
                chunk, plan.start, self.scheduler.block_tables[b])
        elif plan.is_first and plan.is_last:
            # whole prompt in one step — the pre-split prefill path
            logits = self.executor.prefill(b, chunk)
        else:
            logits, expert_load = self.executor.prefill_chunk(
                b, chunk, plan.start,
                is_first=plan.is_first, is_last=plan.is_last)
        if (expert_load is not None and self.pool is not None
                and self.ecfg.prefill_load_feedback):
            # chunked-prefill router traffic feeds the same EMA decode
            # feeds — prompt-heavy workloads rebalance from prompt traffic
            self.pool.observe_load(np.asarray(expert_load))
        self.clock += self.clk.stop("prefill", result=logits,
                                    tokens=plan.length,
                                    servers=self._pool_size(),
                                    alive_frac=self._alive_frac())
        if expert_load is not None:
            self.clock += self._charge_cold_starts(expert_load)
        self.scheduler.prefill_advanced(b, plan.length)
        if plan.is_last and not req.output_tokens:
            # same per-slot key the decode path uses (stored at admission),
            # folded with token index 0 — one key-derivation site.  A
            # *resumed* (preempted) request already holds its next input
            # token, so recompute prefills skip sampling and TTFT.
            key = jnp.asarray(self.scheduler.slot_keys[b])
            first = int(sample(logits, req.sampling.temperature,
                               jax.random.fold_in(key, 0))[0])
            req.output_tokens.append(first)
            req.prefill_time = self.clock
            self.metrics.ttfts.append(self.clock - req.arrival_time)
            self.metrics.events.append(
                {"t": self.clock, "event": "prefill", "rid": req.request_id,
                 "ttft": self.clock - req.arrival_time})

    def _step_decode(self, plan: DecodeBatch) -> None:
        sch = self.scheduler
        B = len(sch.slots)
        active = list(plan.slots)
        tokens = np.zeros((B, 1), np.int32)
        temps = np.zeros(B, np.float32)
        steps = np.zeros(B, np.int32)
        for b in active:
            r = sch.slots[b]
            tokens[b, 0] = r.output_tokens[-1]
            temps[b] = r.sampling.temperature
            steps[b] = len(r.output_tokens)
        self.clk.start()
        if self.kv_pool is not None:
            logits, expert_load = self.executor.decode_paged(
                tokens, self.scheduler.block_tables,
                self.scheduler.cache_lengths())
        else:
            logits, expert_load = self.executor.decode(tokens)
        imbalance = 1.0
        if self.pool is not None:
            # fold this step's router traffic into the EMA first, so the
            # imbalance charged (and surfaced) reflects current traffic;
            # the gauge itself is only computed when something consumes it
            # (cost model or controller) — it walks the mapping in Python
            self.pool.observe_load(np.asarray(expert_load))
            if self.ecfg.charge_imbalance or self.track_imbalance:
                imbalance = self.pool.current_imbalance()
                self.metrics.observe_balance(imbalance)
        dt = self.clk.stop("decode", result=logits, tokens=len(active),
                           servers=self._pool_size(),
                           alive_frac=self._alive_frac(),
                           overlap=(self.ecfg.decode_mode == "pipelined"),
                           imbalance=(imbalance
                                      if self.ecfg.charge_imbalance
                                      else 1.0),
                           contention=self.expert_contention,
                           straggle=self._straggle())
        self._last_decode_time = dt
        self.clock += dt
        self.clock += self._charge_cold_starts(expert_load)
        next_tokens = np.asarray(sample_batch(logits, temps,
                                              sch.slot_keys, steps))

        produced = 0
        for b in active:
            r = sch.slots[b]
            tok = int(next_tokens[b])
            r.output_tokens.append(tok)
            r.token_times.append(self.clock)
            produced += 1
            self.metrics.total_output_tokens += 1
            done = (len(r.output_tokens) >= r.sampling.max_new_tokens or
                    (self.ecfg.eos_token is not None and
                     tok == self.ecfg.eos_token) or
                    len(r.prompt) + len(r.output_tokens) >=
                    self.ecfg.max_seq - 1)
            if done:
                r.finish_time = self.clock
                self.metrics.completed += 1
                self.metrics.itls.extend(r.itl())
                sch.release(b)
        self.metrics.timeline.append(
            {"t": self.clock, "tokens": produced, "halted": False})

    # --------------------------------------------------------- async steps
    def _step_async(self) -> None:
        """One event-driven iteration.

        If the attention client is free, plan eagerly: a prefill chunk
        runs now (overlapping any in-flight wave's expert phase), and a
        decode wave dispatches its expert share into the tier as long as
        fewer than ``async_depth`` waves are in flight — wave k+1 runs on
        wave k's eagerly-sampled tokens before k's combine lands (ping-pong
        double buffering), so the client's attention share and the tier's
        expert share overlap instead of summing.  Otherwise advance the
        clock to the earlier of the next timeline event and the client's
        busy-until, handling the event if that's what comes first.
        """
        if self.clock >= self._client_free_at:
            plan = self.scheduler.next_plan()
            if isinstance(plan, PrefillChunk):
                self._async_prefill(plan)
                return
            if (isinstance(plan, DecodeBatch)
                    and len(self._waves) < self.ecfg.async_depth
                    and self._async_decode(plan)):
                return
        ev_t = self.timeline.peek_time()
        free_t = (self._client_free_at
                  if self._client_free_at > self.clock else None)
        if ev_t is not None and (free_t is None or ev_t <= free_t):
            ev = self.timeline.pop()
            self.clock = max(self.clock, ev.time)
            self._handle_event(ev)
        elif free_t is not None:
            self.clock = free_t
        else:
            self.clock += self.clk.idle()

    def _async_prefill(self, plan: PrefillChunk) -> None:
        """Run one prefill chunk eagerly; its completion (scheduler
        progress, first-token sampling, TTFT) lands at event time.  Values
        are computed now — they don't depend on when the chunk finishes —
        so the event handler only does bookkeeping."""
        req, b = plan.request, plan.slot
        chunk = (plan.tokens if plan.tokens is not None
                 else req.prompt[plan.start:plan.start + plan.length])
        self.clk.start()
        expert_load = None
        if plan.is_first and plan.is_last:
            logits = self.executor.prefill(b, chunk)
        else:
            logits, expert_load = self.executor.prefill_chunk(
                b, chunk, plan.start,
                is_first=plan.is_first, is_last=plan.is_last)
        if (expert_load is not None and self.pool is not None
                and self.ecfg.prefill_load_feedback):
            self.pool.observe_load(np.asarray(expert_load))
        dt = self.clk.stop("prefill", result=logits, tokens=plan.length,
                           servers=self._pool_size(),
                           alive_frac=self._alive_frac())
        if expert_load is not None:
            dt += self._charge_cold_starts(expert_load)
        first = None
        if plan.is_last and not req.output_tokens:
            key = jnp.asarray(self.scheduler.slot_keys[b])
            first = int(sample(logits, req.sampling.temperature,
                               jax.random.fold_in(key, 0))[0])
        t_done = self.clock + dt
        self._client_free_at = t_done
        self.timeline.post(t_done, "prefill_done", slot=b,
                           rid=req.request_id, length=plan.length,
                           last=plan.is_last, first=first, req=req)

    def _slot_pending(self, b: int) -> list:
        """Tokens sampled for slot ``b`` by in-flight waves, oldest first —
        computed eagerly at dispatch but not yet appended (that happens at
        each wave's completion event)."""
        return [int(w["tokens"][b]) for w in self._waves
                if b in w["slot_set"]]

    def _slot_exhausted(self, b: int, r: Request) -> bool:
        """Counting in-flight sampled tokens, will slot ``b`` be done when
        its last wave lands?  Mirrors the lockstep done-check exactly, so
        a slot is never dispatched past its final token even though that
        token hasn't been committed yet.

        A slot with *no* wave in flight is never exhausted: lockstep
        always decodes a ready slot and runs the done check only after
        appending — even when the prefill-sampled first token already
        meets the done condition (max_new_tokens=1, or EOS sampled at
        prefill) it decodes exactly once more and releases at the check.
        Holding a pend-empty slot instead would park it forever: with no
        wave in flight there is no completion event left to release it,
        and its token stream would diverge from lockstep's."""
        pend = self._slot_pending(b)
        if not pend:
            return False
        count = len(r.output_tokens) + len(pend)
        return (count >= r.sampling.max_new_tokens
                or (self.ecfg.eos_token is not None
                    and pend[-1] == self.ecfg.eos_token)
                or len(r.prompt) + count >= self.ecfg.max_seq - 1)

    def _async_decode(self, plan: DecodeBatch) -> bool:
        """Dispatch one decode wave: compute values eagerly (masked so
        non-wave slot rows stay resumable), split the step cost into the
        client share (attention/dispatch/combine — the client is busy for
        it) and the expert share, and enqueue the expert share as
        per-server micro-batches.  A slot whose previous token is still
        in flight decodes on the eagerly-sampled value — values never wait
        for events — while completed-token bookkeeping (append, ITL,
        release) stays at event time.  Returns False when every offered
        slot is already exhausted (nothing dispatched)."""
        sch = self.scheduler
        B = len(sch.slots)
        active = []
        for b in plan.slots:
            r = sch.slots[b]
            if self._slot_exhausted(b, r):
                # park it until its final (in-flight, _slot_exhausted
                # guarantees one) wave's completion releases it
                sch.hold(b)
            else:
                active.append(b)
        if not active:
            return False
        tokens = np.zeros((B, 1), np.int32)
        temps = np.zeros(B, np.float32)
        steps = np.zeros(B, np.int32)
        mask = np.zeros(B, bool)
        for b in active:
            r = sch.slots[b]
            pend = self._slot_pending(b)
            tokens[b, 0] = pend[-1] if pend else r.output_tokens[-1]
            temps[b] = r.sampling.temperature
            steps[b] = len(r.output_tokens) + len(pend)
            mask[b] = True
        self.clk.start()
        logits, expert_load = self.executor.decode_masked(tokens, mask)
        if self.pool is not None:
            self.pool.observe_load(np.asarray(expert_load))
            if self.ecfg.charge_imbalance or self.track_imbalance:
                self.metrics.observe_balance(self.pool.current_imbalance())
        next_tokens = np.asarray(sample_batch(logits, temps,
                                              sch.slot_keys, steps))
        S = self._pool_size()
        af = self._alive_frac()
        client_dt, expert_dt = self.clk.decode_split(
            tokens=len(active), servers=S, alive_frac=af)
        # a wave routing to paged-out experts stalls its own dispatch on
        # the page-in: the client share absorbs the cold-start penalty
        client_dt += self._charge_cold_starts(expert_load)
        t_dispatch = self.clock + client_dt
        self._client_free_at = t_dispatch
        wave_id = self._wave_counter
        self._wave_counter += 1
        entries = self._wave_lane_entries(
            np.asarray(expert_load, np.float64), S, expert_dt)
        wave = {"id": wave_id, "slots": active, "slot_set": set(active),
                "tokens": next_tokens, "pending": set()}
        self._waves.append(wave)
        if not entries:
            # no alive server / no routed-load signal (all-dead pool
            # edge): one aggregate completion at the analytic stretched
            # cost; the sentinel keeps the wave pending until it fires
            wave["pending"].add("wave")
            self.timeline.post(t_dispatch + expert_dt / max(af, 1e-3),
                               "wave_done", wave=wave_id)
        else:
            mbs = self.tier.dispatch_lanes(self.client_id, wave_id,
                                           entries, now=t_dispatch)
            for mb in mbs:
                wave["pending"].add(mb.mb_id)
                self._mb_events[mb.mb_id] = self.timeline.post(
                    mb.finish_t, "mb_done", mb=mb.mb_id,
                    gen=mb.generation, wave=wave_id, server=mb.server,
                    expert=mb.expert)
            if not mbs:
                wave["pending"].add("wave")
                self.timeline.post(t_dispatch, "wave_done", wave=wave_id)
        return True

    def _wave_lane_entries(self, expert_load: np.ndarray, S: int,
                           expert_dt: float) -> list:
        """Decompose one wave's expert share into tier dispatch entries
        ``(server, expert, work_seconds, tokens)``.

        Per-server totals: ``expert_dt`` is the perfectly-balanced
        per-server time; by default each alive server gets the uniform
        share ``expert_dt * S / alive`` (dead servers' work concentrates
        on survivors — the 1/alive_frac stretch, reproduced physically as
        queueing).  With ``charge_imbalance`` the shares follow this
        step's *real* routed load instead, mirroring the lockstep clock's
        analytic imbalance stretch.

        Under ``queue_mode="expert"`` each server's share splits further
        into per-expert lane entries along the routed-load decomposition
        (:func:`~repro.core.load_balance.lane_loads`) — same per-server
        totals, finer queueing granularity — emitted server-major,
        expert-ascending (deterministic).  A server with no routed load
        this wave keeps one aggregate-lane entry so the uniform cadence
        is unchanged.  ``VirtualClock.lane_overhead`` (default 0) is
        added per lane entry when a server's share splits."""
        alive = self._alive_mask()
        caps = getattr(self.pool, "capacities", None)
        lane_mode = self.ecfg.queue_mode == "expert"
        overhead = float(getattr(self.clk, "lane_overhead", 0.0))
        entries = []
        if self.ecfg.charge_imbalance:
            lanes = lane_loads(expert_load, self.pool.smap.table, S,
                               alive=alive, capacities=caps)
            total = float(lanes.sum())
            if total <= 0.0:
                return []
            scale = expert_dt * S / total
            for s in range(S):
                row = lanes[s]
                row_sum = float(row.sum())
                if row_sum <= 0.0:
                    continue
                if lane_mode:
                    nz = np.nonzero(row)[0]
                    extra = overhead if len(nz) > 1 else 0.0
                    for e in nz:
                        entries.append((s, int(e),
                                        scale * float(row[e]) + extra,
                                        float(row[e])))
                else:
                    entries.append((s, -1, scale * row_sum, row_sum))
            return entries
        n_alive = int(alive.sum())
        if n_alive <= 0:
            return []
        w_server = expert_dt * S / n_alive
        if not lane_mode:
            return [(s, -1, w_server, 1.0) for s in range(S) if alive[s]]
        lanes = lane_loads(expert_load, self.pool.smap.table, S,
                           alive=alive, capacities=caps)
        for s in range(S):
            if not alive[s]:
                continue
            row = lanes[s]
            row_sum = float(row.sum())
            if row_sum <= 0.0:
                # uniform cost model: a server with nothing routed this
                # wave still runs its uniform share (dispatch/combine
                # sync) — one aggregate-lane entry keeps the cadence
                entries.append((s, -1, w_server, 0.0))
                continue
            nz = np.nonzero(row)[0]
            extra = overhead if len(nz) > 1 else 0.0
            for e in nz:
                entries.append((s, int(e),
                                w_server * float(row[e]) / row_sum + extra,
                                float(row[e])))
        return entries

    # -------------------------------------------------------- async events
    def _handle_event(self, ev: Event) -> None:
        if ev.kind == "prefill_done":
            self._on_prefill_done(ev)
        elif ev.kind == "mb_done":
            self._on_mb_done(ev)
        elif ev.kind == "wave_done":
            for w in self._waves:
                if w["id"] == ev.payload["wave"]:
                    w["pending"].discard("wave")
                    break
            self._drain_finished_waves()

    def _on_prefill_done(self, ev: Event) -> None:
        p = ev.payload
        b, req = p["slot"], p["req"]
        if self.scheduler.slots[b] is not req:
            return                      # slot was aborted/released meanwhile
        self.scheduler.prefill_advanced(b, p["length"])
        if p["last"] and p["first"] is not None:
            req.output_tokens.append(p["first"])
            req.prefill_time = self.clock
            self.metrics.ttfts.append(self.clock - req.arrival_time)
            self.metrics.events.append(
                {"t": self.clock, "event": "prefill",
                 "rid": req.request_id,
                 "ttft": self.clock - req.arrival_time})

    def _on_mb_done(self, ev: Event) -> None:
        p = ev.payload
        if not self.tier.is_current(p["mb"], p["gen"]):
            return                      # re-dispatched or cancelled since
        mb = self.tier.mbs[p["mb"]]
        self.tier.mark_done(mb)
        self._mb_events.pop(mb.mb_id, None)
        # queueing delay: how long the micro-batch waited behind other
        # work in its lane/on its server — the first-class tail-latency
        # signal, attributed per (server, expert-lane) for the breakdown
        self.metrics.observe_queue_delay(mb.start_t - mb.enqueue_t,
                                         server=mb.server,
                                         expert=mb.expert)
        for w in self._waves:
            if w["id"] == mb.wave_id:
                w["pending"].discard(mb.mb_id)
                break
        self._drain_finished_waves()

    def _drain_finished_waves(self) -> None:
        """Retire drained waves in dispatch order.  Combine is in-order:
        a younger wave whose micro-batches all landed still waits for its
        elders, so per-slot token streams commit in sequence."""
        while self._waves and not self._waves[0]["pending"]:
            self._finish_wave(self._waves.popleft())

    def _finish_wave(self, w: dict) -> None:
        """The wave's last micro-batch drained (and every older wave
        retired): append the (already sampled) tokens at event time,
        retire finished requests."""
        sch = self.scheduler
        next_tokens = w["tokens"]
        produced = 0
        for b in w["slots"]:
            r = sch.slots[b]
            if r is None:
                continue
            tok = int(next_tokens[b])
            r.output_tokens.append(tok)
            r.token_times.append(self.clock)
            produced += 1
            self.metrics.total_output_tokens += 1
            done = (len(r.output_tokens) >= r.sampling.max_new_tokens or
                    (self.ecfg.eos_token is not None and
                     tok == self.ecfg.eos_token) or
                    len(r.prompt) + len(r.output_tokens) >=
                    self.ecfg.max_seq - 1)
            if done:
                # _slot_exhausted kept this slot out of every later wave,
                # so releasing here can't orphan an in-flight token
                r.finish_time = self.clock
                self.metrics.completed += 1
                self.metrics.itls.extend(r.itl())
                sch.release(b)
        self.metrics.timeline.append(
            {"t": self.clock, "tokens": produced, "halted": False})

    def _post_redispatch(self, mb: MicroBatch) -> None:
        """Post the fresh completion event for a failure-re-dispatched
        micro-batch (the cluster fans these to the owning client).  The
        superseded event for the old placement is cancelled outright —
        generation staleness remains as the second guard."""
        stale = self._mb_events.pop(mb.mb_id, None)
        if stale is not None:
            self.timeline.cancel(stale)
        self._mb_events[mb.mb_id] = self.timeline.post(
            mb.finish_t, "mb_done", mb=mb.mb_id, gen=mb.generation,
            wave=mb.wave_id, server=mb.server, expert=mb.expert)

    def _reconcile_waves(self) -> None:
        """Drop cancelled micro-batches from the in-flight waves (a
        failure with no survivors cancels outright) and cancel their
        pending completion events; retire waves left with nothing
        pending."""
        if self.tier is None:
            return
        for w in self._waves:
            for mb_id in list(w["pending"]):
                if mb_id == "wave":
                    continue
                mb = self.tier.mbs.get(mb_id)
                if mb is None or mb.cancelled:
                    w["pending"].discard(mb_id)
                    stale = self._mb_events.pop(mb_id, None)
                    if stale is not None:
                        self.timeline.cancel(stale)
        self._drain_finished_waves()

    def _drain_async(self) -> None:
        """Run the event timeline dry (the quiesce barrier before
        placement changes that re-shard the executor)."""
        if self.ecfg.exec_mode != "async":
            return
        while self.timeline.peek_time() is not None:
            ev = self.timeline.pop()
            self.clock = max(self.clock, ev.time)
            self._handle_event(ev)
        self.clock = max(self.clock, self._client_free_at)

    def run(self, max_steps: int = 10_000,
            on_step: Optional[Callable[["ServingEngine"], None]] = None
            ) -> ServingMetrics:
        """Drive until queue + slots drain (or max_steps)."""
        while (self.queue or any(s is not None for s in self.slots)) \
                and self.step_idx < max_steps:
            if on_step:
                on_step(self)
            self.step()
        self.metrics.wall_time = self.clock
        return self.metrics
