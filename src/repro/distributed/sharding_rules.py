"""Sharding rules: parameter/batch PartitionSpecs per (arch family, phase).

Mesh axes: ``("pod",) data, model``.  Phases:

* ``serve``  — dense weights tensor-parallel over ``model``; replicated over
  data rows (each row is an independent client group); expert banks sharded
  over ``model`` (the 16 logical servers), *replicated over data* — the
  replica pool that failover and load balancing draw from.
* ``train``  — same TP layout + ZeRO-3: the non-server dim of every large
  tensor is additionally sharded over ``data`` and all-gathered at use
  (XLA inserts the gathers at the shard_map island / einsum boundaries).
  Optimizer state inherits the parameter specs (sharded state = ZeRO-1/2).

Specs are matched by parameter *path suffix*; stacked scan dimensions
(leading layer dims) are padded with ``None`` automatically.
"""

from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P



def _path_of(key_path) -> str:
    parts = []
    for p in key_path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


# core-dim specs: (serve, train, decode) per matcher, applied to TRAILING
# dims.  The decode phase replicates attention projections over the model
# axis: the KV cache is sequence-sharded there (flash-decode SP islands), so
# every rank computes the tiny one-token q/k/v redundantly instead of
# re-sharding a multi-GB cache every layer (EXPERIMENTS.md §Perf iter 1).
def _rules(dp: str, mp: str):
    # column-parallel (in, out): out over model; ZeRO-3 shards `in` over data
    col = (P(None, mp), P(dp, mp), P(None, mp))
    # row-parallel (in, out=d): in over model
    row = (P(mp, None), P(mp, dp), P(mp, None))
    repl2 = (P(None, None), P(None, None), P(None, None))
    # attention projections: TP for train/prefill, replicated for decode
    att_col = (P(None, mp), P(dp, mp), P(None, None))
    att_row = (P(mp, None), P(mp, dp), P(None, None))
    expert = (P(mp, None, None, None), P(mp, None, dp, None),
              P(mp, None, None, None))
    return [
        # --- embeddings / head ------------------------------------------
        ("embed",        2, (P(mp, None), P(mp, dp), P(mp, None))),
        ("head",         2, col),
        # --- expert service tier (dims: S, L, d|f, f|d) ------------------
        ("servers/w_gate", 4, expert),
        ("servers/w_up",   4, expert),
        ("servers/w_down", 4, expert),
        ("servers/local_table", 2, (P(mp, None),) * 3),
        ("w_router",     2, repl2),
        # --- attention ----------------------------------------------------
        ("wq",           2, att_col), ("wk", 2, att_col),
        ("wv",           2, att_col), ("wo", 2, att_row),
        # --- dense / shared / residual FFN --------------------------------
        ("w_gate",       2, col), ("w_up", 2, col), ("w_down", 2, row),
        # --- mamba ---------------------------------------------------------
        ("in_proj",      2, col), ("out_proj", 2, row),
        ("conv_w",       2, (P(None, mp),) * 3),
        # --- rwkv (matches the explicit Megatron island in models/rwkv) ---
        ("cmix/w_r",     2, repl2),
        ("w_r",          2, col), ("w_k", 2, col), ("w_v", 2, row),
        ("w_g",          2, col), ("w_o", 2, row),
        ("decay_A",      2, repl2),
        ("decay_B",      2, col),
        ("decay_w0",     1, (P(mp), P(mp), P(mp))),
        ("bonus_u",      2, (P(mp, None),) * 3),
        ("tmix/ln_scale", 1, (P(mp), P(mp), P(mp))),
    ]


# ``train_tp``: sub-~100B archs train with the serve-style TP layout
# (weights replicated over data; classic DP gradient all-reduce) — ZeRO-3's
# per-layer gather/scatter traffic only pays for itself when parameters
# cannot fit replicated-over-data (EXPERIMENTS.md §Perf iter 2).
_PHASE_IDX = {"serve": 0, "train": 1, "decode": 2, "train_tp": 0}


def train_phase_for(total_params: int, model_parallel: int = 16,
                    budget_bytes: int = 4 * 2**30) -> str:
    """ZeRO-3 only when bf16 params + grads per chip exceed the budget."""
    per_chip = 2 * 2 * total_params // model_parallel   # weights + grads
    return "train" if per_chip > budget_bytes else "train_tp"


def _match(path: str, shape, phase: str, dp: str, mp: str) -> P:
    idx = _PHASE_IDX[phase]
    for suffix, core_ndim, specs in _rules(dp, mp):
        if path.endswith(suffix) and len(shape) >= core_ndim:
            spec = specs[idx]
            pad = len(shape) - core_ndim
            return P(*([None] * pad), *spec)
    return P()                                   # replicate (norms, scalars)


def param_shardings(params_abstract, mesh, phase: str = "serve",
                    dp="data", mp: str = "model"):
    """PartitionSpec pytree matching ``params_abstract`` (shapes pytree).

    ``dp`` may be an axis name or a tuple of axis names (multi-pod: the
    batch/FSDP dim shards over ("pod", "data") jointly).
    """
    dp = tuple(dp) if isinstance(dp, (tuple, list)) else dp
    def one(key_path, leaf):
        return _match(_path_of(key_path), leaf.shape, phase, dp, mp)
    return jax.tree_util.tree_map_with_path(one, params_abstract)


def adafactor_state_shardings(params_abstract, pspecs):
    """Specs for Adafactor factored state: vr drops the last param dim,
    vc drops the second-to-last — each inherits the surviving dims' spec
    (so trillion-param factor vectors stay sharded, not replicated)."""
    def one(leaf, spec):
        nd = len(leaf.shape)
        full = list(spec) + [None] * (nd - len(spec))
        if nd >= 2:
            return {"vr": P(*full[:-1]), "vc": P(*full[:-2], full[-1])}
        return {"v": P(*full)}
    tree = jax.tree.map(one, params_abstract, pspecs,
                        is_leaf=lambda x: isinstance(x, P))
    # params_abstract is the outer structure; `one` ran on (leaf, spec) pairs
    return {"f": tree}


def to_named(spec_tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def batch_shardings(mesh, dp_axes: Tuple[str, ...]):
    """tokens/labels (B, S): batch over the data axes."""
    return NamedSharding(mesh, P(dp_axes, None))


def cache_shardings(mesh, dp_axes: Tuple[str, ...], *,
                    seq_shard: bool = False):
    """KV caches: (layers?, B, slots, KV, hd).

    Default: batch over data.  ``seq_shard=True`` (long-context, batch 1):
    slots over data instead (sequence parallelism).
    """
    if seq_shard:
        return P(None, dp_axes, None, None)       # applied to trailing 4 dims
    return P(dp_axes, None, None, None)
