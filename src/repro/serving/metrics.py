"""Throughput / latency meters for the serving benchmarks."""

from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import numpy as np


@dataclass
class ServingMetrics:
    total_requests: int = 0
    completed: int = 0
    total_output_tokens: int = 0
    wall_time: float = 0.0
    itls: List[float] = field(default_factory=list)
    # time-to-first-token per request (arrival -> first sampled token);
    # chunked prefill's latency win shows up here and in max-ITL
    ttfts: List[float] = field(default_factory=list)
    events: List[Dict] = field(default_factory=list)
    # per-interval decode throughput (for the fault-tolerance timeline)
    timeline: List[Dict] = field(default_factory=list)
    # --- async expert tier (exec_mode="async" only; else empty) ---------
    # per-micro-batch queueing delay: time waited behind other work in the
    # micro-batch's queue lane before service started — the first-class
    # tail-latency signal the per-step model couldn't observe.  The two
    # parallel lists attribute each delay to its (server, expert-lane);
    # expert -1 is a server's aggregate lane (queue_mode="server")
    queue_delays: List[float] = field(default_factory=list)
    queue_delay_servers: List[int] = field(default_factory=list)
    queue_delay_experts: List[int] = field(default_factory=list)
    # --- paged-KV counters (zero when the engine runs the dense cache) ---
    preemptions: int = 0               # slots evicted to recompute queue
    prefix_hit_blocks: int = 0         # cached blocks adopted at admission
    prefix_lookup_blocks: int = 0      # block hashes probed at admission
    kv_evictions: int = 0              # cached blocks reclaimed by the pool
    kv_cow_forks: int = 0              # copy-on-write block forks
    kv_peak_block_util: float = 0.0    # max live-block share over the run
    # --- scale-to-zero experts (zero unless elasticity pages experts) ----
    cold_starts: int = 0               # page-ins triggered by routed traffic
    cold_start_time: float = 0.0       # seconds stalled on cold starts
    expert_page_outs: int = 0          # experts this engine paged out
    # --- expert-balance gauges (the ExpertStats EMA surfaced per step) ---
    expert_imbalance: float = 1.0      # latest max/mean alive-server load
    peak_expert_imbalance: float = 1.0 # worst imbalance seen over the run
    rebalances: int = 0                # committed live placement re-plans
    rebalance_noops: int = 0           # evaluations whose plan was identical
    migrated_experts: int = 0          # expert-weight copies applied
    migration_time: float = 0.0        # seconds charged to migration chunks

    @property
    def decode_throughput(self) -> float:
        """Output tokens per second."""
        return self.total_output_tokens / max(self.wall_time, 1e-9)

    @property
    def prefix_hit_rate(self) -> float:
        """Cached share of the prompt blocks probed at admission."""
        return self.prefix_hit_blocks / max(self.prefix_lookup_blocks, 1)

    def observe_balance(self, imbalance: float) -> None:
        """Record the pool's current traffic-EMA imbalance after a decode
        step (the statistic the rebalance controller plans from)."""
        self.expert_imbalance = imbalance
        self.peak_expert_imbalance = max(self.peak_expert_imbalance,
                                         imbalance)

    def observe_kv(self, pool, preemptions: int) -> None:
        """Snapshot the block pool after an engine step (idempotent —
        counters are absolute, not deltas)."""
        self.preemptions = preemptions
        self.prefix_hit_blocks = pool.matched_blocks
        self.prefix_lookup_blocks = pool.queried_blocks
        self.kv_evictions = pool.evictions
        self.kv_cow_forks = pool.cow_forks
        self.kv_peak_block_util = max(self.kv_peak_block_util,
                                      pool.utilization())

    def itl_stats(self) -> Dict[str, float]:
        return _latency_stats(self.itls)

    def ttft_stats(self) -> Dict[str, float]:
        return _latency_stats(self.ttfts)

    @property
    def p99_itl(self) -> float:
        """Tail inter-token latency — the straggler-sensitivity headline
        the async-vs-lockstep differential gates pin."""
        return self.itl_stats()["p99"]

    def observe_queue_delay(self, delay: float, server: int = -1,
                            expert: int = -1) -> None:
        """Record one micro-batch's queueing delay attributed to its
        (server, expert-lane)."""
        self.queue_delays.append(float(delay))
        self.queue_delay_servers.append(int(server))
        self.queue_delay_experts.append(int(expert))

    def queue_delay_stats(self, by: str = None) -> Dict:
        """Queue-delay latency stats — aggregate by default, or broken
        down per server (``by="server"``, keys ``"s"``) / per expert lane
        (``by="lane"``, keys ``"s:e"``)."""
        if by is None:
            return _latency_stats(self.queue_delays)
        return {k: _latency_stats(v)
                for k, v in sorted(self._queue_groups(by).items())}

    def _queue_groups(self, by: str) -> Dict[str, List[float]]:
        if by == "server":
            keys = [str(s) for s in self.queue_delay_servers]
        elif by == "lane":
            keys = [f"{s}:{e}" for s, e in zip(self.queue_delay_servers,
                                               self.queue_delay_experts)]
        else:
            raise ValueError(f"unknown queue-delay grouping {by!r}; "
                             "expected 'server' or 'lane'")
        groups: Dict[str, List[float]] = {}
        for k, d in zip(keys, self.queue_delays):
            groups.setdefault(k, []).append(d)
        return groups

    def throughput_curve(self, bin_width: float) -> List[Tuple[float, float]]:
        """Decode throughput per time bin: [(bin midpoint, tok/s), ...].

        This is the paper's Fig. 10 fault curve — the per-interval dip under
        failures — computed from the step timeline."""
        return _throughput_curve(self.timeline, bin_width)

    def fingerprint(self, ndigits: int = 9) -> str:
        """Content hash of the full run timeline (times rounded to
        ``ndigits``).  Two runs of the same seeded scenario under a virtual
        clock must produce identical fingerprints — the determinism
        contract the scenario tests pin down."""
        def clean(obj):
            if isinstance(obj, float):
                return round(obj, ndigits)
            if isinstance(obj, dict):
                return {k: clean(v) for k, v in sorted(obj.items())}
            if isinstance(obj, (list, tuple)):
                return [clean(v) for v in obj]
            if isinstance(obj, (np.integer,)):
                return int(obj)
            if isinstance(obj, (np.floating,)):
                return round(float(obj), ndigits)
            return obj
        payload = clean({
            "requests": self.total_requests,
            "completed": self.completed,
            "tokens": self.total_output_tokens,
            "wall": self.wall_time,
            "itls": list(self.itls),
            "ttfts": list(self.ttfts),
            "events": list(self.events),
            "timeline": list(self.timeline),
            "kv": [self.preemptions, self.prefix_hit_blocks,
                   self.prefix_lookup_blocks, self.kv_evictions,
                   self.kv_cow_forks, self.kv_peak_block_util],
            "balance": [self.rebalances, self.rebalance_noops,
                        self.migrated_experts, self.migration_time,
                        self.expert_imbalance,
                        self.peak_expert_imbalance],
        })
        if self.queue_delays:
            # async-only keys, added conditionally so every lockstep
            # fingerprint (including committed benchmark baselines) is
            # byte-identical to the pre-async scheme; the lane attribution
            # rides along so a delay landing in the wrong lane is a
            # fingerprint drift, not a silent accounting bug
            payload["queue"] = [round(float(q), ndigits)
                                for q in self.queue_delays]
            payload["queue_lanes"] = [list(self.queue_delay_servers),
                                      list(self.queue_delay_experts)]
        if self.cold_starts or self.expert_page_outs:
            # elasticity-only keys, same conditional scheme: a run that
            # never pages an expert fingerprints byte-identically to the
            # pre-elasticity format
            payload["elastic"] = [self.cold_starts,
                                  round(self.cold_start_time, ndigits),
                                  self.expert_page_outs]
        blob = json.dumps(payload, sort_keys=True).encode()
        return hashlib.sha256(blob).hexdigest()

    def summary(self) -> Dict:
        out = {
            "requests": self.total_requests,
            "completed": self.completed,
            "output_tokens": self.total_output_tokens,
            "wall_time_s": round(self.wall_time, 3),
            "decode_tok_per_s": round(self.decode_throughput, 2),
            "itl": {k: round(v * 1e3, 3) for k, v in self.itl_stats().items()},
            "ttft": {k: round(v * 1e3, 3)
                     for k, v in self.ttft_stats().items()},
        }
        if self.rebalances or self.migrated_experts or \
                self.peak_expert_imbalance > 1.0:
            out["balance"] = {
                "expert_imbalance": round(self.expert_imbalance, 4),
                "peak_expert_imbalance": round(self.peak_expert_imbalance,
                                               4),
                "rebalances": self.rebalances,
                "rebalance_noops": self.rebalance_noops,
                "migrated_experts": self.migrated_experts,
                "migration_time_s": round(self.migration_time, 4),
            }
        if self.prefix_lookup_blocks or self.kv_peak_block_util:
            out["kv"] = {
                "peak_block_util": round(self.kv_peak_block_util, 4),
                "prefix_hit_rate": round(self.prefix_hit_rate, 4),
                "prefix_hit_blocks": self.prefix_hit_blocks,
                "preemptions": self.preemptions,
                "evictions": self.kv_evictions,
                "cow_forks": self.kv_cow_forks,
            }
        if self.queue_delays:
            out["async"] = {
                "micro_batches": len(self.queue_delays),
                "queue_delay_ms": {
                    k: round(v * 1e3, 3)
                    for k, v in self.queue_delay_stats().items()},
                "queue_delay_p99_ms_by_server": {
                    k: round(v["p99"] * 1e3, 3)
                    for k, v in self.queue_delay_stats(by="server").items()},
            }
        if self.cold_starts or self.expert_page_outs:
            out["elastic"] = {
                "cold_starts": self.cold_starts,
                "cold_start_time_s": round(self.cold_start_time, 4),
                "expert_page_outs": self.expert_page_outs,
            }
        return out


def _latency_stats(xs: List[float]) -> Dict[str, float]:
    if not xs:
        return {"mean": 0.0, "p50": 0.0, "p99": 0.0, "max": 0.0}
    a = np.asarray(xs)
    return {"mean": float(a.mean()),
            "p50": float(np.percentile(a, 50)),
            "p99": float(np.percentile(a, 99)),
            "max": float(a.max())}


def _throughput_curve(timeline: List[Dict],
                      bin_width: float) -> List[Tuple[float, float]]:
    if not timeline:
        return []
    t_end = max(entry["t"] for entry in timeline)
    n_bins = max(1, int(np.ceil(t_end / bin_width)))
    toks = np.zeros(n_bins)
    for entry in timeline:
        b = min(int(entry["t"] / bin_width), n_bins - 1)
        toks[b] += entry["tokens"]
    return [((b + 0.5) * bin_width, float(toks[b] / bin_width))
            for b in range(n_bins)]


@dataclass
class ClusterMetrics:
    """The cluster timeline: N clients' :class:`ServingMetrics` plus
    cluster-level state (front-end routing, client failures, shared
    expert-tier placement changes).

    Every aggregate is derived from the per-client meters on read, so a
    client's own fingerprint stays exactly what it would be standalone —
    the cluster fingerprint wraps the per-client fingerprints plus the
    routing/failure record.
    """

    per_client: List[ServingMetrics] = field(default_factory=list)
    events: List[Dict] = field(default_factory=list)   # cluster-level only
    wall_time: float = 0.0
    failed_requests: int = 0                # stranded by client failures
    # of failed_requests, those shed from the INGRESS queue when the last
    # alive client died (they were never routed, so no client counted
    # them — total_requests adds them back to keep completed == total -
    # failed)
    ingress_failed: int = 0
    routed: List[int] = field(default_factory=list)    # requests per client
    # shared-tier placement counters (the cluster-level RebalanceController
    # writes these — same contract as the ServingMetrics fields)
    rebalances: int = 0
    rebalance_noops: int = 0
    migrated_experts: int = 0
    migration_time: float = 0.0
    # --- full-system elasticity (client churn + provisioned resources) ---
    client_spawns: int = 0              # clients (re)joining the fleet
    client_drains: int = 0              # clients drained out of the fleet
    expert_page_outs: int = 0           # experts paged out of the tier
    # integral of provisioned resource units — active attention clients
    # plus expert servers weighted by the resident expert fraction — over
    # cluster time, with the (t, units) change-point trace behind the
    # windowed integral ``resource_seconds_in`` (the elasticity
    # benchmark's saving-vs-static headline)
    resource_seconds: float = 0.0
    resource_trace: List[Tuple[float, float]] = field(default_factory=list)

    # ------------------------------------------------------- aggregates
    @property
    def total_requests(self) -> int:
        return sum(c.total_requests for c in self.per_client) \
            + self.ingress_failed

    @property
    def completed(self) -> int:
        return sum(c.completed for c in self.per_client)

    @property
    def total_output_tokens(self) -> int:
        return sum(c.total_output_tokens for c in self.per_client)

    @property
    def decode_throughput(self) -> float:
        return self.total_output_tokens / max(self.wall_time, 1e-9)

    @property
    def ttfts(self) -> List[float]:
        return [t for c in self.per_client for t in c.ttfts]

    @property
    def itls(self) -> List[float]:
        return [t for c in self.per_client for t in c.itls]

    @property
    def queue_delays(self) -> List[float]:
        return [q for c in self.per_client for q in c.queue_delays]

    @property
    def queue_delay_servers(self) -> List[int]:
        return [s for c in self.per_client for s in c.queue_delay_servers]

    @property
    def queue_delay_experts(self) -> List[int]:
        return [e for c in self.per_client for e in c.queue_delay_experts]

    @property
    def p99_itl(self) -> float:
        return self.itl_stats()["p99"]

    def queue_delay_stats(self, by: str = None) -> Dict:
        """Cluster-wide queue-delay stats; ``by`` groups per server /
        per lane across every client (the tier is shared, so lane keys
        mean the same thing cluster-wide)."""
        if by is None:
            return _latency_stats(self.queue_delays)
        return {k: _latency_stats(v) for k, v in sorted(
            ServingMetrics._queue_groups(self, by).items())}

    @property
    def preemptions(self) -> int:
        return sum(c.preemptions for c in self.per_client)

    @property
    def prefix_hit_rate(self) -> float:
        hits = sum(c.prefix_hit_blocks for c in self.per_client)
        probes = sum(c.prefix_lookup_blocks for c in self.per_client)
        return hits / max(probes, 1)

    @property
    def peak_expert_imbalance(self) -> float:
        return max([c.peak_expert_imbalance for c in self.per_client],
                   default=1.0)

    @property
    def cold_starts(self) -> int:
        return sum(c.cold_starts for c in self.per_client)

    @property
    def cold_start_time(self) -> float:
        return sum(c.cold_start_time for c in self.per_client)

    def resource_seconds_in(self, t0: float, t1: float) -> float:
        """Provisioned resource-seconds over the window ``[t0, t1]`` by
        step integration of the change-point trace (each segment's units
        hold until the next change; the final segment extends to the run's
        accounting frontier).  The elasticity benchmark uses this to pin
        the off-peak-trough saving vs. a statically provisioned run."""
        tr = self.resource_trace
        if not tr:
            return 0.0
        total = 0.0
        for i, (t, units) in enumerate(tr):
            seg_end = tr[i + 1][0] if i + 1 < len(tr) \
                else max(self.wall_time, t1)
            lo, hi = max(t, t0), min(seg_end, t1)
            if hi > lo:
                total += (hi - lo) * units
        return total

    def merged_timeline(self) -> List[Dict]:
        """All clients' step timelines merged on absolute time (stable:
        ties keep client order) — the cluster throughput record."""
        merged = [dict(entry, client=i)
                  for i, c in enumerate(self.per_client)
                  for entry in c.timeline]
        merged.sort(key=lambda e: e["t"])
        return merged

    def throughput_curve(self, bin_width: float) -> List[Tuple[float, float]]:
        return _throughput_curve(self.merged_timeline(), bin_width)

    def itl_stats(self) -> Dict[str, float]:
        return _latency_stats(self.itls)

    def ttft_stats(self) -> Dict[str, float]:
        return _latency_stats(self.ttfts)

    def fingerprint(self, ndigits: int = 9) -> str:
        """Cluster determinism contract: per-client fingerprints (each one
        already hashes that client's full timeline) plus the routing and
        failure record.  Two runs of one seeded scenario against the same
        cluster shape must match bit-for-bit."""
        payload = {
            "clients": [c.fingerprint(ndigits) for c in self.per_client],
            "events": [{k: (round(v, ndigits) if isinstance(v, float)
                            else v) for k, v in sorted(e.items())}
                       for e in self.events],
            "routed": list(self.routed),
            "failed": self.failed_requests,
            "ingress_failed": self.ingress_failed,
            "wall": round(self.wall_time, ndigits),
            "balance": [self.rebalances, self.rebalance_noops,
                        self.migrated_experts,
                        round(self.migration_time, ndigits)],
        }
        if self.client_spawns or self.client_drains or self.expert_page_outs:
            # elasticity-only key (conditional like the per-client scheme:
            # a run with no client churn and no paging fingerprints
            # byte-identically to the pre-elasticity format)
            payload["elastic"] = [self.client_spawns, self.client_drains,
                                  self.expert_page_outs,
                                  round(self.resource_seconds, ndigits)]
        blob = json.dumps(payload, sort_keys=True).encode()
        return hashlib.sha256(blob).hexdigest()

    def summary(self) -> Dict:
        out = {
            "clients": len(self.per_client),
            "requests": self.total_requests,
            "completed": self.completed,
            "failed": self.failed_requests,
            "output_tokens": self.total_output_tokens,
            "wall_time_s": round(self.wall_time, 3),
            "decode_tok_per_s": round(self.decode_throughput, 2),
            "itl": {k: round(v * 1e3, 3)
                    for k, v in self.itl_stats().items()},
            "ttft": {k: round(v * 1e3, 3)
                     for k, v in self.ttft_stats().items()},
            "routed_per_client": list(self.routed),
            "per_client": [
                {"requests": c.total_requests, "completed": c.completed,
                 "output_tokens": c.total_output_tokens}
                for c in self.per_client],
        }
        if self.rebalances or self.migrated_experts:
            out["balance"] = {
                "rebalances": self.rebalances,
                "rebalance_noops": self.rebalance_noops,
                "migrated_experts": self.migrated_experts,
                "migration_time_s": round(self.migration_time, 4),
                "peak_expert_imbalance": round(self.peak_expert_imbalance,
                                               4),
            }
        probes = sum(c.prefix_lookup_blocks for c in self.per_client)
        if probes:
            out["kv"] = {
                "prefix_hit_rate": round(self.prefix_hit_rate, 4),
                "preemptions": self.preemptions,
            }
        if self.client_spawns or self.client_drains or self.expert_page_outs:
            out["elastic"] = {
                "client_spawns": self.client_spawns,
                "client_drains": self.client_drains,
                "expert_page_outs": self.expert_page_outs,
                "cold_starts": self.cold_starts,
                "cold_start_time_s": round(self.cold_start_time, 4),
                "resource_seconds": round(self.resource_seconds, 3),
            }
        return out
