"""Host-level physically-disaggregated engine: the paper-literal protocol
(dynamic batching across clients, timeout failover, re-registration)."""

import numpy as np
import pytest

from repro.configs import get_config
from repro.serving.disaggregated import build_cluster


@pytest.fixture(scope="module")
def cluster():
    cfg = get_config("deepseek-r1").reduced()
    return cfg, build_cluster(cfg, n_clients=2, n_servers=3, n_redundant=3)


def test_dynamic_batching_across_clients(cluster):
    """One server tick aggregates BOTH clients' slots into one batch."""
    cfg, (clients, servers, smap, bank) = cluster
    rng = np.random.default_rng(0)
    x0 = rng.normal(size=(8, cfg.d_model)).astype(np.float32) * 0.3
    x1 = rng.normal(size=(6, cfg.d_model)).astype(np.float32) * 0.3

    # write both clients' requests BEFORE any server tick
    for s in servers:
        s.min_batch = 1
    pend0 = clients[0]._route(x0)
    # run the full layers interleaved: drive advances all servers
    def drive():
        for s in servers:
            s.tick()
    y0 = clients[0].moe_layer(x0, drive)
    y1 = clients[1].moe_layer(x1, drive)
    assert np.isfinite(y0).all() and np.isfinite(y1).all()
    assert sum(s.served_tokens for s in servers) == (8 + 6) * cfg.moe.top_k


def test_timeout_failover_transparent(cluster):
    cfg, (clients, servers, smap, bank) = cluster
    rng = np.random.default_rng(1)
    x = rng.normal(size=(10, cfg.d_model)).astype(np.float32) * 0.3

    def drive():
        for s in servers:
            s.tick()

    y_ref = clients[0].moe_layer(x, drive)
    servers[0].alive = False                  # silent failure
    before = clients[0].retries
    y_fo = clients[0].moe_layer(x, drive)
    assert clients[0].retries > before        # ②(b) timeout path fired
    np.testing.assert_allclose(y_ref, y_fo, rtol=1e-4, atol=1e-4)
    # recovery: re-register
    servers[0].alive = True
    smap.mark_alive(0)
    y_back = clients[0].moe_layer(x, drive)
    np.testing.assert_allclose(y_ref, y_back, rtol=1e-4, atol=1e-4)


def test_straggler_server_failover_transparent(cluster):
    """A straggling server (slow_factor > client timeout) is
    indistinguishable from a dead one to the timeout path: its rows
    re-route to replicas and the layer output is unchanged — the
    protocol-literal counterpart of the async tier's ``slow_server``
    differential pins in test_async_engine.py.  Builds its own cluster:
    the shared fixture's mapping has been failure-mutated by earlier
    tests, which would leave server 0 with no routed rows."""
    cfg, _ = cluster
    clients, servers, smap, bank = build_cluster(
        cfg, n_clients=2, n_servers=3, n_redundant=3)
    for s in servers:
        s.min_batch = 1
    rng = np.random.default_rng(2)
    x = rng.normal(size=(10, cfg.d_model)).astype(np.float32) * 0.3

    def drive():
        for s in servers:
            s.tick()

    y_ref = clients[1].moe_layer(x, drive)
    servers[0].slow_factor = 50               # straggler: ~never serves
    before = clients[1].retries
    y_slow = clients[1].moe_layer(x, drive)
    assert clients[1].retries > before        # timeout path fired
    np.testing.assert_allclose(y_ref, y_slow, rtol=1e-4, atol=1e-4)
    # back to full speed + re-register: served directly again
    servers[0].slow_factor = 1
    smap.mark_alive(0)
    y_back = clients[1].moe_layer(x, drive)
    np.testing.assert_allclose(y_ref, y_back, rtol=1e-4, atol=1e-4)


def test_nonuniform_expert_counts(cluster):
    """EAAS does not require equal experts per server (paper §4.5)."""
    cfg, (clients, servers, smap, bank) = cluster
    counts = [len(s.expert_ids) for s in servers]
    assert len(set(counts)) > 1 or cfg.moe.num_experts % len(servers) == 0
    hosted = set()
    for s in servers:
        hosted.update(s.expert_ids)
    assert hosted == set(range(cfg.moe.num_experts))
