"""Paper Fig. 11 — weak scaling at fine granularity.

Thin driver over the scenario harness.  EAAS scales the expert-server pool
one server at a time; monolithic EP only at group multiples.  Three parts:

* weak scaling: the same Poisson scenario replayed at each pool size
  (incl. counts a monolithic deployment cannot use);
* provisioning curve: the paper's 37.5% saving (traffic 8192 → 5120 req/s;
  monolithic keeps 64 GPUs at group granularity, EAAS shrinks to 40);
* a live autoscaler run: a rate-step scenario where the
  :class:`~repro.serving.autoscale.Autoscaler` walks the pool down to the
  ``provision()`` target — the same policy the provisioning curve assumes,
  now exercised end-to-end against the engine.

Deterministic under the default virtual clock (``clock="wall"`` for real
step timing).
"""

from __future__ import annotations

from typing import Dict, List

from benchmarks.common import (bench_model_cfg, csv_row, run_scenario,
                               save_result)
from repro.core.elastic import provision, resource_saving
from repro.serving import Autoscaler, AutoscalerConfig, EngineConfig, Scenario


def run(server_counts: List[int] = (2, 4, 8), rate: float = 300.0,
        max_new: int = 12, clock: str = "virtual") -> Dict:
    cfg = bench_model_cfg()
    E = cfg.moe.num_experts

    # ---- weak scaling: one scenario, swept over pool sizes --------------
    # under the virtual clock, weight the cost model toward expert compute
    # so the pool-parallel share (what weak scaling measures) dominates
    if clock == "virtual":
        from repro.serving import VirtualClock
        clock_for = lambda: VirtualClock(decode_base=5e-4,
                                         decode_per_token=2e-3)
    else:
        clock_for = lambda: clock
    pts = []
    for s in server_counts:
        if E % s:                       # EAAS would use uneven placement;
            continue                    # reduced config keeps it divisible
        ecfg = EngineConfig(mode="eaas", num_servers=s, max_batch=4,
                            max_seq=64, n_redundant=1)
        sc = Scenario(horizon=0.2, seed=0, max_new=max_new,
                      vocab=cfg.vocab_size).poisson(rate)
        _, res = run_scenario(cfg, ecfg, sc, clock=clock_for())
        pts.append({"servers": s,
                    "tok_per_s": res.metrics.decode_throughput})

    # ---- provisioning curve (the 37.5% story): traffic drops from 8192
    # to 5120 req/s; monolithic must keep 64 GPUs (group granularity 64),
    # EAAS can shrink to ceil(5120/128)=40.
    rate_per_server = 8192 / 64
    saving = resource_saving(5120, rate_per_server, monolithic_group=64)
    prov = {
        "traffic_8192": {"eaas": provision(8192, rate_per_server, 1),
                         "monolithic": provision(8192, rate_per_server, 64)},
        "traffic_5120": {"eaas": provision(5120, rate_per_server, 1),
                         "monolithic": provision(5120, rate_per_server, 64)},
        "resource_saving_pct": 100 * saving,
    }

    # ---- live autoscaler: rate step down, pool follows provision() ------
    ecfg = EngineConfig(mode="eaas", num_servers=8, max_batch=4, max_seq=64,
                        n_redundant=1)
    asc = Autoscaler(AutoscalerConfig(rate_per_server=40, min_servers=1,
                                      max_servers=8, window=0.2,
                                      cooldown=0.1))
    sc = (Scenario(horizon=1.2, seed=0, max_new=4, vocab=cfg.vocab_size)
          .poisson(rate=300).set_rate(t=0.6, rate=80).autoscale(asc))
    eng, res = run_scenario(cfg, ecfg, sc, clock=clock)
    auto = {
        "final_servers": eng.pool.num_servers,
        "provision_target": provision(80, 40, 1),
        "server_trace": [(round(t, 4), n)
                         for t, n in res.server_trace[::25]],
        "scale_events": [e for e in res.metrics.events
                         if e["event"] == "scale"],
    }

    out = {"figure": "fig11_scaling", "clock": clock, "weak_scaling": pts,
           "provisioning": prov, "autoscaler": auto}
    save_result("fig11_scaling", out)
    return out


def main() -> List[str]:
    res = run()
    rows = []
    for p in res["weak_scaling"]:
        rows.append(csv_row(f"fig11_servers_{p['servers']}", 0.0,
                            f"tok_per_s={p['tok_per_s']:.2f}"))
    rows.append(csv_row(
        "fig11_saving", 0.0,
        f"saving_pct={res['provisioning']['resource_saving_pct']:.1f}"))
    rows.append(csv_row(
        "fig11_autoscale", 0.0,
        f"final_servers={res['autoscaler']['final_servers']}"))
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
