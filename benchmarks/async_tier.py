"""Async expert tier benchmark: event-driven vs lockstep execution.

One seeded request trace replayed under ``EngineConfig.exec_mode``
``lockstep`` and ``async`` on an expert-dominated
:class:`~repro.serving.clock.VirtualClock` cost model:

* ``lockstep`` / ``async``          — the plain trace: the bitwise
  token-identity contract (values never depend on execution mode) and the
  ping-pong pipelining throughput edge (wave k+1's attention overlaps
  wave k's expert phase instead of summing with it);
* ``lockstep_straggler`` / ``async_straggler`` — the same trace with one
  expert server running 6x slow: lockstep stretches EVERY decode step by
  the slowest alive server, async queues only that server's micro-batches
  — the p99 ITL gap is the paper's tail-latency claim, and the headline
  gate (``async_p99_beats_lockstep_straggler``).

* ``async_hot_server`` / ``async_hot_lanes`` — Zipf(1.2)-skewed expert
  traffic with a straggler on a hot expert's server, replayed with the
  aggregate per-server FIFO (``queue_mode="server"``) and with per-expert
  queue lanes (``queue_mode="expert"``, service budget 2): cold
  co-located experts overlap the hot lane's backlog instead of
  serializing behind it — lanes must win on throughput AND p99 ITL
  (``lanes_beat_server_*``), with identical token streams.

The full (non-smoke) run adds a saturated bursty-trace pair and the
depth sweep: ``async_depth1`` (strict wave-at-a-time: identity holds and
the cadence collapses back to lockstep — the pipelining win is
depth >= 2) and ``async_depth4`` (deeper speculative pipelining keeps
identity and never loses throughput).

Deterministic under the virtual clock: every number in the JSON is exactly
reproducible, so the ``gate`` section (consumed by ``tools/check_bench.py``
against ``experiments/baselines/async_tier.json``) pins identity and the
p99 win exactly and throughputs within tolerance.
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
from typing import Dict, List

from benchmarks.common import bench_model_cfg, csv_row, save_result
from repro.serving import (EngineConfig, Scenario, ServingEngine,
                           VirtualClock)

NUM_SERVERS = 4
MAX_BATCH = 4
STRAGGLER_RANK = 1
STRAGGLER_FACTOR = 6.0
# the hot-expert pair: a wider expert pool under moderate Zipf bias (so
# several lanes stay live per server) and a straggler on a hot server
HOT_EXPERTS = 16
HOT_ZIPF_ALPHA, HOT_ZIPF_SCALE = 1.2, 0.5
HOT_STRAGGLER_RANK = 3
LANE_BUDGET = 2


def _clock() -> VirtualClock:
    # expert-dominated decode: the regime where the tier's queues (and a
    # straggler server) actually gate the step
    return VirtualClock(decode_base=2e-4, decode_per_token=2e-3,
                        expert_share=0.8)


def _engine(cfg, exec_mode: str, **kw) -> ServingEngine:
    ecfg = EngineConfig(
        mode="eaas", num_servers=NUM_SERVERS, max_batch=MAX_BATCH,
        max_seq=64, n_redundant=2,
        # drop-free dispatch capacity (the bitwise-identity contract)
        pool_tokens_per_client=MAX_BATCH * NUM_SERVERS,
        exec_mode=exec_mode, **kw)
    return ServingEngine(cfg, ecfg, seed=0, clock=_clock())


def _token_fingerprint(tokens: Dict[int, tuple]) -> str:
    blob = repr(sorted(tokens.items())).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def _hot_engine(cfg, queue_mode: str) -> ServingEngine:
    ecfg = EngineConfig(
        mode="eaas", num_servers=NUM_SERVERS, max_batch=8, max_seq=64,
        n_redundant=2, pool_tokens_per_client=32,
        charge_imbalance=True,            # heat costs time, lanes see it
        exec_mode="async", queue_mode=queue_mode, lane_budget=LANE_BUDGET)
    return ServingEngine(cfg, ecfg, seed=0, clock=_clock())


def _measure(eng: ServingEngine, sc: Scenario) -> Dict:
    res = sc.run(eng)
    m = res.metrics
    tokens = {r.request_id: tuple(r.output_tokens) for r in res.requests}
    out = {
        "requests": m.total_requests,
        "completed": m.completed,
        "decode_tok_per_s": m.decode_throughput,
        "p99_itl_s": m.p99_itl,
        "wall_s": eng.clock,
        "token_fingerprint": _token_fingerprint(tokens),
        "_tokens": tokens,
    }
    if eng.tier is not None:
        out["micro_batches"] = eng.tier.completed
        out["queue_delay"] = m.queue_delay_stats()
        out["fired_events"] = len(eng.timeline.log)
        if eng.ecfg.queue_mode == "expert":
            out["queue_delay_by_server"] = {
                k: round(v["p99"], 6)
                for k, v in m.queue_delay_stats(by="server").items()}
            out["live_lanes"] = sum(1 for _ in eng.tier.lanes())
    return out


def run(horizon: float = 0.5, rate: float = 100.0, max_new: int = 12,
        smoke: bool = False) -> Dict:
    if smoke:
        horizon, rate, max_new = 0.25, 100.0, 8
    cfg = bench_model_cfg()
    V = cfg.vocab_size

    def plain():
        return Scenario(horizon=horizon, seed=7, prompt_len=8,
                        max_new=max_new, vocab=V).poisson(rate=rate)

    def straggled():
        return plain().slow_server(STRAGGLER_RANK, t=horizon / 20,
                                   factor=STRAGGLER_FACTOR)

    hot_cfg = cfg.replace(moe=dataclasses.replace(
        cfg.moe, num_experts=HOT_EXPERTS))

    def hot():
        return (Scenario(horizon=horizon, seed=19, prompt_len=8,
                         max_new=max_new, vocab=V)
                .poisson(rate=rate * 0.8)
                .zipf_skew(alpha=HOT_ZIPF_ALPHA, scale=HOT_ZIPF_SCALE)
                .slow_server(HOT_STRAGGLER_RANK, t=horizon / 20,
                             factor=STRAGGLER_FACTOR))

    variants: Dict[str, Dict] = {}
    variants["lockstep"] = _measure(_engine(cfg, "lockstep"), plain())
    variants["async"] = _measure(_engine(cfg, "async"), plain())
    variants["lockstep_straggler"] = _measure(_engine(cfg, "lockstep"),
                                              straggled())
    variants["async_straggler"] = _measure(_engine(cfg, "async"),
                                           straggled())
    variants["async_hot_server"] = _measure(_hot_engine(hot_cfg, "server"),
                                            hot())
    variants["async_hot_lanes"] = _measure(_hot_engine(hot_cfg, "expert"),
                                           hot())

    if not smoke:
        def bursty():
            return (Scenario(horizon=horizon / 4, seed=11, prompt_len=8,
                             max_new=max_new, vocab=V)
                    .bursty(base=rate / 2, peak=6 * rate,
                            period=horizon / 8, duty=0.3))
        variants["lockstep_bursty"] = _measure(_engine(cfg, "lockstep"),
                                               bursty())
        variants["async_bursty"] = _measure(_engine(cfg, "async"),
                                            bursty())
        variants["async_depth1"] = _measure(
            _engine(cfg, "async", async_depth=1), plain())
        variants["async_depth4"] = _measure(
            _engine(cfg, "async", async_depth=4), plain())

    lk, an = variants["lockstep"], variants["async"]
    lks, ans = variants["lockstep_straggler"], variants["async_straggler"]
    hs, hl = variants["async_hot_server"], variants["async_hot_lanes"]
    out: Dict = {"figure": "async_tier", "smoke": smoke,
                 "num_servers": NUM_SERVERS,
                 "straggler": {"rank": STRAGGLER_RANK,
                               "factor": STRAGGLER_FACTOR},
                 "hot": {"experts": HOT_EXPERTS, "alpha": HOT_ZIPF_ALPHA,
                         "scale": HOT_ZIPF_SCALE,
                         "straggler_rank": HOT_STRAGGLER_RANK,
                         "lane_budget": LANE_BUDGET},
                 "variants": {}}
    out["tokens_identical_plain"] = lk["_tokens"] == an["_tokens"]
    out["tokens_identical_straggler"] = lks["_tokens"] == ans["_tokens"]
    out["tokens_identical_hot"] = hs["_tokens"] == hl["_tokens"]
    out["async_speedup_plain"] = (an["decode_tok_per_s"]
                                  / max(lk["decode_tok_per_s"], 1e-9))
    out["straggler_p99_ratio"] = (ans["p99_itl_s"]
                                  / max(lks["p99_itl_s"], 1e-12))
    out["hot_lane_speedup"] = (hl["decode_tok_per_s"]
                               / max(hs["decode_tok_per_s"], 1e-9))
    out["hot_p99_ratio"] = hl["p99_itl_s"] / max(hs["p99_itl_s"], 1e-12)
    for name, v in variants.items():
        out["variants"][name] = {k: val for k, val in v.items()
                                 if k != "_tokens"}

    out["gate"] = {
        "exact": {
            "smoke": smoke,
            "tokens_identical_plain": out["tokens_identical_plain"],
            "tokens_identical_straggler":
                out["tokens_identical_straggler"],
            "tokens_identical_hot": out["tokens_identical_hot"],
            "token_fingerprint_async": an["token_fingerprint"],
            "token_fingerprint_hot": hl["token_fingerprint"],
            # the headline claims, pinned as booleans (the ratios below
            # track the margins within tolerance)
            "async_p99_beats_lockstep_straggler":
                ans["p99_itl_s"] < lks["p99_itl_s"],
            "async_throughput_not_worse":
                an["decode_tok_per_s"] >= lk["decode_tok_per_s"],
            "lanes_beat_server_throughput":
                hl["decode_tok_per_s"] >= hs["decode_tok_per_s"],
            "lanes_beat_server_p99": hl["p99_itl_s"] < hs["p99_itl_s"],
        },
        "tolerance": {
            "tok_per_s_lockstep": lk["decode_tok_per_s"],
            "tok_per_s_async": an["decode_tok_per_s"],
            "p99_itl_lockstep_straggler": lks["p99_itl_s"],
            "p99_itl_async_straggler": ans["p99_itl_s"],
            "straggler_p99_ratio": out["straggler_p99_ratio"],
            "tok_per_s_hot_server": hs["decode_tok_per_s"],
            "tok_per_s_hot_lanes": hl["decode_tok_per_s"],
            "p99_itl_hot_server": hs["p99_itl_s"],
            "p99_itl_hot_lanes": hl["p99_itl_s"],
            "hot_p99_ratio": out["hot_p99_ratio"],
            "queue_delay_p99_hot_lanes": hl["queue_delay"]["p99"],
        },
    }
    if not smoke:
        d1, d4 = variants["async_depth1"], variants["async_depth4"]
        out["gate"]["exact"]["tokens_identical_depth1"] = \
            d1["_tokens"] == lk["_tokens"]
        out["gate"]["exact"]["tokens_identical_depth4"] = \
            d4["_tokens"] == lk["_tokens"]
        out["gate"]["exact"]["depth4_throughput_not_worse"] = \
            d4["decode_tok_per_s"] >= d1["decode_tok_per_s"]
    save_result("async_tier", out)
    return out


def main() -> List[str]:
    res = run()
    rows = []
    for name, v in res["variants"].items():
        rows.append(csv_row(
            f"async_tier_{name}", 0.0,
            f"tok_per_s={v['decode_tok_per_s']:.1f}"
            f";p99_itl={v['p99_itl_s']:.5f}"
            f";completed={v['completed']}"))
    rows.append(csv_row(
        "async_tier_summary", 0.0,
        f"speedup=x{res['async_speedup_plain']:.3f}"
        f";straggler_p99_ratio={res['straggler_p99_ratio']:.3f}"
        f";hot_lane_speedup=x{res['hot_lane_speedup']:.3f}"
        f";hot_p99_ratio={res['hot_p99_ratio']:.3f}"
        f";identical={int(res['tokens_identical_plain'])}"))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single short configuration (CI regression gate)")
    args = ap.parse_args()
    res = run(smoke=args.smoke)
    for name, v in res["variants"].items():
        print(f"{name}: tok_per_s={v['decode_tok_per_s']:.1f} "
              f"p99_itl={v['p99_itl_s']:.5f} completed={v['completed']}")
    print(f"async speedup x{res['async_speedup_plain']:.3f}, straggler "
          f"p99 ratio {res['straggler_p99_ratio']:.3f} (identical="
          f"{res['tokens_identical_plain']}/"
          f"{res['tokens_identical_straggler']})")
    print(f"hot-expert lanes vs server queue: speedup "
          f"x{res['hot_lane_speedup']:.3f}, p99 ratio "
          f"{res['hot_p99_ratio']:.3f} (identical="
          f"{res['tokens_identical_hot']})")
