"""Model substrate: every assigned architecture family, in pure JAX.

The public entrypoint is :func:`repro.models.transformer.build_model`, which
returns a :class:`Model` bundle of ``init / train_forward / prefill / decode``
functions for any registered :class:`~repro.configs.base.ModelConfig`.

(The re-export is lazy: repro.core's modules import repro.models.common, and
transformer imports repro.core — a direct import here would be circular.)
"""


def __getattr__(name):
    if name in ("Model", "build_model", "ParallelCtx"):
        from repro.models import transformer
        return getattr(transformer, name)
    raise AttributeError(name)
