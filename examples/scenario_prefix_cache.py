"""Shared-system-prompt tour: paged KV + prefix caching vs the dense cache.

A multi-tenant trace — every request is one of two shared 16-token system
prompts plus a unique 6-token user suffix — replayed on three engines under
the deterministic virtual clock:

* dense         — per-slot (batch, max_seq) cache, chunked prefill;
* paged         — block-pool cache, prefix caching off (pure paging);
* paged+prefix  — block-pool + hash-based prefix caching: admission adopts
  the cached system-prompt blocks, the prefill plan skips straight to the
  uncached suffix, and the clock charges only those tokens.

All three produce token-identical greedy outputs (paging moves *where* K/V
lives, never *what* is computed); paged+prefix wins mean TTFT by skipping
the shared prefix.

Run:  PYTHONPATH=src python examples/scenario_prefix_cache.py
"""

from repro.configs import get_config
from repro.serving import EngineConfig, Scenario, ServingEngine, VirtualClock

VARIANTS = (
    ("dense", dict()),
    ("paged", dict(kv_mode="paged", kv_block_size=8,
                   kv_prefix_cache=False)),
    ("paged+prefix", dict(kv_mode="paged", kv_block_size=8)),
)


def make_scenario(vocab: int) -> Scenario:
    return (Scenario(horizon=0.2, seed=7, max_new=8, vocab=vocab)
            .shared_prefix(n_prefixes=2, prefix_len=16, suffix_len=6)
            .poisson(rate=150))


def main():
    cfg = get_config("deepseek-r1").reduced()
    results = {}
    for name, kw in VARIANTS:
        ecfg = EngineConfig(mode="eaas", num_servers=4, max_batch=4,
                            max_seq=128, n_redundant=2,
                            pool_tokens_per_client=128,
                            prefill_chunk=8, policy="fair", **kw)
        eng = ServingEngine(cfg, ecfg, clock=VirtualClock())
        res = make_scenario(cfg.vocab_size).run(eng)
        m = res.metrics
        assert m.completed == m.total_requests > 0
        results[name] = res
        kv = m.summary().get("kv", {})
        print(f"{name:14s} ttft_mean={m.ttft_stats()['mean'] * 1e3:7.2f}ms "
              f"tok/s={m.decode_throughput:8.1f} "
              f"hit_rate={kv.get('prefix_hit_rate', 0.0):.3f}")

    def tokens(res):
        return {r.request_id: tuple(r.output_tokens) for r in res.requests}

    t0 = tokens(results["dense"])
    assert all(tokens(r) == t0 for r in results.values()), \
        "greedy outputs must be token-identical across kv modes"
    dense_ttft = results["dense"].metrics.ttft_stats()["mean"]
    prefix_ttft = results["paged+prefix"].metrics.ttft_stats()["mean"]
    assert prefix_ttft < dense_ttft
    print(f"\nidentical greedy tokens across all variants; prefix caching "
          f"cuts mean TTFT x{dense_ttft / prefix_ttft:.2f}")


if __name__ == "__main__":
    main()
