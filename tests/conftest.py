"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single CPU device; SPMD tests spawn subprocesses that set
--xla_force_host_platform_device_count themselves."""

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session", autouse=True)
def _cpu_only():
    assert jax.default_backend() == "cpu"


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
