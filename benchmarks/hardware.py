"""Target-hardware constants for the roofline analysis (v5e-like TPU)."""

PEAK_FLOPS_BF16 = 197e12        # FLOP/s per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
CHIP_HBM_BYTES = 16 * 2**30     # 16 GiB

SINGLE_POD_CHIPS = 256
MULTI_POD_CHIPS = 512
