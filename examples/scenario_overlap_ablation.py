"""Overlap-ablation tour: client pipelining on vs. off, chunked prefill
on vs. off, on one seeded bursty trace (paper §4.2 / Fig. 8).

Four engines replay the *same* flash-crowd scenario under the overlap-aware
virtual clock:

* pipelined decode — two microbatches as independent subgraphs; the clock
  charges ``max(attention, expert) + ε`` per step;
* serialized decode — the same split with the expert round-trip exposed on
  the critical path (the ablation baseline; charged the sum);
* each crossed with chunked prefill (``policy="fair"``: at most one prompt
  chunk between decode steps), which bounds the worst decode gap at the
  price of one ``prefill_base`` per chunk.

Greedy outputs are token-identical across all four — the pipeline and the
chunking change *when* work runs, never *what* it computes.

Run:  PYTHONPATH=src python examples/scenario_overlap_ablation.py
Same seed ⇒ identical output, every run, on any machine.
"""

from repro.configs import get_config
from repro.serving import EngineConfig, Scenario, ServingEngine, VirtualClock


def run_variant(cfg, name: str, **kw):
    # dispatch buffers sized for the longest prefill (128 tokens/step) so no
    # variant ever drops a token — greedy outputs stay bitwise comparable;
    # the clock's decode cost is expert-heavy so the overlap term is visible
    ecfg = EngineConfig(mode="eaas", num_servers=4, max_batch=4, max_seq=128,
                        n_redundant=2, pool_tokens_per_client=128, **kw)
    eng = ServingEngine(cfg, ecfg, clock=VirtualClock(decode_per_token=4e-3))
    sc = (Scenario(horizon=0.5, seed=0, prompt_len=32, max_new=12,
                   vocab=cfg.vocab_size)
          .bursty(base=20, peak=200, period=0.2, duty=0.3))
    res = sc.run(eng)
    m = res.metrics
    print(f"  {name:22s} {m.decode_throughput:8.1f} tok/s"
          f"   max ITL {m.itl_stats()['max'] * 1e3:7.2f} ms"
          f"   p99 TTFT {m.ttft_stats()['p99'] * 1e3:7.2f} ms")
    return {r.request_id: tuple(r.output_tokens) for r in res.requests}, m


def main():
    cfg = get_config("deepseek-r1").reduced()
    print("== overlap ablation (bursty trace, long prompts, virtual clock)")
    tokens = {}
    tokens["pipelined"], m_pipe = run_variant(
        cfg, "pipelined", decode_mode="pipelined")
    tokens["serialized"], m_ser = run_variant(
        cfg, "serialized", decode_mode="serialized")
    tokens["pipelined+chunked"], m_pc = run_variant(
        cfg, "pipelined+chunked", decode_mode="pipelined",
        prefill_chunk=8, policy="fair")
    tokens["serialized+chunked"], _ = run_variant(
        cfg, "serialized+chunked", decode_mode="serialized",
        prefill_chunk=8, policy="fair")

    ident = all(t == tokens["pipelined"] for t in tokens.values())
    print(f"  greedy outputs token-identical across variants: {ident}")
    print(f"  overlap speedup (pipelined / serialized): "
          f"x{m_pipe.decode_throughput / m_ser.decode_throughput:.3f}")
    print(f"  chunking cuts max ITL: "
          f"{m_ser.itl_stats()['max'] * 1e3:.2f} ms -> "
          f"{m_pc.itl_stats()['max'] * 1e3:.2f} ms")


if __name__ == "__main__":
    main()
