"""arctic-480b — Snowflake Arctic (dense-MoE hybrid: 128 experts top-2 with a
dense FFN residual running in parallel).

[hf:Snowflake/snowflake-arctic-base; hf]
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    d_head=128,
    rope_theta=10000.0,
    activation="swiglu",
    moe=MoEConfig(
        num_experts=128,
        top_k=2,
        d_expert=4864,
        dense_residual=True,      # Arctic's signature dense+MoE parallel FFN
        router_score_fn="softmax",
        normalize_topk=True,
    ),
    subquadratic=False,
    source="hf:Snowflake/snowflake-arctic-base",
)
