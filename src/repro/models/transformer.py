"""Architecture stack builder: one entrypoint for all 10 assigned archs.

``build_model(cfg)`` returns a :class:`Model` bundle:

* ``init_params(key)``                       — parameter pytree
* ``loss_fn(params, batch, ctx)``            — train forward (+ MoE aux)
* ``prefill(params, tokens, ctx, ...)``      — fill caches, last logits
* ``decode_step(params, token, cache, ctx)`` — one-token serve step

Layer stacks use ``lax.scan`` over stacked per-layer params (homogeneous
groups); heterogeneous archs scan their repeating unit (gemma3 5:1 groups,
zamba2 mamba×6+shared-attn groups, kimi dense-prefix + MoE scan).

Distribution: dense math runs under GSPMD steered by sharding constraints
(:mod:`repro.distributed.sharding_rules`); the EAAS MoE layer is an explicit
``shard_map`` island (:func:`repro.core.moe_layer.eaas_moe_apply`); long-
context decode uses the explicit sequence-parallel attention island.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import moe_layer as eaas
from repro.core.moe_layer import MoERuntime, MoEStats
from repro.models import attention as attn
from repro.models import kv_cache as kvc
from repro.models import mamba as mam
from repro.models import rwkv as rwk
from repro.models.common import embed_init, rms_norm, rms_norm_init
from repro.models.mlp import init_mlp, mlp
from repro.models.rope import text_mrope_positions


# ---------------------------------------------------------------------------
# Parallel context
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParallelCtx:
    """How a step is distributed.  ``mesh=None`` = single-device (tests)."""

    mesh: Any = None
    axis_data: Tuple[str, ...] = ("data",)      # batch axes (may incl. "pod")
    axis_model: str = "model"
    moe_runtime: Optional[MoERuntime] = None
    moe_mode: str = "local"                     # local | a2a | replicated
    gemm_impl: str = "auto"
    seq_shard_cache: bool = False               # SP decode (slot-sharded)
    seq_shard_axes: Tuple[str, ...] = ()        # slot axes (default: data)
    # train-only: shard the residual stream over model between blocks
    # (Megatron-SP): remat-saved carries shrink model_size×; prefill skips
    # it (no backward ⇒ no saved carries, the reshards would be pure cost)
    sp_residual: bool = False
    remat: bool = True
    ce_chunk: int = 512
    dbo: bool = False                           # double-batch-overlap split
    # fully unroll layer/CE scans (dry-run cost probes need bodies counted
    # per trip; XLA cost_analysis counts while-loop bodies once)
    unroll_scans: bool = False

    @property
    def dp_axes(self) -> Tuple[str, ...]:
        return self.axis_data

    def constraint(self, x, spec):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, spec))


def _P(*args):
    return jax.sharding.PartitionSpec(*args)


# ---------------------------------------------------------------------------
# MoE layer wrapper (local vs shard_map island)
# ---------------------------------------------------------------------------

def _moe_apply(params_moe: Dict, x2d: jax.Array, cfg: ModelConfig,
               ctx: ParallelCtx) -> Tuple[jax.Array, MoEStats]:
    m = cfg.moe
    rt = ctx.moe_runtime
    assert rt is not None, "MoE arch needs ctx.moe_runtime"

    if ctx.mesh is None:
        return eaas.eaas_moe_apply(params_moe, x2d, m, rt,
                                   activation=cfg.activation,
                                   axis_name=None, mode="local")

    mode = ctx.moe_mode
    dp = ctx.dp_axes
    model_ax = ctx.axis_model
    tok_spec = (_P((*dp, model_ax), None) if mode == "a2a"
                else _P((*dp,), None))

    routed = {k: params_moe[k] for k in ("router", "servers")}
    in_specs = (
        {"router": {"w_router": _P(None, None)},
         "servers": {"w_gate": _P(model_ax, None, None, None),
                     "w_up": _P(model_ax, None, None, None),
                     "w_down": _P(model_ax, None, None, None)}},
        tok_spec,
        MoERuntime(mapping=_P(None, None), alive=_P(None),
                   local_table=_P(model_ax, None),
                   num_servers=None, capacity=None, dispatch_method=None,
                   gemm_impl=None),
    )
    n_shards = int(np.prod([ctx.mesh.shape[a] for a in dp])) * (
        ctx.mesh.shape[model_ax] if mode == "a2a" else 1)
    all_axes = (*dp, model_ax)

    def island(p, x, rt_arrays):
        rt_local = rt._replace(mapping=rt_arrays.mapping,
                               alive=rt_arrays.alive,
                               local_table=rt_arrays.local_table)
        y, st = eaas.eaas_moe_apply(
            p, x, m, rt_local, activation=cfg.activation,
            axis_name=model_ax, mode=mode)
        # global stats (replicated out): sum over participating shards
        def allsum(v):
            return jax.lax.psum(v, all_axes)
        denom = n_shards if mode == "a2a" else n_shards * ctx.mesh.shape[model_ax]
        st = MoEStats(
            aux_loss=allsum(st.aux_loss) / denom,
            z_loss=allsum(st.z_loss) / denom,
            dropped=allsum(st.dropped) // (
                1 if mode == "a2a" else ctx.mesh.shape[model_ax]),
            miss=allsum(st.miss),
            expert_load=allsum(st.expert_load) // (
                1 if mode == "a2a" else ctx.mesh.shape[model_ax]),
        )
        return y, st

    rt_arrays = MoERuntime(mapping=rt.mapping, alive=rt.alive,
                           local_table=rt.local_table,
                           num_servers=None, capacity=None,
                           dispatch_method=None, gemm_impl=None)
    stats_specs = MoEStats(aux_loss=_P(), z_loss=_P(), dropped=_P(),
                           miss=_P(), expert_load=_P())
    fn = jax.shard_map(island, mesh=ctx.mesh,
                       in_specs=in_specs,
                       out_specs=(tok_spec, stats_specs),
                       check_vma=False)
    y, st = fn(routed, x2d, rt_arrays)

    # client-side dense extras (shared experts / dense residual) run in
    # GSPMD land with TP sharding like any dense FFN
    extra = eaas._client_extras(
        {k: v for k, v in params_moe.items() if k in ("shared", "residual")},
        x2d, m, cfg.activation)
    return y + extra, st


# ---------------------------------------------------------------------------
# Transformer block (dense or MoE FFN)
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig, is_moe: bool, num_servers: int,
                redundant_table) -> Dict:
    ks = jax.random.split(key, 4)
    p = {
        "ln1": rms_norm_init(cfg.d_model),
        "ln2": rms_norm_init(cfg.d_model),
        "attn": attn.init_attention(ks[0], cfg),
    }
    if is_moe:
        p["moe"] = eaas.init_eaas_moe(
            ks[1], cfg, num_servers,
            redundant_table=redundant_table)
    else:
        p["mlp"] = init_mlp_for_cfg(ks[1], cfg)
    return p


def init_mlp_for_cfg(key, cfg: ModelConfig) -> Dict:
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return init_mlp(key, cfg.d_model, cfg.d_ff, cfg.activation, dt)


def _block_train(p: Dict, cfg: ModelConfig, x: jax.Array,
                 positions: jax.Array, ctx: ParallelCtx, *,
                 is_local: bool = False, mrope_positions=None
                 ) -> Tuple[jax.Array, Optional[MoEStats]]:
    """Full-sequence block (train / prefill shares math, no cache)."""
    B, S, d = x.shape
    h = rms_norm(x, p["ln1"], cfg.rms_norm_eps)
    h = attn.full_attention(p["attn"], cfg, h, positions, is_local=is_local,
                            mrope_positions=mrope_positions,
                            unroll=ctx.unroll_scans)
    x = x + h
    res_spec = (_P(ctx.dp_axes, ctx.axis_model, None) if ctx.sp_residual
                else _P(ctx.dp_axes, None, None))
    x = ctx.constraint(x, res_spec)
    h = rms_norm(x, p["ln2"], cfg.rms_norm_eps)
    stats = None
    if "moe" in p:
        y, stats = _moe_apply(p["moe"], h.reshape(B * S, d), cfg, ctx)
        h = y.reshape(B, S, d)
    else:
        h = mlp(p["mlp"], h, cfg.activation)
    x = x + h
    # sequence-parallel residual: the carry saved per layer for backward is
    # 1/16 the size; attention/FFN internally gather (§Perf iter 3)
    x = ctx.constraint(x, res_spec)
    return x, stats


def _block_decode(p: Dict, cfg: ModelConfig, x: jax.Array,
                  cache: kvc.KVCache, ctx: ParallelCtx, *,
                  is_local: bool = False, mrope_positions=None
                  ) -> Tuple[jax.Array, kvc.KVCache, Optional[MoEStats]]:
    B, _, d = x.shape
    h = rms_norm(x, p["ln1"], cfg.rms_norm_eps)
    if ctx.seq_shard_cache and not is_local:
        h, cache = _sp_decode_attention(p["attn"], cfg, h, cache, ctx,
                                        mrope_positions=mrope_positions)
    else:
        h, cache = attn.decode_attention(p["attn"], cfg, h, cache,
                                         is_local=is_local,
                                         mrope_positions=mrope_positions)
    x = x + h
    h = rms_norm(x, p["ln2"], cfg.rms_norm_eps)
    stats = None
    if "moe" in p:
        y, stats = _moe_apply(p["moe"], h.reshape(B, d), cfg, ctx)
        h = y.reshape(B, 1, d)
    else:
        h = mlp(p["mlp"], h, cfg.activation)
    x = x + h
    return x, cache, stats


# ---------------------------------------------------------------------------
# Sequence-parallel decode attention (long-context: cache sharded over seq)
# ---------------------------------------------------------------------------

def _sp_decode_attention(params, cfg: ModelConfig, x: jax.Array,
                         cache: kvc.KVCache, ctx: ParallelCtx, *,
                         mrope_positions=None):
    """Flash-decode with the KV cache sharded along slots.

    Slot axes come from ``ctx.seq_shard_axes`` (default: the data axes —
    long-context batch-1; decode cells use ("model",) so attention weights
    stay replicated and the multi-GB cache never crosses a link).  The batch
    dim is sharded over the data axes when batch > 1 and data isn't already
    used for slots.  Inside shard_map each shard computes a partial
    (acc, m, l) over its cache slice; one tiny psum combines.  The new
    token's k/v is written only by the owning shard (one-sided, local).
    """
    mesh = ctx.mesh
    if mesh is None:
        return attn.decode_attention(params, cfg, x, cache,
                                     mrope_positions=mrope_positions)
    dp = ctx.seq_shard_axes or ctx.dp_axes
    B_global = x.shape[0]
    batch_axes = ctx.dp_axes if (B_global > 1 and
                                 not set(ctx.dp_axes) & set(dp)) else ()

    h_heads, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    n_shards = int(np.prod([mesh.shape[a] for a in dp]))
    slots_global = cache.k.shape[1]
    shard_sz = slots_global // n_shards

    def island(p, xq, ck, cv, length):
        B = xq.shape[0]
        ridx = sum(jax.lax.axis_index(a) *
                   int(np.prod([mesh.shape[b] for b in dp[i + 1:]]))
                   for i, a in enumerate(dp))
        q = attn._split_heads(xq[:, 0] @ p["wq"], h_heads, hd)[:, None]
        k = attn._split_heads(xq[:, 0] @ p["wk"], kvh, hd)[:, None]
        v = attn._split_heads(xq[:, 0] @ p["wv"], kvh, hd)[:, None]
        pos = length[:, None]
        cos, sin = attn._rope_for(cfg, pos, mrope_positions)
        q = attn.apply_rope(q, cos, sin)
        k = attn.apply_rope(k, cos, sin)
        # masked write into the owning shard
        local = pos[:, 0] - ridx * shard_sz
        ok = (local >= 0) & (local < shard_sz)
        bidx = jnp.arange(B)
        li = jnp.clip(local, 0, shard_sz - 1)
        ck = ck.at[bidx, li].set(
            jnp.where(ok[:, None, None], k[:, 0], ck[bidx, li]))
        cv = cv.at[bidx, li].set(
            jnp.where(ok[:, None, None], v[:, 0], cv[bidx, li]))
        # partial flash over the local slice: valid = global idx < length+1
        gidx = ridx * shard_sz + jnp.arange(shard_sz)
        valid = gidx[None, :] < (length + 1)[:, None]
        local_cache = kvc.KVCache(k=ck, v=cv,
                                  length=jnp.sum(valid, axis=1), window=0)
        # reuse the partial kernel path with an explicit mask via lengths:
        # valid slots are a prefix only on the owning/earlier shards, which
        # jnp.sum(valid) encodes exactly (cache is written in order).
        acc, m, l = attn.decode_attention_partial(p, cfg, q, local_cache)
        g_m = jax.lax.pmax(m, dp)
        scale = jnp.exp(m - g_m)
        num = jax.lax.psum(acc * scale, dp)
        den = jax.lax.psum(l * scale, dp)
        out = (num / jnp.maximum(den, 1e-30))            # (B,1,H,hd)
        out = out.reshape(B, 1, h_heads * hd).astype(xq.dtype) @ p["wo"]
        return out, ck, cv

    b = batch_axes if batch_axes else None
    cache_spec = _P(b, dp, None, None)
    x_spec = _P(b, None, None)
    fn = jax.shard_map(
        island, mesh=mesh,
        in_specs=({k: _P(None, None) for k in ("wq", "wk", "wv", "wo")},
                  x_spec, cache_spec, cache_spec, _P(b)),
        out_specs=(x_spec, cache_spec, cache_spec),
        check_vma=False)
    out, ck, cv = fn(params, x, cache.k, cache.v, cache.length)
    new_cache = kvc.KVCache(k=ck, v=cv, length=cache.length + 1,
                            window=cache.window)
    return out, new_cache


# ---------------------------------------------------------------------------
# Scan helpers (homogeneous stacks)
# ---------------------------------------------------------------------------

def _maybe_remat(fn, ctx: ParallelCtx):
    return jax.checkpoint(fn) if ctx.remat else fn


def _scan_train(blocks: Dict, cfg: ModelConfig, x: jax.Array,
                positions: jax.Array, ctx: ParallelCtx, *,
                is_local: bool = False, mrope=None):
    def body(xc, p):
        out, stats = _block_train(p, cfg, xc, positions, ctx,
                                  is_local=is_local, mrope_positions=mrope)
        if stats is None:
            stats = _zero_stats(cfg)
        return out, stats
    x, stats = jax.lax.scan(_maybe_remat(body, ctx), x, blocks,
                            unroll=ctx.unroll_scans)
    return x, stats


def _scan_prefill(blocks: Dict, caches, cfg: ModelConfig, x: jax.Array,
                  positions: jax.Array, ctx: ParallelCtx, *,
                  is_local: bool = False, mrope=None):
    def body(xc, inp):
        p, c = inp
        out, nc, stats = _block_prefill(p, cfg, xc, positions, c, ctx,
                                        is_local=is_local,
                                        mrope_positions=mrope)
        if stats is None:
            stats = _zero_stats(cfg)
        return out, (nc, stats)
    x, (ncaches, stats) = jax.lax.scan(body, x, (blocks, caches),
                                       unroll=ctx.unroll_scans)
    return x, ncaches, stats


def _scan_decode(blocks: Dict, caches, cfg: ModelConfig, x: jax.Array,
                 ctx: ParallelCtx, *, is_local: bool = False, mrope=None):
    def body(xc, inp):
        p, c = inp
        out, nc, stats = _block_decode(p, cfg, xc, c, ctx,
                                       is_local=is_local,
                                       mrope_positions=mrope)
        if stats is None:
            stats = _zero_stats(cfg)
        return out, (nc, stats)
    x, (ncaches, stats) = jax.lax.scan(body, x, (blocks, caches),
                                       unroll=ctx.unroll_scans)
    return x, ncaches, stats


def _zero_stats(cfg: ModelConfig) -> MoEStats:
    E = cfg.moe.num_experts if cfg.moe else 1
    z = jnp.zeros(())
    return MoEStats(aux_loss=z, z_loss=z, dropped=jnp.zeros((), jnp.int32),
                    miss=jnp.zeros((), jnp.int32),
                    expert_load=jnp.zeros((E,), jnp.int32))


def _sum_stats(*stats_list) -> MoEStats:
    """Reduce *stacked* per-layer MoEStats (every field has a leading layer
    dim — scan ys, or a single block's stats wrapped with ``a[None]``)."""
    acc = None
    for st in stats_list:
        if st is None:
            continue
        red = MoEStats(*[jnp.sum(v, axis=0) for v in st])
        acc = red if acc is None else MoEStats(
            *[a + b for a, b in zip(acc, red)])
    if acc is None:
        z = jnp.zeros(())
        acc = MoEStats(z, z, jnp.zeros((), jnp.int32),
                       jnp.zeros((), jnp.int32), jnp.zeros((1,), jnp.int32))
    return acc


def _stack_one(st: Optional[MoEStats], cfg: ModelConfig) -> MoEStats:
    """Wrap a single (unrolled) block's stats with a layer dim."""
    if st is None:
        st = _zero_stats(cfg)
    return MoEStats(*[v[None] for v in st])


def _block_prefill(p: Dict, cfg: ModelConfig, x: jax.Array,
                   positions: jax.Array, cache: kvc.KVCache,
                   ctx: ParallelCtx, *, is_local: bool = False,
                   mrope_positions=None):
    B, S, d = x.shape
    h = rms_norm(x, p["ln1"], cfg.rms_norm_eps)
    h, (k, v) = attn.full_attention(
        p["attn"], cfg, h, positions, is_local=is_local,
        mrope_positions=mrope_positions, return_kv=True,
        unroll=ctx.unroll_scans)
    cache = kvc.write_prefill(cache, k, v)
    x = x + h
    h = rms_norm(x, p["ln2"], cfg.rms_norm_eps)
    stats = None
    if "moe" in p:
        y, stats = _moe_apply(p["moe"], h.reshape(B * S, d), cfg, ctx)
        h = y.reshape(B, S, d)
    else:
        h = mlp(p["mlp"], h, cfg.activation)
    x = x + h
    return x, cache, stats


def _block_prefill_chunk(p: Dict, cfg: ModelConfig, x: jax.Array,
                         positions: jax.Array, cache: kvc.KVCache,
                         ctx: ParallelCtx, *, mrope_positions=None):
    """One block over a prompt *chunk*: attention against the cache (which
    holds every earlier chunk), chunk K/V written in.  Same math as
    :func:`_block_prefill` restricted to the chunk's rows."""
    B, S, d = x.shape
    h = rms_norm(x, p["ln1"], cfg.rms_norm_eps)
    h, cache = attn.chunk_attention(p["attn"], cfg, h, cache, positions,
                                    mrope_positions=mrope_positions)
    x = x + h
    h = rms_norm(x, p["ln2"], cfg.rms_norm_eps)
    stats = None
    if "moe" in p:
        y, stats = _moe_apply(p["moe"], h.reshape(B * S, d), cfg, ctx)
        h = y.reshape(B, S, d)
    else:
        h = mlp(p["mlp"], h, cfg.activation)
    x = x + h
    return x, cache, stats


def _scan_prefill_chunk(blocks: Dict, caches, cfg: ModelConfig, x: jax.Array,
                        positions: jax.Array, ctx: ParallelCtx, *,
                        mrope=None):
    def body(xc, inp):
        p, c = inp
        out, nc, stats = _block_prefill_chunk(p, cfg, xc, positions, c, ctx,
                                              mrope_positions=mrope)
        if stats is None:
            stats = _zero_stats(cfg)
        return out, (nc, stats)
    x, (ncaches, stats) = jax.lax.scan(body, x, (blocks, caches),
                                       unroll=ctx.unroll_scans)
    return x, ncaches, stats


# ---------------------------------------------------------------------------
# Embedding / head / loss
# ---------------------------------------------------------------------------

def _embed_tokens(params: Dict, cfg: ModelConfig, tokens: jax.Array,
                  ctx: ParallelCtx) -> jax.Array:
    e = jnp.take(params["embed"], tokens, axis=0)
    return ctx.constraint(e, _P(ctx.dp_axes, None, None))


def _head_weight(params: Dict, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["head"]


def _logits(params: Dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    logits = x @ _head_weight(params, cfg)
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    return logits


def chunked_cross_entropy(params: Dict, cfg: ModelConfig, x: jax.Array,
                          labels: jax.Array, ctx: ParallelCtx) -> jax.Array:
    """CE without materializing (B, S, V) logits: scan + remat over seq
    chunks; vocab stays sharded (one-hot contraction, no vocab gather)."""
    B, S, d = x.shape
    V = cfg.padded_vocab
    chunk = min(ctx.ce_chunk, S)
    while S % chunk:
        chunk //= 2
    nc = S // chunk
    xc = x.reshape(B, nc, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(B, nc, chunk).swapaxes(0, 1)

    def body(tot, inp):
        xi, li = inp
        logits = _logits(params, cfg, xi).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(li, V, dtype=jnp.float32)
        gold = jnp.einsum("bcv,bcv->bc", logits, onehot)
        return tot + jnp.sum(lse - gold), None

    tot, _ = jax.lax.scan(jax.checkpoint(body), jnp.zeros((), jnp.float32),
                          (xc, lc), unroll=ctx.unroll_scans)
    return tot / (B * S)


# ---------------------------------------------------------------------------
# Family builders
# ---------------------------------------------------------------------------

def _vmap_init(fn, key, n: int):
    return jax.vmap(fn)(jax.random.split(key, n))


class Model(NamedTuple):
    cfg: ModelConfig
    init_params: Callable
    loss_fn: Callable          # (params, batch, ctx) -> (loss, metrics)
    prefill: Callable          # (params, tokens, ctx, extras) -> (logits, cache)
    decode_step: Callable      # (params, token, cache, ctx, extras) -> (logits, cache)
    init_cache: Callable       # (batch, max_slots, abstract=False) -> cache
    num_servers: int
    # chunked-prefill continuation: (params, tokens, cache, start, ctx) ->
    # (logits, cache).  None for families without cache-resident prefill
    # (the serving scheduler falls back to whole-prompt prefill).
    prefill_chunk: Optional[Callable] = None
    # batch axis shared by every cache leaf (for microbatch splits in the
    # serving executor); None when the cache layout is heterogeneous.
    cache_batch_axis: Optional[int] = None
    # block-pool cache builder: (num_blocks, block_size, batch, max_slots,
    # abstract=False) -> cache pytree of stacked PagedKVCaches.  None for
    # families without paged-cache support (ring caches, recurrent states).
    init_paged_cache: Optional[Callable] = None


def _positions(tokens: jax.Array) -> jax.Array:
    return jnp.arange(tokens.shape[1], dtype=jnp.int32)


def _mrope_from_batch(cfg, batch, tokens):
    if cfg.mrope_sections is None:
        return None
    mp = batch.get("mrope_positions") if isinstance(batch, dict) else None
    if mp is None:
        pos = jnp.broadcast_to(_positions(tokens)[None],
                               (tokens.shape[0], tokens.shape[1]))
        return text_mrope_positions(pos)
    return mp


# --------------------------------------------------- decoder-only (all LM)

def build_model(cfg: ModelConfig, num_servers: int = 1,
                redundant_table=None) -> Model:
    """Dispatch to the family builder."""
    if cfg.family == "hybrid":
        return _build_zamba(cfg)
    if cfg.family == "ssm":
        return _build_rwkv(cfg)
    if cfg.family == "audio":
        return _build_encdec(cfg)
    if cfg.local_global_pattern:
        return _build_local_global(cfg)
    return _build_decoder(cfg, num_servers, redundant_table)


def _build_decoder(cfg: ModelConfig, num_servers: int,
                   redundant_table) -> Model:
    """Uniform decoder (+ optional dense prefix for first_k_dense MoE)."""
    m = cfg.moe
    n_dense_prefix = m.first_k_dense if m else 0
    n_main = cfg.num_layers - n_dense_prefix
    main_is_moe = m is not None
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    def init_params(key):
        ks = jax.random.split(key, 5)
        p = {
            "embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dt),
            "final_ln": rms_norm_init(cfg.d_model),
            "blocks": _vmap_init(
                lambda k: _init_block(k, cfg, main_is_moe, num_servers,
                                      redundant_table),
                ks[1], n_main),
        }
        if n_dense_prefix:
            p["dense_blocks"] = _vmap_init(
                lambda k: _init_block(k, cfg, False, num_servers, None),
                ks[2], n_dense_prefix)
        if not cfg.tie_embeddings:
            from repro.models.common import dense_init
            p["head"] = dense_init(ks[3], cfg.d_model, cfg.padded_vocab, dt)
        return p

    def loss_fn(params, batch, ctx: ParallelCtx):
        tokens, labels = batch["tokens"], batch["labels"]
        x = _embed_tokens(params, cfg, tokens, ctx)
        pos = _positions(tokens)
        mrope = _mrope_from_batch(cfg, batch, tokens)
        stats_all = []
        if n_dense_prefix:
            x, st = _scan_train(params["dense_blocks"], cfg, x, pos, ctx,
                                mrope=mrope)
            stats_all.append(st)
        x, st = _scan_train(params["blocks"], cfg, x, pos, ctx, mrope=mrope)
        stats_all.append(st)
        x = rms_norm(x, params["final_ln"], cfg.rms_norm_eps)
        ce = chunked_cross_entropy(params, cfg, x, labels, ctx)
        stats = _sum_stats(*stats_all)
        loss = ce + stats.aux_loss + stats.z_loss
        return loss, {"ce": ce, "aux": stats.aux_loss,
                      "dropped": stats.dropped, "miss": stats.miss,
                      "expert_load": stats.expert_load}

    def init_cache(batch: int, max_slots: int, abstract: bool = False):
        def stack(n):
            return _stack_kv_cache(n, batch, max_slots, cfg.num_kv_heads,
                                   cfg.head_dim, dt, abstract=abstract)
        cache = {"blocks": stack(n_main)}
        if n_dense_prefix:
            cache["dense"] = stack(n_dense_prefix)
        return cache

    def init_paged_cache(num_blocks: int, block_size: int, batch: int,
                         max_slots: int, abstract: bool = False):
        """Block-pool cache: every layer gets its own pool; one logical
        block id addresses the same slot of every layer's pool, so the host
        keeps a single block table per sequence."""
        assert max_slots % block_size == 0, (max_slots, block_size)

        def stack(n):
            return _stack_paged_kv_cache(
                n, num_blocks, block_size, batch, max_slots // block_size,
                cfg.num_kv_heads, cfg.head_dim, dt, abstract=abstract)
        cache = {"blocks": stack(n_main)}
        if n_dense_prefix:
            cache["dense"] = stack(n_dense_prefix)
        return cache

    def prefill(params, tokens, ctx: ParallelCtx, batch=None,
                max_slots: Optional[int] = None):
        B, S = tokens.shape
        cache = init_cache(B, max_slots or S)
        x = _embed_tokens(params, cfg, tokens, ctx)
        pos = _positions(tokens)
        mrope = _mrope_from_batch(cfg, batch or {}, tokens)
        if n_dense_prefix:
            x, cd, _ = _scan_prefill(params["dense_blocks"], cache["dense"],
                                     cfg, x, pos, ctx, mrope=mrope)
            cache["dense"] = cd
        x, cb, _ = _scan_prefill(params["blocks"], cache["blocks"], cfg, x,
                                 pos, ctx, mrope=mrope)
        cache["blocks"] = cb
        x = rms_norm(x, params["final_ln"], cfg.rms_norm_eps)
        logits = _logits(params, cfg, x[:, -1]).astype(jnp.float32)
        return logits, cache

    def prefill_chunk(params, tokens, cache, start, ctx: ParallelCtx,
                      batch=None):
        """Continue a prefill: process prompt positions [start, start+C)
        against a cache already holding [0, start).  Composing chunks over a
        prompt reproduces :func:`prefill`'s logits and cache exactly (same
        rotated keys, same masked softmax — padding lanes are exact zeros).

        Also returns the chunk's :class:`MoEStats` (summed over layers) so
        chunked prefill feeds ``expert_load`` into the traffic EMA exactly
        like decode steps do — long-prompt-heavy workloads rebalance from
        prompt traffic, not just decode traffic.
        """
        B, C = tokens.shape
        start = jnp.asarray(start, jnp.int32)
        pos = start + jnp.arange(C, dtype=jnp.int32)
        x = _embed_tokens(params, cfg, tokens, ctx)
        mrope = None
        if cfg.mrope_sections is not None:
            mrope = text_mrope_positions(
                jnp.broadcast_to(pos[None], (B, C)))
        stats_all = []
        if n_dense_prefix:
            x, cd, st = _scan_prefill_chunk(params["dense_blocks"],
                                            cache["dense"], cfg, x, pos, ctx,
                                            mrope=mrope)
            cache = dict(cache, dense=cd)
            stats_all.append(st)
        x, cb, st = _scan_prefill_chunk(params["blocks"], cache["blocks"],
                                        cfg, x, pos, ctx, mrope=mrope)
        cache = dict(cache, blocks=cb)
        stats_all.append(st)
        x = rms_norm(x, params["final_ln"], cfg.rms_norm_eps)
        logits = _logits(params, cfg, x[:, -1]).astype(jnp.float32)
        return logits, cache, _sum_stats(*stats_all)

    def decode_step(params, token, cache, ctx: ParallelCtx, batch=None):
        x = _embed_tokens(params, cfg, token, ctx)
        stats_all = []
        if n_dense_prefix:
            x, cd, st = _scan_decode(params["dense_blocks"], cache["dense"],
                                     cfg, x, ctx)
            cache = dict(cache, dense=cd)
            stats_all.append(st)
        x, cb, st = _scan_decode(params["blocks"], cache["blocks"], cfg, x, ctx)
        cache = dict(cache, blocks=cb)
        stats_all.append(st)
        x = rms_norm(x, params["final_ln"], cfg.rms_norm_eps)
        logits = _logits(params, cfg, x[:, 0]).astype(jnp.float32)
        return logits, cache, _sum_stats(*stats_all)

    return Model(cfg, init_params, loss_fn, prefill, decode_step, init_cache,
                 num_servers, prefill_chunk=prefill_chunk,
                 cache_batch_axis=1, init_paged_cache=init_paged_cache)


def _stack_kv_cache(n: int, batch: int, max_slots: int, kv_heads: int,
                    head_dim: int, dtype, *, window: int = 0,
                    abstract: bool = False) -> kvc.KVCache:
    """A stacked (n, ...) KVCache for scan-over-layers stacks."""
    mk = kvc.kv_cache_spec if abstract else kvc.init_kv_cache
    c = mk(batch, max_slots, kv_heads, head_dim, dtype, window=window)
    if abstract:
        lift = lambda a: jax.ShapeDtypeStruct((n,) + a.shape, a.dtype)
    else:
        lift = lambda a: jnp.broadcast_to(a[None], (n,) + a.shape).copy()
    return kvc.KVCache(k=lift(c.k), v=lift(c.v), length=lift(c.length),
                       window=c.window)


def _stack_paged_kv_cache(n: int, num_blocks: int, block_size: int,
                          batch: int, max_blocks: int, kv_heads: int,
                          head_dim: int, dtype, *,
                          abstract: bool = False) -> kvc.PagedKVCache:
    """A stacked (n, ...) PagedKVCache for scan-over-layers stacks.

    Block tables / lengths are broadcast per layer so every leaf carries the
    leading layer dim the scan needs; the executor rewrites them from the
    host-side pool each step."""
    mk = kvc.paged_kv_cache_spec if abstract else kvc.init_paged_kv_cache
    c = mk(num_blocks, block_size, batch, max_blocks, kv_heads, head_dim,
           dtype)
    if abstract:
        lift = lambda a: jax.ShapeDtypeStruct((n,) + a.shape, a.dtype)
    else:
        lift = lambda a: jnp.broadcast_to(a[None], (n,) + a.shape).copy()
    return kvc.PagedKVCache(k=lift(c.k), v=lift(c.v),
                            block_tables=lift(c.block_tables),
                            length=lift(c.length), block_size=c.block_size)


# --------------------------------------------------- gemma3: 5 local : 1 global

def _build_local_global(cfg: ModelConfig) -> Model:
    """gemma3 family: groups of (pattern local layers + 1 global layer),
    plus a trailing remainder of local layers.  Local layers keep only a
    ``sliding_window``-slot ring cache."""
    pat = cfg.local_global_pattern
    group = pat + 1
    n_groups = cfg.num_layers // group
    n_rem = cfg.num_layers - n_groups * group
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    def init_params(key):
        ks = jax.random.split(key, 5)
        def group_init(k):
            k1, k2 = jax.random.split(k)
            return {
                "local": _vmap_init(
                    lambda kk: _init_block(kk, cfg, False, 1, None), k1, pat),
                "global": _init_block(k2, cfg, False, 1, None),
            }
        p = {
            "embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dt),
            "final_ln": rms_norm_init(cfg.d_model),
            "groups": _vmap_init(group_init, ks[1], n_groups),
        }
        if n_rem:
            p["rem_local"] = _vmap_init(
                lambda k: _init_block(k, cfg, False, 1, None), ks[2], n_rem)
        if not cfg.tie_embeddings:
            from repro.models.common import dense_init
            p["head"] = dense_init(ks[3], cfg.d_model, cfg.padded_vocab, dt)
        return p

    def loss_fn(params, batch, ctx: ParallelCtx):
        tokens, labels = batch["tokens"], batch["labels"]
        x = _embed_tokens(params, cfg, tokens, ctx)
        pos = _positions(tokens)

        def group_body(xc, gp):
            xc, _ = _scan_train(gp["local"], cfg, xc, pos, ctx, is_local=True)
            xc, _ = _block_train(gp["global"], cfg, xc, pos, ctx,
                                 is_local=False)
            return xc, jnp.zeros(())

        x, _ = jax.lax.scan(_maybe_remat(group_body, ctx), x,
                            params["groups"], unroll=ctx.unroll_scans)
        if n_rem:
            x, _ = _scan_train(params["rem_local"], cfg, x, pos, ctx,
                               is_local=True)
        x = rms_norm(x, params["final_ln"], cfg.rms_norm_eps)
        ce = chunked_cross_entropy(params, cfg, x, labels, ctx)
        return ce, {"ce": ce}

    def init_cache(batch: int, max_slots: int, abstract: bool = False):
        w = cfg.sliding_window
        local = _stack_kv_cache(pat, batch, max_slots, cfg.num_kv_heads,
                                cfg.head_dim, dt, window=w, abstract=abstract)
        local = jax.tree.map(
            lambda a: (jax.ShapeDtypeStruct((n_groups,) + a.shape, a.dtype)
                       if abstract else
                       jnp.broadcast_to(a[None], (n_groups,) + a.shape).copy()),
            local)
        glob = _stack_kv_cache(n_groups, batch, max_slots, cfg.num_kv_heads,
                               cfg.head_dim, dt, abstract=abstract)
        cache = {"local": local, "global": glob}
        if n_rem:
            cache["rem"] = _stack_kv_cache(
                n_rem, batch, max_slots, cfg.num_kv_heads, cfg.head_dim, dt,
                window=w, abstract=abstract)
        return cache

    def prefill(params, tokens, ctx: ParallelCtx, batch=None,
                max_slots: Optional[int] = None):
        B, S = tokens.shape
        cache = init_cache(B, max_slots or S)
        x = _embed_tokens(params, cfg, tokens, ctx)
        pos = _positions(tokens)

        def group_body(xc, inp):
            gp, cl, cg = inp
            xc, cl, _ = _scan_prefill(gp["local"], cl, cfg, xc, pos, ctx,
                                      is_local=True)
            xc, cg, _ = _block_prefill(gp["global"], cfg, xc, pos, cg, ctx)
            return xc, (cl, cg)

        x, (cl, cg) = jax.lax.scan(
            group_body, x, (params["groups"], cache["local"],
                            cache["global"]), unroll=ctx.unroll_scans)
        cache["local"], cache["global"] = cl, cg
        if n_rem:
            x, cr, _ = _scan_prefill(params["rem_local"], cache["rem"], cfg,
                                     x, pos, ctx, is_local=True)
            cache["rem"] = cr
        x = rms_norm(x, params["final_ln"], cfg.rms_norm_eps)
        return _logits(params, cfg, x[:, -1]).astype(jnp.float32), cache

    def decode_step(params, token, cache, ctx: ParallelCtx, batch=None):
        x = _embed_tokens(params, cfg, token, ctx)

        def group_body(xc, inp):
            gp, cl, cg = inp
            xc, cl, _ = _scan_decode(gp["local"], cl, cfg, xc, ctx,
                                     is_local=True)
            xc, cg, _ = _block_decode(gp["global"], cfg, xc, cg, ctx)
            return xc, (cl, cg)

        x, (cl, cg) = jax.lax.scan(
            group_body, x, (params["groups"], cache["local"],
                            cache["global"]), unroll=ctx.unroll_scans)
        cache = dict(cache, local=cl, **{"global": cg})
        if n_rem:
            x, cr, _ = _scan_decode(params["rem_local"], cache["rem"], cfg,
                                    x, ctx, is_local=True)
            cache["rem"] = cr
        x = rms_norm(x, params["final_ln"], cfg.rms_norm_eps)
        logits = _logits(params, cfg, x[:, 0]).astype(jnp.float32)
        return logits, cache, None

    return Model(cfg, init_params, loss_fn, prefill, decode_step, init_cache, 1)


# --------------------------------------------------- zamba2 hybrid

def _build_zamba(cfg: ModelConfig) -> Model:
    """zamba2: groups of (shared_block_every mamba layers + the SHARED
    attention block).  The shared block's params are reused by every group
    (zamba's signature trick); each application keeps its own KV cache."""
    per = cfg.shared_block_every
    n_groups = cfg.num_layers // per
    assert n_groups * per == cfg.num_layers, (cfg.num_layers, per)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    def init_mamba_layer(k):
        return {"ln": rms_norm_init(cfg.d_model),
                "mamba": mam.init_mamba(k, cfg)}

    def init_params(key):
        ks = jax.random.split(key, 5)
        p = {
            "embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dt),
            "final_ln": rms_norm_init(cfg.d_model),
            "mamba": jax.vmap(lambda k: jax.vmap(init_mamba_layer)(
                jax.random.split(k, per)))(jax.random.split(ks[1], n_groups)),
            "shared": _init_block(ks[2], cfg, False, 1, None),
        }
        if not cfg.tie_embeddings:
            from repro.models.common import dense_init
            p["head"] = dense_init(ks[3], cfg.d_model, cfg.padded_vocab, dt)
        return p

    def _mamba_scan_fwd(layers, cfg_, x, states):
        def body(xc, inp):
            lp, st = inp
            h = rms_norm(xc, lp["ln"], cfg_.rms_norm_eps)
            y, nst = mam.mamba_forward(lp["mamba"], cfg_, h, st)
            return xc + y, nst
        return jax.lax.scan(body, x, (layers, states))

    def _mamba_scan_dec(layers, cfg_, x, states):
        def body(xc, inp):
            lp, st = inp
            h = rms_norm(xc, lp["ln"], cfg_.rms_norm_eps)
            y, nst = mam.mamba_decode(lp["mamba"], cfg_, h, st)
            return xc + y, nst
        return jax.lax.scan(body, x, (layers, states))

    def _states(batch: int, abstract: bool, ctx: ParallelCtx = None):
        st = mam.init_mamba_state(cfg, batch)
        if abstract:
            lift = lambda a: jax.ShapeDtypeStruct(
                (n_groups, per) + a.shape, a.dtype)
        else:
            lift = lambda a: jnp.broadcast_to(
                a[None, None], (n_groups, per) + a.shape).copy()
        st = jax.tree.map(lift, st)
        if ctx is not None and ctx.mesh is not None and not abstract:
            st = mam.MambaState(
                ssm=ctx.constraint(st.ssm,
                                   _P(None, None, ctx.dp_axes,
                                      ctx.axis_model, None, None)),
                conv=ctx.constraint(st.conv,
                                    _P(None, None, ctx.dp_axes, None,
                                       ctx.axis_model)),
            )
        return st

    def init_cache(batch: int, max_slots: int, abstract: bool = False):
        return {
            "mamba": _states(batch, abstract),
            "shared": _stack_kv_cache(n_groups, batch, max_slots,
                                      cfg.num_kv_heads, cfg.head_dim, dt,
                                      abstract=abstract),
        }

    def loss_fn(params, batch, ctx: ParallelCtx):
        tokens, labels = batch["tokens"], batch["labels"]
        B, S = tokens.shape
        x = _embed_tokens(params, cfg, tokens, ctx)
        pos = _positions(tokens)
        zero_states = _states(B, False, ctx)

        def group_body(xc, inp):
            layers, sts = inp
            xc, _ = _mamba_scan_fwd(layers, cfg, xc, sts)
            xc, _ = _block_train(params["shared"], cfg, xc, pos, ctx)
            return xc, jnp.zeros(())

        x, _ = jax.lax.scan(_maybe_remat(group_body, ctx), x,
                            (params["mamba"], zero_states),
                            unroll=ctx.unroll_scans)
        x = rms_norm(x, params["final_ln"], cfg.rms_norm_eps)
        ce = chunked_cross_entropy(params, cfg, x, labels, ctx)
        return ce, {"ce": ce}

    def prefill(params, tokens, ctx: ParallelCtx, batch=None,
                max_slots: Optional[int] = None):
        B, S = tokens.shape
        cache = init_cache(B, max_slots or S)
        x = _embed_tokens(params, cfg, tokens, ctx)
        pos = _positions(tokens)

        def group_body(xc, inp):
            layers, sts, ckv = inp
            xc, nsts = _mamba_scan_fwd(layers, cfg, xc, sts)
            xc, ckv, _ = _block_prefill(params["shared"], cfg, xc, pos, ckv,
                                        ctx)
            return xc, (nsts, ckv)

        x, (nst, ckv) = jax.lax.scan(
            group_body, x, (params["mamba"], cache["mamba"], cache["shared"]),
            unroll=ctx.unroll_scans)
        cache = {"mamba": nst, "shared": ckv}
        x = rms_norm(x, params["final_ln"], cfg.rms_norm_eps)
        return _logits(params, cfg, x[:, -1]).astype(jnp.float32), cache

    def decode_step(params, token, cache, ctx: ParallelCtx, batch=None):
        x = _embed_tokens(params, cfg, token, ctx)

        def group_body(xc, inp):
            layers, sts, ckv = inp
            xc, nsts = _mamba_scan_dec(layers, cfg, xc, sts)
            xc, ckv, _ = _block_decode(params["shared"], cfg, xc, ckv, ctx)
            return xc, (nsts, ckv)

        x, (nst, ckv) = jax.lax.scan(
            group_body, x, (params["mamba"], cache["mamba"], cache["shared"]),
            unroll=ctx.unroll_scans)
        cache = {"mamba": nst, "shared": ckv}
        x = rms_norm(x, params["final_ln"], cfg.rms_norm_eps)
        logits = _logits(params, cfg, x[:, 0]).astype(jnp.float32)
        return logits, cache, None

    return Model(cfg, init_params, loss_fn, prefill, decode_step, init_cache, 1)


# --------------------------------------------------- rwkv6

def _build_rwkv(cfg: ModelConfig) -> Model:
    L = cfg.num_layers
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    def layer_init(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln1": rms_norm_init(cfg.d_model),
            "ln2": rms_norm_init(cfg.d_model),
            "tmix": rwk.init_rwkv_tmix(k1, cfg),
            "cmix": rwk.init_rwkv_cmix(k2, cfg),
        }

    def init_params(key):
        ks = jax.random.split(key, 4)
        p = {
            "embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dt),
            "final_ln": rms_norm_init(cfg.d_model),
            "blocks": _vmap_init(layer_init, ks[1], L),
        }
        if not cfg.tie_embeddings:
            from repro.models.common import dense_init
            p["head"] = dense_init(ks[2], cfg.d_model, cfg.padded_vocab, dt)
        return p

    def _states(batch: int, abstract: bool, ctx: ParallelCtx = None):
        st = rwk.init_rwkv_state(cfg, batch)
        if abstract:
            lift = lambda a: jax.ShapeDtypeStruct((L,) + a.shape, a.dtype)
        else:
            lift = lambda a: jnp.broadcast_to(a[None], (L,) + a.shape).copy()
        st = jax.tree.map(lift, st)
        if ctx is not None and ctx.mesh is not None and not abstract:
            # the wkv carry drives the sharding of the whole recurrence:
            # heads over model, batch over data (§Perf iter 2)
            st = rwk.RwkvState(
                wkv=ctx.constraint(st.wkv,
                                   _P(None, ctx.dp_axes, ctx.axis_model,
                                      None, None)),
                shift_tmix=ctx.constraint(st.shift_tmix,
                                          _P(None, ctx.dp_axes, None)),
                shift_cmix=ctx.constraint(st.shift_cmix,
                                          _P(None, ctx.dp_axes, None)),
            )
        return st

    def init_cache(batch: int, max_slots: int, abstract: bool = False):
        return {"states": _states(batch, abstract)}

    def _forward(params, x, states, ctx):
        if ctx.mesh is not None:
            # explicit Megatron-TP island: one psum per sub-layer
            layer = rwk.rwkv_block_spmd(cfg, ctx.mesh, ctx.dp_axes,
                                        ctx.axis_model)

            def body(xc, inp):
                p, st = inp
                xc, S, sh_t, sh_c = layer(p["tmix"], p["cmix"], p["ln1"],
                                          p["ln2"], xc, st.wkv,
                                          st.shift_tmix, st.shift_cmix)
                return xc, rwk.RwkvState(S, sh_t, sh_c)
        else:
            def body(xc, inp):
                p, st = inp
                xc, nst = rwk.rwkv_block_forward(
                    p["tmix"], p["cmix"], cfg, xc, st,
                    (p["ln1"], p["ln2"]))
                return xc, nst
        return jax.lax.scan(body, x, (params["blocks"], states),
                            unroll=ctx.unroll_scans)

    def loss_fn(params, batch, ctx: ParallelCtx):
        tokens, labels = batch["tokens"], batch["labels"]
        B, S = tokens.shape
        x = _embed_tokens(params, cfg, tokens, ctx)
        x, _ = _forward(params, x, _states(B, False, ctx), ctx)
        x = rms_norm(x, params["final_ln"], cfg.rms_norm_eps)
        ce = chunked_cross_entropy(params, cfg, x, labels, ctx)
        return ce, {"ce": ce}

    def prefill(params, tokens, ctx: ParallelCtx, batch=None,
                max_slots: Optional[int] = None):
        B, S = tokens.shape
        x = _embed_tokens(params, cfg, tokens, ctx)
        x, nst = _forward(params, x, _states(B, False, ctx), ctx)
        x = rms_norm(x, params["final_ln"], cfg.rms_norm_eps)
        return (_logits(params, cfg, x[:, -1]).astype(jnp.float32),
                {"states": nst})

    def decode_step(params, token, cache, ctx: ParallelCtx, batch=None):
        x = _embed_tokens(params, cfg, token, ctx)

        def body(xc, inp):
            p, st = inp
            xc, nst = rwk.rwkv_block_decode(
                p["tmix"], p["cmix"], cfg, xc, st, (p["ln1"], p["ln2"]))
            return xc, nst
        x, nst = jax.lax.scan(body, x, (params["blocks"], cache["states"]),
                              unroll=ctx.unroll_scans)
        x = rms_norm(x, params["final_ln"], cfg.rms_norm_eps)
        logits = _logits(params, cfg, x[:, 0]).astype(jnp.float32)
        return logits, {"states": nst}, None

    return Model(cfg, init_params, loss_fn, prefill, decode_step, init_cache, 1)


# --------------------------------------------------- whisper enc-dec

def _build_encdec(cfg: ModelConfig) -> Model:
    """whisper-base backbone.  The conv/mel frontend is a stub: batches carry
    precomputed frame embeddings (B, encoder_seq_len, d_model)."""
    Le, Ld = cfg.num_encoder_layers, cfg.num_layers
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    def enc_layer_init(k):
        return _init_block(k, cfg, False, 1, None)

    def dec_layer_init(k):
        ks = jax.random.split(k, 2)
        p = _init_block(ks[0], cfg, False, 1, None)
        p["ln_x"] = rms_norm_init(cfg.d_model)
        p["cross"] = attn.init_cross_attention(ks[1], cfg)
        return p

    def init_params(key):
        ks = jax.random.split(key, 5)
        return {
            "embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dt),
            "final_ln": rms_norm_init(cfg.d_model),
            "encoder": _vmap_init(enc_layer_init, ks[1], Le),
            "decoder": _vmap_init(dec_layer_init, ks[2], Ld),
        }

    def _encode(params, frames, ctx):
        pos = jnp.arange(frames.shape[1], dtype=jnp.int32)

        def body(xc, p):
            h = rms_norm(xc, p["ln1"], cfg.rms_norm_eps)
            h = attn.full_attention(p["attn"], cfg, h, pos, causal=False,
                                    unroll=ctx.unroll_scans)
            xc = xc + h
            h = rms_norm(xc, p["ln2"], cfg.rms_norm_eps)
            return xc + mlp(p["mlp"], h, cfg.activation), jnp.zeros(())

        x, _ = jax.lax.scan(body, frames.astype(dt), params["encoder"],
                            unroll=ctx.unroll_scans)
        return x

    def _dec_block_train(p, x, enc_out, pos, ctx):
        h = rms_norm(x, p["ln1"], cfg.rms_norm_eps)
        h = attn.full_attention(p["attn"], cfg, h, pos,
                                unroll=ctx.unroll_scans)
        x = x + h
        h = rms_norm(x, p["ln_x"], cfg.rms_norm_eps)
        x = x + attn.cross_attention(p["cross"], cfg, h, enc_out)
        h = rms_norm(x, p["ln2"], cfg.rms_norm_eps)
        return x + mlp(p["mlp"], h, cfg.activation)

    def loss_fn(params, batch, ctx: ParallelCtx):
        tokens, labels = batch["tokens"], batch["labels"]
        frames = batch["frames"]
        enc_out = _encode(params, frames, ctx)
        x = _embed_tokens(params, cfg, tokens, ctx)
        pos = _positions(tokens)

        def body(xc, p):
            return _dec_block_train(p, xc, enc_out, pos, ctx), jnp.zeros(())

        x, _ = jax.lax.scan(_maybe_remat(body, ctx), x, params["decoder"],
                            unroll=ctx.unroll_scans)
        x = rms_norm(x, params["final_ln"], cfg.rms_norm_eps)
        ce = chunked_cross_entropy(params, cfg, x, labels, ctx)
        return ce, {"ce": ce}

    def init_cache(batch: int, max_slots: int, abstract: bool = False):
        self_kv = _stack_kv_cache(Ld, batch, max_slots, cfg.num_kv_heads,
                                  cfg.head_dim, dt, abstract=abstract)
        shape = (Ld, batch, cfg.encoder_seq_len, cfg.num_kv_heads,
                 cfg.head_dim)
        if abstract:
            ck = jax.ShapeDtypeStruct(shape, dt)
            cv = jax.ShapeDtypeStruct(shape, dt)
        else:
            ck = jnp.zeros(shape, dt)
            cv = jnp.zeros(shape, dt)
        return {"self": self_kv, "cross_k": ck, "cross_v": cv}

    def prefill(params, tokens, ctx: ParallelCtx, batch=None,
                max_slots: Optional[int] = None):
        """Encodes frames, caches cross-attention K/V, prefills decoder."""
        B, S = tokens.shape
        frames = (batch or {}).get("frames")
        if frames is None:
            frames = jnp.zeros((B, cfg.encoder_seq_len, cfg.d_model), dt)
        enc_out = _encode(params, frames, ctx)
        cache = init_cache(B, max_slots or S)
        x = _embed_tokens(params, cfg, tokens, ctx)
        pos = _positions(tokens)

        def body(xc, inp):
            p, ckv = inp
            h = rms_norm(xc, p["ln1"], cfg.rms_norm_eps)
            h, (k, v) = attn.full_attention(p["attn"], cfg, h, pos,
                                            return_kv=True,
                                            unroll=ctx.unroll_scans)
            ckv = kvc.write_prefill(ckv, k, v)
            xc = xc + h
            h = rms_norm(xc, p["ln_x"], cfg.rms_norm_eps)
            xc = xc + attn.cross_attention(p["cross"], cfg, h, enc_out)
            h = rms_norm(xc, p["ln2"], cfg.rms_norm_eps)
            xc = xc + mlp(p["mlp"], h, cfg.activation)
            kx = attn._split_heads(enc_out @ p["cross"]["wk"],
                                   cfg.num_kv_heads, cfg.head_dim)
            vx = attn._split_heads(enc_out @ p["cross"]["wv"],
                                   cfg.num_kv_heads, cfg.head_dim)
            return xc, (ckv, kx, vx)

        x, (self_kv, ck, cv) = jax.lax.scan(
            body, x, (params["decoder"], cache["self"]),
            unroll=ctx.unroll_scans)
        cache = {"self": self_kv, "cross_k": ck, "cross_v": cv}
        x = rms_norm(x, params["final_ln"], cfg.rms_norm_eps)
        return _logits(params, cfg, x[:, -1]).astype(jnp.float32), cache

    def decode_step(params, token, cache, ctx: ParallelCtx, batch=None):
        x = _embed_tokens(params, cfg, token, ctx)

        def body(xc, inp):
            p, ckv, kx, vx = inp
            h = rms_norm(xc, p["ln1"], cfg.rms_norm_eps)
            h, ckv = attn.decode_attention(p["attn"], cfg, h, ckv)
            xc = xc + h
            h = rms_norm(xc, p["ln_x"], cfg.rms_norm_eps)
            xc = xc + attn.cross_attention_cached(p["cross"], cfg, h, kx, vx)
            h = rms_norm(xc, p["ln2"], cfg.rms_norm_eps)
            xc = xc + mlp(p["mlp"], h, cfg.activation)
            return xc, ckv

        x, self_kv = jax.lax.scan(
            body, x, (params["decoder"], cache["self"], cache["cross_k"],
                      cache["cross_v"]), unroll=ctx.unroll_scans)
        cache = dict(cache, self=self_kv)
        x = rms_norm(x, params["final_ln"], cfg.rms_norm_eps)
        logits = _logits(params, cfg, x[:, 0]).astype(jnp.float32)
        return logits, cache, None

    return Model(cfg, init_params, loss_fn, prefill, decode_step, init_cache, 1)
