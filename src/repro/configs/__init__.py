"""Architecture registry.

``get_config("kimi-k2-1t-a32b")`` returns the exact published config;
``ASSIGNED_ARCHS`` lists the 10 graded architectures in assignment order.
"""

from __future__ import annotations

from typing import Dict, List

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig
from repro.configs.shapes import (
    ALL_SHAPES,
    DECODE_32K,
    InputShape,
    LONG_500K,
    PREFILL_32K,
    TRAIN_4K,
    applicable,
    reduced_shape,
    shape_by_name,
)

from repro.configs import (  # noqa: E402  (registry imports)
    arctic_480b,
    deepseek_r1,
    gemma3_4b,
    granite_3_2b,
    kimi_k2_1t_a32b,
    minitron_8b,
    phi3_medium_14b,
    qwen2_vl_2b,
    rwkv6_7b,
    whisper_base,
    zamba2_2_7b,
)

ASSIGNED_ARCHS: List[str] = [
    "granite-3-2b",
    "gemma3-4b",
    "minitron-8b",
    "phi3-medium-14b",
    "arctic-480b",
    "kimi-k2-1t-a32b",
    "zamba2-2.7b",
    "whisper-base",
    "rwkv6-7b",
    "qwen2-vl-2b",
]

REGISTRY: Dict[str, ModelConfig] = {
    m.CONFIG.arch_id: m.CONFIG
    for m in (
        granite_3_2b, gemma3_4b, minitron_8b, phi3_medium_14b, arctic_480b,
        kimi_k2_1t_a32b, zamba2_2_7b, whisper_base, rwkv6_7b, qwen2_vl_2b,
        deepseek_r1,
    )
}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(REGISTRY)}")
    return REGISTRY[arch_id]


__all__ = [
    "ModelConfig", "MoEConfig", "SSMConfig", "InputShape",
    "ALL_SHAPES", "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
    "ASSIGNED_ARCHS", "REGISTRY", "get_config", "shape_by_name",
    "applicable", "reduced_shape",
]
