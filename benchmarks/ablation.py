"""Paper Fig. 13 — ablation of the three key optimizations.

* CUDA-graph analogue: whole-step jit vs eager op-by-op execution.
* kernel (group) shrink: grouped GEMM iterating only active groups vs a
  DeepGEMM-style scheduler visiting every expert group (the ``ref`` impl —
  G masked dense matmuls — is exactly that inefficiency).
* double batching: the two-microbatch overlap split vs serialized chaining.
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_model_cfg, csv_row, save_result
from repro.core import moe_layer as eaas
from repro.core.moe_layer import default_runtime
from repro.core.overlap import double_batch_overlap
from repro.kernels import ops as kops


def _time(fn, *args, iters: int = 10) -> float:
    y = fn(*args)
    jax.block_until_ready(y)
    t0 = time.perf_counter()
    for _ in range(iters):
        y = fn(*args)
    jax.block_until_ready(y)
    return (time.perf_counter() - t0) / iters


def run(T: int = 256, iters: int = 10) -> Dict:
    cfg = bench_model_cfg()
    m = cfg.moe
    key = jax.random.PRNGKey(0)
    params = eaas.init_eaas_moe(key, cfg, num_servers=4)
    rt = default_runtime(cfg, 4, T, gemm_impl="xla_ragged")
    x = jax.random.normal(jax.random.PRNGKey(1), (T, cfg.d_model),
                          jnp.float32) * 0.1

    def moe_step(x):
        y, _ = eaas.eaas_moe_apply(params, x, m, rt,
                                   activation=cfg.activation)
        return y

    # --- CUDA graph analogue: jit vs eager -------------------------------
    t_jit = _time(jax.jit(moe_step), x, iters=iters)
    with jax.disable_jit():
        t_eager = _time(moe_step, x, iters=max(iters // 3, 2))

    # --- group shrink: active-groups-only vs all-groups scheduler --------
    M, K, N, G = 512, cfg.d_model, m.d_expert, m.num_experts
    xg = jax.random.normal(jax.random.PRNGKey(2), (M, K), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(3), (G, K, N),
                          jnp.float32) * 0.05
    # sparse activation: only 2 of G groups active (fine-grained MoE decode)
    sizes = np.zeros(G, np.int32)
    sizes[1] = M // 2
    sizes[5] = M - M // 2
    gs = jnp.asarray(sizes)
    f_shrink = jax.jit(lambda a, b, c: kops.grouped_gemm(
        a, b, c, impl="xla_ragged"))
    f_noshrink = jax.jit(lambda a, b, c: kops.grouped_gemm(
        a, b, c, impl="ref"))          # visits every group (DeepGEMM-style)
    t_shrink = _time(f_shrink, xg, w, gs, iters=iters)
    t_noshrink = _time(f_noshrink, xg, w, gs, iters=iters)

    # --- double batching ---------------------------------------------------
    # A single CPU device has no network to overlap, so the overlap gain is
    # derived from the *compiled dry-run's* roofline terms on the production
    # mesh: serialized step = compute + collective; overlapped = max of the
    # two (double-batch-overlap hides the smaller behind the larger).  The
    # program-structure variant (independent microbatch subgraphs) is still
    # exercised for correctness.
    wd = jax.random.normal(jax.random.PRNGKey(4),
                           (cfg.d_model, cfg.d_model), jnp.float32) * 0.05
    dense = lambda a: jnp.tanh(a @ wd)
    y_dbo = jax.jit(lambda a: double_batch_overlap(dense, moe_step, a,
                                                   enabled=True))(x)
    y_serial = jax.jit(lambda a: double_batch_overlap(dense, moe_step, a,
                                                      enabled=False))(x)
    dbo_exact = float(jnp.max(jnp.abs(y_dbo - y_serial)))

    t_compute, t_coll = _dryrun_terms("kimi-k2-1t-a32b", "decode_32k")
    serial_s = t_compute + t_coll
    overlap_s = max(t_compute, t_coll)
    out = {
        "figure": "fig13_ablation",
        "cuda_graph_analogue": {
            "jit_us": t_jit * 1e6, "eager_us": t_eager * 1e6,
            "drop_pct_without": 100 * (1 - t_jit / t_eager)},
        "kernel_shrink": {
            "shrink_us": t_shrink * 1e6, "noshrink_us": t_noshrink * 1e6,
            "drop_pct_without": 100 * (1 - t_shrink / t_noshrink)},
        "double_batching": {
            "overlap_equivalence_maxerr": dbo_exact,
            "compute_s": t_compute, "collective_s": t_coll,
            "serial_s": serial_s, "overlap_s": overlap_s,
            "drop_pct_without": 100 * (1 - overlap_s / serial_s)
            if serial_s else 0.0},
    }
    save_result("fig13_ablation", out)
    return out


def _dryrun_terms(arch: str, shape: str):
    """(compute_s, collective_s) from the dry-run artifact, if present."""
    import json
    import os

    from benchmarks.hardware import ICI_BW, PEAK_FLOPS_BF16
    path = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "dryrun", f"{arch}_{shape}_pod16x16.json")
    if not os.path.exists(path):
        return 1.0, 0.5          # placeholder before the dry-run has run
    r = json.load(open(path))
    rc = r.get("roofline_corrected", {})
    return (rc.get("flops", 0.0) / PEAK_FLOPS_BF16,
            rc.get("coll_total", 0.0) / ICI_BW)


def main() -> List[str]:
    res = run()
    rows = []
    for key, nice in [("cuda_graph_analogue", "cudagraph"),
                      ("kernel_shrink", "shrink"),
                      ("double_batching", "dbo")]:
        r = res[key]
        us = [v for k, v in r.items() if k.endswith("_us")]
        rows.append(csv_row(f"fig13_{nice}", us[0] if us else 0.0,
                            f"drop_without={r['drop_pct_without']:.1f}pct"))
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
