"""EAAS core datatypes: the shared-communication-buffer layout and dispatch
records (paper §3.2).

A buffer slot for one (client, server) pair is::

    STATE   : uint8   0=EMPTY  1=CLIENT_WRITE_DONE  2=SERVER_DONE  3=OFFLINE
    HEADER  : layer_id int32, count int32   (tokens valid in this slot)
    PAYLOAD : hidden   (capacity, d_model)  token activations
              expert_id(capacity,) int32    global expert id per token
              score    (capacity,) fp32     router score per token

In the SPMD in-graph path the STATE flag is replaced by data dependence and
the HEADER/PAYLOAD ride a single all-to-all (DESIGN.md §2); the host-level
engine (serving/engine.py) uses the literal flags via core/monitor.py.
"""

from __future__ import annotations

from typing import NamedTuple

import jax

# Buffer protocol states (paper §3.2)
STATE_EMPTY = 0
STATE_CLIENT_WRITE_DONE = 1
STATE_SERVER_DONE = 2
STATE_OFFLINE = 3


class RouterOutput(NamedTuple):
    """Client-side gating result for T tokens."""

    expert_ids: jax.Array      # (T, k) int32, global expert ids
    scores: jax.Array          # (T, k) fp32, combination weights
    full_probs: jax.Array      # (T, E) fp32 (for aux losses / stats)
    aux_loss: jax.Array        # scalar fp32 load-balancing loss
    z_loss: jax.Array          # scalar fp32 router z-loss


class DispatchBuffers(NamedTuple):
    """Client → server request buffers: one slot per destination server.

    These ARE the paper's shared communication buffers: ``counts`` is the
    header, the rest is the payload.  Leading dim = num_servers.
    """

    hidden: jax.Array          # (S, C, d) activations
    expert_id: jax.Array       # (S, C) int32 global expert id (-1 = empty)
    score: jax.Array           # (S, C) fp32
    counts: jax.Array          # (S,) int32 header: valid tokens per slot
    # --- client-side bookkeeping for the combine step -------------------
    combine_slot: jax.Array    # (T, k) int32 flat index into (S*C) or -1
    dropped: jax.Array         # scalar int32: tokens over capacity


class ServeResult(NamedTuple):
    """Server → client response buffers (mirrors DispatchBuffers layout)."""

    hidden: jax.Array          # (S, C, d) score-weighted expert outputs


class ExpertPlacement(NamedTuple):
    """Static per-deployment placement (from load_balance.plan).

    ``primary_owner[e]``   server rank owning expert e's primary copy
    ``redundant_table``    (S, n_red) int32 global expert id per redundant
                           slot (-1 = unused slot)
    ``mapping``            (E, R) int32 candidate server per replica (-1 pad)
    """

    primary_owner: jax.Array
    redundant_table: jax.Array
    mapping: jax.Array
