"""zamba2-2.7b — Zyphra Zamba2 (Mamba2 backbone + shared attention block).

[arXiv:2411.15242; hf]  54 Mamba2 layers with a single *shared* transformer
block (attention + MLP) interleaved every 6 layers; ssm_state=64.
"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    d_head=80,
    rope_theta=10000.0,
    activation="swiglu",
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64),
    shared_block_every=6,
    subquadratic=True,             # SSM state is O(1) in sequence length
    source="arXiv:2411.15242",
)
