"""Expert→server mapping: the service-discovery table (paper Fig. 6).

The mapping is **runtime data, not program structure**: a (E, R) table of
candidate server ranks per expert plus a (S,) liveness mask.  Failover, new
server registration and load rebalancing all reduce to rewriting these arrays
— no recompilation, no communication-group rebuild.  This is the TPU analogue
of the paper's "client updates its local expert-to-server mapping mask".

The host-side :class:`ExpertServerMap` mutates numpy copies; ``device_arrays``
returns the jnp views fed to the jitted step.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class ExpertServerMap:
    """Host-side mutable view of the expert→server mapping."""

    def __init__(self, mapping: np.ndarray, num_servers: int):
        assert mapping.ndim == 2
        self.table = np.asarray(mapping, np.int32)          # (E, R)
        self.alive = np.ones((num_servers,), bool)
        self.num_servers = num_servers

    # ------------------------------------------------------------- control
    def mark_dead(self, server: int) -> None:
        self.alive[server] = False

    def mark_alive(self, server: int) -> None:
        self.alive[server] = True

    def register_replica(self, expert: int, server: int) -> None:
        """A new server announced it hosts `expert` (paper: registration)."""
        row = self.table[expert]
        free = np.where(row < 0)[0]
        if len(free) == 0:
            raise ValueError(f"replica table full for expert {expert}")
        row[free[0]] = server

    def drop_replica(self, expert: int, server: int) -> None:
        row = self.table[expert]
        row[row == server] = -1

    def alive_replica_count(self) -> np.ndarray:
        ok = (self.table >= 0) & self.alive[np.clip(self.table, 0, None)]
        return ok.sum(axis=1)

    # ------------------------------------------------------------- device
    def device_arrays(self) -> Tuple[jax.Array, jax.Array]:
        return jnp.asarray(self.table), jnp.asarray(self.alive)


def default_mapping(num_experts: int, num_servers: int,
                    max_replicas: int = 4) -> np.ndarray:
    """Primary-only placement: expert e on server e // (E/S) (block layout)."""
    table = np.full((num_experts, max_replicas), -1, np.int32)
    per = num_experts // num_servers
    assert per * num_servers == num_experts, (num_experts, num_servers)
    table[:, 0] = np.arange(num_experts) // per
    return table


# quantization of the salt-derived uniform used for weighted replica picks;
# coarse enough to stay exact in fp32, fine enough that capacity ratios up
# to ~1000:1 still resolve
_WEIGHT_QUANT = 4096


def lookup(table: jax.Array, alive: jax.Array, expert_ids: jax.Array,
           salt: jax.Array,
           weights: Optional[jax.Array] = None) -> jax.Array:
    """Pick an alive replica server per (token, k) routing decision.

    table: (E, R) int32; alive: (S,) bool; expert_ids: (T, k) int32;
    salt: (T, k) int32 (e.g. token index — spreads load across replicas);
    weights: optional (S,) fp32 relative server capacities — when given,
    tokens spread over the alive replicas *proportionally* to capacity
    (a 2x server absorbs 2x the replica traffic) instead of uniformly,
    via the same deterministic salt (no RNG: the salt quantizes to a
    uniform in [0, 1) and picks the replica whose cumulative-capacity
    interval contains it).  ``weights=None`` is the homogeneous pool and
    reproduces the uniform ``salt % count`` spreading bit-exactly.
    Returns server ids (T, k) int32.  If every replica of an expert is dead
    the token falls back to server 0 (counted upstream as a routing error —
    the monitor repairs the table long before this can happen in practice).
    """
    cand = table[expert_ids]                                 # (T, k, R)
    ok = (cand >= 0) & alive[jnp.clip(cand, 0, None)]        # (T, k, R)
    cnt = ok.sum(axis=-1)                                    # (T, k)
    if weights is None:
        pick = salt % jnp.maximum(cnt, 1)                    # (T, k)
        prefix = jnp.cumsum(ok.astype(jnp.int32), axis=-1)   # 1-based rank
        sel = ok & (prefix == (pick + 1)[..., None])
        r = jnp.argmax(sel, axis=-1)                         # first match
    else:
        w = jnp.where(ok, weights[jnp.clip(cand, 0, None)], 0.0)
        total = w.sum(axis=-1)                               # (T, k)
        # multiplicative hash (Knuth; odd -> a bijection mod the quant) so
        # the small sequential token-index salts of one step spread over
        # the whole [0, 1) lattice instead of clustering near zero
        h = (salt.astype(jnp.uint32) * jnp.uint32(2654435761)) \
            % jnp.uint32(_WEIGHT_QUANT)
        u = (h.astype(jnp.float32) + 0.5) / _WEIGHT_QUANT * total
        csum = jnp.cumsum(w, axis=-1)                        # (T, k, R)
        r = jnp.argmax(csum > u[..., None], axis=-1)         # first cover
    server = jnp.take_along_axis(cand, r[..., None], axis=-1)[..., 0]
    return jnp.where(cnt > 0, server, 0).astype(jnp.int32)
