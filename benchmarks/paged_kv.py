"""Paged KV-cache benchmark: prefix-share and block-size sweep.

One seeded shared-system-prompt trace (the multi-tenant workload where
prefix caching pays) replayed across engine variants under the virtual
clock:

* ``dense``            — the per-slot (batch, max_seq) cache baseline;
* ``paged``            — block-pool cache, prefix caching off (pure paging);
* ``paged_prefix``     — block-pool + hash-based prefix caching: admission
  adopts the cached system-prompt blocks and the clock is charged only the
  uncached suffix — the deterministic TTFT win;
* ``paged_tiny_pool``  — the same engine with the pool shrunk to the
  single-request minimum: admission gates, the pool saturates, and
  preemption (release + recompute re-queue) keeps the engine live.

All variants run chunked prefill with the same chunking, so greedy outputs
are token-identical across the whole sweep (the equivalence column) —
paging moves *where* K/V lives, never *what* is computed.

Outputs TTFT / throughput / hit-rate / preemption counters per variant as
JSON + CSV.  ``--smoke`` runs a single short configuration for CI.
"""

from __future__ import annotations

import argparse
import hashlib
from typing import Dict, List

from benchmarks.common import bench_model_cfg, csv_row, run_scenario, \
    save_result
from repro.serving import EngineConfig, Scenario

PROMPT_PREFIX = 16      # shared system-prompt tokens (2 blocks at bs=8)
PROMPT_SUFFIX = 6       # unique per-request tokens
CHUNK = 8               # prefill chunk, aligned with the prefix


def _engine_cfg(**kw) -> EngineConfig:
    return EngineConfig(mode="eaas", num_servers=4, max_batch=4, max_seq=128,
                        n_redundant=2, pool_tokens_per_client=128,
                        prefill_chunk=CHUNK, policy="fair", **kw)


def _scenario(vocab: int, horizon: float, max_new: int,
              n_prefixes: int) -> Scenario:
    return (Scenario(horizon=horizon, seed=7, max_new=max_new, vocab=vocab)
            .shared_prefix(n_prefixes=n_prefixes,
                           prefix_len=PROMPT_PREFIX,
                           suffix_len=PROMPT_SUFFIX)
            .poisson(rate=150))


def _variants(block_size: int, max_seq: int = 128):
    min_pool = max_seq // block_size + 1       # one maximal request
    return (
        ("dense", dict()),
        ("paged", dict(kv_mode="paged", kv_block_size=block_size,
                       kv_prefix_cache=False)),
        ("paged_prefix", dict(kv_mode="paged", kv_block_size=block_size)),
        ("paged_tiny_pool", dict(kv_mode="paged", kv_block_size=block_size,
                                 kv_num_blocks=min_pool)),
    )


def run(horizon: float = 0.3, max_new: int = 24, n_prefixes: int = 2,
        block_sizes=(8, 16), smoke: bool = False) -> Dict:
    if smoke:
        horizon, max_new, block_sizes = 0.12, 8, (8,)
    cfg = bench_model_cfg()
    out: Dict = {"figure": "paged_kv", "smoke": smoke,
                 "prefix_len": PROMPT_PREFIX, "suffix_len": PROMPT_SUFFIX,
                 "sweeps": {}}
    for bs in block_sizes:
        sweep: Dict = {}
        baseline_tokens = None
        for name, kw in _variants(bs):
            _, res = run_scenario(
                cfg, _engine_cfg(**kw),
                _scenario(cfg.vocab_size, horizon, max_new, n_prefixes))
            m = res.metrics
            tokens = {r.request_id: tuple(r.output_tokens)
                      for r in res.requests}
            if baseline_tokens is None:
                baseline_tokens = tokens
            sweep[name] = {
                "completed": m.completed,
                "requests": m.total_requests,
                "decode_tok_per_s": m.decode_throughput,
                "ttft": m.ttft_stats(),
                "itl": m.itl_stats(),
                "prefix_hit_rate": m.prefix_hit_rate,
                "preemptions": m.preemptions,
                "kv_peak_block_util": m.kv_peak_block_util,
                "tokens_match_dense": tokens == baseline_tokens,
            }
        d, p = sweep["dense"], sweep["paged_prefix"]
        sweep["ttft_speedup"] = (d["ttft"]["mean"] /
                                 max(p["ttft"]["mean"], 1e-12))
        sweep["token_fingerprint"] = hashlib.sha256(
            repr(sorted(baseline_tokens.items())).encode()).hexdigest()[:16]
        out["sweeps"][f"bs{bs}"] = sweep
    # regression-gate contract (tools/check_bench.py): token identity is
    # exact, throughput/TTFT ratios within tolerance
    gate_exact: Dict = {"smoke": smoke}
    gate_tol: Dict = {}
    for sweep_name, sweep in out["sweeps"].items():
        gate_exact[f"{sweep_name}/token_fingerprint"] = \
            sweep["token_fingerprint"]
        gate_tol[f"{sweep_name}/ttft_speedup"] = sweep["ttft_speedup"]
        for name, r in sweep.items():
            if isinstance(r, dict):
                gate_exact[f"{sweep_name}/{name}/tokens_match_dense"] = \
                    r["tokens_match_dense"]
                gate_tol[f"{sweep_name}/{name}/tok_per_s"] = \
                    r["decode_tok_per_s"]
    out["gate"] = {"exact": gate_exact, "tolerance": gate_tol}
    save_result("paged_kv", out)
    return out


def main() -> List[str]:
    res = run()
    rows = []
    for sweep_name, sweep in res["sweeps"].items():
        for name, r in sweep.items():
            if not isinstance(r, dict):
                continue
            rows.append(csv_row(
                f"paged_kv_{sweep_name}_{name}", 0.0,
                f"ttft_mean_ms={r['ttft']['mean'] * 1e3:.2f}"
                f";tok_per_s={r['decode_tok_per_s']:.1f}"
                f";hit_rate={r['prefix_hit_rate']:.3f}"
                f";preempt={r['preemptions']}"
                f";identical={int(r['tokens_match_dense'])}"))
        rows.append(csv_row(f"paged_kv_{sweep_name}_ttft_speedup", 0.0,
                            f"x{sweep['ttft_speedup']:.3f}"))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single short configuration (CI)")
    args = ap.parse_args()
    if args.smoke:
        res = run(smoke=True)
        for sweep_name, sweep in res["sweeps"].items():
            for name, r in sweep.items():
                if isinstance(r, dict):
                    print(f"{sweep_name}/{name}: "
                          f"ttft_mean={r['ttft']['mean'] * 1e3:.2f}ms "
                          f"hit={r['prefix_hit_rate']:.3f} "
                          f"preempt={r['preemptions']} "
                          f"identical={r['tokens_match_dense']}")
            print(f"{sweep_name}: ttft_speedup x{sweep['ttft_speedup']:.3f}")
    else:
        print("\n".join(main()))
