"""Dense FFN blocks (SwiGLU / GeLU / squared-ReLU)."""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import activation_fn, dense_init


def init_mlp(key, d_model: int, d_ff: int, activation: str, dtype) -> Dict:
    ks = jax.random.split(key, 3)
    if activation == "swiglu":
        return {
            "w_gate": dense_init(ks[0], d_model, d_ff, dtype),
            "w_up": dense_init(ks[1], d_model, d_ff, dtype),
            "w_down": dense_init(ks[2], d_ff, d_model, dtype),
        }
    return {
        "w_up": dense_init(ks[0], d_model, d_ff, dtype),
        "w_down": dense_init(ks[1], d_ff, d_model, dtype),
    }


def mlp(params: Dict, x: jax.Array, activation: str) -> jax.Array:
    if activation == "swiglu":
        g = jax.nn.silu(x @ params["w_gate"])
        return (g * (x @ params["w_up"])) @ params["w_down"]
    act = activation_fn(activation)
    return act(x @ params["w_up"]) @ params["w_down"]


def init_mlp_for(key, cfg: ModelConfig) -> Dict:
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return init_mlp(key, cfg.d_model, cfg.d_ff, cfg.activation, dt)
