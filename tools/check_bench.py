#!/usr/bin/env python
"""CI benchmark-regression gate: compare benchmark JSONs against their
committed baselines.

Contract: the benchmark JSON carries a top-level ``gate`` object::

    "gate": {
        "exact":     {"<key>": <value>, ...},   # must match bit-for-bit
        "tolerance": {"<key>": <number>, ...}   # relative tolerance
    }

``exact`` holds token-identity fingerprints, equivalence booleans and the
smoke flag — anything whose change means the benchmark no longer computes
the same thing.  ``tolerance`` holds throughput-like numbers that may
drift with the environment; they must stay within ``--tolerance`` relative
error of the baseline (default 20%, and one-sided checks make no sense for
a virtual clock — both directions flag, a silent speedup usually means the
benchmark stopped measuring what it did).

Every key present in the *baseline* must be present and conforming in the
current run; extra keys in the current run are reported but pass (so a
benchmark can grow new metrics before its baseline is refreshed).

The JSONs also carry a top-level ``env`` stamp (resolved jax / jaxlib /
python versions, written by ``benchmarks.common.save_result``).  Exact
fingerprints are only stable within one resolved jax build — the versions
the baselines were generated with are pinned in ``constraints.txt`` — so
on an exact-key failure with mismatched envs the report names the version
drift instead of leaving a bare fingerprint diff.

Usage::

    # one benchmark:
    python tools/check_bench.py \
        --current experiments/bench/expert_balance.json \
        --baseline experiments/baselines/expert_balance.json

    # every committed baseline at once (the registry-driven CI lane —
    # pairs experiments/baselines/*.json with experiments/bench/*.json):
    python tools/check_bench.py --all

    # determinism: two runs of the same smoke must agree on EVERY gate
    # key bit-for-bit (tolerance keys included — same build, same seed):
    python tools/check_bench.py --compare run_a.json run_b.json

    # refresh a baseline after an intentional change:
    python tools/check_bench.py --current ... --baseline ... \
        --write-baseline

Exit status: 0 = pass, 1 = regression, 2 = bad invocation / missing file.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import shutil
import sys
from typing import Dict, List, Tuple

BENCH_DIR = os.path.join("experiments", "bench")
BASELINES_DIR = os.path.join("experiments", "baselines")


def load_doc(path: str) -> Dict:
    with open(path) as f:
        return json.load(f)


def gate_of(doc: Dict, path: str) -> Tuple[Dict, Dict]:
    gate = doc.get("gate")
    if not isinstance(gate, dict):
        raise ValueError(f"{path}: no 'gate' object — the benchmark does "
                         "not participate in the regression lane")
    return gate.get("exact", {}), gate.get("tolerance", {})


def env_note(base_doc: Dict, cur_doc: Dict) -> List[str]:
    """Name the resolved-version drift when the two runs disagree (the
    usual cause of an otherwise-mysterious fingerprint mismatch)."""
    base_env = base_doc.get("env") or {}
    cur_env = cur_doc.get("env") or {}
    out = []
    for key in sorted(set(base_env) | set(cur_env)):
        b, c = base_env.get(key, "?"), cur_env.get(key, "?")
        if b != c:
            out.append(f"env '{key}': baseline built with {b}, current "
                       f"run has {c} — exact fingerprints are only "
                       "stable within one resolved build; pin via "
                       "constraints.txt or refresh the baseline")
    return out


def compare(base_exact: Dict, base_tol: Dict, cur_exact: Dict,
            cur_tol: Dict, tolerance: float) -> Tuple[List[str], List[str]]:
    """Returns (failures, notes)."""
    failures: List[str] = []
    notes: List[str] = []
    for key, want in base_exact.items():
        if key not in cur_exact:
            failures.append(f"exact '{key}': missing from current run")
        elif cur_exact[key] != want:
            failures.append(f"exact '{key}': baseline {want!r} != "
                            f"current {cur_exact[key]!r}")
    for key, want in base_tol.items():
        if key not in cur_tol:
            failures.append(f"tolerance '{key}': missing from current run")
            continue
        have = cur_tol[key]
        denom = max(abs(float(want)), 1e-12)
        rel = abs(float(have) - float(want)) / denom
        line = (f"tolerance '{key}': baseline {want:.6g}, "
                f"current {have:.6g} (drift {rel * 100:.1f}%)")
        if rel > tolerance:
            failures.append(line + f" > {tolerance * 100:.0f}% allowed")
        else:
            notes.append(line)
    for key in cur_exact.keys() - base_exact.keys():
        notes.append(f"exact '{key}': new (not in baseline) — ignored")
    for key in cur_tol.keys() - base_tol.keys():
        notes.append(f"tolerance '{key}': new (not in baseline) — ignored")
    return failures, notes


def check_pair(current: str, baseline: str, tolerance: float) -> int:
    if not os.path.exists(current):
        print(f"check_bench: current run {current} not found "
              "(did the benchmark run?)", file=sys.stderr)
        return 2
    try:
        base_doc, cur_doc = load_doc(baseline), load_doc(current)
        base_exact, base_tol = gate_of(base_doc, baseline)
        cur_exact, cur_tol = gate_of(cur_doc, current)
    except (ValueError, json.JSONDecodeError) as e:
        print(f"check_bench: {e}", file=sys.stderr)
        return 2
    failures, notes = compare(base_exact, base_tol, cur_exact, cur_tol,
                              tolerance)
    if any(f.startswith("exact") for f in failures):
        failures.extend(env_note(base_doc, cur_doc))
    name = os.path.basename(baseline)
    for line in notes:
        print(f"  [ok] {line}")
    if failures:
        print(f"check_bench: {name}: {len(failures)} regression(s):")
        for line in failures:
            print(f"  [FAIL] {line}")
        return 1
    print(f"check_bench: {name}: pass ({len(base_exact)} exact, "
          f"{len(base_tol)} toleranced keys)")
    return 0


def check_all(tolerance: float) -> int:
    """The registry-driven lane: every committed baseline gates the
    matching fresh smoke JSON.  A baseline with no current run is a hard
    failure — the smoke either crashed or was never registered."""
    baselines = sorted(glob.glob(os.path.join(BASELINES_DIR, "*.json")))
    if not baselines:
        print(f"check_bench: no baselines under {BASELINES_DIR}",
              file=sys.stderr)
        return 2
    worst = 0
    for baseline in baselines:
        current = os.path.join(BENCH_DIR, os.path.basename(baseline))
        worst = max(worst, check_pair(current, baseline, tolerance))
    if worst == 0:
        print(f"check_bench: all {len(baselines)} gated benchmarks pass")
    return worst


def check_identical(path_a: str, path_b: str) -> int:
    """Determinism lane: two runs of the same smoke on the same build must
    agree on every gate key bit-for-bit (tolerance keys included — under
    a virtual clock there is nothing to tolerate)."""
    for p in (path_a, path_b):
        if not os.path.exists(p):
            print(f"check_bench: {p} not found", file=sys.stderr)
            return 2
    try:
        doc_a, doc_b = load_doc(path_a), load_doc(path_b)
        exact_a, tol_a = gate_of(doc_a, path_a)
        exact_b, tol_b = gate_of(doc_b, path_b)
    except (ValueError, json.JSONDecodeError) as e:
        print(f"check_bench: {e}", file=sys.stderr)
        return 2
    failures: List[str] = []
    for section, a, b in (("exact", exact_a, exact_b),
                          ("tolerance", tol_a, tol_b)):
        for key in sorted(set(a) | set(b)):
            if key not in a or key not in b:
                failures.append(f"{section} '{key}': present in only one "
                                "run")
            elif a[key] != b[key]:
                failures.append(f"{section} '{key}': {a[key]!r} != "
                                f"{b[key]!r}")
    name = os.path.basename(path_a)
    if failures:
        failures.extend(env_note(doc_a, doc_b))
        print(f"check_bench: {name}: NOT deterministic — "
              f"{len(failures)} diff(s):")
        for line in failures:
            print(f"  [FAIL] {line}")
        return 1
    print(f"check_bench: {name}: deterministic "
          f"({len(exact_a)} exact, {len(tol_a)} toleranced keys agree)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="benchmark JSON regression gate")
    ap.add_argument("--current",
                    help="JSON written by the benchmark run under test")
    ap.add_argument("--baseline",
                    help="committed baseline JSON "
                         "(experiments/baselines/*.json)")
    ap.add_argument("--all", action="store_true",
                    help="gate every experiments/baselines/*.json against "
                         "the matching experiments/bench/*.json")
    ap.add_argument("--compare", nargs=2, metavar=("A", "B"),
                    help="determinism check: two runs of the same smoke "
                         "must agree on every gate key bit-for-bit")
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="max relative drift for tolerance keys "
                         "(default 0.2)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="copy the current JSON over the baseline "
                         "(intentional-change update flow) and exit 0")
    args = ap.parse_args(argv)

    if args.compare:
        return check_identical(*args.compare)
    if args.all:
        return check_all(args.tolerance)
    if not args.current or not args.baseline:
        ap.error("--current/--baseline required (or use --all/--compare)")

    if args.write_baseline:
        if not os.path.exists(args.current):
            print(f"check_bench: current run {args.current} not found",
                  file=sys.stderr)
            return 2
        os.makedirs(os.path.dirname(args.baseline) or ".", exist_ok=True)
        shutil.copyfile(args.current, args.baseline)
        print(f"check_bench: baseline {args.baseline} refreshed from "
              f"{args.current}")
        return 0

    if not os.path.exists(args.baseline):
        print(f"check_bench: baseline {args.baseline} not found — commit "
              "one with --write-baseline", file=sys.stderr)
        return 2
    return check_pair(args.current, args.baseline, args.tolerance)


if __name__ == "__main__":
    raise SystemExit(main())
