"""Optimizers: AdamW (small models) and Adafactor (factored second moments —
the only optimizer whose state fits for the 1T-parameter MoEs at 256 chips).

Functional API:  ``opt.init(params) -> state``;
``opt.update(grads, state, params, step) -> (updates, state)``.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class OptimizerBundle(NamedTuple):
    init: Callable
    update: Callable
    name: str


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def cosine_schedule(base_lr: float, warmup: int, total: int) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr


# ------------------------------------------------------------------- AdamW

def adamw(lr: Callable | float = 1e-3, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0) -> OptimizerBundle:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        t = jnp.asarray(step, jnp.float32) + 1
        lr_t = lr_fn(step)

        def upd(g, mu, nu, p):
            g = g.astype(jnp.float32)
            mu = b1 * mu + (1 - b1) * g
            nu = b2 * nu + (1 - b2) * jnp.square(g)
            mu_hat = mu / (1 - b1 ** t)
            nu_hat = nu / (1 - b2 ** t)
            u = mu_hat / (jnp.sqrt(nu_hat) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr_t * u).astype(p.dtype), mu, nu

        flat_g, treedef = jax.tree.flatten(grads)
        flat_mu = treedef.flatten_up_to(state["mu"])
        flat_nu = treedef.flatten_up_to(state["nu"])
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, n, p)
               for g, m, n, p in zip(flat_g, flat_mu, flat_nu, flat_p)]
        updates = treedef.unflatten([o[0] for o in out])
        state = {"mu": treedef.unflatten([o[1] for o in out]),
                 "nu": treedef.unflatten([o[2] for o in out])}
        return updates, state

    return OptimizerBundle(init, update, "adamw")


# --------------------------------------------------------------- Adafactor

def adafactor(lr: Callable | float = 1e-2, decay: float = 0.8,
              eps: float = 1e-30, clip_threshold: float = 1.0
              ) -> OptimizerBundle:
    """Factored second-moment optimizer (Shazeer & Stern, 2018).

    For a (..., R, C) tensor the second moment is stored as row/col factors —
    O(R + C) instead of O(R·C).  First moment omitted (β1 = 0), matching the
    memory-lean configuration used for trillion-parameter training.
    """
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        def factors(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree.map(factors, params,
                                  is_leaf=lambda x: hasattr(x, "ndim"))}

    def update(grads, state, params, step):
        t = jnp.asarray(step, jnp.float32) + 1
        beta = 1.0 - t ** (-decay)
        lr_t = lr_fn(step)

        def upd(g, f, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if p.ndim >= 2:
                vr = beta * f["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * f["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                    eps)[..., None]
                v = (vr[..., None] * vc[..., None, :]) / denom
                nf = {"vr": vr, "vc": vc}
            else:
                v = beta * f["v"] + (1 - beta) * g2
                nf = {"v": v}
            u = g / jnp.sqrt(v + eps)
            # update clipping (Adafactor's RMS-based trust ratio)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return (-lr_t * u).astype(p.dtype), nf

        flat_g, treedef = jax.tree.flatten(grads)
        flat_f = treedef.flatten_up_to(state["f"])
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, f, p) for g, f, p in zip(flat_g, flat_f, flat_p)]
        updates = treedef.unflatten([o[0] for o in out])
        state = {"f": treedef.unflatten([o[1] for o in out])}
        return updates, state

    return OptimizerBundle(init, update, "adafactor")
